"""Support-restricted bundle step benchmark -> BENCH_bundle.json.

    PYTHONPATH=src python benchmarks/bench_bundle.py [--smoke]

What PR 1's sparse backend did for the DIRECTION (O(P * k_max) instead
of O(s * P)), the support restriction (DESIGN.md section 11) does for
the remaining O(s) passes of a bundle step: the u/v gradient factors,
the Q-candidate Armijo grid, and the z += alpha * X_B d_B margin
maintenance. This bench measures each component separately and the
end-to-end step, over:

  * a sparsity x samples grid (sparsity in {0.9, 0.99, 0.999},
    s in {4k, 32k, 128k}), nnz_per_col = (1 - sparsity) * s — support
    scope is only timed where it is eligible (P * k_max < s; the grid
    records eligibility, which is the DESIGN.md section 11.3 contract);
  * an s-scaling arm at FIXED nnz_per_col: the s-independence
    certificate — the support-scoped line search must stay near-flat
    from s = 4k to 128k while the full-scope one grows linearly;
  * a short full-vs-support solve (objective trajectory max rel diff —
    the <= 1e-6 equivalence evidence at bench scale).

Full-scope baselines: "full_batched" is the PRE-support behavior (all
Q = 40 candidates in one (Q, s) pass — ls_chunk=40 reproduces it
exactly) and "full_chunked" the new chunked early-exit default.
Headline keys (guarded by tests/test_bundle_support.py):

    linesearch_speedup_at_0999   support vs full_batched at the largest
                                 benched s (the O(s*Q) gap grows with
                                 s; small-s cells are dispatch-bound)
    bundle_step_speedup_at_0999  whole step at s = 4096
    linesearch_support_s_growth  t(128k) / t(4k) at fixed nnz_per_col
                                 (1.0 = perfectly s-independent; the
                                 full-scope ratio is ~s ratio = 32)
    objective_traj_max_rel_diff

Writes BENCH_bundle.json at the repo root and benchmarks/results/.
Timings are of the jnp (XLA) paths — interpret-mode Pallas timings on
CPU would measure the interpreter (see benchmarks/bench_sparse.py).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import PCDNConfig, make_problem, solve
from repro.core import bundles as B
from repro.core.direction import delta_decrement, newton_direction
from repro.core.linesearch import (ArmijoParams, armijo_batched,
                                   armijo_support)
from repro.core.pcdn import make_outer_iteration, resolve_ls_scope
from repro.data import make_sparse_classification

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results")

P_BUNDLE = 64
ARMIJO = ArmijoParams()


def _timed(fn, *args, n_timed=10):
    out = fn(*args)
    jax.block_until_ready(out)
    times = []
    for _ in range(n_timed):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def _sparse_problem(s, n, nnz_per_col, seed=0):
    pcsc, y, _ = make_sparse_classification(s, n, nnz_per_col=nnz_per_col,
                                            seed=seed)
    return make_problem(pcsc, y, c=1.0)


def bench_components(prob, P=P_BUNDLE, seed=0):
    """Per-component jitted timings of ONE bundle step, both scopes."""
    design = prob.design
    n, s = prob.n_features, prob.n_samples
    rng = np.random.default_rng(seed)
    w = jnp.asarray((rng.standard_normal(n) *
                     (rng.random(n) < 0.1)).astype(np.float32))
    z = prob.margins(w)
    idx = jnp.asarray(rng.permutation(n)[:P], jnp.int32)
    loss = prob.loss

    @jax.jit
    def dir_full(w, z, idx):
        slab = design.gather_slab(idx)
        w_B, _ = B.gather_vec(w, idx)
        g, h = prob.bundle_grad_hess(z, slab, w_B)   # u/v over all s
        return newton_direction(g, h, w_B)

    @jax.jit
    def sup_build(idx):
        return design.slab_row_support(design.gather_slab(idx))

    @jax.jit
    def dir_support(w, z, idx):
        slab = design.gather_slab(idx)
        w_B, _ = B.gather_vec(w, idx)
        sup = design.slab_row_support(slab)
        z_R = jnp.take(z, sup.support, mode="fill", fill_value=0)
        y_R = jnp.take(prob.y, sup.support, mode="fill", fill_value=1)
        g, h = prob.bundle_grad_hess_support(slab, sup.pos, z_R, y_R, w_B)
        return newton_direction(g, h, w_B)

    # shared line-search inputs (one real direction)
    slab = design.gather_slab(idx)
    w_B, _ = B.gather_vec(w, idx)
    g, h = prob.bundle_grad_hess(z, slab, w_B)
    d = newton_direction(g, h, w_B)
    Delta = delta_decrement(g, h, w_B, d, ARMIJO.gamma)
    delta_z = design.slab_matvec(slab, d)
    sup = design.slab_row_support(slab)
    z_R = jnp.take(z, sup.support, mode="fill", fill_value=0)
    y_R = jnp.take(prob.y, sup.support, mode="fill", fill_value=1)
    delta_R = design.slab_matvec_support(slab, sup.pos, d)

    @jax.jit
    def ls_full_batched(z, delta_z, w_B, d, Delta):
        return armijo_batched(loss, prob.c, z, delta_z, prob.y, w_B, d,
                              Delta, ARMIJO).alpha

    @jax.jit
    def ls_support(z_R, delta_R, y_R, w_B, d, Delta):
        return armijo_support(loss, prob.c, z_R, delta_R, y_R, w_B, d,
                              Delta, ARMIJO).alpha

    @jax.jit
    def zup_full(z, idx, d, alpha):
        slab = design.gather_slab(idx)
        return z + alpha * design.slab_matvec(slab, d)

    @jax.jit
    def zup_support(z, idx, d, alpha):
        slab = design.gather_slab(idx)
        sup = design.slab_row_support(slab)
        delta_R = design.slab_matvec_support(slab, sup.pos, d)
        return design.scatter_support(z, sup.support, alpha * delta_R)

    alpha = jnp.float32(0.5)
    t_build = _timed(sup_build, idx)
    comp = {
        "direction": {"full": _timed(dir_full, w, z, idx),
                      "support": _timed(dir_support, w, z, idx)},
        "linesearch": {"full_batched": _timed(ls_full_batched, z, delta_z,
                                              w_B, d, Delta),
                       # support cost INCLUDES the support build so the
                       # speedup never hides shared work
                       "support": _timed(ls_support, z_R, delta_R, y_R,
                                         w_B, d, Delta) + t_build},
        "z_update": {"full": _timed(zup_full, z, idx, d, alpha),
                     "support": _timed(zup_support, z, idx, d, alpha)},
        "support_build": t_build,
    }
    comp["linesearch"]["speedup"] = (comp["linesearch"]["full_batched"] /
                                     comp["linesearch"]["support"])
    return comp


def bench_step(prob, P=P_BUNDLE, **cfg_kw):
    """Median seconds per bundle step of one jitted outer iteration."""
    cfg = PCDNConfig(P=P, max_outer=1, seed=1, **cfg_kw)
    n = prob.n_features
    b = -(-n // P)
    w = jnp.zeros((n,), prob.dtype)
    z = prob.margins(w)
    key = jax.random.PRNGKey(0)
    outer = make_outer_iteration(prob, cfg)
    return _timed(outer, w, z, key, n_timed=5) / b


def bench_cell(s, n, sparsity, P=P_BUNDLE, seed=0):
    nnz_per_col = max(1, int(round((1.0 - sparsity) * s)))
    prob = _sparse_problem(s, n, nnz_per_col, seed=seed)
    # time support wherever it is FEASIBLE (r_max < s) — including cells
    # where it loses, so the table shows the real crossover; the auto
    # rule's pick (margin * r_max <= s, DESIGN.md section 11.3) is
    # recorded separately.
    eligible = P * prob.design.k_max < s
    row = {
        "s": s, "n": n, "P": P, "sparsity": sparsity,
        "k_max": int(prob.design.k_max),
        "r_max": int(P * prob.design.k_max),
        "support_feasible": eligible,
        "auto_picks_support":
            resolve_ls_scope(PCDNConfig(P=P), prob) == "support",
        "bundle_step_seconds": {
            # ls_chunk=40 == the pre-support all-Q batched pass
            "full_batched": bench_step(prob, P, ls_scope="full",
                                       ls_chunk=40),
            "full_chunked": bench_step(prob, P, ls_scope="full"),
        },
    }
    if eligible:
        row["bundle_step_seconds"]["support"] = bench_step(
            prob, P, ls_scope="support")
        row["bundle_step_speedup"] = (
            row["bundle_step_seconds"]["full_batched"] /
            row["bundle_step_seconds"]["support"])
        row["components"] = bench_components(prob, P, seed=seed)
    bs = row["bundle_step_seconds"]
    sup = bs.get("support")
    sup_txt = ("%.2f ms (%.1fx)" % (sup * 1e3, row["bundle_step_speedup"])
               if sup else "ineligible (P*k_max >= s)")
    print(f"s={s} sparsity={sparsity}: full_batched "
          f"{bs['full_batched']*1e3:.2f} ms, full_chunked "
          f"{bs['full_chunked']*1e3:.2f} ms, support {sup_txt}", flush=True)
    return row


def bench_s_scaling(s_list, n, nnz_per_col, P=P_BUNDLE):
    """Fixed column degree, growing s: the s-independence certificate."""
    rows = []
    for s in s_list:
        prob = _sparse_problem(s, n, nnz_per_col, seed=3)
        comp = bench_components(prob, P, seed=3)
        rows.append({
            "s": s, "nnz_per_col": nnz_per_col,
            "linesearch_full_batched": comp["linesearch"]["full_batched"],
            "linesearch_support": comp["linesearch"]["support"],
            "bundle_step_full_batched": bench_step(prob, P,
                                                   ls_scope="full",
                                                   ls_chunk=40),
            "bundle_step_support": bench_step(prob, P, ls_scope="support"),
        })
        r = rows[-1]
        print(f"s-scaling s={s}: "
              f"ls full {r['linesearch_full_batched']*1e3:.2f} ms vs "
              f"support {r['linesearch_support']*1e3:.2f} ms; "
              f"step full {r['bundle_step_full_batched']*1e3:.2f} ms vs "
              f"support {r['bundle_step_support']*1e3:.2f} ms", flush=True)
    return rows


def bench_trajectory(s, n, sparsity, P=P_BUNDLE, max_outer=8):
    nnz_per_col = max(1, int(round((1.0 - sparsity) * s)))
    prob = _sparse_problem(s, n, nnz_per_col, seed=5)
    rf = solve(prob, PCDNConfig(P=P, max_outer=max_outer, seed=2,
                                ls_scope="full"))
    rs = solve(prob, PCDNConfig(P=P, max_outer=max_outer, seed=2,
                                ls_scope="support"))
    k = min(len(rf.history.objective), len(rs.history.objective))
    rel = float(np.max(
        np.abs(rf.history.objective[:k] - rs.history.objective[:k]) /
        np.abs(rf.history.objective[:k])))
    print(f"trajectory full vs support max rel diff: {rel:.2e}", flush=True)
    return rel


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes (CI); headline keys still written")
    args = ap.parse_args(argv)

    if args.smoke:
        n, s_grid, s_scale, nnz_fix = 512, [1024, 4096], [1024, 4096], 8
        headline_s = 4096
    else:
        n, s_scale, nnz_fix = 4096, [4096, 32768, 131072], 32
        s_grid = [4096, 32768, 131072]
        headline_s = 4096

    grid = [bench_cell(s, n, sp)
            for sp in (0.9, 0.99, 0.999) for s in s_grid]
    scaling = bench_s_scaling(s_scale, n, nnz_fix)
    traj_rel = bench_trajectory(headline_s, n, 0.999)

    head = next(r for r in grid
                if r["sparsity"] == 0.999 and r["s"] == headline_s)
    # the line-search headline is the LARGEST benched s at 0.999: the
    # O(P*k_max*Q) vs O(s*Q) gap grows with s by construction, and the
    # sub-ms small-s cells are dispatch-noise-bound (their per-cell
    # figures stay in the grid)
    big = max((r for r in grid if r["sparsity"] == 0.999
               and "components" in r), key=lambda r: r["s"])
    sc0, sc1 = scaling[0], scaling[-1]
    payload = {
        "smoke": bool(args.smoke),
        "backend": jax.default_backend(),
        "P": P_BUNDLE,
        "grid": grid,
        "s_scaling_fixed_nnz": scaling,
        "linesearch_speedup_at_0999":
            big["components"]["linesearch"]["speedup"],
        "linesearch_speedup_s": big["s"],
        "bundle_step_speedup_at_0999": head["bundle_step_speedup"],
        "linesearch_support_s_growth":
            sc1["linesearch_support"] / sc0["linesearch_support"],
        "linesearch_full_s_growth":
            sc1["linesearch_full_batched"] / sc0["linesearch_full_batched"],
        "s_growth_factor": sc1["s"] / sc0["s"],
        "objective_traj_max_rel_diff": traj_rel,
    }
    ls_x = payload["linesearch_speedup_at_0999"]
    step_x = payload["bundle_step_speedup_at_0999"]
    print(f"headline: ls speedup {ls_x:.1f}x (s={big['s']}), step speedup "
          f"{step_x:.1f}x at sparsity 0.999 s={headline_s}; support ls grows "
          f"{payload['linesearch_support_s_growth']:.2f}x over a "
          f"{payload['s_growth_factor']:.0f}x s range (full: "
          f"{payload['linesearch_full_s_growth']:.1f}x)", flush=True)

    os.makedirs(RESULTS_DIR, exist_ok=True)
    for path in (os.path.join(REPO_ROOT, "BENCH_bundle.json"),
                 os.path.join(RESULTS_DIR, "BENCH_bundle.json")):
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=1, default=float)
    print("wrote BENCH_bundle.json")
    return payload


if __name__ == "__main__":
    main()
