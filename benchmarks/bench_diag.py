"""Diagnostics overhead + certified-P agreement -> BENCH_diag.json
(DESIGN.md section 15).

    PYTHONPATH=src python benchmarks/bench_diag.py [--smoke]

Three arms:

  * attribution — a fixed-iteration PCDN solve (tol_kkt=0 pins both
    arms to identical solver work) timed with diagnostics fully off vs
    the full `--diag-out` harvest (record_kkt_vec + record_aux), with
    INTERLEAVED repeats (A B A B ...) so machine-load drift hits both
    arms. Headline `attribution.overhead_pct` is the acceptance
    number: the per-feature harvest must cost <= 5% of solve wall time.

  * safep — the power-iteration spectral-radius estimate of the
    normalized Gram vs `numpy.linalg.eigvalsh` of the densified matrix,
    on dense AND padded-CSC designs. Headline `safep.agreement` is the
    acceptance bool (every rel-err <= 1e-4); the ESO ω bound is
    cross-checked against a direct per-row count.

  * report — wall time to build the health-report payload and render
    the markdown from the enabled arm's real SolveHistory (no gate,
    recorded so regressions are visible in the trajectory).

Smoke mode writes only to benchmarks/results/ (CI); the full run also
writes the repo-root BENCH_diag.json that the acceptance criterion and
`benchmarks/sentinel.py` read.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import jax

from repro.core import PCDNConfig, make_problem, solve
from repro.data.synthetic import make_classification
from repro.diag import report as diag_report
from repro.diag import safep

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results")


def _time_pair(fn_a, fn_b, repeats: int = 5):
    """Best-of-N for two arms with INTERLEAVED repeats (A B A B ...), so
    slow machine-load drift hits both arms equally. Both arms are warmed
    before any timing (compile excluded)."""
    fn_a()
    fn_b()
    best_a = best_b = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn_a()
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a, best_b


def bench_attribution(s, n, P, iters, repeats, seed=0):
    """Off-vs-on wall time for the full --diag-out harvest on identical
    solver work."""
    X, y, _ = make_classification(s, n, sparsity=0.5, seed=seed)
    prob = make_problem(X, y, c=2.0)
    cfg_off = PCDNConfig(P=P, max_outer=iters, tol_kkt=0.0, seed=seed)
    cfg_on = dataclasses.replace(cfg_off, record_kkt_vec=True,
                                 record_aux=True)

    t_off, t_on = _time_pair(lambda: solve(prob, cfg_off),
                             lambda: solve(prob, cfg_on), repeats)
    res_off = solve(prob, cfg_off)
    res_on = solve(prob, cfg_on)

    assert res_on.history.kkt_vec is not None, \
        "enabled arm must thread the per-feature violation series"
    assert res_off.history.kkt_vec is None, \
        "disabled arm must not carry the attribution series"
    # byte-identical solver work: the extra outputs ride along, they do
    # not perturb the iterates
    drift = float(np.max(np.abs(
        np.asarray(res_on.w, np.float64) - np.asarray(res_off.w,
                                                      np.float64))))
    # attribution correctness on the benchmark shape: final recorded
    # vector == direct dense recomputation at the final iterate
    import jax.numpy as jnp
    w = jnp.asarray(res_on.w)
    g = prob.full_grad(prob.design.matvec(w), w)
    direct = np.asarray(prob.kkt_violation_from_grad(w, g), np.float64)
    attr_err = float(np.max(np.abs(
        res_on.history.kkt_vec[-1].astype(np.float64) - direct)))

    overhead = (t_on - t_off) / t_off * 100.0
    row = {
        "s": s, "n": n, "P": P, "iters": iters,
        "disabled_s": t_off, "enabled_s": t_on,
        "overhead_pct": overhead,
        "w_max_abs_drift": drift,
        "kkt_vec_shape": list(res_on.history.kkt_vec.shape),
        "attr_max_abs_err": attr_err,
    }
    print(f"[attribution] {iters} iters (s={s}, n={n}, P={P}): off "
          f"{t_off * 1e3:.1f}ms, on {t_on * 1e3:.1f}ms -> "
          f"{overhead:+.2f}% overhead, drift {drift:.1e}, "
          f"attr err {attr_err:.1e}", flush=True)
    return row, res_on


def _direct_rho(Xd: np.ndarray) -> float:
    norms = np.linalg.norm(Xd, axis=0)
    norms[norms == 0] = 1.0
    Xn = Xd / norms
    return float(np.linalg.eigvalsh(Xn.T @ Xn).max())


def bench_safep(shapes, seed=0):
    """Power iteration vs eigvalsh on dense + padded-CSC designs."""
    from repro.core import PaddedCSCDesign

    rows = []
    for i, (s, n, sparsity) in enumerate(shapes):
        X, y, _ = make_classification(s, n, sparsity=sparsity,
                                      seed=seed + i)
        for layout in ("dense", "padded_csc"):
            prob = make_problem(X, y, c=1.0, layout=layout)
            t0 = time.perf_counter()
            # high-sparsity Grams have a tight eigengap; give the power
            # method room to actually converge before judging agreement
            cert = safep.certify(prob.design, seed=seed, n_iter=3000)
            dt = time.perf_counter() - t0
            Xd = np.asarray(X, np.float64) if layout == "dense" else \
                np.asarray(prob.design.to_dense(), np.float64)
            rho_direct = _direct_rho(Xd)
            rel = abs(cert["rho_normalized"] - rho_direct) \
                / max(rho_direct, 1e-12)
            omega_direct = int(np.max(np.sum(Xd != 0, axis=1))) \
                if Xd.size else 0
            rows.append({
                "s": s, "n": n, "sparsity": sparsity, "layout": layout,
                "rho_power": cert["rho_normalized"],
                "rho_direct": rho_direct, "rel_err": rel,
                "power_iters": cert["power_iters"],
                "power_converged": cert["power_converged"],
                "omega": cert["omega"], "omega_direct": omega_direct,
                "omega_match": cert["omega"] == omega_direct,
                "P_spectral": cert["P_spectral"],
                "P_eso": cert["P_eso"], "P_cert": cert["P_cert"],
                "seconds": dt,
            })
            print(f"[safep] s={s} n={n} sp={sparsity} {layout}: rho "
                  f"{cert['rho_normalized']:.6f} vs {rho_direct:.6f} "
                  f"(rel {rel:.2e}), omega {cert['omega']} "
                  f"(direct {omega_direct}), P_cert {cert['P_cert']} "
                  f"in {dt * 1e3:.0f}ms", flush=True)
    max_rel = max(r["rel_err"] for r in rows)
    agreement = max_rel <= 1e-4 and all(r["omega_match"] for r in rows)
    return {"problems": rows, "max_rel_err": max_rel,
            "agreement": agreement}


def bench_report(res, prob_meta, repeats=5):
    """Payload build + markdown render time from a real SolveHistory."""
    hist = {k: np.asarray(v).tolist()
            for k, v in res.history._asdict().items() if v is not None}
    report = {"provenance": prob_meta, "loss": "logistic",
              "n_features": prob_meta["n"],
              "objective": float(res.objective),
              "converged": bool(res.converged),
              "nnz": int(np.sum(np.asarray(res.w) != 0)),
              "seconds": 0.0, "history": hist}

    def render():
        payload = diag_report.build_payload(report=report, tol_kkt=1e-3)
        return diag_report.render_markdown(payload)

    md = render()
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        render()
        best = min(best, time.perf_counter() - t0)
    print(f"[report] {len(md)} chars rendered in {best * 1e3:.1f}ms",
          flush=True)
    return {"render_s": best, "markdown_chars": len(md)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes (CI)")
    args = ap.parse_args(argv)

    if args.smoke:
        s, n, P, iters, repeats = 400, 300, 64, 10, 3
        shapes = [(120, 80, 0.0), (150, 100, 0.9)]
    else:
        s, n, P, iters, repeats = 2000, 2000, 256, 40, 5
        shapes = [(300, 200, 0.0), (400, 300, 0.9), (500, 400, 0.99)]

    attr_row, res_on = bench_attribution(s, n, P, iters, repeats)
    safep_block = bench_safep(shapes)
    report_row = bench_report(res_on, {"solver": "pcdn", "P": P, "s": s,
                                       "n": n, "tol_kkt": 0.0})

    payload = {
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "attribution": attr_row,
        "safep": safep_block,
        "report": report_row,
    }
    print(f"[diag] HEADLINE attribution overhead: "
          f"{attr_row['overhead_pct']:+.2f}% (acceptance: <= 5%); "
          f"safep agreement: {safep_block['agreement']} "
          f"(max rel err {safep_block['max_rel_err']:.2e})", flush=True)

    os.makedirs(RESULTS_DIR, exist_ok=True)
    targets = [os.path.join(RESULTS_DIR, "BENCH_diag.json")]
    if not args.smoke:
        targets.append(os.path.join(REPO_ROOT, "BENCH_diag.json"))
    for path in targets:
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=1, default=float)
    print("wrote BENCH_diag.json")
    return payload


if __name__ == "__main__":
    main()
