"""Unified-engine benchmark -> BENCH_engine.json.

    PYTHONPATH=src python benchmarks/bench_engine.py [--smoke]

The headline capability the engine refactor unlocks (DESIGN.md section
9): a WARM-STARTED regularization-path sweep running on the SHARDED
backend — one mesh placement, one compiled dynamic-c shard_map program,
(w, z, active) chained across the c-grid — versus the pre-engine
deployment of one cold sharded solve per grid point (fresh placement +
compile + zero-start every time, which is what `solve_sharded` alone
could do). Three traversals of the SAME grid, every point stopping at
the same full-set KKT tolerance:

    cold_solves   one `solve_sharded` per point (per-point placement +
                  compile; the seed deployment baseline)
    cold_shared   state reset per point, but ONE placed backend and ONE
                  compiled program — isolates warm-start value from
                  compile/placement amortization
    warm_shrink   the engine sweep: warm starts + active-set shrinking
                  on the mesh (the flagship config)

Runs on 8 forced host devices (mesh (2, 4) data x model) so it exercises
the real collective schedule; set XLA_FLAGS yourself to override.

Writes BENCH_engine.json at the repo root and a copy under
benchmarks/results/.
"""
import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import time         # noqa: E402

import numpy as np  # noqa: E402
import jax          # noqa: E402

from repro.core import PCDNConfig                       # noqa: E402
from repro.core.sharded import solve_sharded            # noqa: E402
from repro.data import make_classification              # noqa: E402
from repro.engine import (ShardedBackend,               # noqa: E402
                          ShardedPCDNConfig)
from repro.path import PathConfig, run_path             # noqa: E402

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + short grid (CI)")
    args = ap.parse_args(argv)

    if args.smoke:
        s, n, P_local, n_points, span, max_outer = 600, 1024, 16, 5, 30.0, 300
    else:
        s, n, P_local, n_points, span, max_outer = 2000, 4096, 32, 12, 100.0, 600
    tol = 1e-3
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    X, y, _ = make_classification(s, n, sparsity=0.99, corr=0.2, seed=1)

    scfg = ShardedPCDNConfig(P_local=P_local, c=1.0, tol_kkt=tol,
                             shrink=True)
    # stop parameters for the sweep (P is informational here — execution
    # comes from scfg; see PathConfig docstring)
    solver = PCDNConfig(P=P_local * mesh.shape["model"],
                        max_outer=max_outer, tol_kkt=tol)
    pcfg = PathConfig(solver=solver, n_points=n_points, span=span)

    # --- engine: place + compile once, then the warm shrinking sweep ----
    t0 = time.perf_counter()
    backend = ShardedBackend(X, y, mesh, scfg)
    st = backend.init_state()   # trigger placement
    _ = jax.block_until_ready(backend.outer(
        *st, np.asarray(True), np.asarray(1.0, np.float32)))  # compile
    setup_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    warm = run_path(None, pcfg, backend=backend)
    warm_s = time.perf_counter() - t0
    cs = warm.cs

    # --- ablation: same placed backend + program, state reset per point
    t0 = time.perf_counter()
    cold_shared = run_path(None, dataclasses.replace(pcfg,
                                                     warm_start=False),
                           backend=backend)
    cold_shared_s = time.perf_counter() - t0

    # --- baseline: one cold solve_sharded per point (fresh placement +
    # compile each — the pre-engine per-process deployment)
    t0 = time.perf_counter()
    cold_iters, cold_conv, cold_objs, cold_kkts = 0, True, [], []
    for c in cs:
        w, f, conv, k, hist = solve_sharded(
            X, y, mesh, dataclasses.replace(scfg, c=float(c), shrink=False),
            max_outer=max_outer, tol_kkt=tol)
        cold_iters += k
        cold_conv &= conv
        cold_objs.append(f)
        cold_kkts.append(hist["kkt"][-1])
    cold_solves_s = time.perf_counter() - t0

    warm_objs = np.array([p.objective for p in warm.points])
    cold_objs = np.array(cold_objs)
    engine_s = warm_s + setup_s
    payload = {
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "mesh": {"data": 2, "model": 4},
        "problem": {"s": s, "n": n, "sparsity": 0.99,
                    "P_local": P_local},
        "grid": {"n_points": n_points, "span": span,
                 "c_max": float(warm.c_max), "tol_kkt": tol},
        "warm_shrink_seconds_incl_setup": engine_s,
        "warm_shrink_sweep_seconds": warm_s,
        "setup_seconds": setup_s,
        "warm_iters": int(sum(p.n_outer for p in warm.points)),
        "warm_all_converged": all(p.converged for p in warm.points),
        "warm_max_point_kkt": float(max(p.kkt for p in warm.points)),
        "cold_shared_program_seconds": cold_shared_s,
        "cold_shared_iters": int(sum(p.n_outer
                                     for p in cold_shared.points)),
        "cold_solves_seconds": cold_solves_s,
        "cold_solves_iters": int(cold_iters),
        "cold_solves_all_converged": bool(cold_conv),
        "cold_solves_max_point_kkt": float(np.max(cold_kkts)),
        "speedup_engine_vs_cold_solves": cold_solves_s / engine_s,
        "speedup_warm_vs_cold_shared": cold_shared_s / warm_s,
        "objective_max_rel_diff_vs_cold": float(np.max(
            np.abs(warm_objs - cold_objs) / np.abs(cold_objs))),
    }
    print(f"sharded warm+shrink sweep {engine_s:.1f}s (setup {setup_s:.1f}s)"
          f" vs {n_points} cold sharded solves {cold_solves_s:.1f}s -> "
          f"{payload['speedup_engine_vs_cold_solves']:.1f}x "
          f"(shared-program cold {cold_shared_s:.1f}s; warm iters "
          f"{payload['warm_iters']} vs cold {payload['cold_solves_iters']})",
          flush=True)
    print(f"objective max rel diff vs cold "
          f"{payload['objective_max_rel_diff_vs_cold']:.1e}", flush=True)

    os.makedirs(RESULTS_DIR, exist_ok=True)
    for path in (os.path.join(REPO_ROOT, "BENCH_engine.json"),
                 os.path.join(RESULTS_DIR, "BENCH_engine.json")):
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=1, default=float)
    print("wrote BENCH_engine.json")
    return payload


if __name__ == "__main__":
    main()
