"""Fault-tolerance cost benchmark -> BENCH_fault.json (DESIGN.md §16).

    PYTHONPATH=src python benchmarks/bench_fault.py [--smoke]

Two questions, two arms:

  * checkpoint — what does crash-safety COST when nothing crashes? A
    fixed-iteration PCDN solve (tol_kkt=0 so both arms do identical
    solver work) timed bare vs with a `SolveCheckpointer` snapshotting
    every 10th iteration (the `--ckpt-every` default). The headline
    `checkpoint.overhead_pct` is the acceptance number: crash-safety
    must cost <= 5% of solve wall time at the default cadence.

  * recovery — does recovery actually RECOVER? The real `launch.path`
    CLI is SIGKILL'd mid-sweep via the `REPRO_FAULT_PLAN` env channel
    (no test-only flags), resumed with `--resume`, and the resumed
    report is compared point-by-point against an uninterrupted run.
    `recovery.objective_rel_diff` is the acceptance number (<= 1e-6:
    the sweep checkpoints full solver state at point granularity, so
    resume is exact, not approximate), `recovery.resume_seconds` the
    headline cost of picking the sweep back up.

Smoke mode writes only to benchmarks/results/ (CI); the full run also
writes the repo-root BENCH_fault.json that `sentinel.py` gates.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import subprocess
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import jax

from repro.core import PCDNConfig, make_problem
from repro.data.synthetic import make_classification
from repro.engine import LocalBackend
from repro.engine import loop as engine_loop
from repro.fault import SolveCheckpointer

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results")


def _time_pair(fn_a, fn_b, repeats: int = 5):
    """Best-of-N for two arms with INTERLEAVED repeats (A B A B ...), so
    machine-load drift hits both arms equally. Warmed before timing."""
    fn_a()
    fn_b()
    best_a = best_b = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn_a()
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a, best_b


def bench_checkpoint(s, n, P, iters, every, repeats, seed=0):
    """Bare vs checkpointing wall time on identical solver work."""
    X, y, _ = make_classification(s, n, sparsity=0.5, seed=seed)
    prob = make_problem(X, y, c=2.0)
    backend = LocalBackend(prob, PCDNConfig(P=P, max_outer=iters,
                                            tol_kkt=0.0, seed=seed))
    ckdir = tempfile.mkdtemp(prefix="bench_fault_ck_")

    def run(state_cb):
        _, res = engine_loop.run_outer_loop(
            backend.outer, backend.init_state(), prob.c,
            max_outer=iters, tol_kkt=0.0, state_callback=state_cb)
        return res

    def run_bare():
        return run(None)

    def run_ckpt():
        ck = SolveCheckpointer(ckdir, every=every)
        return run(ck.solve_callback(backend))

    try:
        t_bare, t_ckpt = _time_pair(run_bare, run_ckpt, repeats)
        res_bare = run_bare()
        res_ckpt = run_ckpt()
        n_steps = len(SolveCheckpointer(ckdir, every=every).manager.steps())
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)
    # identical solver work: snapshots observe the carry, never touch it
    drift = abs(res_ckpt.objective - res_bare.objective) \
        / max(1.0, abs(res_bare.objective))
    overhead = (t_ckpt - t_bare) / t_bare * 100.0
    row = {
        "s": s, "n": n, "P": P, "iters": iters, "every": every,
        "bare_s": t_bare, "ckpt_s": t_ckpt,
        "overhead_pct": overhead,
        "objective_rel_drift": drift,
        "committed_steps": n_steps,
    }
    print(f"[checkpoint] {iters} iters (s={s}, n={n}, P={P}, "
          f"every={every}): bare {t_bare * 1e3:.1f}ms, ckpt "
          f"{t_ckpt * 1e3:.1f}ms -> {overhead:+.2f}% overhead, "
          f"{n_steps} committed steps, drift {drift:.1e}", flush=True)
    return row


def _run_cli(args, extra_env=None):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO_ROOT, "src")
    env["REPRO_AUTOTUNE"] = "off"
    env.pop("REPRO_FAULT_PLAN", None)
    if extra_env:
        env.update(extra_env)
    return subprocess.run([sys.executable, "-m"] + args,
                          capture_output=True, text=True, env=env,
                          timeout=900)


def bench_recovery(dataset, points, P, max_outer, tol):
    """SIGKILL the path CLI mid-sweep, resume, compare to uninterrupted."""
    work = tempfile.mkdtemp(prefix="bench_fault_rec_")
    base = ["repro.launch.path", "--dataset", dataset,
            "--points", str(points), "--P", str(P),
            "--max-outer", str(max_outer), "--tol", str(tol)]
    try:
        ref_path = os.path.join(work, "ref.json")
        out = _run_cli(base + ["--out", ref_path])
        if out.returncode != 0:
            raise RuntimeError(f"reference sweep failed:\n{out.stderr}")
        ckdir = os.path.join(work, "ck")
        kill_at = points // 2
        killed = _run_cli(
            base + ["--ckpt-dir", ckdir],
            extra_env={"REPRO_FAULT_PLAN": json.dumps(
                {"crash_at_point": kill_at, "crash_kind": "sigkill"})})
        if killed.returncode != -9:
            raise RuntimeError(f"expected SIGKILL exit, got "
                               f"{killed.returncode}:\n{killed.stderr}")
        res_path = os.path.join(work, "res.json")
        t0 = time.perf_counter()
        resumed = _run_cli(base + ["--ckpt-dir", ckdir, "--resume",
                                   "--out", res_path])
        resume_s = time.perf_counter() - t0
        if resumed.returncode != 0:
            raise RuntimeError(f"resume failed:\n{resumed.stderr}")
        with open(ref_path) as fh:
            ref = json.load(fh)
        with open(res_path) as fh:
            res = json.load(fh)
        rel = max(
            abs(a["objective"] - b["objective"]) / abs(a["objective"])
            for a, b in zip(ref["points"], res["points"]))
        row = {
            "dataset": dataset, "points": points, "P": P,
            "max_outer": max_outer, "tol": tol,
            "killed_at_point": kill_at,
            "sigkill_exit": killed.returncode,
            "resume_seconds": resume_s,
            "best_index_matches": ref["best_index"] == res["best_index"],
            "objective_rel_diff": rel,
        }
    finally:
        shutil.rmtree(work, ignore_errors=True)
    print(f"[recovery] {dataset} {points}-point sweep SIGKILL'd at point "
          f"{kill_at}: resumed in {resume_s:.2f}s, max objective rel "
          f"diff {rel:.2e}, best_index match="
          f"{row['best_index_matches']}", flush=True)
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes (CI)")
    args = ap.parse_args(argv)

    if args.smoke:
        s, n, P, iters, repeats = 400, 300, 64, 20, 3
        points, sweep_P, sweep_outer = 3, 64, 10
    else:
        s, n, P, iters, repeats = 2000, 2000, 256, 40, 5
        points, sweep_P, sweep_outer = 6, 64, 25

    ckpt_row = bench_checkpoint(s, n, P, iters, every=10, repeats=repeats)
    recovery_row = bench_recovery("a9a", points, sweep_P, sweep_outer,
                                  tol=1e-3)

    payload = {
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "checkpoint": ckpt_row,
        "recovery": recovery_row,
    }
    print(f"[fault] HEADLINE checkpoint overhead at every=10: "
          f"{ckpt_row['overhead_pct']:+.2f}% (acceptance: <= 5%); "
          f"resumed-sweep objective rel diff "
          f"{recovery_row['objective_rel_diff']:.2e} "
          f"(acceptance: <= 1e-6)", flush=True)

    os.makedirs(RESULTS_DIR, exist_ok=True)
    targets = [os.path.join(RESULTS_DIR, "BENCH_fault.json")]
    if not args.smoke:
        targets.append(os.path.join(REPO_ROOT, "BENCH_fault.json"))
    for path in targets:
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=1, default=float)
    print("wrote BENCH_fault.json")
    return payload


if __name__ == "__main__":
    main()
