"""Kernel autotuning benchmark: measured roofline placement + the bf16
equivalence study (DESIGN.md section 12).

For every hot kernel x shape cell this driver

  1. times the DEFAULT launch config (the historical hard-coded launch),
  2. runs `kernels.autotune.tune` over the declared search space
     (block sizes along each tileable axis plus the impl axis:
     Pallas kernel vs the jitted jnp oracle) and persists the winner
     into the autotune cache so later solves/serves pick it up,
  3. places the cell on a MEASURED roofline: peak FLOP/s calibrated
     with a large f32 matmul, peak bytes/s with a streaming triad,
     analytic per-kernel flop/byte counts -> compute/memory terms,
     bound classification and attained fraction of the roofline bound,

then runs the bf16-vs-fp32 trajectory study — same problem, same
config, tol_kkt=0 and a fixed outer budget so iteration counts match by
construction — and reports the max objective rel-diff, the number the
CLI's `--dtype bf16` envelope gate (launch/common.py) is calibrated
against.

Output: BENCH_kernels.json at the repo root (the committed headline
artifact tests/test_autotune.py guards) + benchmarks/results/. `--smoke`
runs one tiny cell per kernel with few repeats and writes ONLY
benchmarks/results/BENCH_kernels_smoke.json, so CI smoke never clobbers
the committed headline numbers.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_kernels [--smoke]
        [--strategy exhaustive|hillclimb] [--repeats N] [--no-study]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import RESULTS_DIR, emit

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
HEADLINE = os.path.join(REPO_ROOT, "BENCH_kernels.json")


# ---------------------------------------------------------------------------
# peak calibration (roofline.calibrate_peaks wraps these for reuse)


def calibrate_peak_flops(n: int = 1024, repeats: int = 5) -> float:
    """Measured f32 matmul peak, FLOP/s. The (n, n) x (n, n) product is
    2n^3 flops and the best-case compute ceiling XLA reaches here."""
    import jax
    import jax.numpy as jnp
    a = jnp.asarray(np.random.default_rng(0).standard_normal((n, n)),
                    jnp.float32)
    f = jax.jit(lambda x: x @ x)
    jax.block_until_ready(f(a))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(f(a))
        best = min(best, time.perf_counter() - t0)
    return 2.0 * n ** 3 / best


def calibrate_peak_bandwidth(mb: int = 64, repeats: int = 5) -> float:
    """Measured streaming bandwidth, bytes/s (read + write of an f32
    buffer: y = x * 2 + 1 moves 8 bytes per element)."""
    import jax
    import jax.numpy as jnp
    n = mb * (1 << 20) // 4
    x = jnp.zeros((n,), jnp.float32)
    f = jax.jit(lambda v: v * 2.0 + 1.0)
    jax.block_until_ready(f(x))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(f(x))
        best = min(best, time.perf_counter() - t0)
    return 8.0 * n / best


# ---------------------------------------------------------------------------
# kernel cells: operand builders + analytic flop/byte counts
#
# Flop counts are the useful math of the kernel's contract (what the XLA
# oracle also has to do), byte counts the once-through traffic of its
# operands/outputs at their STORAGE dtype — the terms a perfectly fused
# implementation cannot avoid, i.e. the roofline bound for the cell.


def _rng(seed=0):
    return np.random.default_rng(seed)


def _cell_bundle(p, k, r, q, dtype):
    import jax.numpy as jnp
    from repro.kernels import ops
    g = _rng(1)
    isz = jnp.dtype(dtype).itemsize
    vals = jnp.asarray(g.standard_normal((p, k)), dtype)
    pos = jnp.asarray(g.integers(0, r, (p, k)), jnp.int32)
    z = jnp.asarray(g.standard_normal((r,)), jnp.float32)
    y = jnp.asarray(g.choice([-1.0, 1.0], (r,)), jnp.float32)
    w = jnp.asarray(0.1 * g.standard_normal((p,)), jnp.float32)
    alphas = jnp.asarray(0.5 ** np.arange(q), jnp.float32)

    def runner(cfg):
        return lambda: ops.pcdn_bundle(vals, pos, z, y, w, alphas, 1.0,
                                       impl=cfg["impl"],
                                       block_q=cfg["block_q"])

    flops = 5 * p * k + 8 * q * r + 3 * q * p   # direction + Armijo grid
    bytes_ = p * k * (isz + 4) + q * (r + p) * 4 + 2 * r * 4
    return runner, flops, bytes_


def _cell_direction(s, p, dtype):
    import jax.numpy as jnp
    from repro.kernels import ops
    g = _rng(2)
    isz = jnp.dtype(dtype).itemsize
    XB = jnp.asarray(g.standard_normal((s, p)), dtype)
    u = jnp.asarray(g.standard_normal((s,)), jnp.float32)
    v = jnp.asarray(np.abs(g.standard_normal((s,))), jnp.float32)
    w = jnp.asarray(0.1 * g.standard_normal((p,)), jnp.float32)

    def runner(cfg):
        return lambda: ops.pcdn_direction(XB, u, v, w, impl=cfg["impl"],
                                          block_s=cfg["block_s"],
                                          block_p=cfg["block_p"])

    return runner, 5 * s * p, s * p * isz + 2 * s * 4 + 4 * p * 4


def _cell_sparse_direction(p, k, s, dtype):
    import jax.numpy as jnp
    from repro.kernels import ops
    g = _rng(3)
    isz = jnp.dtype(dtype).itemsize
    rows = jnp.asarray(g.integers(0, s, (p, k)), jnp.int32)
    vals = jnp.asarray(g.standard_normal((p, k)), dtype)
    u = jnp.asarray(g.standard_normal((s,)), jnp.float32)
    v = jnp.asarray(np.abs(g.standard_normal((s,))), jnp.float32)
    w = jnp.asarray(0.1 * g.standard_normal((p,)), jnp.float32)

    def runner(cfg):
        return lambda: ops.pcdn_sparse_direction(
            rows, vals, u, v, w, impl=cfg["impl"],
            block_p=cfg["block_p"], block_k=cfg["block_k"])

    return runner, 5 * p * k, p * k * (isz + 4) + 2 * s * 4 + 4 * p * 4


def _cell_linesearch(s, q, dtype):
    import jax.numpy as jnp
    from repro.kernels import ops
    g = _rng(4)
    z = jnp.asarray(g.standard_normal((s,)), jnp.float32)
    d = jnp.asarray(0.1 * g.standard_normal((s,)), jnp.float32)
    y = jnp.asarray(g.choice([-1.0, 1.0], (s,)), jnp.float32)
    alphas = jnp.asarray(0.5 ** np.arange(q), jnp.float32)

    def runner(cfg):
        return lambda: ops.pcdn_linesearch(z, d, y, alphas,
                                           impl=cfg["impl"],
                                           block_s=cfg["block_s"])

    return runner, 8 * q * s, 3 * s * 4 + 2 * q * 4


def _cell_margins_dense(b, n, k, a, dtype):
    import jax.numpy as jnp
    from repro.kernels import ops
    g = _rng(5)
    isz = jnp.dtype(dtype).itemsize
    X = jnp.asarray(g.standard_normal((b, n)), dtype)
    idx = jnp.asarray(np.sort(g.permutation(n)[:a])[None, :].repeat(k, 0),
                      jnp.int32)
    val = jnp.asarray(g.standard_normal((k, a)), dtype)

    def runner(cfg):
        return lambda: ops.serve_margins_dense(
            X, idx, val, impl=cfg["impl"],
            block_b=cfg["block_b"], block_a=cfg["block_a"])

    # the gather touches (b, a) of X per model; idx/val stream once
    return (runner, 2 * b * k * a,
            b * a * isz * k + k * a * (4 + isz) + b * k * 4)


def _cell_margins_csc(n, kmax, k, a, b, dtype):
    import jax.numpy as jnp
    from repro.kernels import ops
    g = _rng(6)
    isz = jnp.dtype(dtype).itemsize
    col_rows = jnp.asarray(g.integers(0, b, (n, kmax)), jnp.int32)
    col_vals = jnp.asarray(g.standard_normal((n, kmax)), dtype)
    idx = jnp.asarray(np.sort(g.permutation(n)[:a])[None, :].repeat(k, 0),
                      jnp.int32)
    val = jnp.asarray(g.standard_normal((k, a)), dtype)

    def runner(cfg):
        return lambda: ops.serve_margins_csc(col_rows, col_vals, idx, val,
                                             n_requests=b,
                                             impl=cfg["impl"])

    return (runner, 2 * k * a * kmax,
            a * kmax * (4 + isz) * k + k * a * (4 + isz) + b * k * 4)


# (kernel, shape dict, builder) — full mode runs every row, --smoke the
# first row per kernel with tiny shapes.
CELLS = [
    ("pcdn_bundle", dict(p=128, k=32, r=1024, q=20),
     lambda d: _cell_bundle(128, 32, 1024, 20, d)),
    ("pcdn_bundle", dict(p=256, k=64, r=4096, q=20),
     lambda d: _cell_bundle(256, 64, 4096, 20, d)),
    ("pcdn_direction", dict(s=2048, p=128),
     lambda d: _cell_direction(2048, 128, d)),
    ("pcdn_direction", dict(s=8192, p=256),
     lambda d: _cell_direction(8192, 256, d)),
    ("pcdn_sparse_direction", dict(p=128, k=64, s=4096),
     lambda d: _cell_sparse_direction(128, 64, 4096, d)),
    ("pcdn_linesearch", dict(s=8192, q=20),
     lambda d: _cell_linesearch(8192, 20, d)),
    ("serve_margins_dense", dict(b=128, n=2048, k=8, a=256),
     lambda d: _cell_margins_dense(128, 2048, 8, 256, d)),
    ("serve_margins_csc", dict(n=2048, kmax=16, k=8, a=256, b=128),
     lambda d: _cell_margins_csc(2048, 16, 8, 256, 128, d)),
]

SMOKE_CELLS = [
    ("pcdn_bundle", dict(p=32, k=8, r=128, q=8),
     lambda d: _cell_bundle(32, 8, 128, 8, d)),
    ("pcdn_direction", dict(s=256, p=32),
     lambda d: _cell_direction(256, 32, d)),
    ("pcdn_sparse_direction", dict(p=32, k=8, s=256),
     lambda d: _cell_sparse_direction(32, 8, 256, d)),
    ("pcdn_linesearch", dict(s=512, q=8),
     lambda d: _cell_linesearch(512, 8, d)),
    ("serve_margins_dense", dict(b=16, n=128, k=4, a=32),
     lambda d: _cell_margins_dense(16, 128, 4, 32, d)),
    ("serve_margins_csc", dict(n=128, kmax=8, k=4, a=32, b=16),
     lambda d: _cell_margins_csc(128, 8, 4, 32, 16, d)),
]


def roofline_terms(flops, bytes_, us, peaks):
    """Place one measured cell against the calibrated peaks."""
    t_compute_us = flops / peaks["flops_per_s"] * 1e6
    t_memory_us = bytes_ / peaks["bytes_per_s"] * 1e6
    bound_us = max(t_compute_us, t_memory_us)
    return {
        "flops": int(flops), "bytes": int(bytes_),
        "intensity_flops_per_byte": flops / max(bytes_, 1),
        "t_compute_us": t_compute_us, "t_memory_us": t_memory_us,
        "bound": "compute" if t_compute_us >= t_memory_us else "memory",
        "roofline_us": bound_us,
        # fraction of the roofline bound the measured kernel attains
        # (1.0 == at the roof; small == far below it)
        "attained_frac": bound_us / max(us, 1e-9),
    }


def run_cells(cells, dtype_name, peaks, strategy, repeats, persist):
    from repro.kernels import autotune
    import jax.numpy as jnp
    dtype = jnp.dtype(dtype_name)
    out = []
    for kernel, shape, build in cells:
        runner, flops, bytes_ = build(dtype)
        bucket = autotune.shape_bucket(**shape)
        res = autotune.tune(kernel, runner, bucket, dtype,
                            strategy=strategy, repeats=repeats,
                            persist=persist)
        cell = {
            "kernel": kernel, "shape": shape, "dtype": dtype_name,
            "default": {"config": autotune.DEFAULTS[kernel],
                        "us": res.default_us},
            "tuned": {"config": res.config, "us": res.us},
            "speedup": res.speedup,
            "n_candidates": len(res.table),
            "roofline": roofline_terms(flops, bytes_, res.us, peaks),
        }
        out.append(cell)
        emit(f"kernels/{kernel}", res.us,
             f"default={res.default_us:.0f}us tuned={res.us:.0f}us "
             f"x{res.speedup:.2f} cfg={res.config} "
             f"bound={cell['roofline']['bound']}")
    return out


# ---------------------------------------------------------------------------
# bf16-vs-fp32 equivalence study


def bf16_study(max_outer: int, losses=("logistic", "squared_hinge"),
               scale=None):
    """Matched-iteration trajectory comparison: same data, same config,
    tol_kkt=0 and a fixed outer budget, so iteration k of the bf16 run
    lines up with iteration k of the fp32 run. Reports the max relative
    objective difference across the trajectory — the calibration number
    behind launch/common.py's BF16_MIN_TOL gate."""
    import jax.numpy as jnp
    from repro.core import PCDNConfig, make_problem, solve
    from repro.data import paper_like
    study = {"dataset": "a9a", "max_outer": max_outer, "losses": {},
             "max_objective_rel_diff": 0.0}
    X, y, _ = paper_like("a9a", seed=0, scale=scale)
    for loss in losses:
        cfg = PCDNConfig(P=128, max_outer=max_outer, tol_kkt=0.0, seed=0)
        runs = {}
        for name, dt in (("fp32", jnp.float32), ("bf16", jnp.bfloat16)):
            prob = make_problem(X, y, c=1.0, loss=loss, dtype=dt)
            res = solve(prob, cfg)
            runs[name] = np.asarray(res.history.objective, np.float64)
        n = min(len(runs["fp32"]), len(runs["bf16"]))
        rel = np.abs(runs["bf16"][:n] - runs["fp32"][:n]) / \
            np.maximum(np.abs(runs["fp32"][:n]), 1e-12)
        study["losses"][loss] = {
            "n_iters": int(n),
            "final_fp32": float(runs["fp32"][n - 1]),
            "final_bf16": float(runs["bf16"][n - 1]),
            "max_rel_diff": float(rel.max()),
        }
        study["max_objective_rel_diff"] = max(
            study["max_objective_rel_diff"], float(rel.max()))
        emit(f"kernels/bf16_study_{loss}", 0.0,
             f"iters={n} max_rel_diff={rel.max():.2e}")
    study["envelope_rel_diff"] = 1e-3
    study["pass"] = study["max_objective_rel_diff"] <= 1e-3
    return study


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, few repeats, results-dir output "
                         "only (CI tier-1)")
    ap.add_argument("--strategy", default="exhaustive",
                    choices=["exhaustive", "hillclimb"])
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--dtypes", default="float32,bfloat16",
                    help="comma list of storage dtypes to sweep")
    ap.add_argument("--no-study", action="store_true",
                    help="skip the bf16 trajectory study")
    ap.add_argument("--no-persist", action="store_true",
                    help="do not write winners into the autotune cache")
    args = ap.parse_args(argv)

    from repro.kernels import autotune
    repeats = args.repeats or (2 if args.smoke else 5)
    cells = SMOKE_CELLS if args.smoke else CELLS

    emit("kernels/calibrate", 0.0, "measuring peaks...")
    peaks = {"flops_per_s": calibrate_peak_flops(
                 256 if args.smoke else 1024),
             "bytes_per_s": calibrate_peak_bandwidth(
                 8 if args.smoke else 64)}
    emit("kernels/peaks", 0.0,
         f"{peaks['flops_per_s'] / 1e9:.1f} GFLOP/s "
         f"{peaks['bytes_per_s'] / 1e9:.1f} GB/s")

    all_cells = []
    for dtype_name in [d for d in args.dtypes.split(",") if d]:
        all_cells += run_cells(cells, dtype_name, peaks, args.strategy,
                               repeats, persist=not args.no_persist)

    payload = {
        "meta": {"backend": autotune.backend_tag(),
                 "strategy": args.strategy, "repeats": repeats,
                 "smoke": bool(args.smoke),
                 "when": time.strftime("%Y-%m-%dT%H:%M:%S")},
        "peaks": {"flops_gflops": peaks["flops_per_s"] / 1e9,
                  "bandwidth_gbps": peaks["bytes_per_s"] / 1e9},
        "cells": all_cells,
    }
    if not args.no_study:
        payload["bf16_study"] = bf16_study(
            max_outer=5 if args.smoke else 30,
            scale=0.25 if args.smoke else None)

    best = max(c["speedup"] for c in all_cells)
    payload["headline"] = {
        "best_speedup": best,
        "all_tuned_at_least_default": all(
            c["tuned"]["us"] <= c["default"]["us"] for c in all_cells),
    }
    emit("kernels/headline", 0.0, f"best tuned-over-default x{best:.2f}")

    os.makedirs(RESULTS_DIR, exist_ok=True)
    if args.smoke:
        out = os.path.join(RESULTS_DIR, "BENCH_kernels_smoke.json")
        paths = [out]
    else:
        paths = [HEADLINE, os.path.join(RESULTS_DIR, "BENCH_kernels.json")]
    for p in paths:
        with open(p, "w") as fh:
            json.dump(payload, fh, indent=1, default=float)
        print(f"[bench_kernels] wrote {p}")
    return payload


if __name__ == "__main__":
    main()
