"""Telemetry overhead benchmark -> BENCH_obs.json (DESIGN.md section 13).

    PYTHONPATH=src python benchmarks/bench_obs.py [--smoke]

Measures what the observability subsystem costs when it is ON and proves
it costs nothing when it is OFF:

  * solve — a fixed-iteration PCDN solve (tol_kkt=0 so both arms do
    identical solver work) timed with telemetry disabled (record_aux off,
    registry off, tracer off) vs fully enabled (per-bundle (q, alpha)
    aux outputs + registry counters/histograms + trace spans). The
    headline `solve.overhead_pct` is the acceptance number: the enabled
    plane must cost <= 5% of solve wall time.

  * batcher — the serving front-end under a steady padded-bucket stream,
    same disabled-vs-enabled comparison (per-chunk latency histograms,
    counters and trace events are the instrumented path).

  * sharded — a 1x1-mesh ShardedBackend arm asserting the aux series
    (bundle_q / bundle_alpha) actually reach SolveHistory through
    shard_map, i.e. the telemetry plane exists on the mesh backend too.

The enabled arm records a real trace, which the benchmark validates with
`repro.obs.trace.validate_trace` before reporting — the emitted file
format is checked, not assumed. Smoke mode writes only to
benchmarks/results/ (CI); the full run also writes the repo-root
BENCH_obs.json that the acceptance criterion reads.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import jax

from repro import obs
from repro.core import PCDNConfig, make_problem, solve
from repro.data.synthetic import make_classification
from repro.engine import ShardedBackend, ShardedPCDNConfig
from repro.engine import loop as engine_loop
from repro.launch.mesh import make_host_mesh
from repro.serve.batcher import MicroBatcher
from repro.serve.predict import ModelBank

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results")


def _time(fn, repeats: int = 5) -> float:
    """Best-of-N seconds per call, post-warmup (compile excluded)."""
    fn()                                   # warmup / compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _time_pair(fn_a, fn_b, repeats: int = 5):
    """Best-of-N for two arms with INTERLEAVED repeats (A B A B ...), so
    slow machine-load drift hits both arms equally — back-to-back arm
    timing is exactly how a 2.4s solve reads as 13% slower than itself
    on a noisy box. Both arms are warmed before any timing."""
    fn_a()
    fn_b()
    best_a = best_b = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn_a()
        best_a = min(best_a, time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        best_b = min(best_b, time.perf_counter() - t0)
    return best_a, best_b


def _problem(s: int, n: int, c: float = 2.0, seed: int = 0):
    X, y, _ = make_classification(s, n, sparsity=0.5, seed=seed)
    return make_problem(X, y, c=c)


def bench_solve(s, n, P, iters, repeats, seed=0):
    """Disabled-vs-enabled wall time on identical solver work: tol_kkt=0
    pins both arms to exactly `iters` outer iterations."""
    prob = _problem(s, n, seed=seed)
    cfg_off = PCDNConfig(P=P, max_outer=iters, tol_kkt=0.0, seed=seed)
    cfg_on = dataclasses.replace(cfg_off, record_aux=True)

    def run_off():
        obs.disable()
        return solve(prob, cfg_off)

    def run_on():
        # re-enabling resets the tracer, so timed repeats do not grow an
        # unbounded in-memory event list
        obs.enable(metrics=True, trace_=True, process_name="bench_obs")
        return solve(prob, cfg_on)

    t_off, t_on = _time_pair(run_off, run_on, repeats)
    res_off = run_off()
    res_on = run_on()
    snap = obs.registry.get_registry().snapshot()
    trace_obj = obs.trace.get_tracer().to_dict()
    n_events = obs.trace.validate_trace(trace_obj)
    obs.disable()

    assert res_on.history.bundle_q is not None, \
        "enabled arm must thread per-bundle q into SolveHistory"
    assert res_off.history.bundle_q is None, \
        "disabled arm must not carry aux series"
    # identical solver work: the aux outputs ride along, they do not
    # perturb the iterates
    drift = abs(res_on.objective - res_off.objective) \
        / max(1.0, abs(res_off.objective))
    overhead = (t_on - t_off) / t_off * 100.0
    row = {
        "s": s, "n": n, "P": P, "iters": iters,
        "disabled_s": t_off, "enabled_s": t_on,
        "overhead_pct": overhead,
        "objective_rel_drift": drift,
        "bundle_q_shape": list(res_on.history.bundle_q.shape),
        "registry_counters": {k: v for k, v in snap["counters"].items()},
        "trace_events": n_events,
    }
    print(f"[solve] {iters} iters (s={s}, n={n}, P={P}): disabled "
          f"{t_off * 1e3:.1f}ms, enabled {t_on * 1e3:.1f}ms -> "
          f"{overhead:+.2f}% overhead, {n_events} trace events, "
          f"drift {drift:.1e}", flush=True)
    return row, trace_obj


def bench_batcher(K, n, n_requests, buckets, repeats, seed=0):
    """Steady-state batcher stream, disabled vs enabled registry+trace.
    Buckets are warmed first so neither arm pays compiles."""
    rng = np.random.default_rng(seed + 3)
    nnz = max(1, n // 100)
    W = np.zeros((K, n), np.float32)
    for k in range(K):
        sup = rng.choice(n, size=nnz, replace=False)
        W[k, sup] = rng.standard_normal(nnz).astype(np.float32)
    bank = ModelBank.from_dense(W, kind="path")
    X = rng.standard_normal((n_requests, n)).astype(np.float32)
    sizes = rng.integers(1, buckets[-1] + 1, size=32)

    def stream(batcher):
        start = 0
        for r in sizes:
            stop = min(start + int(r), n_requests)
            if stop <= start:
                start, stop = 0, int(r)
            batcher.predict(X[start:stop])
            start = stop

    def warmed():
        b = MicroBatcher(bank, buckets=buckets, layout="dense")
        for bk in buckets:
            b.predict(X[:bk])
        return b

    obs.disable()
    b_off = warmed()
    obs.enable(metrics=True, trace_=True, process_name="bench_obs")
    b_on = warmed()

    def run_off():
        obs.disable()
        stream(b_off)

    def run_on():
        obs.enable(metrics=True, trace_=True, process_name="bench_obs")
        stream(b_on)

    t_off, t_on = _time_pair(run_off, run_on, repeats)
    stats_on = b_on.stats()
    obs.disable()

    overhead = (t_on - t_off) / t_off * 100.0
    row = {
        "K": K, "n": n, "stream_batches": len(sizes),
        "disabled_s": t_off, "enabled_s": t_on,
        "overhead_pct": overhead,
        "latency_p50_s": stats_on.get("latency_p50_s"),
        "latency_p99_s": stats_on.get("latency_p99_s"),
    }
    print(f"[batcher] {len(sizes)}-batch stream: disabled "
          f"{t_off * 1e3:.1f}ms, enabled {t_on * 1e3:.1f}ms -> "
          f"{overhead:+.2f}% overhead", flush=True)
    return row


def bench_sharded(s, n, P, iters, seed=0):
    """1x1-mesh aux presence: the per-bundle (q, alpha) series must come
    out of the shard_map program and land in SolveHistory."""
    X, y, _ = make_classification(s, n, sparsity=0.5, seed=seed)
    mesh = make_host_mesh(1, 1)
    cfg = ShardedPCDNConfig(P_local=P, c=2.0, seed=seed, record_aux=True)
    backend = ShardedBackend(X, y, mesh, cfg)
    res = engine_loop.solve(backend, 2.0, max_outer=iters, tol_kkt=0.0)
    assert res.history.bundle_q is not None \
        and res.history.bundle_alpha is not None, \
        "sharded backend must thread aux through shard_map"
    row = {"mesh": [1, 1], "iters": res.n_outer,
           "aux_present": True,
           "bundle_q_shape": list(res.history.bundle_q.shape),
           "mean_q": float(np.mean(
               res.history.bundle_q[res.history.bundle_q >= 0]))}
    print(f"[sharded] 1x1 mesh: bundle_q {row['bundle_q_shape']} "
          f"mean_q={row['mean_q']:.2f}", flush=True)
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes (CI)")
    args = ap.parse_args(argv)

    if args.smoke:
        s, n, P, iters, repeats = 400, 300, 64, 10, 3
        K, bank_n, n_requests, buckets = 8, 4096, 512, (16, 64)
        sh_s, sh_n, sh_P, sh_iters = 200, 150, 32, 5
    else:
        s, n, P, iters, repeats = 2000, 2000, 256, 40, 5
        K, bank_n, n_requests, buckets = 16, 16384, 2048, (16, 64, 256)
        sh_s, sh_n, sh_P, sh_iters = 600, 500, 64, 10

    solve_row, trace_obj = bench_solve(s, n, P, iters, repeats)
    batcher_row = bench_batcher(K, bank_n, n_requests, buckets, repeats)
    sharded_row = bench_sharded(sh_s, sh_n, sh_P, sh_iters)

    payload = {
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "solve": solve_row,
        "batcher": batcher_row,
        "sharded": sharded_row,
        "trace_valid": True,
        "trace_events": solve_row["trace_events"],
    }
    print(f"[obs] HEADLINE solve overhead (enabled vs disabled): "
          f"{solve_row['overhead_pct']:+.2f}% "
          f"(acceptance: <= 5%)", flush=True)

    os.makedirs(RESULTS_DIR, exist_ok=True)
    targets = [os.path.join(RESULTS_DIR, "BENCH_obs.json")]
    if not args.smoke:
        targets.append(os.path.join(REPO_ROOT, "BENCH_obs.json"))
    for path in targets:
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=1, default=float)
    # the trace the enabled arm recorded, for schema validation in CI
    trace_path = os.path.join(RESULTS_DIR, "bench_obs_trace.json")
    with open(trace_path, "w") as fh:
        json.dump(trace_obj, fh)
    print(f"wrote BENCH_obs.json + {os.path.basename(trace_path)}")
    return payload


if __name__ == "__main__":
    main()
