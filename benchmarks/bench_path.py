"""Regularization-path engine benchmark -> BENCH_path.json.

    PYTHONPATH=src python benchmarks/bench_path.py [--smoke]

Two sections on one 99.9%-sparse synthetic problem (DESIGN.md §8):

  * warm_vs_cold + shrink_vs_noshrink — the SAME 20-point c-grid
    traversed four ways, every traversal stopping at the same per-point
    full-set KKT tolerance:

      cold_solves   20 independent cold `pcdn.solve` calls, one per grid
                    point, each paying its own XLA compile — the seed
                    deployment baseline (`repro.launch.solve` today: one
                    cold solve per process);
      cold_shared   state reset per point but one compiled dynamic-c
                    program — isolates warm-start value from compile
                    amortization;
      warm          the warm-started sweep (state chained);
      warm_shrink   the path engine's flagship config: warm starts +
                    PCDNConfig(shrink=True).

    The headline `speedup_engine_vs_cold_solves` compares warm_shrink
    against cold_solves; shrink_vs_noshrink (warm vs warm_shrink) shows
    shrinking cutting path wall-time further, with the max relative
    final-objective deviation pinned at f32 noise.

  * batch_vs_looped — the serving workload: B bootstrap label-resamples
    of the same design at one production c (CV-fold shape: similar
    per-problem difficulty, so the lockstep batch wastes almost nothing)
    solved simultaneously by the vmapped multi-problem solver, vs a
    Python loop of cold `pcdn.solve` (fresh compile each, the
    per-process baseline) and vs a loop of B=1 calls through one shared
    compiled batch program. Dense layout: vmapping turns every bundle
    reduction into a batched GEMM, which is where the throughput comes
    from even on CPU. Reports problems/second. (A wide c-grid on the
    sparse layout is the batch engine's WORST case — dissimilar
    iteration counts + gather-bound math; the sweep sections cover that
    regime with warm starts instead.)

Writes BENCH_path.json at the repo root and a copy under
benchmarks/results/.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import PCDNConfig, make_problem, pcdn, solve
from repro.data import make_classification
from repro.path import PathConfig, c_grid, run_path, solve_batch
from repro.path.batch import make_batch_outer

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results")


def _warmed_outer(prob, solver):
    """Compile a path outer before timing; returns (outer, compile_s)."""
    outer = pcdn.make_path_outer(prob, solver)
    n, s = prob.n_features, prob.n_samples
    t0 = time.perf_counter()
    out = outer(jnp.zeros((n,), prob.dtype), jnp.zeros((s,), prob.dtype),
                jax.random.PRNGKey(0), jnp.ones((n,), bool),
                jnp.asarray(True), jnp.asarray(1.0, prob.dtype))
    jax.block_until_ready(out)
    return outer, time.perf_counter() - t0


def bench_sweeps(X, y, solver, n_points, span):
    """Traverse one c-grid four ways -> (warm_vs_cold, shrink_vs_noshrink)."""
    prob = make_problem(X, y, c=1.0, layout="padded_csc")
    cs = c_grid(prob.c_max(), n_points=n_points, span=span)
    cfg = PathConfig(solver=solver, n_points=n_points, span=span)

    # warm path: one compile + the chained sweep
    outer, compile_s = _warmed_outer(prob, solver)
    t0 = time.perf_counter()
    warm = run_path(prob, cfg, outer=outer)
    warm_s = time.perf_counter() - t0

    # the engine's flagship config: warm starts + active-set shrinking
    shrink_solver = dataclasses.replace(solver, shrink=True)
    shrink_outer, shrink_compile_s = _warmed_outer(prob, shrink_solver)
    t0 = time.perf_counter()
    warm_shrink = run_path(prob, dataclasses.replace(cfg,
                                                     solver=shrink_solver),
                           outer=shrink_outer)
    warm_shrink_s = time.perf_counter() - t0

    # ablation: same single program, state reset at every point
    t0 = time.perf_counter()
    cold_shared = run_path(prob, dataclasses.replace(cfg, warm_start=False),
                           outer=outer)
    cold_shared_s = time.perf_counter() - t0

    # baseline: 20 independent cold solves (fresh jit each — the one-
    # solve-per-process deployment this subsystem replaces)
    t0 = time.perf_counter()
    cold_iters, cold_conv, cold_objs = 0, True, []
    for c in cs:
        res = solve(make_problem(X, y, c=float(c), layout="padded_csc"),
                    solver)
        cold_iters += res.n_outer
        cold_conv &= res.converged
        cold_objs.append(res.objective)
    cold_solves_s = time.perf_counter() - t0

    cold_objs = np.array(cold_objs)
    warm_objs = np.array([p.objective for p in warm.points])
    shrink_objs = np.array([p.objective for p in warm_shrink.points])
    engine_s = warm_shrink_s + shrink_compile_s
    warm_vs_cold = {
        "n_points": n_points, "span": span, "c_max": prob.c_max(),
        "tol_kkt": solver.tol_kkt,
        "warm_shrink_seconds_incl_compile": engine_s,
        "warm_seconds_incl_compile": warm_s + compile_s,
        "compile_seconds": compile_s,
        "warm_iters": int(sum(p.n_outer for p in warm.points)),
        "warm_all_converged": all(p.converged for p in warm.points),
        "cold_solves_seconds": cold_solves_s,
        "cold_solves_iters": int(cold_iters),
        "cold_solves_all_converged": bool(cold_conv),
        "cold_shared_program_seconds": cold_shared_s,
        "cold_shared_iters": int(sum(p.n_outer for p in cold_shared.points)),
        "speedup_engine_vs_cold_solves": cold_solves_s / engine_s,
        "speedup_warm_only_vs_cold_solves":
            cold_solves_s / (warm_s + compile_s),
        "speedup_warm_only_vs_cold_shared": cold_shared_s / warm_s,
        "objective_max_rel_diff_vs_cold": float(np.max(
            np.abs(warm_objs - cold_objs) / np.abs(cold_objs))),
    }
    shrink_vs_noshrink = {
        "noshrink_seconds": warm_s,
        "shrink_seconds": warm_shrink_s,
        "speedup": warm_s / warm_shrink_s,
        "shrink_all_converged": all(p.converged
                                    for p in warm_shrink.points),
        "final_full_set_kkt": float(warm_shrink.points[-1].kkt),
        "objective_max_rel_diff": float(np.max(
            np.abs(shrink_objs - warm_objs) / np.abs(warm_objs))),
    }
    print(f"path engine (warm+shrink) {engine_s:.1f}s vs "
          f"{n_points} cold solves {cold_solves_s:.1f}s -> "
          f"{warm_vs_cold['speedup_engine_vs_cold_solves']:.1f}x "
          f"(warm only {warm_s + compile_s:.1f}s, "
          f"shared-program cold {cold_shared_s:.1f}s)", flush=True)
    print(f"shrink {warm_shrink_s:.1f}s vs noshrink {warm_s:.1f}s -> "
          f"{shrink_vs_noshrink['speedup']:.2f}x, obj rel diff "
          f"{shrink_vs_noshrink['objective_max_rel_diff']:.1e}", flush=True)
    return warm_vs_cold, shrink_vs_noshrink


def bench_batch(X, y, solver, batch, flip_frac=0.1, seed=3):
    prob = make_problem(X, y, c=1.0, layout="dense")
    c = 3.5 * prob.c_max()          # a mid-path production operating point
    cs = [float(c)] * batch
    rng = np.random.default_rng(seed)
    flip = rng.random((batch, prob.n_samples)) < flip_frac
    ys = np.stack([np.where(flip[i], -y, y)
                   for i in range(batch)]).astype(np.float32)

    outer = make_batch_outer(prob, solver, batched_labels=True)
    _ = solve_batch(prob, dataclasses.replace(solver, max_outer=1), cs,
                    ys=ys, outer=outer)                # compile
    t0 = time.perf_counter()
    bres = solve_batch(prob, solver, cs, ys=ys, outer=outer)
    batched_s = time.perf_counter() - t0

    # baseline 1: one cold pcdn.solve per fold, fresh compile each
    t0 = time.perf_counter()
    loop_objs = []
    for i in range(batch):
        res = solve(make_problem(X, ys[i], c=float(c)), solver)
        loop_objs.append(res.objective)
    looped_s = time.perf_counter() - t0

    # baseline 2: sequential folds through ONE compiled program (B=1
    # calls into a shared batch outer — compile paid once, no vmap win)
    outer1 = make_batch_outer(prob, solver, batched_labels=True)
    _ = solve_batch(prob, dataclasses.replace(solver, max_outer=1),
                    cs[:1], ys=ys[:1], outer=outer1)   # compile
    t0 = time.perf_counter()
    for i in range(batch):
        solve_batch(prob, solver, cs[:1], ys=ys[i:i + 1], outer=outer1)
    looped_shared_s = time.perf_counter() - t0

    obj_rel = float(np.max(np.abs(np.asarray(bres.objective) -
                                  np.array(loop_objs)) /
                           np.abs(loop_objs)))
    row = {
        "batch": batch, "c": float(c), "flip_frac": flip_frac,
        "layout": "dense",
        "batched_seconds": batched_s,
        "looped_cold_solves_seconds": looped_s,
        "looped_shared_program_seconds": looped_shared_s,
        "batched_problems_per_second": batch / batched_s,
        "looped_problems_per_second": batch / looped_s,
        "throughput_gain_vs_cold_solves": looped_s / batched_s,
        "throughput_gain_vs_shared_loop": looped_shared_s / batched_s,
        "all_converged": bool(np.all(np.asarray(bres.converged))),
        "objective_max_rel_diff": obj_rel,
    }
    print(f"batched {batch} folds {batched_s:.1f}s vs looped cold "
          f"{looped_s:.1f}s (shared-program loop {looped_shared_s:.1f}s) "
          f"-> {row['throughput_gain_vs_cold_solves']:.1f}x / "
          f"{row['throughput_gain_vs_shared_loop']:.1f}x, obj rel diff "
          f"{obj_rel:.1e}", flush=True)
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes + short grid (CI)")
    args = ap.parse_args(argv)

    if args.smoke:
        s, n, P, n_points, span, batch = 600, 2048, 128, 6, 30.0, 4
        max_outer = 400
    else:
        s, n, P, n_points, span, batch = 3000, 8192, 256, 20, 300.0, 16
        max_outer = 1000

    X, y, _ = make_classification(s, n, sparsity=0.999, corr=0.2, seed=1)
    solver = PCDNConfig(P=P, max_outer=max_outer, tol_kkt=1e-3)

    warm_vs_cold, shrink_vs_noshrink = bench_sweeps(X, y, solver,
                                                    n_points, span)
    payload = {
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "problem": {"s": s, "n": n, "sparsity": 0.999, "P": P},
        "warm_vs_cold": warm_vs_cold,
        "shrink_vs_noshrink": shrink_vs_noshrink,
        "batch_vs_looped": bench_batch(X, y, solver, batch),
    }

    os.makedirs(RESULTS_DIR, exist_ok=True)
    for path in (os.path.join(REPO_ROOT, "BENCH_path.json"),
                 os.path.join(RESULTS_DIR, "BENCH_path.json")):
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=1, default=float)
    print("wrote BENCH_path.json")
    return payload


if __name__ == "__main__":
    main()
