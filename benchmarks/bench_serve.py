"""Serving-engine benchmark -> BENCH_serve.json (DESIGN.md section 10.5).

    PYTHONPATH=src python benchmarks/bench_serve.py [--smoke]

Three sections over a bank of K synthetic sparse models:

  * scorer — the margin hot loop, dense (B, n) request slabs: the dense
    baseline z = X @ W.T (what serving a densified model costs, O(B*n*K))
    vs the engine's sparse-gather scorer that touches only each model's
    active coordinates (O(B*A*K), serve.predict / the algorithm of
    kernels/pcdn_margin.py). Swept over weight sparsity x batch size —
    the headline `speedup_at_099` (sparse-gather vs dense at >= 0.99
    weight sparsity, largest batch) is the acceptance number: exploiting
    solution sparsity in the scoring loop, the serving-side mirror of
    Scherrer et al.'s training-side trick.

  * csc_scorer — the same bank scoring feature-major padded-CSC request
    batches (request sparsity exploited too; work O(A * k_max), free of
    both B density and n).

  * batcher — the microbatching front-end under a steady request stream:
    ragged batches padded to bucket shapes, demonstrating one compile
    per bucket (never per batch) and steady-state rows/s by bucket.

Pallas-kernel routes are equivalence-checked here but timed only when
they are compiled (not on the CPU interpreter, whose timings would
measure the interpreter, not the kernel — see benchmarks/bench_sparse.py
for the same policy).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import jax
import jax.numpy as jnp

from repro.core.design_matrix import PaddedCSCDesign
from repro.kernels import ops
from repro.serve.batcher import MicroBatcher
from repro.serve.predict import (ModelBank, margins_dense,
                                 margins_padded_csc)

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results")


def _time(fn, repeats: int = 5) -> float:
    """Best-of-N seconds per call, post-warmup (compile excluded)."""
    fn()                                   # warmup / compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def make_bank(K: int, n: int, sparsity: float, seed: int = 0) -> ModelBank:
    rng = np.random.default_rng(seed)
    nnz = max(1, int(round((1.0 - sparsity) * n)))
    W = np.zeros((K, n), np.float32)
    for k in range(K):
        sup = rng.choice(n, size=nnz, replace=False)
        W[k, sup] = rng.standard_normal(nnz).astype(np.float32)
    return ModelBank.from_dense(W, kind="path")


def bench_scorer(K, n, batches, sparsities, seed=0):
    rng = np.random.default_rng(seed + 1)
    rows = []
    for sp in sparsities:
        bank = make_bank(K, n, sp, seed=seed)
        W = jnp.zeros((K, n), jnp.float32).at[
            jnp.arange(K)[:, None], bank.idx].add(
            bank.val, mode="drop")
        dense_fn = jax.jit(lambda X, W=W: X @ W.T)
        for B in batches:
            X = jnp.asarray(rng.standard_normal((B, n)), jnp.float32)
            t_dense = _time(lambda: np.asarray(dense_fn(X)))
            t_sparse = _time(lambda: np.asarray(margins_dense(bank, X)))
            row = {"sparsity": sp, "batch": B, "a_max": bank.a_max,
                   "dense_s": t_dense, "sparse_gather_s": t_sparse,
                   "dense_rows_per_s": B / t_dense,
                   "sparse_rows_per_s": B / t_sparse,
                   "speedup": t_dense / t_sparse}
            err = float(jnp.max(jnp.abs(
                dense_fn(X) - margins_dense(bank, X))))
            row["max_abs_err"] = err
            rows.append(row)
            print(f"[scorer] sparsity={sp} B={B}: dense "
                  f"{row['dense_rows_per_s']:.0f} rows/s, sparse-gather "
                  f"{row['sparse_rows_per_s']:.0f} rows/s -> "
                  f"{row['speedup']:.1f}x (err {err:.1e})", flush=True)
    return rows


def derive_route_crossover(scorer_rows):
    """The measured dense-vs-union-gather crossover per sparsity level:
    the smallest measured batch from which the sparse-gather route wins
    (speedup >= 1 there AND at every larger measured batch — monotone
    in practice, and requiring it keeps a noisy mid-table win from
    flipping the route), or None when dense wins everywhere. Committed
    under the `route_crossover` key; serve.predict.pick_route reads it
    so launch.predict --route auto picks the measured winner instead of
    always preferring the sparse path."""
    table = []
    for sp in sorted({r["sparsity"] for r in scorer_rows}):
        rows = sorted((r for r in scorer_rows if r["sparsity"] == sp),
                      key=lambda r: r["batch"])
        crossover = None
        for i, r in enumerate(rows):
            if all(q["speedup"] >= 1.0 for q in rows[i:]):
                crossover = r["batch"]
                break
        table.append({"sparsity": sp, "min_batch_sparse": crossover})
    return table


def bench_csc_scorer(K, n, batches, sparsity, req_density, seed=0):
    rng = np.random.default_rng(seed + 2)
    bank = make_bank(K, n, sparsity, seed=seed)
    rows = []
    for B in batches:
        mask = rng.random((B, n)) < req_density
        Xd = np.where(mask, rng.standard_normal((B, n)), 0.0) \
            .astype(np.float32)
        design = PaddedCSCDesign.from_dense(Xd)
        Xj = jnp.asarray(Xd)
        t_dense_req = _time(lambda: np.asarray(margins_dense(bank, Xj)))
        t_csc = _time(lambda: np.asarray(margins_padded_csc(bank, design)))
        err = float(jnp.max(jnp.abs(
            margins_dense(bank, Xj) - margins_padded_csc(bank, design))))
        rows.append({"batch": B, "req_density": req_density,
                     "k_max": design.k_max,
                     "dense_request_s": t_dense_req,
                     "padded_csc_s": t_csc,
                     "csc_rows_per_s": B / t_csc,
                     "max_abs_err": err})
        print(f"[csc] B={B} k_max={design.k_max}: dense-request "
              f"{B / t_dense_req:.0f} rows/s, padded-csc "
              f"{B / t_csc:.0f} rows/s (err {err:.1e})", flush=True)
    return rows


def bench_batcher(K, n, sparsity, n_requests, buckets, seed=0):
    rng = np.random.default_rng(seed + 3)
    bank = make_bank(K, n, sparsity, seed=seed)
    X = rng.standard_normal((n_requests, n)).astype(np.float32)
    batcher = MicroBatcher(bank, buckets=buckets, layout="dense")
    # ragged steady-state stream: random batch sizes, Zipf-ish mix
    sizes = rng.integers(1, buckets[-1] + 1, size=64)
    t0 = time.perf_counter()
    start = 0
    for r in sizes:
        stop = min(start + int(r), n_requests)
        if stop <= start:
            start = 0
            stop = int(r)
        batcher.predict(X[start:stop])
        start = stop
    wall = time.perf_counter() - t0
    stats = batcher.stats()
    stats["wall_seconds"] = wall
    stats["stream_batches"] = len(sizes)
    print(f"[batcher] {stats['total_rows']} rows over {len(sizes)} ragged "
          f"batches, {stats['compiles']} compiles "
          f"({len(buckets)} buckets), steady "
          f"{(stats['steady_rows_per_s'] or 0):.0f} rows/s", flush=True)
    return stats


def check_kernels(K, n, B, sparsity, seed=0):
    """Equivalence of the Pallas margin kernels against the XLA scorer
    (timed only when compiled; on CPU they run interpreted)."""
    rng = np.random.default_rng(seed + 4)
    bank = make_bank(K, n, sparsity, seed=seed)
    Xd = np.where(rng.random((B, n)) < 0.05,
                  rng.standard_normal((B, n)), 0.0).astype(np.float32)
    design = PaddedCSCDesign.from_dense(Xd)
    Xj = jnp.asarray(Xd)
    zr = margins_dense(bank, Xj)
    err_dense = float(jnp.max(jnp.abs(
        zr - margins_dense(bank, Xj, use_kernels=True))))
    err_csc = float(jnp.max(jnp.abs(
        zr - margins_padded_csc(bank, design, use_kernels=True))))
    out = {"interpret": bool(ops.interpret_mode()),
           "dense_kernel_max_err": err_dense,
           "csc_kernel_max_err": err_csc}
    print(f"[kernels] dense err {err_dense:.1e}, csc err {err_csc:.1e} "
          f"(interpret={ops.interpret_mode()})", flush=True)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes (CI)")
    args = ap.parse_args(argv)

    if args.smoke:
        K, n = 8, 8192
        batches = (64, 256)
        sparsities = (0.99, 0.999)
        n_requests, buckets = 1024, (16, 64, 256)
    else:
        K, n = 16, 32768
        batches = (64, 256, 1024)
        sparsities = (0.9, 0.99, 0.999)
        n_requests, buckets = 8192, (16, 64, 256, 1024)

    scorer = bench_scorer(K, n, batches, sparsities)
    # headline: best speedup among banks AT LEAST 0.99 sparse on the
    # largest batch — the name says ">= 0.99" because the winning row is
    # the sparsest one (the paper's solutions are >= 99.9% sparse); the
    # per-sparsity table above reports every point honestly
    at99 = [r for r in scorer if r["sparsity"] >= 0.99
            and r["batch"] == max(b["batch"] for b in scorer)]
    best = max(at99, key=lambda r: r["speedup"])
    payload = {
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "bank": {"K": K, "n": n},
        "scorer": scorer,
        "speedup_at_ge_099": best["speedup"],
        "headline_sparsity": best["sparsity"],
        "headline_batch": best["batch"],
        "route_crossover": derive_route_crossover(scorer),
        "csc_scorer": bench_csc_scorer(K, n, batches, sparsities[-1],
                                       req_density=0.02),
        "batcher": bench_batcher(K, n, sparsities[-1], n_requests, buckets),
        "kernel_equivalence": check_kernels(K, min(n, 4096), 64,
                                            sparsities[-1]),
    }
    print(f"[serve] HEADLINE sparse-gather vs dense: "
          f"{best['speedup']:.1f}x at sparsity={best['sparsity']} "
          f"B={best['batch']}", flush=True)

    os.makedirs(RESULTS_DIR, exist_ok=True)
    for path in (os.path.join(REPO_ROOT, "BENCH_serve.json"),
                 os.path.join(RESULTS_DIR, "BENCH_serve.json")):
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=1, default=float)
    print("wrote BENCH_serve.json")
    return payload


if __name__ == "__main__":
    main()
