"""Serving-loop load benchmark -> BENCH_serve2.json (DESIGN.md 14.7).

    PYTHONPATH=src python benchmarks/bench_serve2.py [--smoke]

Open-loop Poisson load against the continuous-batching `ServeLoop`
(serve/loop.py), the headline p50/p99-vs-offered-load story of ROADMAP
item 1. Three sections:

  * sync — the synchronous per-batch baseline: the SAME machinery with
    buckets=(1,), i.e. a FIFO server that scores one request per engine
    round-trip (the MicroBatcher's semantics behind a queue, so the
    comparison isolates batching policy, not implementation).
  * loop — deadline-aware continuous batching over the full bucket
    ladder. Both arms sweep a geometric ladder of offered rates anchored
    at each arm's measured compute capacity; a rate point is SUSTAINED
    when its measured p99 admission-to-response latency meets the SLO
    with zero admission rejects. The headline is the ratio of max
    sustained rows/s (acceptance: >= 2x, pinned by the guard test).
  * hot_swap — steady mid-rate traffic with two live best-c swaps from
    a freshly "solved" path family fired mid-stream: recompiles must be
    ZERO (scorer jit caches flat — capacity-padded banks), responses
    span old and new versions with no gap, and SLO violations during
    the swap run stay zero.

Rates are OPEN-LOOP: arrivals never wait for responses; when the
generator falls behind it submits immediately and the measured offered
rate (not the target) is what sustained/max numbers quote.
"""
from __future__ import annotations

import argparse
import contextlib
import gc
import json
import os
import sys
import threading
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import jax

from repro.serve.artifact import ModelArtifact, ModelFamily
from repro.serve.loop import ServeLoop, drive_poisson
from repro.serve.predict import scorer_cache_sizes

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results")

# a rate point is sustained when measured p99 <= SLO and nothing was shed
SLO_MS = 25.0
BUDGET_FRAC = 0.6          # request budget under the SLO: jitter headroom
RATE_LADDER = (0.25, 0.4, 0.6, 0.8, 1.0, 1.2, 1.5)


@contextlib.contextmanager
def _quiesce_gc():
    """Collector pauses (the default gen0 threshold is 700 objects; a
    drive allocates a future + result per request) would show up as
    latency tail that is the BENCH's fault, not the server's — collect
    up front, disable during the measured drive, restore after."""
    gc.collect()
    was_enabled = gc.isenabled()
    gc.disable()
    try:
        yield
    finally:
        if was_enabled:
            gc.enable()


def make_family(n: int, nnz: int, K: int, seed: int,
                kind: str = "path") -> ModelFamily:
    """K synthetic sparse models as a servable family; path members get
    val_accuracy metas so pick_best_c has something to select on."""
    rng = np.random.default_rng(seed)
    models = []
    for k in range(K):
        idx = np.sort(rng.choice(n, size=nnz, replace=False))
        models.append(ModelArtifact(
            n_features=n, w_indices=idx,
            w_values=rng.standard_normal(nnz), loss_name="logistic",
            c=0.1 * (k + 1),
            meta={"val_accuracy": 0.7 + 0.02 * k, "nnz": nnz}))
    if kind == "binary":
        return ModelFamily(kind="binary", models=(models[0],))
    return ModelFamily(kind="path", models=tuple(models))


def _capacity_rps(loop: ServeLoop) -> float:
    """Compute-bound ceiling from the warmup-seeded latency model:
    max_bucket rows per estimated max-bucket call."""
    slot = loop.stats()["models"][loop.models()[0]]
    maxb = max(int(b) for b in slot["latency_model_s"])
    return maxb / slot["latency_model_s"][str(maxb)]


def _slot_delta(before: dict, after: dict) -> dict:
    return {"rows": after["rows"] - before["rows"],
            "pad_rows": after["pad_rows"] - before["pad_rows"],
            "flushes": {k: after["flushes"][k] - before["flushes"][k]
                        for k in after["flushes"]}}


def sweep_rates(loop: ServeLoop, X, slo_ms: float, duration_s: float,
                n_clamp, label: str, seed: int = 0) -> dict:
    """Drive the rate ladder; returns per-rate rows + max sustained.

    The fixed ladder is anchored at the arm's estimated compute
    capacity; if its top rung is still sustained the sweep keeps
    climbing (x1.5 steps, bounded) so the reported max is bracketed by
    a measured violation, not by where the ladder happened to end.
    """
    name = loop.models()[0]
    budget = BUDGET_FRAC * slo_ms / 1e3
    anchor = _capacity_rps(loop)
    rows = []

    def probe(rate, i):
        # best-of-2: a single ambient scheduler stall on a timeshared
        # box puts tens of ms into a few hundred samples' p99 — a rate
        # the server sustains in EITHER attempt is sustainable (the
        # best-of-N policy of every other bench here, applied to load)
        attempts = []
        for a in range(2):
            n = int(np.clip(rate * duration_s, *n_clamp))
            before = loop.stats()["models"][name]
            with _quiesce_gc():
                drive = drive_poisson(loop, X, rate_rps=rate,
                                      n_requests=n, model=name,
                                      budget_s=budget,
                                      seed=seed + 7 * i + a,
                                      timeout_s=120.0)
            drive.pop("results")
            delta = _slot_delta(before, loop.stats()["models"][name])
            served = delta["rows"] + delta["pad_rows"]
            attempts.append(
                {**drive,
                 "slo_ms": slo_ms,
                 "sustained": (drive["p99_s"] is not None
                               and drive["p99_s"] <= slo_ms / 1e3
                               and drive["rejects"] == 0),
                 "padding_efficiency": (delta["rows"] / served
                                        if served else None),
                 "flushes": delta["flushes"]})
            if attempts[-1]["sustained"]:
                break
        row = attempts[-1] if attempts[-1]["sustained"] else \
            min(attempts, key=lambda r: r["p99_s"] or float("inf"))
        row["attempts"] = len(attempts)
        rows.append(row)
        print(f"[{label}] target {rate:.0f} rps -> offered "
              f"{row['offered_rps']:.0f}, p50 "
              f"{1e3 * (row['p50_s'] or 0):.2f}ms p99 "
              f"{1e3 * (row['p99_s'] or 0):.2f}ms rejects "
              f"{row['rejects']} "
              f"{'SUSTAINED' if row['sustained'] else 'violated'}",
              flush=True)
        return row

    for i, mult in enumerate(RATE_LADDER):
        probe(anchor * mult, i)
    rate = anchor * RATE_LADDER[-1]
    for j in range(4):                       # climb past the ladder top
        if not rows[-1]["sustained"]:
            break
        rate *= 1.5
        probe(rate, len(RATE_LADDER) + j)
    rate = anchor * RATE_LADDER[0]
    for j in range(4):                       # descend below the ladder
        if any(r["sustained"] for r in rows):
            break
        rate /= 1.5
        probe(rate, 2000 + j)
    # bisect the sustained/violated boundary: the anchor is a lone warm
    # call's estimate and can be far from the loaded capacity, leaving
    # the ladder coarse exactly where the max lives
    for j in range(3):
        ok = max((r["target_rps"] for r in rows if r["sustained"]),
                 default=None)
        if ok is None:
            break
        above = [r["target_rps"] for r in rows
                 if not r["sustained"] and r["target_rps"] > ok]
        if not above:
            break
        mid = float(np.sqrt(ok * min(above)))
        if mid < 1.08 * ok:
            break
        probe(mid, 1000 + j)
    sustained = [r["offered_rps"] for r in rows if r["sustained"]]
    return {"anchor_rps": anchor, "rates": rows,
            "max_sustained_rps": max(sustained) if sustained else None}


def bench_loop_vs_sync(K, n, nnz, max_batch, duration_s, n_clamp, seed=0):
    fam = make_family(n, nnz, K, seed, kind="path")
    rng = np.random.default_rng(seed + 1)
    X = rng.standard_normal((512, n)).astype(np.float32)
    out = {}
    for label, buckets in (("sync", (1,)), ("loop", None)):
        loop = ServeLoop({"m": fam}, buckets=buckets, max_batch=max_batch,
                         default_budget_s=BUDGET_FRAC * SLO_MS / 1e3,
                         max_queue=16 * max_batch, route="auto")
        out[label] = sweep_rates(loop, X, SLO_MS, duration_s, n_clamp,
                                 label, seed=seed)
        out[label]["routes"] = \
            loop.stats()["models"]["m"]["routes"]
        loop.stop()
    s, l = out["sync"]["max_sustained_rps"], out["loop"]["max_sustained_rps"]
    out["headline_speedup"] = (l / s) if (s and l) else None
    ratio = (f"{out['headline_speedup']:.1f}x"
             if out["headline_speedup"] else "n/a")
    print(f"[serve2] HEADLINE continuous batching vs per-request: "
          f"{(l or 0):.0f} vs {(s or 0):.0f} rows/s sustained at "
          f"p99<={SLO_MS}ms -> {ratio}", flush=True)
    return out


def bench_hot_swap(n, nnz, max_batch, duration_s, n_swaps, seed=0):
    """Steady traffic + live best-c swaps: zero recompiles, zero SLO
    violations, responses spanning every installed version."""
    prod = make_family(n, nnz, 1, seed, kind="binary")
    loop = ServeLoop({"prod": prod}, max_batch=max_batch,
                     default_budget_s=BUDGET_FRAC * SLO_MS / 1e3,
                     max_queue=16 * max_batch, route="auto")
    rng = np.random.default_rng(seed + 2)
    X = rng.standard_normal((256, n)).astype(np.float32)
    # the single warm call behind _capacity_rps is optimistic about
    # capacity under a competing generator thread (one core, GIL
    # timesharing): calibrate the swap-run rate against MEASURED p99 so
    # the run sits comfortably inside capacity — swap attribution is
    # meaningless on top of ambient congestion
    rate = 0.25 * _capacity_rps(loop)
    for attempt in range(4):
        with _quiesce_gc():
            cal = drive_poisson(loop, X, rate_rps=rate,
                                n_requests=int(np.clip(rate, 200, 2000)),
                                model="prod",
                                budget_s=BUDGET_FRAC * SLO_MS / 1e3,
                                seed=seed + 99 + attempt, timeout_s=120.0)
        cal.pop("results")
        # deadline flushing floors e2e latency near the request budget
        # (0.6 * SLO) at ANY rate — "comfortable" means p99 holds 10%
        # headroom under the SLO, not some fraction of the budget floor
        calibrated = (cal["p99_s"] is not None
                      and cal["p99_s"] <= 0.9 * SLO_MS / 1e3
                      and cal["rejects"] == 0)
        print(f"[hot_swap] calibrate {rate:.0f} rps: p99 "
              f"{1e3 * (cal['p99_s'] or 0):.2f}ms rejects "
              f"{cal['rejects']} -> {'ok' if calibrated else 'halve'}",
              flush=True)
        if calibrated:
            break
        rate *= 0.5
    caches0 = scorer_cache_sizes()
    slo = SLO_MS / 1e3
    # swap attribution is meaningless on top of ambient congestion: if
    # the BACKGROUND (non-swap) tail melts down mid-drive — host noise on
    # a shared box, not anything the swap did — halve the rate and redo
    # the whole swap drive rather than report polluted attribution
    for attempt in range(3):
        n_req = int(np.clip(rate * duration_s, 200, 20000))
        windows = []                         # (t_fire, t_installed) pairs
        tickets = []

        def _fire(delay, swap_seed):
            time.sleep(delay)
            fam = make_family(n, nnz, 4, swap_seed, kind="path")
            t_fire = time.perf_counter()
            tk = loop.swap(model=fam)          # best-c selected live
            tk.installed.wait(10.0)
            tickets.append(tk)
            windows.append((t_fire, time.perf_counter()))

        span = n_req / rate
        threads = [threading.Thread(
            target=_fire,
            args=((j + 1) * span / (n_swaps + 1), seed + 10 + j),
            daemon=True) for j in range(n_swaps)]
        for t in threads:
            t.start()
        with _quiesce_gc():
            drive = drive_poisson(loop, X, rate_rps=rate, n_requests=n_req,
                                  model="prod",
                                  budget_s=BUDGET_FRAC * SLO_MS / 1e3,
                                  seed=seed, timeout_s=120.0)
        for t in threads:
            t.join()
        results = drive.pop("results")
        slo_violations = sum(r.latency_s > slo for r in results)
        congested = (drive["rejects"] > 0
                     or slo_violations > 0.05 * max(len(results), 1))
        if not congested or attempt == 2:
            break
        print(f"[hot_swap] background congestion "
              f"({slo_violations}/{len(results)} late at {rate:.0f} rps) "
              f"-> halve and retry", flush=True)
        rate *= 0.5
    loop.stop()
    caches1 = scorer_cache_sizes()
    versions = sorted({r.version for r in results})
    # attribution: a violation is the swap's fault only if its response
    # completed inside a swap window (fire -> installed, + one SLO of
    # settling); tail spikes elsewhere are background scheduler noise,
    # reported separately as slo_violations
    in_window = [r for r in results
                 if any(t0 <= r.t_done <= t1 + slo for t0, t1 in windows)]
    swap_window_violations = sum(r.latency_s > slo for r in in_window)
    out = {"rate_rps": rate, "n_requests": n_req, "n_swaps": n_swaps,
           "slo_ms": SLO_MS,
           "installed_versions": sorted(t.version for t in tickets),
           "response_versions": versions,
           "recompiles": sum(caches1.values()) - sum(caches0.values()),
           "slo_violations": int(slo_violations),
           "swap_window_responses": len(in_window),
           "swap_window_violations": int(swap_window_violations),
           "rejects": drive["rejects"],
           "p99_s": drive["p99_s"]}
    print(f"[hot_swap] {n_swaps} swaps under {rate:.0f} rps: response "
          f"versions {versions}, recompiles={out['recompiles']}, "
          f"swap_window_violations={swap_window_violations} "
          f"(background {slo_violations} over {n_req}), "
          f"p99={1e3 * (drive['p99_s'] or 0):.2f}ms", flush=True)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small shapes / short drives (CI)")
    args = ap.parse_args(argv)

    # a single-core box timeshares the load generator and the scheduler
    # under the GIL; the default 5ms switch interval would add +-10ms of
    # pure thread-scheduling jitter to every latency sample
    sys.setswitchinterval(1e-3)

    if args.smoke:
        K, n, nnz = 4, 2048, 20
        max_batch, duration_s, n_clamp = 32, 0.6, (50, 2000)
        n_swaps = 1
    else:
        K, n, nnz = 16, 32768, 33           # 0.999 weight sparsity
        max_batch, duration_s, n_clamp = 256, 2.5, (200, 20000)
        n_swaps = 2

    payload = {
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "slo_ms": SLO_MS,
        "budget_frac": BUDGET_FRAC,
        "bank": {"K": K, "n": n, "nnz_per_model": nnz,
                 "sparsity": 1.0 - nnz / n, "max_batch": max_batch},
        **bench_loop_vs_sync(K, n, nnz, max_batch, duration_s, n_clamp),
        "hot_swap": bench_hot_swap(n, nnz, max_batch, duration_s, n_swaps),
    }

    os.makedirs(RESULTS_DIR, exist_ok=True)
    for path in (os.path.join(REPO_ROOT, "BENCH_serve2.json"),
                 os.path.join(RESULTS_DIR, "BENCH_serve2.json")):
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=1, default=float)
    print("wrote BENCH_serve2.json")
    return payload


if __name__ == "__main__":
    main()
