"""Dense vs padded-CSC bundle-step benchmark -> BENCH_sparse.json.

    PYTHONPATH=src python benchmarks/bench_sparse.py [--quick] [--no-big]

Per sparsity level (0.9 / 0.99 / 0.999) on the same synthetic problem:

  * bundle-step wall time for both backends (one jitted outer iteration
    = b bundle steps, timed after warm-up, divided by b)
  * memory: design-matrix resident bytes + per-bundle transient slab
    bytes (the two quantities the backend choice actually changes)
  * objective-trajectory max relative deviation dense vs sparse over a
    short PCDN run (equivalence evidence at bench scale)

Plus the "big" certificate: a 99.9%-sparse 20k x 50k problem (nnz/col
<= 64) generated directly in padded-CSC — the dense (s, n) form would be
~4 GB and is never materialized — solved for a few outer iterations via
`pcdn.solve`. Writes BENCH_sparse.json at the repo root and a copy under
benchmarks/results/.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

import jax

from repro.core import PCDNConfig, make_problem, solve
from repro.core.pcdn import make_outer_iteration
from repro.data import make_classification, make_sparse_classification

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results")


def _time_outer(problem, cfg, n_timed=5):
    """Median seconds per *bundle step* of the jitted outer iteration."""
    import jax.numpy as jnp
    n = problem.n_features
    b = -(-n // cfg.P)
    w = jnp.zeros((n,), problem.dtype)
    z = problem.margins(w)
    key = jax.random.PRNGKey(0)
    outer = make_outer_iteration(problem, cfg)
    out = outer(w, z, key)                      # compile + warm-up
    jax.block_until_ready(out)
    times = []
    for _ in range(n_timed):
        t0 = time.perf_counter()
        out = outer(w, z, key)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) / b


def _design_bytes(problem):
    d = problem.design
    if hasattr(d, "col_rows"):
        return int(d.col_rows.nbytes + d.col_vals.nbytes)
    return int(d.X.nbytes)


def _slab_bytes(problem, P):
    d = problem.design
    if hasattr(d, "col_rows"):
        return int(P * d.k_max * (4 + d.col_vals.dtype.itemsize))
    return int(problem.n_samples * P * d.X.dtype.itemsize)


def bench_level(s, n, sparsity, P, n_outer_traj=6, seed=0):
    X, y, _ = make_classification(s, n, sparsity=sparsity, corr=0.2,
                                  seed=seed)
    dense = make_problem(X, y, c=1.0)
    sparse = make_problem(X, y, c=1.0, layout="padded_csc")
    cfg = PCDNConfig(P=P, max_outer=n_outer_traj, seed=1)

    t_dense = _time_outer(dense, cfg)
    t_sparse = _time_outer(sparse, cfg)

    rd = solve(dense, cfg)
    rs = solve(sparse, cfg)
    traj_rel = float(np.max(
        np.abs(rd.history.objective - rs.history.objective) /
        np.abs(rd.history.objective)))

    row = {
        "s": s, "n": n, "P": P, "sparsity": sparsity,
        "k_max": int(sparse.design.k_max),
        "bundle_step_seconds": {"dense": t_dense, "padded_csc": t_sparse},
        "speedup": t_dense / t_sparse,
        "design_bytes": {"dense": _design_bytes(dense),
                         "padded_csc": _design_bytes(sparse)},
        "slab_bytes_per_bundle": {"dense": _slab_bytes(dense, P),
                                  "padded_csc": _slab_bytes(sparse, P)},
        "objective_traj_max_rel_diff": traj_rel,
    }
    print(f"sparsity={sparsity}: dense {t_dense*1e3:.2f} ms/bundle, "
          f"padded_csc {t_sparse*1e3:.2f} ms/bundle "
          f"({row['speedup']:.1f}x), k_max={row['k_max']}, "
          f"traj_rel={traj_rel:.2e}", flush=True)
    return row


def bench_big(s=20_000, n=50_000, nnz_per_col=64, P=512, max_outer=3):
    """Sparse-only certificate: dense form (~s*n*4 B) never materialized."""
    pcsc, y, _ = make_sparse_classification(s, n, nnz_per_col=nnz_per_col,
                                            seed=7)
    prob = make_problem(pcsc, y, c=1.0)
    cfg = PCDNConfig(P=P, max_outer=max_outer, seed=0)
    t0 = time.perf_counter()
    res = solve(prob, cfg)
    wall = time.perf_counter() - t0
    row = {
        "s": s, "n": n, "nnz_per_col_max": nnz_per_col, "P": P,
        "k_max": int(prob.design.k_max),
        "design_bytes_padded_csc": _design_bytes(prob),
        "design_bytes_dense_equivalent": int(s) * int(n) * 4,
        "n_outer": int(res.n_outer),
        "objective_start": float(res.history.objective[0]),
        "objective_end": float(res.objective),
        "monotone_decrease": bool(np.all(np.diff(res.history.objective)
                                         <= 1e-6)),
        "wall_seconds": wall,
    }
    print(f"big sparse {s}x{n}: {row['design_bytes_padded_csc']/2**20:.0f} "
          f"MiB sparse vs {row['design_bytes_dense_equivalent']/2**30:.1f} "
          f"GiB dense-equivalent, F {row['objective_start']:.1f} -> "
          f"{row['objective_end']:.1f} in {wall:.1f}s", flush=True)
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller shapes (CI smoke)")
    ap.add_argument("--no-big", action="store_true",
                    help="skip the 20k x 50k sparse-only run")
    args = ap.parse_args(argv)

    if args.quick:
        s, n, P = 1024, 2048, 128
    else:
        s, n, P = 4096, 8192, 256

    payload = {
        "backend": jax.default_backend(),
        "shapes": {"s": s, "n": n, "P": P},
        "levels": [bench_level(s, n, sp, P) for sp in (0.9, 0.99, 0.999)],
    }
    if not args.no_big:
        payload["big_sparse_only"] = bench_big(
            **({"s": 4000, "n": 10_000, "P": 256} if args.quick else {}))

    os.makedirs(RESULTS_DIR, exist_ok=True)
    for path in (os.path.join(REPO_ROOT, "BENCH_sparse.json"),
                 os.path.join(RESULTS_DIR, "BENCH_sparse.json")):
        with open(path, "w") as fh:
            json.dump(payload, fh, indent=1, default=float)
    print("wrote BENCH_sparse.json")
    return payload


if __name__ == "__main__":
    main()
