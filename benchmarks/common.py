"""Shared benchmark utilities: datasets, timing, CSV emission."""
from __future__ import annotations

import json
import os
import time
from typing import Dict

import numpy as np

from repro.core import PCDNConfig, cdn_config, make_problem, solve
from repro.data import paper_like

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
os.makedirs(RESULTS_DIR, exist_ok=True)


def emit(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.1f},{derived}"
    print(line, flush=True)
    return line


def save_json(name: str, payload: Dict) -> None:
    with open(os.path.join(RESULTS_DIR, f"{name}.json"), "w") as fh:
        json.dump(payload, fh, indent=1, default=float)


def dataset(name: str, seed: int = 0, with_test: bool = False):
    return paper_like(name, seed=seed, with_test=with_test)


def f_star_for(problem, seed: int = 0) -> float:
    """Tight optimum via long PCDN run (paper uses CDN at eps=1e-8)."""
    res = solve(problem, PCDNConfig(P=min(problem.n_features, 512),
                                    max_outer=400, tol_kkt=1e-6, seed=seed))
    return res.objective


def time_to_accuracy(problem, cfg: PCDNConfig, f_star: float,
                     eps: float, max_outer: int = 300):
    """-> (seconds, outer_iters, converged)."""
    import dataclasses
    cfg2 = dataclasses.replace(cfg, max_outer=max_outer, tol_kkt=0.0,
                               tol_rel_obj=eps)
    t0 = time.perf_counter()
    res = solve(problem, cfg2, f_star=f_star)
    return time.perf_counter() - t0, res.n_outer, res.converged
