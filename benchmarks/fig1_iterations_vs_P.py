"""Figure 1: E[lambda_bar(B)]/P and iteration count T_eps vs bundle size P.

Verifies Eq. 19: T_eps is positively correlated with E[lambda_bar]/P and
decreases with P, on a9a-like and real-sim-like profiles (eps = 1e-3, as
in the paper)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import dataset, emit, f_star_for, save_json
from repro.core import PCDNConfig, make_problem, solve
from repro.core.problem import expected_max_of_sample


def run(quick: bool = True):
    # T_eps counts INNER (bundle) iterations t — the unit of Theorem 3 —
    # i.e. n_outer * ceil(n / P).
    eps = 1e-4
    out = {}
    t_all = time.perf_counter()
    for ds_name in ("a9a", "real-sim"):
        X, y, spec = dataset(ds_name)
        prob = make_problem(X, y, c=spec.c_logistic)
        lam = np.sort(np.asarray(prob.column_norms_sq(), np.float64))
        n = prob.n_features
        f_star = f_star_for(prob)
        Ps = sorted({1, max(n // 64, 2), max(n // 16, 4), max(n // 4, 8), n})
        rows = []
        for P in Ps:
            elam_over_P = expected_max_of_sample(lam, P) / P
            res = solve(prob, PCDNConfig(P=P, max_outer=300, tol_kkt=0.0,
                                         tol_rel_obj=eps), f_star=f_star)
            T_inner = res.n_outer * (-(-n // P))
            rows.append({"P": P, "elam_over_P": elam_over_P,
                         "T_eps": T_inner, "outer": res.n_outer,
                         "converged": res.converged})
        out[ds_name] = rows
        T = [r["T_eps"] for r in rows]
        el = [r["elam_over_P"] for r in rows]
        mono = all(b <= a for a, b in zip(T, T[1:]))
        corr = float(np.corrcoef(np.log(T), np.log(el))[0, 1])
        emit(f"fig1/{ds_name}", 1e6 * (time.perf_counter() - t_all),
             f"T_eps {T[0]}->{T[-1]} decreasing={mono} "
             f"corr(log T, log E[lam]/P)={corr:.3f}")
    save_json("fig1_iterations_vs_P", out)
    return out


if __name__ == "__main__":
    run()
