"""Figure 2: training time vs bundle size P (real-sim, both losses),
locating the optimal P*. Also exercises Eq. 20's trade-off: larger P =>
fewer outer iterations but more line-search steps per iteration."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import dataset, emit, f_star_for, save_json
from repro.core import PCDNConfig, make_problem, solve


def run(quick: bool = True):
    X, y, spec = dataset("real-sim")
    out = {}
    for loss, c in (("logistic", spec.c_logistic),
                    ("squared_hinge", spec.c_svm)):
        prob = make_problem(X, y, c=c, loss=loss)
        f_star = f_star_for(prob)
        n = prob.n_features
        Ps = sorted({8, 64, 256, 1024, n})
        rows = []
        for P in Ps:
            t0 = time.perf_counter()
            res = solve(prob, PCDNConfig(P=P, max_outer=200, tol_kkt=0.0,
                                         tol_rel_obj=1e-3), f_star=f_star)
            dt = time.perf_counter() - t0
            rows.append({"P": P, "seconds": dt, "outer": res.n_outer,
                         "mean_ls_steps": float(res.history.ls_steps.mean()),
                         "converged": res.converged})
        best = min(rows, key=lambda r: r["seconds"])
        out[loss] = {"rows": rows, "P_star": best["P"]}
        emit(f"fig2/real-sim/{loss}", best["seconds"] * 1e6,
             f"P*={best['P']} t={best['seconds']:.2f}s")
    save_json("fig2_time_vs_P", out)
    return out


if __name__ == "__main__":
    run()
