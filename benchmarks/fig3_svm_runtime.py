"""Figure 3: L2-loss-SVM runtime comparison — PCDN vs CDN vs TRON across
dataset profiles and stopping accuracies (markers-above-diagonal plot in
the paper; we report the runtime ratios)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import dataset, emit, f_star_for, save_json
from repro.core import PCDNConfig, cdn_config, make_problem, solve, tron


def run(quick: bool = True):
    out = {}
    epss = [1e-2, 1e-3] if quick else [1e-2, 1e-3, 1e-4]
    for ds_name in ("a9a", "real-sim", "news20"):
        X, y, spec = dataset(ds_name)
        if quick and ds_name == "news20":
            # CDN at P=1 over 16k features is minutes/outer on 1 CPU core;
            # quick mode trims the feature count (profile is preserved)
            X = X[:, :4096]
        prob = make_problem(X, y, c=spec.c_svm, loss="squared_hinge")
        f_star = f_star_for(prob)
        n = prob.n_features
        P = max(min(n // 4, 512), 8)
        rows = []
        for eps in epss:
            def timed(make_res):
                t0 = time.perf_counter()
                r = make_res()
                return time.perf_counter() - t0, r

            t_pcdn, _ = timed(lambda: solve(
                prob, PCDNConfig(P=P, max_outer=300, tol_kkt=0.0,
                                 tol_rel_obj=eps), f_star=f_star))
            t_cdn, _ = timed(lambda: solve(
                prob, cdn_config(max_outer=300, tol_kkt=0.0,
                                 tol_rel_obj=eps), f_star=f_star))
            t_tron, _ = timed(lambda: tron.solve(
                prob, tron.TRONConfig(max_outer=200, tol_kkt=eps)))
            rows.append({"eps": eps, "pcdn_s": t_pcdn, "cdn_s": t_cdn,
                         "tron_s": t_tron})
        out[ds_name] = rows
        last = rows[-1]
        emit(f"fig3/{ds_name}", last["pcdn_s"] * 1e6,
             f"speedup_vs_cdn={last['cdn_s'] / last['pcdn_s']:.2f} "
             f"vs_tron={last['tron_s'] / last['pcdn_s']:.2f}")
    save_json("fig3_svm_runtime", out)
    return out


if __name__ == "__main__":
    run()
