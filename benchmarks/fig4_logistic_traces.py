"""Figure 4 / Figure 7: logistic-regression timing traces — relative
function-value difference, test accuracy and model NNZ vs wall time for
PCDN vs SCDN vs CDN. Reproduces the qualitative claims: PCDN fastest;
SCDN slower than CDN on gisette (correlated features); SCDN divergence
risk at higher P_bar."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.core import PCDNConfig, cdn_config, make_problem, scdn, solve
from repro.core.scdn import SCDNConfig
from repro.data import paper_like
from repro.data.synthetic import train_accuracy


def run(quick: bool = True):
    out = {}
    for ds_name in ("a9a", "real-sim", "gisette"):
        Xtr, ytr, Xte, yte, spec = paper_like(ds_name, with_test=True)
        prob = make_problem(Xtr, ytr, c=spec.c_logistic)
        f_star = solve(prob, PCDNConfig(P=min(prob.n_features, 512),
                                        max_outer=400,
                                        tol_kkt=1e-6)).objective
        n = prob.n_features
        P = max(min(n // 8, 1024), 8)
        entry = {}

        mo = 80 if quick else 150
        rel = 1e-4 if quick else 1e-5
        res_p = solve(prob, PCDNConfig(P=P, max_outer=mo, tol_kkt=0.0,
                                       tol_rel_obj=rel), f_star=f_star)
        entry["pcdn"] = {
            "P": P,
            "time": res_p.history.wall_time.tolist(),
            "rel_f": ((res_p.history.objective - f_star) /
                      abs(f_star)).tolist(),
            "nnz": res_p.history.nnz.tolist(),
            "test_acc": train_accuracy(Xte, yte, np.asarray(res_p.w)),
        }
        res_c = solve(prob, cdn_config(max_outer=mo, tol_kkt=0.0,
                                       tol_rel_obj=rel), f_star=f_star)
        entry["cdn"] = {
            "time": res_c.history.wall_time.tolist(),
            "rel_f": ((res_c.history.objective - f_star) /
                      abs(f_star)).tolist(),
            "test_acc": train_accuracy(Xte, yte, np.asarray(res_c.w)),
        }
        res_s = scdn.solve(prob, SCDNConfig(P_bar=8, max_rounds=mo,
                                            tol_kkt=1e-4 if quick else 1e-5))
        entry["scdn"] = {
            "P_bar": 8,
            "time": res_s.history["wall_time"].tolist(),
            "rel_f": ((res_s.history["objective"] - f_star) /
                      abs(f_star)).tolist(),
            "diverged": bool(res_s.diverged),
            "test_acc": train_accuracy(Xte, yte, np.asarray(res_s.w)),
        }
        out[ds_name] = entry
        speedup = (res_c.history.wall_time[-1] /
                   max(res_p.history.wall_time[-1], 1e-9))
        emit(f"fig4/{ds_name}", res_p.history.wall_time[-1] * 1e6,
             f"pcdn_acc={entry['pcdn']['test_acc']:.3f} "
             f"speedup_vs_cdn={speedup:.2f} "
             f"scdn_diverged={res_s.diverged}")
    save_json("fig4_logistic_traces", out)
    return out


if __name__ == "__main__":
    run()
