"""Figure 5: PCDN speedup (vs CDN) as a function of data size, with
sample duplication so feature correlation is exactly preserved
(section 5.4.1)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.core import PCDNConfig, cdn_config, make_problem, solve
from repro.data import paper_like
from repro.data.synthetic import duplicate_samples


def run(quick: bool = True):
    X0, y0, spec = paper_like("a9a")
    factors = [1.0, 2.0, 4.0] if quick else [1.0, 2.0, 4.0, 8.0, 16.0]
    rows = []
    for f in factors:
        X, y = duplicate_samples(X0, y0, f)
        prob = make_problem(X, y, c=spec.c_logistic)
        f_star = solve(prob, PCDNConfig(P=prob.n_features, max_outer=300,
                                        tol_kkt=1e-6)).objective

        def timed(cfg):
            t0 = time.perf_counter()
            solve(prob, cfg, f_star=f_star)
            return time.perf_counter() - t0

        t_p = timed(PCDNConfig(P=prob.n_features // 2, max_outer=200,
                               tol_kkt=0.0, tol_rel_obj=1e-3))
        t_c = timed(cdn_config(max_outer=200, tol_kkt=0.0,
                               tol_rel_obj=1e-3))
        rows.append({"factor": f, "samples": X.shape[0],
                     "pcdn_s": t_p, "cdn_s": t_c,
                     "speedup": t_c / max(t_p, 1e-9)})
    sp = [r["speedup"] for r in rows]
    # paper: speedup approximately constant in data size
    spread = (max(sp) - min(sp)) / max(np.mean(sp), 1e-9)
    emit("fig5/a9a", rows[-1]["pcdn_s"] * 1e6,
         f"speedups={['%.2f' % s for s in sp]} rel_spread={spread:.2f}")
    save_json("fig5_datasize_scaling", {"rows": rows})
    return rows


if __name__ == "__main__":
    run()
