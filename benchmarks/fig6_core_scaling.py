"""Figure 6: runtime vs core count.

This container exposes ONE physical core, so hardware core-scaling cannot
be measured directly. We reproduce the figure's content in two honest
parts:
  1. measured: per-outer-iteration work decomposition (parallelizable
     direction+linesearch flops vs serial bookkeeping) from the solver's
     own op counts on real runs;
  2. modeled: Amdahl projection runtime(cores) from that decomposition,
     reported alongside the paper's observed saturation behaviour.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.core import PCDNConfig, make_problem, solve
from repro.data import paper_like


def run(quick: bool = True):
    X, y, spec = paper_like("real-sim")
    prob = make_problem(X, y, c=spec.c_logistic)
    s, n = prob.X.shape
    P = 512
    res = solve(prob, PCDNConfig(P=P, max_outer=5))
    mean_q = float(res.history.ls_steps.mean())

    # per-bundle flop decomposition (dense adaptation, DESIGN.md section 3):
    parallel_flops = (
        4.0 * s * P           # grad+hess tall-skinny matvecs over the slab
        + 2.0 * s * P         # Xd
        + mean_q * 2.0 * s    # per-candidate objective deltas
    )
    serial_flops = 6.0 * P + 4.0 * s   # direction epilogue + z update
    frac_parallel = parallel_flops / (parallel_flops + serial_flops)

    cores = [1, 2, 4, 8, 16, 23, 24]
    t1 = res.history.wall_time[-1] / max(res.n_outer, 1)
    rows = [{"cores": c,
             "modeled_time_per_outer":
                 t1 * ((1 - frac_parallel) + frac_parallel / c)}
            for c in cores]
    sat = rows[-1]["modeled_time_per_outer"] / rows[0][
        "modeled_time_per_outer"]
    emit("fig6/real-sim", t1 * 1e6,
         f"parallel_frac={frac_parallel:.4f} "
         f"t24/t1={sat:.3f} (saturating, matches paper Fig. 6 shape)")
    save_json("fig6_core_scaling", {
        "measured_time_per_outer_1core": t1,
        "parallel_fraction": frac_parallel,
        "mean_linesearch_steps": mean_q,
        "rows": rows,
        "note": "container has 1 physical core; scaling is an Amdahl "
                "projection from the measured work decomposition",
    })
    return rows


if __name__ == "__main__":
    run()
