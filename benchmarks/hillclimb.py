"""Perf hillclimb driver (EXPERIMENTS.md section Perf).

Two cells, both about the paper's own solver:

  kernel (default) — greedy coordinate hillclimb of the fused bundle
     kernel's launch config (kernels/autotune.tune strategy="hillclimb"):
     start from the hard-coded default launch, improve one axis at a
     time (block_q tiling of the Armijo candidate grid, the impl axis),
     log every accepted step. The climb trajectory IS the deliverable:
     it shows which axis bought what on this backend, and the winner is
     persisted into the autotune cache so every later solve picks it up.
  ladder — the collective-schedule ladder of the sharded solver:
     faithful sequential Armijo + unfused psums -> fused psums ->
     batched candidates (single psum), with kernel-fusion memory
     accounting. (The historical cells A/B — transformer dry-run
     experiments from the seed scaffold, unrelated to this paper's
     solver — were retired; their archived results remain under
     results/hillclimb/.)

Usage: PYTHONPATH=src python -m benchmarks.hillclimb [--cell kernel|ladder|all]
Writes benchmarks/results/hillclimb/<name>.json.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import json

RESULTS = os.path.join(os.path.dirname(__file__), "results", "hillclimb")


def save(name, payload):
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, f"{name}.json"), "w") as fh:
        json.dump(payload, fh, indent=1, default=float)
    r = payload.get("roofline", {})
    if r:
        print(f"  {name}: comp={r['t_compute_s']:.3f} mem={r['t_memory_s']:.3f} "
              f"coll={r['t_collective_s']:.3f} useful={r['useful_flops_ratio']:.3f}",
              flush=True)


def cell_kernel(smoke: bool = False):
    """Autotune hillclimb on the fused bundle kernel (+ its sparse
    direction sibling): the measured counterpart of bench_kernels'
    exhaustive sweep, logging the greedy trajectory step by step."""
    import numpy as np
    from benchmarks import bench_kernels as bk
    from repro.kernels import autotune

    cells = [c for c in (bk.SMOKE_CELLS if smoke else bk.CELLS)
             if c[0] in ("pcdn_bundle", "pcdn_sparse_direction")]
    import jax.numpy as jnp
    for kernel, shape, build in cells:
        print(f"[kernel] climbing {kernel} {shape}...", flush=True)
        runner, _, _ = build(jnp.float32)
        res = autotune.tune(kernel, runner, autotune.shape_bucket(**shape),
                            jnp.float32, strategy="hillclimb",
                            repeats=2 if smoke else 5, persist=not smoke)
        for i, step in enumerate(res.trajectory):
            print(f"  step {i}: {step['config']} -> {step['us']:.0f}us",
                  flush=True)
        shape_tag = "_".join(f"{k}{v}" for k, v in sorted(shape.items()))
        save(f"kernel_{kernel}_{shape_tag}", {
            "kernel": kernel, "shape": shape,
            "default_us": res.default_us, "tuned_us": res.us,
            "speedup": res.speedup,
            "trajectory": list(res.trajectory),
            "n_measured": len(res.table),
        })
        print(f"  {kernel}: default={res.default_us:.0f}us "
              f"tuned={res.us:.0f}us x{res.speedup:.2f}", flush=True)


def cell_ladder():
    """pcdn collective-schedule ladder (sharded solver)."""
    from repro.launch.dryrun import lower_solver_cell
    ladder = [
        ("baseline_faithful", dict(ls_kind="backtracking", fuse=False)),
        ("fused_psums", dict(ls_kind="backtracking", fuse=True)),
        ("batched_linesearch", dict(ls_kind="batched", fuse=True)),
    ]
    for name, kw in ladder:
        print(f"[ladder] pcdn {name}...", flush=True)
        res = lower_solver_cell(**kw)
        save(f"C_pcdn_{name}", res)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="kernel",
                    choices=["kernel", "ladder", "all"])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes, no cache writes")
    args = ap.parse_args()
    if args.cell in ("kernel", "all"):
        cell_kernel(smoke=args.smoke)
    if args.cell in ("ladder", "all"):
        cell_ladder()


if __name__ == "__main__":
    main()
