"""Perf hillclimb driver (EXPERIMENTS.md section Perf).

Three cells selected from the baseline roofline table:
  A. qwen1.5-32b x prefill_32k  — worst useful-flops fraction (0.07):
     40 heads don't divide the 16-wide model axis -> 16x-replicated
     attention. Change: zero-initialized head padding 40->48 (output-exact).
  B. grok-1-314b x train_4k     — most collective-bound cell (largest
     absolute collective term). Changes: expert-sharding rule fix,
     dispatch-buffer dtype, capacity factor.
  C. pcdn solver (the paper's own technique) — collective-schedule ladder:
     faithful sequential Armijo + unfused psums -> fused psums -> batched
     candidates (single psum), plus the kernel-fusion memory accounting.

Usage: PYTHONPATH=src python -m benchmarks.hillclimb [--cell A|B|C]
Writes benchmarks/results/hillclimb/<name>.json.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import json

RESULTS = os.path.join(os.path.dirname(__file__), "results", "hillclimb")


def save(name, payload):
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, f"{name}.json"), "w") as fh:
        json.dump(payload, fh, indent=1, default=float)
    r = payload.get("roofline", {})
    if r:
        print(f"  {name}: comp={r['t_compute_s']:.3f} mem={r['t_memory_s']:.3f} "
              f"coll={r['t_collective_s']:.3f} useful={r['useful_flops_ratio']:.3f}",
              flush=True)


def cell_a():
    """qwen1.5-32b head padding."""
    from repro.launch import dryrun
    import repro.configs.qwen1_5_32b as q
    base = q.CONFIG
    for cell in ("prefill_32k", "train_4k"):
        print(f"[A] qwen1.5-32b {cell} baseline...", flush=True)
        res = dryrun.lower_cell("qwen1.5-32b", cell, False)
        save(f"A_qwen15_{cell}_baseline", res)
        print(f"[A] qwen1.5-32b {cell} pad_heads=48...", flush=True)
        q.CONFIG = base.replace(pad_heads=48, pad_kv_heads=48)
        try:
            res = dryrun.lower_cell("qwen1.5-32b", cell, False)
            res["variant"] = "pad_heads=48"
            save(f"A_qwen15_{cell}_padded", res)
            print(f"[A] qwen1.5-32b {cell} padded + fused_qkv...",
                  flush=True)
            q.CONFIG = base.replace(pad_heads=48, pad_kv_heads=48,
                                    fused_qkv=True)
            res = dryrun.lower_cell("qwen1.5-32b", cell, False)
            res["variant"] = "pad_heads=48 + fused_qkv"
            save(f"A_qwen15_{cell}_padded_fused", res)
        finally:
            q.CONFIG = base


def cell_b():
    """grok-1-314b train_4k: capacity-factor iteration on top of the
    expert-sharding fix (the fix itself is measured against the archived
    pre-fix run: flops 1.306e19 -> see baseline)."""
    from repro.launch import dryrun
    import repro.configs.grok_1_314b as g
    import dataclasses
    base = g.CONFIG
    print("[B] grok train_4k baseline (post expert-fix)...", flush=True)
    res = dryrun.lower_cell("grok-1-314b", "train_4k", False)
    save("B_grok_train_baseline", res)
    print("[B] grok train_4k capacity_factor=1.0...", flush=True)
    g.CONFIG = base.replace(moe=dataclasses.replace(base.moe,
                                                    capacity_factor=1.0))
    try:
        res = dryrun.lower_cell("grok-1-314b", "train_4k", False)
        res["variant"] = "capacity_factor=1.0"
        save("B_grok_train_cap10", res)
        print("[B] grok train_4k + fused_qkv...", flush=True)
        g.CONFIG = base.replace(
            moe=dataclasses.replace(base.moe, capacity_factor=1.0),
            fused_qkv=True)
        res = dryrun.lower_cell("grok-1-314b", "train_4k", False)
        res["variant"] = "capacity_factor=1.0 + fused_qkv"
        save("B_grok_train_cap10_fusedqkv", res)
    finally:
        g.CONFIG = base


def cell_c():
    """pcdn solver ladder."""
    from repro.launch.dryrun import lower_solver_cell
    ladder = [
        ("baseline_faithful", dict(ls_kind="backtracking", fuse=False)),
        ("fused_psums", dict(ls_kind="backtracking", fuse=True)),
        ("batched_linesearch", dict(ls_kind="batched", fuse=True)),
    ]
    for name, kw in ladder:
        print(f"[C] pcdn {name}...", flush=True)
        res = lower_solver_cell(**kw)
        save(f"C_pcdn_{name}", res)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all", choices=["A", "B", "C", "all"])
    args = ap.parse_args()
    if args.cell in ("A", "all"):
        cell_a()
    if args.cell in ("B", "all"):
        cell_b()
    if args.cell in ("C", "all"):
        cell_c()


if __name__ == "__main__":
    main()
