"""Roofline reports.

Two modes:

  kernel (default) — read BENCH_kernels.json (the committed headline
    artifact benchmarks/bench_kernels.py writes: per-kernel default/tuned
    timings, analytic flop/byte counts, measured peak calibration) and
    render the per-kernel roofline placement table to
    benchmarks/results/kernel_roofline.md — one row per kernel x shape x
    dtype cell: compute/memory terms against the MEASURED peaks, bound
    classification, attained fraction of the roofline bound, and the
    tuned-over-default speedup.
  --legacy — the original aggregation of the dry-run JSONs into the
    EXPERIMENTS.md section-Roofline table (per arch x shape x mesh).

`calibrate_peaks()` re-exports the measurement helpers so tests and
other drivers can calibrate without importing the whole benchmark.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, save_json

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")
HBM_PER_CHIP = 16e9  # v5e-class
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ---------------------------------------------------------------------------
# kernel mode


def calibrate_peaks(smoke: bool = False) -> dict:
    """Measured (FLOP/s, bytes/s) peaks of this backend — the roofline
    axes bench_kernels places cells against."""
    from benchmarks.bench_kernels import (calibrate_peak_bandwidth,
                                          calibrate_peak_flops)
    return {"flops_per_s": calibrate_peak_flops(256 if smoke else 1024),
            "bytes_per_s": calibrate_peak_bandwidth(8 if smoke else 64)}


def load_bench_kernels(path: str | None = None) -> dict:
    """The committed headline artifact (repo root), falling back to the
    results-dir copy and the CI smoke artifact."""
    candidates = [path] if path else [
        os.path.join(REPO_ROOT, "BENCH_kernels.json"),
        os.path.join(RESULTS_DIR, "BENCH_kernels.json"),
        os.path.join(RESULTS_DIR, "BENCH_kernels_smoke.json"),
    ]
    for p in candidates:
        if p and os.path.exists(p):
            with open(p) as fh:
                return json.load(fh)
    raise FileNotFoundError(
        "no BENCH_kernels.json found — run "
        "`PYTHONPATH=src python -m benchmarks.bench_kernels` first")


def kernel_table(bench: dict) -> str:
    lines = [
        "| kernel | shape | dtype | default (us) | tuned (us) | speedup | "
        "F/B | bound | roof (us) | attained |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in bench["cells"]:
        r = c["roofline"]
        shape = " ".join(f"{k}={v}" for k, v in sorted(c["shape"].items()))
        lines.append(
            f"| {c['kernel']} | {shape} | {c['dtype']} | "
            f"{c['default']['us']:.0f} | {c['tuned']['us']:.0f} | "
            f"x{c['speedup']:.2f} | "
            f"{r['intensity_flops_per_byte']:.2f} | {r['bound']} | "
            f"{r['roofline_us']:.1f} | {r['attained_frac']:.3f} |")
    return "\n".join(lines)


def run_kernel(path: str | None = None) -> str:
    bench = load_bench_kernels(path)
    md = [f"## Kernel roofline placement "
          f"(backend {bench['meta']['backend']}, "
          f"{bench['peaks']['flops_gflops']:.1f} GFLOP/s, "
          f"{bench['peaks']['bandwidth_gbps']:.1f} GB/s measured)",
          "",
          kernel_table(bench), ""]
    study = bench.get("bf16_study")
    if study:
        md += [f"bf16 equivalence study ({study['dataset']}, "
               f"{study['max_outer']} matched outers): max objective "
               f"rel-diff {study['max_objective_rel_diff']:.2e} "
               f"(envelope {study['envelope_rel_diff']:.0e}, "
               f"{'PASS' if study['pass'] else 'FAIL'})", ""]
    head = bench.get("headline", {})
    if head:
        md += [f"headline: best tuned-over-default "
               f"x{head['best_speedup']:.2f}; every cell tuned <= "
               f"default: {head['all_tuned_at_least_default']}", ""]
    text = "\n".join(md)
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out = os.path.join(RESULTS_DIR, "kernel_roofline.md")
    with open(out, "w") as fh:
        fh.write(text)
    emit("roofline/kernel_cells", 0.0,
         f"{len(bench['cells'])} cells -> {out}")
    return text


# ---------------------------------------------------------------------------
# legacy dry-run mode


def load_all():
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as fh:
            rows.append(json.load(fh))
    return rows


def markdown_table(rows, multi_pod=False):
    lines = [
        "| arch | cell | comp (s) | mem (s) | coll (s) | bottleneck | "
        "useful | MFU bound | bytes/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("multi_pod") != multi_pod:
            continue
        if r["status"] == "SKIP":
            lines.append(f"| {r['arch']} | {r['cell']} | — | — | — | "
                         f"SKIP | — | — | {r['reason'][:40]}… |")
            continue
        if r["status"] != "OK":
            lines.append(f"| {r['arch']} | {r['cell']} | — | — | — | "
                         f"FAIL | — | — | — |")
            continue
        rf = r["roofline"]
        mem_dev = r["memory"]["bytes_per_device"]
        fit = "✓" if mem_dev <= HBM_PER_CHIP else "✗"
        lines.append(
            f"| {r['arch']} | {r['cell']} | {rf['t_compute_s']:.3f} | "
            f"{rf['t_memory_s']:.3f} | {rf['t_collective_s']:.3f} | "
            f"{rf['bottleneck']} | {rf['useful_flops_ratio']:.2f} | "
            f"{rf['mfu_bound']:.2f} | {mem_dev / 1e9:.1f}GB {fit} |")
    return "\n".join(lines)


def run(quick: bool = True):
    rows = load_all()
    ok = [r for r in rows if r["status"] == "OK"]
    fail = [r for r in rows if r["status"] == "FAIL"]
    skip = [r for r in rows if r["status"] == "SKIP"]
    emit("roofline/cells", 0.0,
         f"ok={len(ok)} fail={len(fail)} skip={len(skip)}")
    by_bott = {}
    for r in ok:
        b = r["roofline"]["bottleneck"]
        by_bott[b] = by_bott.get(b, 0) + 1
    emit("roofline/bottlenecks", 0.0, str(by_bott))
    md = {"single_pod": markdown_table(rows, False),
          "multi_pod": markdown_table(rows, True)}
    save_json("roofline_summary", {
        "counts": {"ok": len(ok), "fail": len(fail), "skip": len(skip)},
        "bottlenecks": by_bott,
    })
    with open(os.path.join(os.path.dirname(__file__), "results",
                           "roofline_tables.md"), "w") as fh:
        fh.write("## Single-pod (16x16 = 256 chips)\n\n")
        fh.write(md["single_pod"])
        fh.write("\n\n## Multi-pod (2x16x16 = 512 chips)\n\n")
        fh.write(md["multi_pod"])
        fh.write("\n")
    return md


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--legacy", action="store_true",
                    help="the original dry-run aggregation tables")
    ap.add_argument("--bench", default=None,
                    help="explicit BENCH_kernels.json path")
    args = ap.parse_args()
    if args.legacy:
        run()
    else:
        print(run_kernel(args.bench))
