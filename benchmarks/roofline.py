"""Roofline report: aggregates the dry-run JSONs into the EXPERIMENTS.md
section-Roofline table (per arch x shape x mesh: three terms, bottleneck,
useful-flops ratio, memory fit)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, save_json

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")
HBM_PER_CHIP = 16e9  # v5e-class


def load_all():
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as fh:
            rows.append(json.load(fh))
    return rows


def markdown_table(rows, multi_pod=False):
    lines = [
        "| arch | cell | comp (s) | mem (s) | coll (s) | bottleneck | "
        "useful | MFU bound | bytes/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("multi_pod") != multi_pod:
            continue
        if r["status"] == "SKIP":
            lines.append(f"| {r['arch']} | {r['cell']} | — | — | — | "
                         f"SKIP | — | — | {r['reason'][:40]}… |")
            continue
        if r["status"] != "OK":
            lines.append(f"| {r['arch']} | {r['cell']} | — | — | — | "
                         f"FAIL | — | — | — |")
            continue
        rf = r["roofline"]
        mem_dev = r["memory"]["bytes_per_device"]
        fit = "✓" if mem_dev <= HBM_PER_CHIP else "✗"
        lines.append(
            f"| {r['arch']} | {r['cell']} | {rf['t_compute_s']:.3f} | "
            f"{rf['t_memory_s']:.3f} | {rf['t_collective_s']:.3f} | "
            f"{rf['bottleneck']} | {rf['useful_flops_ratio']:.2f} | "
            f"{rf['mfu_bound']:.2f} | {mem_dev / 1e9:.1f}GB {fit} |")
    return "\n".join(lines)


def run(quick: bool = True):
    rows = load_all()
    ok = [r for r in rows if r["status"] == "OK"]
    fail = [r for r in rows if r["status"] == "FAIL"]
    skip = [r for r in rows if r["status"] == "SKIP"]
    emit("roofline/cells", 0.0,
         f"ok={len(ok)} fail={len(fail)} skip={len(skip)}")
    by_bott = {}
    for r in ok:
        b = r["roofline"]["bottleneck"]
        by_bott[b] = by_bott.get(b, 0) + 1
    emit("roofline/bottlenecks", 0.0, str(by_bott))
    md = {"single_pod": markdown_table(rows, False),
          "multi_pod": markdown_table(rows, True)}
    save_json("roofline_summary", {
        "counts": {"ok": len(ok), "fail": len(fail), "skip": len(skip)},
        "bottlenecks": by_bott,
    })
    with open(os.path.join(os.path.dirname(__file__), "results",
                           "roofline_tables.md"), "w") as fh:
        fh.write("## Single-pod (16x16 = 256 chips)\n\n")
        fh.write(md["single_pod"])
        fh.write("\n\n## Multi-pod (2x16x16 = 512 chips)\n\n")
        fh.write(md["multi_pod"])
        fh.write("\n")
    return md


if __name__ == "__main__":
    run()
