"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--full]``

Prints ``name,us_per_call,derived`` CSV lines (per the repo convention)
and writes JSON artifacts under benchmarks/results/.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger eps grids / more datasets")
    ap.add_argument("--only", default=None,
                    help="comma-separated module suffixes to run")
    args = ap.parse_args(argv)
    quick = not args.full

    from benchmarks import (fig1_iterations_vs_P, fig2_time_vs_P,
                            fig3_svm_runtime, fig4_logistic_traces,
                            fig5_datasize_scaling, fig6_core_scaling,
                            roofline, table3_optimal_P)
    modules = [
        ("fig1", fig1_iterations_vs_P),
        ("fig2", fig2_time_vs_P),
        ("fig3", fig3_svm_runtime),
        ("fig4", fig4_logistic_traces),
        ("fig5", fig5_datasize_scaling),
        ("fig6", fig6_core_scaling),
        ("table3", table3_optimal_P),
        ("roofline", roofline),
    ]
    if args.only:
        keep = set(args.only.split(","))
        modules = [m for m in modules if m[0] in keep]

    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        t0 = time.time()
        try:
            mod.run(quick=quick)
            print(f"# {name} done in {time.time() - t0:.1f}s",
                  file=sys.stderr, flush=True)
        except Exception:
            failures += 1
            print(f"{name},0,ERROR")
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} benchmark module(s) failed")


if __name__ == "__main__":
    main()
