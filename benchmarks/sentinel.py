"""Perf-regression sentinel over the committed BENCH_*.json artifacts.

    PYTHONPATH=src python benchmarks/sentinel.py [--root DIR] [--strict]

Every benchmark writes a repo-root BENCH_*.json with a headline number
(speedup, overhead, agreement bool). Those artifacts are committed, so
the repo's performance story is versioned — but nothing ever *checked*
them. This gate does: each headline key is compared against a declared
floor (or ceiling), chosen well below the measured values so machine
variance does not flap the gate while a real regression (a speedup
collapsing toward 1x, an overhead blowing past its budget, a tuned
kernel losing to the default launch) fails CI loudly.

The sentinel also writes `benchmarks/results/BENCH_trajectory.json`
aggregating every committed artifact's headline numbers into one
record — the cross-PR performance trajectory in a single file.

Missing artifacts are reported and skipped (exit 0) unless `--strict`,
which CI uses for the artifacts the repo is expected to carry.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "results")

# (artifact, dotted key path, op, floor/ceiling, note)
# Floors sit ~2-3x below the committed measurements (see note) so the
# gate trips on regressions, not on machine variance.
CHECKS = [
    ("BENCH_serve.json", "speedup_at_ge_099", ">=", 2.0,
     "sparse scorer vs dense matmul at 0.999 sparsity (measured ~7.4x)"),
    ("BENCH_serve2.json", "headline_speedup", ">=", 2.0,
     "continuous batching vs per-request dispatch (measured ~6.5x)"),
    ("BENCH_obs.json", "solve.overhead_pct", "<=", 5.0,
     "telemetry-enabled solve overhead budget"),
    ("BENCH_obs.json", "batcher.overhead_pct", "<=", 5.0,
     "telemetry-enabled batcher overhead budget"),
    ("BENCH_kernels.json", "headline.all_tuned_at_least_default", "==",
     True, "autotuned launches must never lose to the defaults"),
    ("BENCH_kernels.json", "headline.best_speedup", ">=", 1.5,
     "best tuned-vs-default kernel speedup (measured ~5.3x)"),
    ("BENCH_bundle.json", "linesearch_speedup_at_0999", ">=", 2.0,
     "support-restricted line search at 0.999 sparsity (measured ~5.2x)"),
    ("BENCH_bundle.json", "bundle_step_speedup_at_0999", ">=", 1.5,
     "support-restricted bundle step at 0.999 sparsity (measured ~3.1x)"),
    ("BENCH_engine.json", "speedup_engine_vs_cold_solves", ">=", 2.0,
     "sharded warm+shrink sweep vs cold solves (measured ~4.9x)"),
    ("BENCH_path.json", "warm_vs_cold.speedup_engine_vs_cold_solves",
     ">=", 1.5, "warm-started shrinking sweep vs cold (measured ~3.8x)"),
    ("BENCH_diag.json", "attribution.overhead_pct", "<=", 5.0,
     "per-feature KKT attribution overhead budget"),
    ("BENCH_diag.json", "safep.agreement", "==", True,
     "power-iteration rho must agree with direct eigenvalues"),
    ("BENCH_fault.json", "checkpoint.overhead_pct", "<=", 5.0,
     "crash-safe checkpointing budget at --ckpt-every 10 (measured ~0%)"),
    ("BENCH_fault.json", "recovery.objective_rel_diff", "<=", 1e-6,
     "SIGKILL'd sweep resumed via --resume must match the uninterrupted "
     "run (measured exact)"),
]


def get_path(obj, dotted: str):
    cur = obj
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            raise KeyError(dotted)
        cur = cur[part]
    return cur


def check_one(value, op: str, bound):
    if op == ">=":
        return float(value) >= float(bound)
    if op == "<=":
        return float(value) <= float(bound)
    if op == "==":
        return value == bound
    raise ValueError(f"unknown op {op!r}")


def run(root: str, strict: bool = False, out_dir: str = RESULTS_DIR):
    """-> (exit_status, results list, trajectory dict)."""
    loaded: dict = {}
    results = []
    status = 0
    for fname, key, op, bound, note in CHECKS:
        path = os.path.join(root, fname)
        if fname not in loaded:
            if os.path.exists(path):
                try:
                    with open(path) as fh:
                        loaded[fname] = json.load(fh)
                except (OSError, json.JSONDecodeError) as exc:
                    loaded[fname] = exc
            else:
                loaded[fname] = None
        obj = loaded[fname]
        row = {"artifact": fname, "key": key, "op": op, "bound": bound,
               "note": note}
        if obj is None:
            row.update(status="MISSING", value=None)
            if strict:
                status = 1
        elif isinstance(obj, Exception):
            row.update(status="UNREADABLE", value=None, error=str(obj))
            status = 1
        else:
            try:
                value = get_path(obj, key)
            except KeyError:
                row.update(status="NO_KEY", value=None)
                status = 1
            else:
                ok = check_one(value, op, bound)
                row.update(status="OK" if ok else "FAIL", value=value)
                if not ok:
                    status = 1
        results.append(row)
        tag = row["status"]
        val = row["value"]
        val_s = f"{val:.4g}" if isinstance(val, float) else str(val)
        print(f"[sentinel] {tag:9s} {fname}:{key} = {val_s} "
              f"(want {op} {bound})")

    # cross-PR trajectory: every committed artifact's checked headline
    # values in one aggregate record
    trajectory = {"root": os.path.abspath(root), "artifacts": {}}
    for fname, obj in sorted(loaded.items()):
        if obj is None or isinstance(obj, Exception):
            continue
        heads = {}
        for f2, key, _op, _bound, _note in CHECKS:
            if f2 != fname:
                continue
            try:
                heads[key] = get_path(obj, key)
            except KeyError:
                pass
        entry = {"headlines": heads}
        if isinstance(obj, dict) and "backend" in obj:
            entry["backend"] = obj["backend"]
        trajectory["artifacts"][fname] = entry
    trajectory["checks"] = results
    trajectory["status"] = "pass" if status == 0 else "fail"
    os.makedirs(out_dir, exist_ok=True)
    out = os.path.join(out_dir, "BENCH_trajectory.json")
    with open(out, "w") as fh:
        json.dump(trajectory, fh, indent=1, default=float)
    print(f"[sentinel] trajectory -> {out}")
    n_ok = sum(1 for r in results if r["status"] == "OK")
    print(f"[sentinel] {n_ok}/{len(results)} checks OK -> "
          f"{'PASS' if status == 0 else 'FAIL'}")
    return status, results, trajectory


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default=REPO_ROOT,
                    help="directory holding the BENCH_*.json artifacts "
                         "(default: the repo root)")
    ap.add_argument("--strict", action="store_true",
                    help="missing artifacts fail the gate (CI mode)")
    args = ap.parse_args(argv)
    status, _, _ = run(args.root, strict=args.strict)
    return status


if __name__ == "__main__":
    sys.exit(main())
