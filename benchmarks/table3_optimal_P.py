"""Table 3: empirically selected optimal bundle size P* per dataset
profile (logistic + L2-SVM)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, save_json
from repro.core import PCDNConfig, make_problem, solve
from repro.data import paper_like


def run(quick: bool = True):
    datasets = ["a9a", "real-sim", "gisette"] if quick else \
        ["a9a", "real-sim", "news20", "gisette", "rcv1"]
    out = {}
    for ds_name in datasets:
        X, y, spec = paper_like(ds_name)
        row = {}
        for loss, c in (("logistic", spec.c_logistic),
                        ("squared_hinge", spec.c_svm)):
            prob = make_problem(X, y, c=c, loss=loss)
            n = prob.n_features
            f_star = solve(prob, PCDNConfig(P=min(n, 512), max_outer=300,
                                            tol_kkt=1e-6)).objective
            Ps = sorted({max(n // 32, 2), max(n // 8, 4), max(n // 2, 8), n})
            best_P, best_t = None, np.inf
            for P in Ps:
                t0 = time.perf_counter()
                solve(prob, PCDNConfig(P=P, max_outer=150, tol_kkt=0.0,
                                       tol_rel_obj=1e-3), f_star=f_star)
                dt = time.perf_counter() - t0
                if dt < best_t:
                    best_P, best_t = P, dt
            row[loss] = {"P_star": best_P, "seconds": best_t,
                         "n_features": n}
        out[ds_name] = row
        emit(f"table3/{ds_name}", row["logistic"]["seconds"] * 1e6,
             f"P*_logistic={row['logistic']['P_star']} "
             f"P*_svm={row['squared_hinge']['P_star']}")
    save_json("table3_optimal_P", out)
    return out


if __name__ == "__main__":
    run()
