"""Quickstart: solve an l1-regularized logistic regression with PCDN.

    PYTHONPATH=src python examples/quickstart.py

Generates a real-sim-profile dataset, runs PCDN at high parallelism
(P = n/8), and verifies monotone descent + a sparse solution — the
paper's headline behaviour — then compares against CDN (P = 1).

Need the whole regularization path instead of one c? The path engine
(DESIGN.md section 8) sweeps a geometric c-grid from the analytic c_max
with warm starts and active-set shrinking, one compiled program for all
points:

    from repro.path import PathConfig, run_path
    cfg = PathConfig(solver=PCDNConfig(P=256, shrink=True), n_points=20)
    res = run_path(prob, cfg, val_design=Xte, val_y=yte)
    print(res.best.c, res.best.val_accuracy)   # model selection done

See examples/regularization_path.py and `python -m repro.launch.path`.

The script ends with the serving loop (DESIGN.md section 10): the fitted
sparse solution is saved as a versioned model artifact, loaded back, and
served through the microbatched sparse-margin engine — the same
save -> load -> predict path `python -m repro.launch.predict` drives.
"""
import os
import tempfile
import time

import numpy as np

from repro.core import PCDNConfig, cdn_config, make_problem, solve
from repro.data import paper_like
from repro.data.synthetic import train_accuracy
from repro.serve import (MicroBatcher, ModelBank, artifact_from_solution,
                         decide, load_model, save_model)


def main():
    Xtr, ytr, Xte, yte, spec = paper_like("real-sim", with_test=True)
    prob = make_problem(Xtr, ytr, c=spec.c_logistic)
    n = prob.n_features
    print(f"dataset: real-sim profile, s={Xtr.shape[0]} n={n} "
          f"c={spec.c_logistic}")

    P = n // 8
    t0 = time.time()
    res = solve(prob, PCDNConfig(P=P, max_outer=60, tol_kkt=1e-3))
    t_pcdn = time.time() - t0
    f = res.history.objective
    assert np.all(np.diff(f) <= 1e-5 * np.abs(f[:-1]) + 1e-4), \
        "PCDN must descend monotonically (Lemma 1c, f32 tolerance)"
    nnz = int(res.history.nnz[-1])
    acc = train_accuracy(Xte, yte, np.asarray(res.w))
    print(f"PCDN  P={P}: F={res.objective:.4f} nnz={nnz}/{n} "
          f"test_acc={acc:.3f} time={t_pcdn:.1f}s "
          f"(converged={res.converged})")

    t0 = time.time()
    res_cdn = solve(prob, cdn_config(max_outer=60, tol_kkt=1e-3))
    t_cdn = time.time() - t0
    print(f"CDN   P=1: F={res_cdn.objective:.4f} time={t_cdn:.1f}s")
    print(f"speedup (even on 1 CPU core, from bundling): "
          f"{t_cdn / max(t_pcdn, 1e-9):.2f}x")

    # --- serve it: save -> load -> predict (DESIGN.md section 10) -------
    path = os.path.join(tempfile.mkdtemp(), "quickstart_model.json")
    save_model(path, artifact_from_solution(
        res.w, "logistic", spec.c_logistic,
        meta={"objective": float(res.objective), "nnz": nnz}))
    print(f"saved model artifact ({nnz} active weights) -> {path}")

    bank = ModelBank.from_family(load_model(path))
    batcher = MicroBatcher(bank, buckets=(64, 256), layout="dense")
    preds = decide(bank, batcher.predict(Xte))
    served_acc = float(np.mean(preds == yte))
    stats = batcher.stats()
    print(f"served {stats['total_rows']} requests through "
          f"{stats['compiles']} compiled bucket shapes: "
          f"accuracy={served_acc:.3f}")
    # f32 reduction order differs between the numpy scorer and the XLA
    # union-gather engine; only margins at +-eps of zero may flip
    assert abs(served_acc - acc) <= 0.005, \
        "serving must reproduce the fit-time scorer"


if __name__ == "__main__":
    main()
