"""Regularization path: sweep c from the analytic c_max, pick the best
model on a held-out split (DESIGN.md section 8).

    PYTHONPATH=src python examples/regularization_path.py

Solves a real-sim-profile l1 logistic problem along a 12-point geometric
c-grid with warm starts + active-set shrinking, prints the path table
(objective / nnz / KKT / validation accuracy per point), and compares
the sweep's wall time against what 12 independent cold solves cost.
The same grid is then solved a second way — all points at once via the
vmapped batch solver — to show the two serving modes agree.
"""
import time

import numpy as np

from repro.core import PCDNConfig, make_problem
from repro.data import paper_like
from repro.path import PathConfig, run_path, solve_batch


def main():
    Xtr, ytr, Xte, yte, spec = paper_like("real-sim", with_test=True)
    prob = make_problem(Xtr, ytr, c=1.0)
    solver = PCDNConfig(P=prob.n_features // 8, max_outer=120,
                        tol_kkt=1e-3, shrink=True)
    cfg = PathConfig(solver=solver, n_points=12, span=50.0)

    print(f"dataset: real-sim profile, s={Xtr.shape[0]} "
          f"n={prob.n_features}, c_max={prob.c_max():.5g}")
    t0 = time.time()
    res = run_path(prob, cfg, val_design=Xte, val_y=yte)
    t_path = time.time() - t0

    print(f"\n{'c':>10} {'F':>12} {'nnz':>6} {'kkt':>9} {'iters':>6} "
          f"{'val_acc':>8}")
    for p in res.points:
        print(f"{p.c:>10.4g} {p.objective:>12.4f} {p.nnz:>6d} "
              f"{p.kkt:>9.2e} {p.n_outer:>6d} {p.val_accuracy:>8.4f}")
    best = res.best
    print(f"\nbest c = {best.c:.4g} (val_acc={best.val_accuracy:.4f}, "
          f"nnz={best.nnz}/{prob.n_features})")
    total_iters = sum(p.n_outer for p in res.points)
    print(f"warm sweep: {t_path:.1f}s, {total_iters} outer iterations "
          f"across {cfg.n_points} points (one compiled program)")

    # same grid, solved all-at-once by the vmapped batch engine
    t0 = time.time()
    bres = solve_batch(prob, PCDNConfig(P=solver.P, max_outer=120,
                                        tol_kkt=1e-3), res.cs)
    t_batch = time.time() - t0
    rel = np.max(np.abs(np.asarray(bres.objective) -
                        np.array([p.objective for p in res.points])) /
                 np.array([max(abs(p.objective), 1e-9)
                           for p in res.points]))
    print(f"vmapped batch of {len(res.cs)} solves: {t_batch:.1f}s, "
          f"max objective deviation from the sweep: {rel:.1e}")


if __name__ == "__main__":
    main()
