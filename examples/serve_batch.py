"""Batched serving example: prefill a batch of prompts and decode greedily
from the KV cache (incremental decode == full forward, tested invariant).

    PYTHONPATH=src python examples/serve_batch.py [--arch falcon-mamba-7b]

Try an SSM arch to see O(1)-state decode, or a dense arch for KV caching.
"""
import argparse

from repro.launch.serve import main as serve_main


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="falcon-mamba-7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args(argv)
    serve_main(["--arch", args.arch, "--batch", str(args.batch),
                "--new-tokens", str(args.new_tokens)])


if __name__ == "__main__":
    main()
