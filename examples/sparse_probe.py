"""PCDN meets the LM stack: train an l1-sparse logistic probe on frozen
transformer hidden states (DESIGN.md section 5 — where the paper's convex
solver plugs into the assigned architectures).

    PYTHONPATH=src python examples/sparse_probe.py [--arch yi-6b]

Builds a reduced backbone, extracts final hidden states for a synthetic
binary task (does the sequence contain a marker token?), and fits the
probe with PCDN — the feature axis (d_model) is exactly the axis the
distributed solver shards.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core import PCDNConfig, make_problem, solve
from repro.data.synthetic import train_accuracy
from repro.models.transformer import Model
from repro.launch.specs import train_batch_specs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b", choices=list(ARCH_IDS))
    ap.add_argument("--samples", type=int, default=256)
    ap.add_argument("--seq", type=int, default=24)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=True)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    model = Model(cfg, mesh)
    params = model.init_params(jax.random.PRNGKey(0))

    # synthetic task: label = does token 7 appear in the sequence?
    rng = np.random.default_rng(1)
    feats, labels = [], []
    marker = 7
    from repro.models.layers import apply_embed
    for i in range(0, args.samples, 32):
        batch = train_batch_specs(cfg, batch=32, seq=args.seq,
                                  concrete=True, seed=i)
        toks = np.asarray(batch["tokens"]).copy()
        has = (toks == marker).any(axis=1)
        # flip half the negatives to positives by injection
        inject = rng.random(32) < 0.5
        toks[inject & ~has, 2] = marker
        batch["tokens"] = jnp.asarray(toks)
        has = (toks == marker).any(axis=1)
        # frozen-backbone features: mean-pooled final hidden state
        if cfg.family == "encdec":
            h = model.encode(params, batch["frames"])
        else:
            xin = apply_embed(cfg, params["embed"], batch["tokens"])
            if cfg.family == "vlm":
                xin = jnp.concatenate(
                    [batch["patches"].astype(xin.dtype), xin], axis=1)
            h = model.backbone(params, xin, jnp.arange(xin.shape[1]))
        feats.append(np.asarray(jnp.mean(h, axis=1), np.float32))
        labels.append(np.where(has, 1.0, -1.0).astype(np.float32))
    X = np.concatenate(feats)
    y = np.concatenate(labels)
    cut = int(0.8 * len(y))

    prob = make_problem(X[:cut], y[:cut], c=1.0)
    res = solve(prob, PCDNConfig(P=max(cfg.d_model // 4, 4),
                                 max_outer=200, tol_kkt=1e-3))
    acc = train_accuracy(X[cut:], y[cut:], np.asarray(res.w))
    nnz = int(np.sum(np.asarray(res.w) != 0))
    print(f"[sparse_probe] {args.arch}: probe acc={acc:.3f} "
          f"nnz={nnz}/{cfg.d_model} F={res.objective:.4f} "
          f"converged={res.converged}")
    assert acc > 0.5, "probe should beat chance"


if __name__ == "__main__":
    main()
