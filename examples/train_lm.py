"""End-to-end LM training driver example: train a reduced qwen2 on the
synthetic token stream with checkpointing + fault-tolerant resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 60]

(On real hardware use ``python -m repro.launch.train --full --arch <id>``
to train the full-size configs on the production mesh.)
"""
import argparse
import os
import shutil

from repro.launch.train import main as train_main


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_example_ckpt")
    args = ap.parse_args(argv)

    if os.path.exists(args.ckpt_dir):
        shutil.rmtree(args.ckpt_dir)

    # phase 1: train half the steps, checkpointing along the way
    half = args.steps // 2
    losses1 = train_main(["--arch", args.arch, "--steps", str(half),
                          "--batch", "8", "--seq", "128",
                          "--ckpt-dir", args.ckpt_dir,
                          "--ckpt-every", "10"])
    # phase 2: "restart after preemption" — resumes from the checkpoint
    print("[example] simulating preemption + restart...")
    losses2 = train_main(["--arch", args.arch, "--steps",
                          str(args.steps - half), "--batch", "8",
                          "--seq", "128", "--ckpt-dir", args.ckpt_dir,
                          "--ckpt-every", "10"])
    assert losses2[-1] < losses1[0], "loss must fall across the restart"
    print(f"[example] OK: loss {losses1[0]:.3f} -> {losses2[-1]:.3f} "
          f"across a checkpointed restart")


if __name__ == "__main__":
    main()
