"""Architecture registry: one module per assigned arch (``--arch <id>``)."""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "pixtral-12b",
    "recurrentgemma-2b",
    "yi-6b",
    "qwen2-0.5b",
    "qwen1.5-32b",
    "gemma-7b",
    "whisper-small",
    "falcon-mamba-7b",
    "deepseek-moe-16b",
    "grok-1-314b",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch: str, reduced: bool = False):
    """Load the full (or reduced smoke-test) ModelConfig for an arch id."""
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; have {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.REDUCED if reduced else mod.CONFIG


def all_configs(reduced: bool = False):
    return {a: get_config(a, reduced) for a in ARCH_IDS}
