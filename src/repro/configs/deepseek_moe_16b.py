"""deepseek-moe-16b [moe]: fine-grained MoE — 2 shared + 64 routed top-6,
dense first layer. [arXiv:2401.06066; hf]"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,                   # per routed expert
    vocab_size=102_400,
    mlp_type="swiglu",
    rope_theta=10_000.0,
    moe=MoEConfig(
        n_experts=64, top_k=6, n_shared=2,
        d_ff_expert=1408, d_ff_shared=2816,
        capacity_factor=1.25,
        first_layer_dense=True, d_ff_dense=10944,
    ),
)

REDUCED = CONFIG.replace(
    name="deepseek-moe-16b-reduced",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=32, vocab_size=256,
    moe=MoEConfig(n_experts=8, top_k=2, n_shared=2, d_ff_expert=32,
                  d_ff_shared=64, capacity_factor=8.0,
                  first_layer_dense=True, d_ff_dense=128),
    dtype="float32", remat=False,
)
