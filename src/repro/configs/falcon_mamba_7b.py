"""falcon-mamba-7b [ssm]: attention-free Mamba-1 architecture.
[arXiv:2410.05355; unverified]"""
from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,                   # unused (attention-free)
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,                      # no MLP blocks; mamba block only
    vocab_size=65_024,
    rope_theta=0.0,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
)

REDUCED = CONFIG.replace(
    name="falcon-mamba-7b-reduced",
    n_layers=2, d_model=64, vocab_size=256,
    ssm=SSMConfig(d_state=4, d_conv=4, expand=2),
    dtype="float32", remat=False,
)
