"""gemma-7b [dense]: GeGLU, head_dim 256, scaled embeddings, tied unembed.
[arXiv:2403.08295; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    head_dim=256,                # != d_model // n_heads (192) by design
    d_ff=24576,
    vocab_size=256_000,
    mlp_type="geglu",
    tie_embeddings=True,
    embed_scale=True,
    rope_theta=10_000.0,
)

REDUCED = CONFIG.replace(
    name="gemma-7b-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=128, vocab_size=256, dtype="float32", remat=False,
)
