"""grok-1-314b [moe]: 8 experts top-2, wide gated FFN.
[hf:xai-org/grok-1; unverified]"""
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,                  # per expert
    vocab_size=131_072,
    mlp_type="swiglu",
    rope_theta=10_000.0,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32768,
                  capacity_factor=1.25),
)

REDUCED = CONFIG.replace(
    name="grok-1-314b-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=256,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64,
                  capacity_factor=8.0),
    dtype="float32", remat=False,
)
