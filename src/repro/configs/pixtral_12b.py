"""pixtral-12b [vlm]: Pixtral-ViT frontend (stub) + Mistral-Nemo decoder.
[hf:mistralai/Pixtral-12B-2409; unverified]"""
from repro.models.config import ModelConfig, VLMConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    mlp_type="swiglu",
    rope_theta=1_000_000.0,
    vlm=VLMConfig(n_patches=256),
)

REDUCED = CONFIG.replace(
    name="pixtral-12b-reduced",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    vlm=VLMConfig(n_patches=8),
    dtype="float32",
    remat=False,
)
