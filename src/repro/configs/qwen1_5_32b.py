"""qwen1.5-32b [dense]: MHA (kv == heads) with QKV bias.
[hf:Qwen/Qwen1.5-0.5B family scaling; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152_064,
    mlp_type="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

REDUCED = CONFIG.replace(
    name="qwen1.5-32b-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32", remat=False,
)
