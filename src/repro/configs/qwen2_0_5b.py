"""qwen2-0.5b [dense]: GQA with QKV bias, tied embeddings.
[arXiv:2407.10671; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151_936,
    mlp_type="swiglu",
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)

REDUCED = CONFIG.replace(
    name="qwen2-0.5b-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32", remat=False,
)
