"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, (rec,rec,attn)
pattern. [arXiv:2402.19427; hf]"""
from repro.models.config import HybridConfig, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,                 # 8 x (rec,rec,attn) + 2 tail rec layers
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,                # MQA on the local-attention layers
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    mlp_type="geglu",
    embed_scale=True,
    rope_theta=10_000.0,
    hybrid=HybridConfig(lru_width=2560, conv_width=4, window=2048),
)

REDUCED = CONFIG.replace(
    name="recurrentgemma-2b-reduced",
    n_layers=5,                  # 1 triple + 2 tail rec layers
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    hybrid=HybridConfig(lru_width=64, conv_width=4, window=16),
    dtype="float32",
    remat=False,
)
