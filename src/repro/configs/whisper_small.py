"""whisper-small [audio]: encoder-decoder; conv/audio frontend is a STUB —
input_specs() supplies precomputed frame embeddings (B, 1500, d_model).
[arXiv:2212.04356; unverified]"""
from repro.models.config import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,                 # decoder layers (12 encoder layers below)
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51_865,
    mlp_type="gelu_mlp",
    qkv_bias=True,
    rope_theta=0.0,              # absolute (sinusoidal) positions
    encdec=EncDecConfig(n_encoder_layers=12, encoder_frames=1500,
                        max_target_positions=448),
)

REDUCED = CONFIG.replace(
    name="whisper-small-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    encdec=EncDecConfig(n_encoder_layers=2, encoder_frames=16,
                        max_target_positions=448),
    dtype="float32", remat=False,
)
