"""yi-6b [dense]: llama-architecture GQA. [arXiv:2403.04652; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64_000,
    mlp_type="swiglu",
    rope_theta=5_000_000.0,
)

REDUCED = CONFIG.replace(
    name="yi-6b-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256, dtype="float32", remat=False,
)
