"""Core: the paper's primary contribution — PCDN and its comparison solvers."""
from repro.core.design_matrix import (DenseDesign, DesignMatrix,
                                      PaddedCSCDesign, as_design)
from repro.core.linesearch import ArmijoParams
from repro.core.problem import (L1Problem, expected_max_column_norm,
                                make_problem)
from repro.core.pcdn import (PCDNConfig, SolveResult, cdn_config, solve,
                             with_bundle_size)
from repro.core import scdn, tron

__all__ = [
    "ArmijoParams", "L1Problem", "make_problem", "expected_max_column_norm",
    "PCDNConfig", "SolveResult", "cdn_config", "solve", "scdn", "tron",
    "with_bundle_size",
    "DesignMatrix", "DenseDesign", "PaddedCSCDesign", "as_design",
]
