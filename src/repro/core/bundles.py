"""Random Gauss-Seidel bundle partitioning (paper Eq. 8).

Each outer iteration draws a fresh random permutation of the feature set N
and slices it into b = ceil(n / P) disjoint bundles of size P. When P does
not divide n the final bundle is padded with sentinel indices (== n); all
bundle math masks them out, so semantics match the paper's ragged last
bundle exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def num_bundles(n: int, P: int) -> int:
    return -(-n // P)  # ceil


def partition(key: Array, n: int, P: int) -> Array:
    """-> (b, P) int32 bundle indices; entries == n are padding."""
    b = num_bundles(n, P)
    perm = jax.random.permutation(key, n)
    pad = jnp.full((b * P - n,), n, dtype=perm.dtype)
    return jnp.concatenate([perm, pad]).reshape(b, P).astype(jnp.int32)


def partition_active(key: Array, active: Array, P: int) -> tuple[Array, Array]:
    """Active-set partition for the shrinking solver (DESIGN.md section 8.2).

    active: (n,) bool mask of un-shrunk features. Returns (idxs, b_active):
    idxs is the same static (b, P) layout as `partition`, but a fresh
    random permutation is stably reordered so every ACTIVE feature lands
    in the leading ceil(n_active / P) bundles (random order within the
    active block); all inactive/pad slots hold the sentinel n and are
    masked out of bundle math exactly like ragged-tail padding. b_active
    is the dynamic number of leading bundles that contain any work — the
    solver's fori_loop trip count, so shrunk features cost zero compute
    while every shape stays static.
    """
    n = active.shape[0]
    b = num_bundles(n, P)
    perm = jax.random.permutation(key, n)
    order = jnp.argsort(~active[perm], stable=True)   # actives first
    perm = perm[order]
    flat = jnp.where(active[perm], perm, n)
    pad = jnp.full((b * P - n,), n, dtype=flat.dtype)
    idxs = jnp.concatenate([flat, pad]).reshape(b, P).astype(jnp.int32)
    n_active = jnp.sum(active.astype(jnp.int32))
    b_active = (n_active + P - 1) // P
    return idxs, b_active


def gather_slab(X: Array, idx: Array) -> tuple[Array, Array]:
    """Gather the dense (s, P) column slab for one bundle from a raw array.

    idx: (P,) with possible sentinel n. Returns (XB, valid_mask) where
    padded columns are zeroed so they contribute nothing to any reduction.
    Solvers holding an L1Problem go through design.gather_slab instead
    (backend-dispatched — DESIGN.md section 7); this raw-array version
    remains for the sharded dense path, which works on local blocks.
    """
    n = X.shape[1]
    valid = idx < n
    safe = jnp.minimum(idx, n - 1)
    XB = jnp.take(X, safe, axis=1)
    XB = XB * valid[None, :].astype(X.dtype)
    return XB, valid


def gather_vec(v: Array, idx: Array) -> tuple[Array, Array]:
    """Gather (P,) entries of a feature-indexed vector with pad masking."""
    n = v.shape[0]
    valid = idx < n
    safe = jnp.minimum(idx, n - 1)
    out = jnp.take(v, safe) * valid.astype(v.dtype)
    return out, valid


def scatter_add(w: Array, idx: Array, upd: Array) -> Array:
    """w[idx] += upd with sentinel-safe drop semantics."""
    return w.at[idx].add(upd, mode="drop")
