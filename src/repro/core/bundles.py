"""Random Gauss-Seidel bundle partitioning (paper Eq. 8).

Each outer iteration draws a fresh random permutation of the feature set N
and slices it into b = ceil(n / P) disjoint bundles of size P. When P does
not divide n the final bundle is padded with sentinel indices (== n); all
bundle math masks them out, so semantics match the paper's ragged last
bundle exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def num_bundles(n: int, P: int) -> int:
    return -(-n // P)  # ceil


def partition(key: Array, n: int, P: int) -> Array:
    """-> (b, P) int32 bundle indices; entries == n are padding."""
    b = num_bundles(n, P)
    perm = jax.random.permutation(key, n)
    pad = jnp.full((b * P - n,), n, dtype=perm.dtype)
    return jnp.concatenate([perm, pad]).reshape(b, P).astype(jnp.int32)


def gather_slab(X: Array, idx: Array) -> tuple[Array, Array]:
    """Gather the dense (s, P) column slab for one bundle from a raw array.

    idx: (P,) with possible sentinel n. Returns (XB, valid_mask) where
    padded columns are zeroed so they contribute nothing to any reduction.
    Solvers holding an L1Problem go through design.gather_slab instead
    (backend-dispatched — DESIGN.md section 7); this raw-array version
    remains for the sharded dense path, which works on local blocks.
    """
    n = X.shape[1]
    valid = idx < n
    safe = jnp.minimum(idx, n - 1)
    XB = jnp.take(X, safe, axis=1)
    XB = XB * valid[None, :].astype(X.dtype)
    return XB, valid


def gather_vec(v: Array, idx: Array) -> tuple[Array, Array]:
    """Gather (P,) entries of a feature-indexed vector with pad masking."""
    n = v.shape[0]
    valid = idx < n
    safe = jnp.minimum(idx, n - 1)
    out = jnp.take(v, safe) * valid.astype(v.dtype)
    return out, valid


def scatter_add(w: Array, idx: Array, upd: Array) -> Array:
    """w[idx] += upd with sentinel-safe drop semantics."""
    return w.at[idx].add(upd, mode="drop")
