"""Design-matrix backends: dense array vs. padded feature-major sparse.

The paper's six benchmark datasets (Table 2) are >99.9% sparse; a dense
(s, n) array caps the reproduction at toy scale and makes every bundle
gather O(s * P) regardless of nnz. This module gives every solver layer a
single `DesignMatrix` interface with two interchangeable backends
(DESIGN.md section 7):

  * `DenseDesign`     — the original (s, n) jnp array. Default; every
    existing caller and benchmark keeps its exact semantics.
  * `PaddedCSCDesign` — feature-major ELL/CSC hybrid: for each column j,
    the row ids and values of its nonzeros, padded to a static width
    k_max so all shapes are jit/scan-stable:

        col_rows : (n, k_max) int32, row id or sentinel `s` for padding
        col_vals : (n, k_max) float, 0 at padding slots

    Gather of a size-P bundle is O(P * k_max) instead of O(s * P);
    gradient/Hessian reductions become masked segment sums and the
    margin update z += alpha * X_B d_B a scatter-add at `col_rows`.

Both backends are registered pytrees, so an `L1Problem` carrying either
flows through `jax.jit` / `lax.scan` unchanged.

Mixed precision (DESIGN.md section 12): values may be STORED in bf16
(`dtype=jnp.bfloat16` at construction) while every reduction below
ACCUMULATES in f32 — products/sums upcast via
`jnp.promote_types(storage, float32)`, which is bitwise a no-op for f32
storage. Solver state (w, z, u, v) stays f32 regardless; only the
design values and their HBM traffic shrink. Bundle slabs are small
NamedTuples (`DenseSlab` / `SparseSlab`) produced by `gather_slab` and
consumed by `slab_grad_hess` / `slab_matvec` — the only three methods the
inner solver loops touch.

The k_max trade-off (DESIGN.md section 7.2): memory and gather work scale
with n * k_max = n * max_j nnz(col j), so a single heavy column inflates
every column's padding. `from_csr` accepts an explicit `k_max` to cap it
(raising if a real column overflows); hot/cold column splitting is the
documented follow-up for power-law datasets.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


class DenseSlab(NamedTuple):
    """Dense (s, P) column slab for one bundle; padded columns zeroed."""
    XB: Array          # (s, P)
    valid: Array       # (P,) bool


class SparseSlab(NamedTuple):
    """Padded-CSC slab: per bundle feature, its nonzero rows/values."""
    rows: Array        # (P, k_max) int32; sentinel == n_samples at padding
    vals: Array        # (P, k_max) float; 0 at padding
    valid: Array       # (P,) bool


class SlabSupport(NamedTuple):
    """The row support of one bundle slab (DESIGN.md section 11).

    support: (r_max,) int32 — sorted UNIQUE row ids touched by the
    bundle, sentinel-padded (== n_samples) exactly like k_max padding;
    r_max = P * k_max is the static worst case. pos: (P, k_max) int32 —
    for every slab entry, its index into `support` (always in-bounds;
    padding entries point at a sentinel slot and carry value 0, so they
    contribute nothing to any support-scoped reduction).
    """
    support: Array     # (r_max,) int32, sorted, sentinel == n_samples
    pos: Array         # (P, k_max) int32, index into support


Slab = Union[DenseSlab, SparseSlab]


def padded_row_support(rows: Array, sentinel: int) -> SlabSupport:
    """Static-shape unique row set of a padded (P, k_max) row-id array.

    Sort the flattened ids, blank duplicates to the sentinel, re-sort so
    the unique ids stay sorted with all sentinels trailing, then recover
    every entry's slot with one searchsorted. O(P*k_max log(P*k_max)) —
    never touches the sample axis.
    """
    flat = rows.reshape(-1)
    srt = jnp.sort(flat)
    dup = jnp.concatenate([jnp.zeros((1,), bool), srt[1:] == srt[:-1]])
    support = jnp.sort(jnp.where(dup, sentinel, srt))
    pos = jnp.searchsorted(support, rows).astype(jnp.int32)
    return SlabSupport(support=support.astype(jnp.int32), pos=pos)


class DesignMatrix:
    """Interface both backends implement (duck-typed; no abc overhead).

    matvec(w)            -> (s,)  margins X @ w
    rmatvec(u)           -> (n,)  X^T @ u
    column_norms_sq()    -> (n,)  diag(X^T X)
    gather_slab(idx)     -> Slab  for a (P,) bundle with sentinel == n
    slab_grad_hess(...)  -> (g, h) raw bundle reductions (no l2 / floor)
    slab_matvec(...)     -> (s,)  X_B @ d_B (dense margins delta)
    """

    layout: str = "abstract"


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DenseDesign(DesignMatrix):
    """The original dense backend: X is a plain (s, n) array."""

    X: Array
    layout = "dense"

    def tree_flatten(self):
        return (self.X,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(X=children[0])

    # -- shape/dtype ---------------------------------------------------------
    @property
    def shape(self) -> Tuple[int, int]:
        return self.X.shape

    @property
    def n_samples(self) -> int:
        return self.X.shape[0]

    @property
    def n_features(self) -> int:
        return self.X.shape[1]

    @property
    def dtype(self):
        return self.X.dtype

    @property
    def acc_dtype(self):
        """Accumulation dtype: f32 for bf16 storage, identity for f32+."""
        return jnp.promote_types(self.X.dtype, jnp.float32)

    # -- whole-matrix products ----------------------------------------------
    def matvec(self, w: Array) -> Array:
        return self.X.astype(self.acc_dtype) @ w

    def rmatvec(self, u: Array) -> Array:
        return self.X.T.astype(self.acc_dtype) @ u

    def column_norms_sq(self) -> Array:
        return jnp.sum(jnp.square(self.X.astype(self.acc_dtype)), axis=0)

    # -- bundle slab protocol -------------------------------------------------
    def gather_slab(self, idx: Array) -> DenseSlab:
        """idx: (P,) int32 with sentinel n for the ragged last bundle."""
        n = self.X.shape[1]
        valid = idx < n
        safe = jnp.minimum(idx, n - 1)
        XB = jnp.take(self.X, safe, axis=1)
        XB = XB * valid[None, :].astype(self.X.dtype)
        return DenseSlab(XB=XB, valid=valid)

    def slab_grad_hess(self, slab: DenseSlab, u: Array, v: Array):
        """g_j = sum_i u_i X_ij ; h_j = sum_i v_i X_ij^2 (raw, no l2/floor).

        The two tall-skinny matvecs are the compute hot-spot that
        kernels/pcdn_direction fuses on TPU (DESIGN.md section 3.1).
        """
        XB = slab.XB.astype(self.acc_dtype)
        g = XB.T @ u
        h = jnp.square(XB).T @ v
        return g, h

    def slab_matvec(self, slab: DenseSlab, d: Array) -> Array:
        """delta_z = X_B @ d_B, the (s,) margin delta of a bundle step."""
        return slab.XB.astype(self.acc_dtype) @ d

    def slab_coordinate_deltas(self, slab: DenseSlab, d: Array) -> Array:
        """(P, s) per-coordinate margin deltas d_j * X[:, j] — the blind
        single-coordinate steps SCDN's racing line searches evaluate."""
        return (slab.XB * d[None, :]).T

    def to_dense(self) -> Array:
        return self.X


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PaddedCSCDesign(DesignMatrix):
    """Feature-major padded sparse backend (ELL over columns).

    col_rows[j, k] is the row id of the k-th nonzero of column j, or the
    sentinel `n_samples` at padding slots; col_vals holds the values with
    zeros at padding. Static (n, k_max) shapes keep every solver loop
    jit/scan-able; sentinel rows are dropped by `mode="drop"` scatters and
    zero-filled by `mode="fill"` gathers, so padding contributes nothing
    to any reduction (DESIGN.md section 7.1).
    """

    col_rows: Array    # (n, k_max) int32
    col_vals: Array    # (n, k_max) float
    _n_samples: int    # static: sentinel value and margins length
    layout = "padded_csc"

    def tree_flatten(self):
        return (self.col_rows, self.col_vals), (self._n_samples,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        rows, vals = children
        return cls(col_rows=rows, col_vals=vals, _n_samples=aux[0])

    # -- shape/dtype ---------------------------------------------------------
    @property
    def shape(self) -> Tuple[int, int]:
        return (self._n_samples, self.col_rows.shape[0])

    @property
    def n_samples(self) -> int:
        return self._n_samples

    @property
    def n_features(self) -> int:
        return self.col_rows.shape[0]

    @property
    def k_max(self) -> int:
        return self.col_rows.shape[1]

    @property
    def dtype(self):
        return self.col_vals.dtype

    @property
    def acc_dtype(self):
        """Accumulation dtype: f32 for bf16 storage, identity for f32+."""
        return jnp.promote_types(self.col_vals.dtype, jnp.float32)

    # -- whole-matrix products ----------------------------------------------
    def matvec(self, w: Array) -> Array:
        """z = X @ w as one scatter-add of every weighted nonzero."""
        acc = self.acc_dtype
        z = jnp.zeros((self._n_samples,), acc)
        return z.at[self.col_rows].add(
            self.col_vals.astype(acc) * w[:, None], mode="drop")

    def rmatvec(self, u: Array) -> Array:
        """X^T u: gather u at each column's rows, masked segment sum."""
        ug = jnp.take(u, self.col_rows, mode="fill", fill_value=0)
        return jnp.sum(ug * self.col_vals.astype(self.acc_dtype), axis=1)

    def column_norms_sq(self) -> Array:
        return jnp.sum(jnp.square(self.col_vals.astype(self.acc_dtype)),
                       axis=1)

    # -- bundle slab protocol -------------------------------------------------
    def gather_slab(self, idx: Array) -> SparseSlab:
        """O(P * k_max) bundle gather — never touches the other columns."""
        n = self.col_rows.shape[0]
        s = self._n_samples
        valid = idx < n
        safe = jnp.minimum(idx, n - 1)
        rows = jnp.where(valid[:, None], jnp.take(self.col_rows, safe,
                                                  axis=0), s)
        vals = jnp.take(self.col_vals, safe, axis=0) * \
            valid[:, None].astype(self.col_vals.dtype)
        return SparseSlab(rows=rows, vals=vals, valid=valid)

    def slab_grad_hess(self, slab: SparseSlab, u: Array, v: Array):
        """Masked segment reductions over the padded column layout."""
        vals = slab.vals.astype(self.acc_dtype)
        ug = jnp.take(u, slab.rows, mode="fill", fill_value=0)
        vg = jnp.take(v, slab.rows, mode="fill", fill_value=0)
        g = jnp.sum(ug * vals, axis=1)
        h = jnp.sum(vg * jnp.square(vals), axis=1)
        return g, h

    def slab_matvec(self, slab: SparseSlab, d: Array) -> Array:
        """delta_z via scatter-add at col_rows (duplicate rows accumulate)."""
        acc = self.acc_dtype
        z = jnp.zeros((self._n_samples,), acc)
        return z.at[slab.rows].add(slab.vals.astype(acc) * d[:, None],
                                   mode="drop")

    # -- support-scoped slab protocol (DESIGN.md section 11) -----------------
    def slab_row_support(self, slab: SparseSlab) -> SlabSupport:
        """Static (r_max = P * k_max) unique row set of one bundle slab.

        Everything a bundle step does to the per-sample intermediates is
        zero outside these rows (delta_i = 0 there), so the line search
        and the z update can be restricted to them — O(P * k_max) work
        instead of O(s) per bundle.
        """
        return padded_row_support(slab.rows, self._n_samples)

    def slab_grad_hess_support(self, slab: SparseSlab, pos: Array,
                               u_R: Array, v_R: Array):
        """`slab_grad_hess` with u/v given only at the support rows.

        u_R/v_R: (r_max,) factors evaluated at support order; pos maps
        each slab entry into them (always in-bounds, padding vals are 0),
        so the gather never touches the (s,)-sized vectors. Bitwise equal
        to the full-scope reduction: same addends in the same k-order.
        """
        vals = slab.vals.astype(self.acc_dtype)
        ug = jnp.take(u_R, pos)
        vg = jnp.take(v_R, pos)
        g = jnp.sum(ug * vals, axis=1)
        h = jnp.sum(vg * jnp.square(vals), axis=1)
        return g, h

    def slab_matvec_support(self, slab: SparseSlab, pos: Array,
                            d: Array) -> Array:
        """Support-compressed margin delta: (r_max,) values delta_R with
        delta_R[r] = (X_B d_B)[support[r]]. Sentinel support slots stay
        exactly 0 (padding entries carry value 0)."""
        acc = self.acc_dtype
        r_max = pos.shape[0] * pos.shape[1]
        out = jnp.zeros((r_max,), acc)
        return out.at[pos].add(slab.vals.astype(acc) * d[:, None])

    def scatter_support(self, z: Array, support: Array, upd: Array) -> Array:
        """z[support] += upd with sentinel slots dropped (the support-
        scoped form of the z += alpha * X_B d_B margin maintenance)."""
        return z.at[support].add(upd, mode="drop")

    def slab_coordinate_deltas(self, slab: SparseSlab, d: Array) -> Array:
        """(P, s) per-coordinate margin deltas (vmapped single scatters)."""
        s = self._n_samples
        acc = self.acc_dtype

        def one(rows_j, vals_j, d_j):
            return jnp.zeros((s,), acc).at[rows_j].add(
                vals_j.astype(acc) * d_j, mode="drop")

        return jax.vmap(one)(slab.rows, slab.vals, d)

    def to_dense(self) -> Array:
        """Materialize (s, n) — test/debug only; O(s * n) memory."""
        s, n = self.shape
        out = jnp.zeros((s, n), self.col_vals.dtype)
        cols = jnp.broadcast_to(jnp.arange(n)[:, None], self.col_rows.shape)
        return out.at[self.col_rows, cols].add(self.col_vals, mode="drop")

    # -- constructors ---------------------------------------------------------
    @classmethod
    def from_csr(cls, data, indices, indptr, shape, k_max=None,
                 dtype=np.float32) -> "PaddedCSCDesign":
        """Build from CSR triple without ever densifying (numpy-side).

        k_max defaults to the max column nnz; passing a smaller value
        raises if any column overflows (truncation would silently change
        the objective).
        """
        rows_np, vals_np, s, n = padded_csc_arrays(
            data, indices, indptr, shape, k_max=k_max, dtype=dtype)
        return cls(col_rows=jnp.asarray(rows_np),
                   col_vals=jnp.asarray(vals_np), _n_samples=s)

    @classmethod
    def from_dense(cls, X, k_max=None, dtype=np.float32) -> "PaddedCSCDesign":
        """Convert a small dense matrix (tests / benchmarks)."""
        X = np.asarray(X, dtype=dtype)
        s, n = X.shape
        nz_rows, nz_cols = np.nonzero(X.T)  # feature-major order
        # X.T nonzero walks columns of X in order: nz_rows is the column id
        indptr = np.concatenate(
            [[0], np.cumsum(np.bincount(nz_rows, minlength=n))])
        counts = np.diff(indptr).astype(np.int64)
        k = int(max(1, counts.max() if counts.size else 1))
        if k_max is not None:
            if k > int(k_max):
                raise ValueError(
                    f"k_max={k_max} < max column nnz {k}")
            k = int(k_max)
        col_rows = np.full((n, k), s, np.int32)
        col_vals = np.zeros((n, k), dtype)
        pos = np.arange(nz_rows.shape[0]) - indptr[nz_rows]
        col_rows[nz_rows, pos] = nz_cols
        col_vals[nz_rows, pos] = X.T[nz_rows, nz_cols]
        return cls(col_rows=jnp.asarray(col_rows),
                   col_vals=jnp.asarray(col_vals), _n_samples=s)


def padded_csc_arrays(data, indices, indptr, shape, k_max=None,
                      dtype=np.float32):
    """CSR triple -> (col_rows, col_vals, s, n) numpy padded-CSC arrays.

    Fully vectorized: stable-sorts the nnz stream by column, computes each
    entry's rank within its column from the column-start offsets, and
    scatters into the padded layout. O(nnz log nnz), no (s, n) temporary.
    """
    s, n = shape
    data = np.asarray(data, dtype=dtype)
    indices = np.asarray(indices, dtype=np.int64)
    indptr = np.asarray(indptr, dtype=np.int64)
    nnz = data.shape[0]
    row_ids = np.repeat(np.arange(s, dtype=np.int64), np.diff(indptr))
    order = np.argsort(indices, kind="stable")
    cols = indices[order]
    rows = row_ids[order]
    vals = data[order]
    counts = np.bincount(cols, minlength=n).astype(np.int64)
    k = int(max(1, counts.max() if counts.size else 1))
    if k_max is not None:
        if k > int(k_max):
            raise ValueError(f"k_max={k_max} < max column nnz {k}")
        k = int(k_max)
    col_start = np.concatenate([[0], np.cumsum(counts)])
    pos = np.arange(nnz, dtype=np.int64) - col_start[cols]
    col_rows = np.full((n, k), s, np.int32)
    col_vals = np.zeros((n, k), dtype)
    col_rows[cols, pos] = rows
    col_vals[cols, pos] = vals
    return col_rows, col_vals, int(s), int(n)


def as_design(X, dtype=jnp.float32, layout: str = "auto",
              k_max=None) -> DesignMatrix:
    """Coerce whatever callers hand us into a DesignMatrix.

    Accepts an existing DesignMatrix (passed through), a dense numpy/jax
    array, or a CSR-like object with .data/.indices/.indptr/.shape (e.g.
    data.libsvm.CSRMatrix or a scipy csr_matrix) — the latter never
    densifies. layout: "auto" keeps arrays dense and CSR sparse; "dense"
    / "padded_csc" force a backend (forcing CSR dense is refused — it
    would silently materialize (s, n)).
    """
    if isinstance(X, DesignMatrix):
        return X
    if all(hasattr(X, a) for a in ("col_rows", "col_vals", "shape")):
        # data.libsvm.PaddedCSC (numpy-side padded layout)
        if layout == "dense":
            raise ValueError(
                "PaddedCSC input with layout='dense' would densify; pass "
                "layout='padded_csc'/'auto'.")
        if k_max is not None and int(k_max) != int(X.col_rows.shape[1]):
            raise ValueError(
                f"k_max={k_max} conflicts with the prebuilt PaddedCSC "
                f"width {X.col_rows.shape[1]}; re-pad at conversion time.")
        return PaddedCSCDesign(col_rows=jnp.asarray(X.col_rows),
                               col_vals=jnp.asarray(X.col_vals, dtype=dtype),
                               _n_samples=int(X.shape[0]))
    if all(hasattr(X, a) for a in ("data", "indices", "indptr", "shape")):
        if layout == "dense":
            raise ValueError(
                "CSR input with layout='dense' would densify; pass "
                "layout='padded_csc'/'auto' (or convert explicitly).")
        return PaddedCSCDesign.from_csr(X.data, X.indices, X.indptr,
                                        X.shape, k_max=k_max, dtype=dtype)
    if layout == "padded_csc":
        return PaddedCSCDesign.from_dense(np.asarray(X), k_max=k_max,
                                          dtype=dtype)
    return DenseDesign(X=jnp.asarray(np.asarray(X), dtype=dtype))
