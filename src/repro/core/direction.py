"""One-dimensional Newton direction for the l1 subproblem (paper Eq. 4/5).

    d(w; j) = argmin_d  g d + (1/2) h d^2 + |w_j + d|

with g = grad_j L(w), h = hess_jj L(w) > 0. Closed form (Eq. 5):

    d = -(g + 1)/h   if g + 1 <= h w_j
    d = -(g - 1)/h   if g - 1 >= h w_j
    d = -w_j         otherwise

Vectorized over a bundle; this is exactly what kernels/pcdn_direction
computes in its epilogue on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def newton_direction(g: Array, h: Array, w: Array) -> Array:
    """Eq. 5, elementwise over a bundle. g, h, w: (P,) -> d: (P,)."""
    d_neg = -(g + 1.0) / h  # active when subgradient wants w to move up
    d_pos = -(g - 1.0) / h
    return jnp.where(
        g + 1.0 <= h * w,
        d_neg,
        jnp.where(g - 1.0 >= h * w, d_pos, -w),
    )


def delta_decrement(g: Array, h: Array, w: Array, d: Array,
                    gamma: float) -> Array:
    """Armijo decrement Delta (paper Eq. 7), restricted to the bundle.

    Delta = g.d + gamma d^T H d + ||w+d||_1 - ||w||_1
    (coordinates outside the bundle contribute nothing since d=0 there).
    """
    quad = jnp.sum(h * jnp.square(d))
    lin = jnp.sum(g * d)
    l1 = jnp.sum(jnp.abs(w + d)) - jnp.sum(jnp.abs(w))
    return lin + gamma * quad + l1


def delta_upper_bound(h: Array, d: Array, gamma: float) -> Array:
    """Lemma 1(c) Eq. 16 upper bound: (gamma - 1) d^T H d  (<= 0)."""
    return (gamma - 1.0) * jnp.sum(h * jnp.square(d))
