"""P-dimensional Armijo line search (paper Eq. 6/11, Algorithm 4).

Accept the largest alpha = beta^q, q = 0, 1, 2, ... with

    F_c(w + alpha d) - F_c(w) <= sigma * alpha * Delta            (Eq. 6)

evaluated through the per-sample intermediates (section 3.1):
    z     = X w                     (maintained across iterations)
    delta = X d = X_B d_B           (one matvec per bundle)

    F_c(w + a d) - F_c(w)
      = c * sum_i [phi(z_i + a delta_i) - phi(z_i)] + ||w + a d||_1 - ||w||_1

so no pass over X is needed inside the backtracking loop — the exact
analogue of Algorithm 4's e^{w.x} / d.x bookkeeping, in stable z-space.

Four variants (DESIGN.md sections 3.2 / 11):

  * `armijo_backtracking`   — faithful sequential loop (lax.while_loop),
    identical to Algorithm 4. This is the paper-faithful baseline.
  * `armijo_batched`        — TPU-native: evaluates all Q candidates
    beta^0..beta^{Q-1} in one vectorized pass and selects the first
    satisfying candidate. Same accepted alpha (tested), no sequential
    dependence; this is what kernels/pcdn_linesearch implements.
  * `armijo_chunked`        — the full-scope DEFAULT: while_loop over
    Q-chunks (8 candidates per pass) with early exit, so the (Q, s)
    candidate grid is never materialized and the common one-chunk accept
    costs 8/Q of the batched pass.
  * `armijo_support`        — support-scoped: the same batched grid but
    over the bundle's gathered row support (z_R, delta_R, y_R), each of
    length r_max = P * k_max — O(P * k_max * Q) instead of O(s * Q),
    exact because phi(z_i + alpha * 0) - phi(z_i) == 0 bitwise wherever
    the bundle touches no nonzero of row i.

All return (alpha, n_steps, accepted) where n_steps is q+1 (paper's q^t
counts evaluations) and accepted=False means even the smallest candidate
failed (alpha=0 returned; cannot happen in theory per Thm 2, but guards
float underflow).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.losses import Loss

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ArmijoParams:
    """Paper section 5.1: sigma=0.01, gamma=0, beta=0.5 for all solvers."""

    beta: float = 0.5
    sigma: float = 0.01
    gamma: float = 0.0
    max_steps: int = 40  # beta^40 ~ 1e-12: below this alpha is numerically 0


class LineSearchResult(NamedTuple):
    alpha: Array      # scalar, accepted step size (0.0 if not accepted)
    n_steps: Array    # int32, number of candidates evaluated (q + 1)
    accepted: Array   # bool


def objective_delta(loss: Loss, c: float, z: Array, delta: Array, y: Array,
                    w_B: Array, d_B: Array, alpha: Array,
                    l2: float = 0.0) -> Array:
    """F_c(w + alpha d) - F_c(w) through intermediates. alpha: scalar.
    `l2` adds the elastic-net quadratic (l2/2)(||w+ad||^2 - ||w||^2) on
    the bundle coordinates (d = 0 elsewhere)."""
    lo = c * jnp.sum(loss.value(z + alpha * delta, y) - loss.value(z, y))
    l1 = jnp.sum(jnp.abs(w_B + alpha * d_B)) - jnp.sum(jnp.abs(w_B))
    out = lo + l1
    if l2:
        out = out + 0.5 * l2 * (jnp.sum(jnp.square(w_B + alpha * d_B)) -
                                jnp.sum(jnp.square(w_B)))
    return out


def objective_delta_batched(loss: Loss, c: float, z: Array, delta: Array,
                            y: Array, w_B: Array, d_B: Array,
                            alphas: Array, l2: float = 0.0) -> Array:
    """Vectorized over a (Q,) vector of candidate alphas -> (Q,) deltas.

    Loss part broadcasts (Q, 1) x (s,) -> (Q, s); reduced over samples.
    The (Q, s) grid is materialized here, so large-s callers go through
    `armijo_chunked` (the full-scope solver default — it feeds this
    function chunk-sized alpha vectors) or `armijo_support` (which
    passes r_max-sized gathered arrays); the sharded solver reduces the
    (Q,) partials with a single psum instead (DESIGN.md sections 3.2 /
    3.4 / 11).
    """
    zq = z[None, :] + alphas[:, None] * delta[None, :]
    lo = c * jnp.sum(loss.value(zq, y[None, :]) - loss.value(z, y)[None, :],
                     axis=-1)
    wq = w_B[None, :] + alphas[:, None] * d_B[None, :]
    l1 = jnp.sum(jnp.abs(wq), axis=-1) - jnp.sum(jnp.abs(w_B))
    out = lo + l1
    if l2:
        out = out + 0.5 * l2 * (jnp.sum(jnp.square(wq), axis=-1) -
                                jnp.sum(jnp.square(w_B)))
    return out


def armijo_backtracking(loss: Loss, c: float, z: Array, delta: Array,
                        y: Array, w_B: Array, d_B: Array, Delta: Array,
                        params: ArmijoParams,
                        l2: float = 0.0) -> LineSearchResult:
    """Faithful Algorithm 4: try alpha = 1, beta, beta^2, ... sequentially."""
    sigma = params.sigma
    beta = params.beta

    def cond(state):
        q, alpha, done = state
        return jnp.logical_and(~done, q < params.max_steps)

    def body(state):
        q, alpha, _ = state
        f_delta = objective_delta(loss, c, z, delta, y, w_B, d_B, alpha, l2)
        ok = f_delta <= sigma * alpha * Delta
        next_alpha = jnp.where(ok, alpha, alpha * beta)
        return q + 1, next_alpha, ok

    q, alpha, ok = jax.lax.while_loop(
        cond, body, (jnp.int32(0), jnp.asarray(1.0, z.dtype),
                     jnp.asarray(False)))
    alpha = jnp.where(ok, alpha, 0.0)
    return LineSearchResult(alpha=alpha, n_steps=q, accepted=ok)


def candidate_alphas(params: ArmijoParams, dtype=jnp.float32) -> Array:
    """beta^0 .. beta^{max_steps-1}."""
    q = jnp.arange(params.max_steps, dtype=dtype)
    return jnp.power(jnp.asarray(params.beta, dtype), q)


def select_first_satisfying(f_deltas: Array, alphas: Array,
                            Delta: Array, sigma: float) -> LineSearchResult:
    """Given per-candidate objective deltas, pick the first Armijo-accepted
    alpha (largest candidate). Shared by the jnp path and the Pallas kernel
    wrapper."""
    ok = f_deltas <= sigma * alphas * Delta
    any_ok = jnp.any(ok)
    first = jnp.argmax(ok)  # first True (argmax returns lowest index)
    alpha = jnp.where(any_ok, alphas[first], 0.0)
    return LineSearchResult(alpha=alpha,
                            n_steps=jnp.asarray(first + 1, jnp.int32),
                            accepted=any_ok)


def armijo_batched(loss: Loss, c: float, z: Array, delta: Array, y: Array,
                   w_B: Array, d_B: Array, Delta: Array,
                   params: ArmijoParams, l2: float = 0.0) -> LineSearchResult:
    """TPU-native variant: one vectorized pass over all candidates."""
    alphas = candidate_alphas(params, z.dtype)
    f_deltas = objective_delta_batched(loss, c, z, delta, y, w_B, d_B,
                                       alphas, l2)
    return select_first_satisfying(f_deltas, alphas, Delta, params.sigma)


def armijo_chunked(loss: Loss, c: float, z: Array, delta: Array, y: Array,
                   w_B: Array, d_B: Array, Delta: Array,
                   params: ArmijoParams, l2: float = 0.0,
                   chunk: int = 8) -> LineSearchResult:
    """Chunked early-exit variant: the full-scope solver default.

    Evaluates candidates in while_loop chunks of `chunk`, stopping at the
    first chunk containing a satisfying alpha. Peak work per pass is
    (chunk, s) instead of (Q, s), and since alpha = 1 or beta is accepted
    on almost every bundle (paper Table 4: mean q^t ~ 1), the typical
    cost is one chunk. Accepted alpha and n_steps match armijo_batched
    exactly; when NO candidate satisfies (never per Thm 2) n_steps is Q
    — every candidate really was evaluated — where the batched variant
    reports 1.
    """
    alphas = candidate_alphas(params, z.dtype)
    Q = alphas.shape[0]
    chunk = min(chunk, Q)
    n_chunks = -(-Q // chunk)
    # pad with the smallest candidate: a duplicate can never be the FIRST
    # satisfying alpha (its original either passed earlier or also fails)
    alphas_p = jnp.concatenate(
        [alphas, jnp.full((n_chunks * chunk - Q,), alphas[-1], alphas.dtype)])
    sigma = params.sigma

    def cond(st):
        i, _alpha, _n, done = st
        return jnp.logical_and(~done, i < n_chunks)

    def body(st):
        i, _alpha, _n, _done = st
        a = jax.lax.dynamic_slice(alphas_p, (i * chunk,), (chunk,))
        f_deltas = objective_delta_batched(loss, c, z, delta, y, w_B, d_B,
                                           a, l2)
        ok = f_deltas <= sigma * a * Delta
        any_ok = jnp.any(ok)
        first = jnp.argmax(ok)
        alpha = jnp.where(any_ok, a[first], 0.0)
        n = jnp.where(any_ok, i * chunk + first + 1, Q).astype(jnp.int32)
        return i + 1, alpha, n, any_ok

    _, alpha, n_steps, accepted = jax.lax.while_loop(
        cond, body, (jnp.int32(0), jnp.asarray(0.0, z.dtype),
                     jnp.int32(Q), jnp.asarray(False)))
    return LineSearchResult(alpha=alpha, n_steps=n_steps, accepted=accepted)


def armijo_support(loss: Loss, c: float, z_R: Array, delta_R: Array,
                   y_R: Array, w_B: Array, d_B: Array, Delta: Array,
                   params: ArmijoParams, l2: float = 0.0) -> LineSearchResult:
    """Support-scoped batched search (DESIGN.md section 11).

    z_R / delta_R / y_R are the per-sample intermediates gathered at the
    bundle's (r_max,) row support (`PaddedCSCDesign.slab_row_support`),
    sentinel slots filled with z = delta = 0 — their candidate loss
    delta is phi(0 + alpha * 0) - phi(0) == 0 bitwise, so the (Q, r_max)
    grid computes exactly the full-scope objective delta while touching
    r_max <= P * k_max rows instead of all s samples.
    """
    alphas = candidate_alphas(params, z_R.dtype)
    f_deltas = objective_delta_batched(loss, c, z_R, delta_R, y_R, w_B, d_B,
                                       alphas, l2)
    return select_first_satisfying(f_deltas, alphas, Delta, params.sigma)
