"""P-dimensional Armijo line search (paper Eq. 6/11, Algorithm 4).

Accept the largest alpha = beta^q, q = 0, 1, 2, ... with

    F_c(w + alpha d) - F_c(w) <= sigma * alpha * Delta            (Eq. 6)

evaluated through the per-sample intermediates (section 3.1):
    z     = X w                     (maintained across iterations)
    delta = X d = X_B d_B           (one matvec per bundle)

    F_c(w + a d) - F_c(w)
      = c * sum_i [phi(z_i + a delta_i) - phi(z_i)] + ||w + a d||_1 - ||w||_1

so no pass over X is needed inside the backtracking loop — the exact
analogue of Algorithm 4's e^{w.x} / d.x bookkeeping, in stable z-space.

Two variants (DESIGN.md section 3.2):

  * `armijo_backtracking`   — faithful sequential loop (lax.while_loop),
    identical to Algorithm 4. This is the paper-faithful baseline.
  * `armijo_batched`        — TPU-native: evaluates all Q candidates
    beta^0..beta^{Q-1} in one vectorized pass and selects the first
    satisfying candidate. Same accepted alpha (tested), no sequential
    dependence; this is what kernels/pcdn_linesearch implements.

Both return (alpha, n_steps, accepted) where n_steps is q+1 (paper's q^t
counts evaluations) and accepted=False means even the smallest candidate
failed (alpha=0 returned; cannot happen in theory per Thm 2, but guards
float underflow).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.losses import Loss

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ArmijoParams:
    """Paper section 5.1: sigma=0.01, gamma=0, beta=0.5 for all solvers."""

    beta: float = 0.5
    sigma: float = 0.01
    gamma: float = 0.0
    max_steps: int = 40  # beta^40 ~ 1e-12: below this alpha is numerically 0


class LineSearchResult(NamedTuple):
    alpha: Array      # scalar, accepted step size (0.0 if not accepted)
    n_steps: Array    # int32, number of candidates evaluated (q + 1)
    accepted: Array   # bool


def objective_delta(loss: Loss, c: float, z: Array, delta: Array, y: Array,
                    w_B: Array, d_B: Array, alpha: Array,
                    l2: float = 0.0) -> Array:
    """F_c(w + alpha d) - F_c(w) through intermediates. alpha: scalar.
    `l2` adds the elastic-net quadratic (l2/2)(||w+ad||^2 - ||w||^2) on
    the bundle coordinates (d = 0 elsewhere)."""
    lo = c * jnp.sum(loss.value(z + alpha * delta, y) - loss.value(z, y))
    l1 = jnp.sum(jnp.abs(w_B + alpha * d_B)) - jnp.sum(jnp.abs(w_B))
    out = lo + l1
    if l2:
        out = out + 0.5 * l2 * (jnp.sum(jnp.square(w_B + alpha * d_B)) -
                                jnp.sum(jnp.square(w_B)))
    return out


def objective_delta_batched(loss: Loss, c: float, z: Array, delta: Array,
                            y: Array, w_B: Array, d_B: Array,
                            alphas: Array, l2: float = 0.0) -> Array:
    """Vectorized over a (Q,) vector of candidate alphas -> (Q,) deltas.

    Loss part broadcasts (Q, 1) x (s,) -> (Q, s); reduced over samples.
    For very large s callers should chunk (the sharded solver reduces the
    (Q,) partials with a single psum — DESIGN.md section 3.4).
    """
    zq = z[None, :] + alphas[:, None] * delta[None, :]
    lo = c * jnp.sum(loss.value(zq, y[None, :]) - loss.value(z, y)[None, :],
                     axis=-1)
    wq = w_B[None, :] + alphas[:, None] * d_B[None, :]
    l1 = jnp.sum(jnp.abs(wq), axis=-1) - jnp.sum(jnp.abs(w_B))
    out = lo + l1
    if l2:
        out = out + 0.5 * l2 * (jnp.sum(jnp.square(wq), axis=-1) -
                                jnp.sum(jnp.square(w_B)))
    return out


def armijo_backtracking(loss: Loss, c: float, z: Array, delta: Array,
                        y: Array, w_B: Array, d_B: Array, Delta: Array,
                        params: ArmijoParams,
                        l2: float = 0.0) -> LineSearchResult:
    """Faithful Algorithm 4: try alpha = 1, beta, beta^2, ... sequentially."""
    sigma = params.sigma
    beta = params.beta

    def cond(state):
        q, alpha, done = state
        return jnp.logical_and(~done, q < params.max_steps)

    def body(state):
        q, alpha, _ = state
        f_delta = objective_delta(loss, c, z, delta, y, w_B, d_B, alpha, l2)
        ok = f_delta <= sigma * alpha * Delta
        next_alpha = jnp.where(ok, alpha, alpha * beta)
        return q + 1, next_alpha, ok

    q, alpha, ok = jax.lax.while_loop(
        cond, body, (jnp.int32(0), jnp.asarray(1.0, z.dtype),
                     jnp.asarray(False)))
    alpha = jnp.where(ok, alpha, 0.0)
    return LineSearchResult(alpha=alpha, n_steps=q, accepted=ok)


def candidate_alphas(params: ArmijoParams, dtype=jnp.float32) -> Array:
    """beta^0 .. beta^{max_steps-1}."""
    q = jnp.arange(params.max_steps, dtype=dtype)
    return jnp.power(jnp.asarray(params.beta, dtype), q)


def select_first_satisfying(f_deltas: Array, alphas: Array,
                            Delta: Array, sigma: float) -> LineSearchResult:
    """Given per-candidate objective deltas, pick the first Armijo-accepted
    alpha (largest candidate). Shared by the jnp path and the Pallas kernel
    wrapper."""
    ok = f_deltas <= sigma * alphas * Delta
    any_ok = jnp.any(ok)
    first = jnp.argmax(ok)  # first True (argmax returns lowest index)
    alpha = jnp.where(any_ok, alphas[first], 0.0)
    return LineSearchResult(alpha=alpha,
                            n_steps=jnp.asarray(first + 1, jnp.int32),
                            accepted=any_ok)


def armijo_batched(loss: Loss, c: float, z: Array, delta: Array, y: Array,
                   w_B: Array, d_B: Array, Delta: Array,
                   params: ArmijoParams, l2: float = 0.0) -> LineSearchResult:
    """TPU-native variant: one vectorized pass over all candidates."""
    alphas = candidate_alphas(params, z.dtype)
    f_deltas = objective_delta_batched(loss, c, z, delta, y, w_B, d_B,
                                       alphas, l2)
    return select_first_satisfying(f_deltas, alphas, Delta, params.sigma)
