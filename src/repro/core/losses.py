"""Loss functions for l1-regularized minimization (paper Eq. 1-3).

Every loss is expressed through the per-sample margin ``z_i = w . x_i``,
which is the intermediate quantity the paper maintains (section 3.1 keeps
``e^{w.x_i}``; we keep ``z`` itself and use log1p-stable forms — see
DESIGN.md section 3.3).

For a loss ``phi(z, y)`` the solver needs:
  * ``value(z, y)``   — per-sample loss values, numerically stable
  * ``dz(z, y)``      — d phi / d z        (gradient factor)
  * ``d2z(z, y)``     — d^2 phi / d z^2    (diagonal-Hessian factor;
                         generalized second derivative for L2-SVM)
  * ``theta``         — the Lemma 1(b) constant: 1/4 (logistic), 2 (svm)

The full objective is ``F_c(w) = c * sum_i phi(z_i, y_i) + ||w||_1``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array

# Positive floor added to Hessian diagonal entries so the Newton step is
# well defined (paper footnote 1 / Lemma 1(b): nu = 1e-12 for L2-SVM; we
# apply it uniformly — for logistic it is inactive in practice).
HESSIAN_FLOOR = 1e-12


def _softplus(m: Array) -> Array:
    """log(1 + e^m), stable for any m."""
    return jnp.maximum(m, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(m)))


def _sigmoid(m: Array) -> Array:
    return jax.nn.sigmoid(m)


@dataclasses.dataclass(frozen=True)
class Loss:
    """A convex per-sample loss phi(z; y) with margin z = w.x."""

    name: str
    value: Callable[[Array, Array], Array]
    dz: Callable[[Array, Array], Array]
    d2z: Callable[[Array, Array], Array]
    theta: float  # Lemma 1(b): d2z <= theta * 1 pointwise in the paper's scaling

    def margin_objective(self, z: Array, y: Array, c: float) -> Array:
        """c * sum_i phi(z_i, y_i)  (loss part of F_c)."""
        return c * jnp.sum(self.value(z, y))


# --- logistic regression (paper Eq. 2) --------------------------------------
# phi = log(1 + exp(-y z));  tau(s) = 1/(1+e^{-s})
# dphi/dz   = (tau(yz) - 1) * y
# d2phi/dz2 = tau(yz)(1 - tau(yz))


def _log_value(z: Array, y: Array) -> Array:
    return _softplus(-y * z)


def _log_dz(z: Array, y: Array) -> Array:
    return (_sigmoid(y * z) - 1.0) * y


def _log_d2z(z: Array, y: Array) -> Array:
    t = _sigmoid(y * z)
    return t * (1.0 - t)


LOGISTIC = Loss("logistic", _log_value, _log_dz, _log_d2z, theta=0.25)


# --- L2-loss SVM (squared hinge, paper Eq. 3) --------------------------------
# phi = max(0, 1 - y z)^2
# dphi/dz   = -2 y max(0, 1 - y z)
# d2phi/dz2 = 2 * 1[y z < 1]   (generalized)


def _svm_value(z: Array, y: Array) -> Array:
    return jnp.square(jnp.maximum(0.0, 1.0 - y * z))


def _svm_dz(z: Array, y: Array) -> Array:
    return -2.0 * y * jnp.maximum(0.0, 1.0 - y * z)


def _svm_d2z(z: Array, y: Array) -> Array:
    return 2.0 * (y * z < 1.0).astype(z.dtype)


SQUARED_HINGE = Loss("squared_hinge", _svm_value, _svm_dz, _svm_d2z, theta=2.0)


# --- squared loss (Lasso; paper section 6 extension) -------------------------
# phi = 0.5 (z - y)^2  with y real-valued


def _sq_value(z: Array, y: Array) -> Array:
    return 0.5 * jnp.square(z - y)


def _sq_dz(z: Array, y: Array) -> Array:
    return z - y


def _sq_d2z(z: Array, y: Array) -> Array:
    return jnp.ones_like(z)


SQUARED = Loss("squared", _sq_value, _sq_dz, _sq_d2z, theta=1.0)


LOSSES = {l.name: l for l in (LOGISTIC, SQUARED_HINGE, SQUARED)}


def get_loss(name: str) -> Loss:
    if name not in LOSSES:
        raise KeyError(f"unknown loss {name!r}; have {sorted(LOSSES)}")
    return LOSSES[name]
