"""PCDN — Parallel Coordinate Descent Newton (paper Algorithm 3).

Outer iteration k:
  1. randomly partition N into b = ceil(n/P) bundles          (Eq. 8)
  2. for each bundle B^t sequentially (Gauss-Seidel):
     a. P one-dimensional Newton directions in parallel       (Eq. 4/5/10)
     b. one P-dimensional Armijo line search along d^t        (Eq. 6/11)
     c. w += alpha d ;  z += alpha * X_B d_B                  (Alg. 4 step 5)

CDN (Yuan et al. 2010) is exactly this solver with P=1 (`cdn_config`).

The inner loop is a single `lax.scan` over bundles, so one outer iteration
is one XLA computation; per-sample intermediates z live in the carry, which
is the paper's "maintain e^{w.x_i}" technique (section 3.1) in z-space.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bundles as B
from repro.core.design_matrix import SparseSlab
from repro.core.direction import delta_decrement, newton_direction
from repro.core.linesearch import (ArmijoParams, armijo_backtracking,
                                   armijo_batched)
from repro.core.problem import L1Problem

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PCDNConfig:
    P: int                       # bundle size == degree of parallelism
    armijo: ArmijoParams = ArmijoParams()
    max_outer: int = 200
    tol_kkt: float = 1e-3        # stop when KKT violation <= tol_kkt
    tol_rel_obj: float = 0.0     # optional: stop when F <= (1+tol)(F*) given f_star
    ls_kind: str = "batched"     # "batched" (TPU-native) | "backtracking" (faithful)
    seed: int = 0
    use_kernels: bool = False    # route bundle math through Pallas kernels
    # -- active-set shrinking (CDN heritage; DESIGN.md section 8.2) ----------
    shrink: bool = False         # mask near-optimal zero features out of bundles
    shrink_tol: float = 0.01     # shrink j when w_j == 0 and |g_j| < 1 - shrink_tol
    recheck_every: int = 1       # full-set KKT recheck period (un-shrinks violators)


def cdn_config(**kw) -> PCDNConfig:
    """CDN = PCDN with bundle size 1 (paper section 2.1)."""
    kw.setdefault("ls_kind", "backtracking")
    return PCDNConfig(P=1, **kw)


class SolveHistory(NamedTuple):
    outer_iter: np.ndarray     # (K,)
    objective: np.ndarray      # (K,) F_c(w) after each outer iteration
    kkt: np.ndarray            # (K,)
    nnz: np.ndarray            # (K,) number of nonzeros in w
    ls_steps: np.ndarray       # (K,) mean line-search steps per bundle
    wall_time: np.ndarray      # (K,) cumulative seconds
    n_active: np.ndarray       # (K,) un-shrunk features (== n without shrink)


class SolveResult(NamedTuple):
    w: Array
    objective: float
    n_outer: int
    converged: bool
    history: SolveHistory


def _line_search_fn(cfg: PCDNConfig) -> Callable:
    if cfg.ls_kind == "batched":
        return armijo_batched
    if cfg.ls_kind == "backtracking":
        return armijo_backtracking
    raise ValueError(f"unknown ls_kind {cfg.ls_kind!r}")


def make_bundle_step(problem: L1Problem, cfg: PCDNConfig):
    """One inner iteration t (steps 6-11 of Algorithm 3) as a scan body."""
    loss = problem.loss
    ls = _line_search_fn(cfg)
    gamma = cfg.armijo.gamma

    if cfg.use_kernels:
        from repro.kernels import ops as kops

    def step(carry, idx):
        w, z = carry
        slab = problem.design.gather_slab(idx)
        w_B, _ = B.gather_vec(w, idx)
        if cfg.use_kernels:
            u = problem.grad_factor(z)
            v = problem.hess_factor(z)
            if isinstance(slab, SparseSlab):
                d, g, h = kops.pcdn_sparse_direction(
                    slab.rows, slab.vals, u, v, w_B,
                    l2=problem.elastic_net_l2)
            else:
                d, g, h = kops.pcdn_direction(
                    slab.XB, u, v, w_B, l2=problem.elastic_net_l2)
        else:
            g, h = problem.bundle_grad_hess(z, slab, w_B)
            d = newton_direction(g, h, w_B)
        Delta = delta_decrement(g, h, w_B, d, gamma)
        delta_z = problem.design.slab_matvec(slab, d)
        res = ls(loss, problem.c, z, delta_z, problem.y, w_B, d, Delta,
                 cfg.armijo, l2=problem.elastic_net_l2)
        w = B.scatter_add(w, idx, res.alpha * d)
        z = z + res.alpha * delta_z
        return (w, z), (res.n_steps, res.alpha)

    return step


def make_outer_iteration(problem: L1Problem, cfg: PCDNConfig):
    """jit-able: one full outer iteration (all b bundles) + diagnostics."""
    n = problem.n_features
    step = make_bundle_step(problem, cfg)

    def outer(w: Array, z: Array, key: Array):
        key, sub = jax.random.split(key)
        idxs = B.partition(sub, n, cfg.P)                  # (b, P)
        (w, z), (steps, alphas) = jax.lax.scan(step, (w, z), idxs)
        f = problem.objective_from_margins(z, w)           # incl. l2 term
        kkt = problem.kkt_violation(w, z)
        nnz = jnp.sum(w != 0)
        return w, z, key, f, kkt, nnz, jnp.mean(steps.astype(jnp.float32))

    return jax.jit(outer)


def make_path_outer(problem: L1Problem, cfg: PCDNConfig):
    """The regularization-path engine's outer iteration (DESIGN.md section 8).

    A single jitted function reused across every path point and shrink
    state — none of the quantities that vary along a λ-sweep is baked in:

        outer(w, z, key, active, recheck, c)
          -> (w, z, key, f, kkt, nnz, mean_q, active, n_active)

    * `c` is a traced scalar (problem.with_c substitution), so a 20-point
      c-grid compiles ONCE instead of 20 times.
    * `active` is the (n,) un-shrunk mask. Bundles are drawn from the
      active set only (bundles.partition_active) and the bundle loop is a
      fori_loop with the dynamic trip count ceil(n_active / P): shrunk
      features keep their slots (static shapes) but cost zero compute.
    * `kkt` is always the FULL-set violation — the full gradient is
      already needed for the stop criterion, so the shrink bookkeeping is
      free. Shrinking masks j when w_j == 0 and |g_j| < 1 - shrink_tol
      (strictly interior to the l1 subdifferential box, per CDN's
      shrinking heritage); when `recheck` is set, any feature whose
      violation exceeds tol_kkt is un-shrunk again, so a wrongly shrunk
      feature survives at most recheck_every outer iterations.

    With cfg.shrink=False the active mask passes through untouched and
    the bundle loop covers the full feature set — the scan-based
    make_outer_iteration and this function then compute the same update
    (modulo the independent random partition draw).
    """
    n = problem.n_features

    def outer(w: Array, z: Array, key: Array, active: Array,
              recheck: Array, c: Array):
        prob = problem.with_c(c)
        step = make_bundle_step(prob, cfg)
        key, sub = jax.random.split(key)
        if cfg.shrink:
            idxs, b_active = B.partition_active(sub, active, cfg.P)

            def body(t, carry):
                (w, z), q_sum = carry
                (w, z), (q, _alpha) = step((w, z), idxs[t])
                return (w, z), q_sum + q.astype(jnp.float32)

            (w, z), q_sum = jax.lax.fori_loop(
                0, b_active, body, ((w, z), jnp.float32(0.0)))
            mean_q = q_sum / jnp.maximum(b_active, 1).astype(jnp.float32)
        else:
            idxs = B.partition(sub, n, cfg.P)
            (w, z), (steps, _alphas) = jax.lax.scan(step, (w, z), idxs)
            mean_q = jnp.mean(steps.astype(jnp.float32))

        f = prob.objective_from_margins(z, w)
        g = prob.full_grad(z, w)
        viol = prob.kkt_violation_from_grad(w, g)
        kkt = jnp.max(viol)
        if cfg.shrink:
            interior = (w == 0) & (jnp.abs(g) < 1.0 - cfg.shrink_tol)
            active = active & ~interior
            active = active | (recheck & (viol > cfg.tol_kkt))
        nnz = jnp.sum(w != 0)
        n_active = jnp.sum(active.astype(jnp.int32))
        return w, z, key, f, kkt, nnz, mean_q, active, n_active

    return jax.jit(outer)


def run_outer_loop(problem: L1Problem, cfg: PCDNConfig, outer,
                   w: Array, z: Array, key: Array, active: Array,
                   c: float,
                   f_star: Optional[float] = None,
                   callback: Optional[Callable] = None):
    """Host-side convergence loop around a `make_path_outer` iteration.

    Shared by solve() (shrink mode) and the path driver, so the stop
    logic (full-set KKT, optional relative-objective) and history
    recording exist once. Returns (w, z, key, active, SolveResult).
    """
    c_arr = jnp.asarray(c, problem.dtype)
    hist = {k: [] for k in SolveHistory._fields}
    t0 = time.perf_counter()
    converged = False
    f = float(problem.with_c(float(c)).objective_from_margins(z, w))
    k = 0
    for k in range(cfg.max_outer):
        # iteration 0 always rechecks so a stale warm-started active set
        # (e.g. carried across path points) is repaired immediately.
        recheck = jnp.asarray(k == 0 or cfg.recheck_every <= 1
                              or k % cfg.recheck_every == 0)
        w, z, key, f_, kkt, nnz, mean_q, active, n_active = outer(
            w, z, key, active, recheck, c_arr)
        f = float(f_)
        hist["outer_iter"].append(k)
        hist["objective"].append(f)
        hist["kkt"].append(float(kkt))
        hist["nnz"].append(int(nnz))
        hist["ls_steps"].append(float(mean_q))
        hist["wall_time"].append(time.perf_counter() - t0)
        hist["n_active"].append(int(n_active))
        if callback is not None:
            callback(k, w, f, float(kkt))
        if float(kkt) <= cfg.tol_kkt:
            converged = True
            break
        if f_star is not None and cfg.tol_rel_obj > 0:
            if (f - f_star) <= cfg.tol_rel_obj * abs(f_star):
                converged = True
                break
    history = SolveHistory(**{k: np.asarray(v) for k, v in hist.items()})
    result = SolveResult(w=w, objective=f, n_outer=k + 1,
                         converged=converged, history=history)
    return w, z, key, active, result


def solve(problem: L1Problem, cfg: PCDNConfig,
          w0: Optional[Array] = None,
          f_star: Optional[float] = None,
          callback: Optional[Callable] = None) -> SolveResult:
    """Run PCDN until the KKT (or relative-objective) stop or max_outer."""
    n = problem.n_features
    w = jnp.zeros((n,), problem.dtype) if w0 is None else w0
    z = problem.margins(w)
    key = jax.random.PRNGKey(cfg.seed)

    if cfg.shrink:
        outer = make_path_outer(problem, cfg)
    else:
        # adapt the legacy static-c iteration (identical compiled program
        # to previous releases) to the run_outer_loop signature
        legacy = make_outer_iteration(problem, cfg)

        def outer(w, z, key, active, recheck, c):
            w, z, key, f, kkt, nnz, mean_q = legacy(w, z, key)
            return w, z, key, f, kkt, nnz, mean_q, active, n

    active = jnp.ones((n,), bool)
    *_, result = run_outer_loop(problem, cfg, outer, w, z, key, active,
                                problem.c, f_star=f_star,
                                callback=callback)
    return result
