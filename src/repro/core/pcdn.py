"""PCDN — Parallel Coordinate Descent Newton (paper Algorithm 3).

Outer iteration k:
  1. randomly partition N into b = ceil(n/P) bundles          (Eq. 8)
  2. for each bundle B^t sequentially (Gauss-Seidel):
     a. P one-dimensional Newton directions in parallel       (Eq. 4/5/10)
     b. one P-dimensional Armijo line search along d^t        (Eq. 6/11)
     c. w += alpha d ;  z += alpha * X_B d_B                  (Alg. 4 step 5)

CDN (Yuan et al. 2010) is exactly this solver with P=1 (`cdn_config`).

The inner loop is a single `lax.scan` over bundles, so one outer iteration
is one XLA computation; per-sample intermediates z live in the carry, which
is the paper's "maintain e^{w.x_i}" technique (section 3.1) in z-space.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bundles as B
from repro.core.design_matrix import SparseSlab
from repro.core.direction import delta_decrement, newton_direction
from repro.core.linesearch import (ArmijoParams, armijo_backtracking,
                                   armijo_batched)
from repro.core.problem import L1Problem

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PCDNConfig:
    P: int                       # bundle size == degree of parallelism
    armijo: ArmijoParams = ArmijoParams()
    max_outer: int = 200
    tol_kkt: float = 1e-3        # stop when KKT violation <= tol_kkt
    tol_rel_obj: float = 0.0     # optional: stop when F <= (1+tol)(F*) given f_star
    ls_kind: str = "batched"     # "batched" (TPU-native) | "backtracking" (faithful)
    seed: int = 0
    use_kernels: bool = False    # route bundle math through Pallas kernels


def cdn_config(**kw) -> PCDNConfig:
    """CDN = PCDN with bundle size 1 (paper section 2.1)."""
    kw.setdefault("ls_kind", "backtracking")
    return PCDNConfig(P=1, **kw)


class SolveHistory(NamedTuple):
    outer_iter: np.ndarray     # (K,)
    objective: np.ndarray      # (K,) F_c(w) after each outer iteration
    kkt: np.ndarray            # (K,)
    nnz: np.ndarray            # (K,) number of nonzeros in w
    ls_steps: np.ndarray       # (K,) mean line-search steps per bundle
    wall_time: np.ndarray      # (K,) cumulative seconds


class SolveResult(NamedTuple):
    w: Array
    objective: float
    n_outer: int
    converged: bool
    history: SolveHistory


def _line_search_fn(cfg: PCDNConfig) -> Callable:
    if cfg.ls_kind == "batched":
        return armijo_batched
    if cfg.ls_kind == "backtracking":
        return armijo_backtracking
    raise ValueError(f"unknown ls_kind {cfg.ls_kind!r}")


def make_bundle_step(problem: L1Problem, cfg: PCDNConfig):
    """One inner iteration t (steps 6-11 of Algorithm 3) as a scan body."""
    loss = problem.loss
    ls = _line_search_fn(cfg)
    gamma = cfg.armijo.gamma

    if cfg.use_kernels:
        from repro.kernels import ops as kops

    def step(carry, idx):
        w, z = carry
        slab = problem.design.gather_slab(idx)
        w_B, _ = B.gather_vec(w, idx)
        if cfg.use_kernels:
            u = problem.grad_factor(z)
            v = problem.hess_factor(z)
            if isinstance(slab, SparseSlab):
                d, g, h = kops.pcdn_sparse_direction(
                    slab.rows, slab.vals, u, v, w_B,
                    l2=problem.elastic_net_l2)
            else:
                d, g, h = kops.pcdn_direction(
                    slab.XB, u, v, w_B, l2=problem.elastic_net_l2)
        else:
            g, h = problem.bundle_grad_hess(z, slab, w_B)
            d = newton_direction(g, h, w_B)
        Delta = delta_decrement(g, h, w_B, d, gamma)
        delta_z = problem.design.slab_matvec(slab, d)
        res = ls(loss, problem.c, z, delta_z, problem.y, w_B, d, Delta,
                 cfg.armijo, l2=problem.elastic_net_l2)
        w = B.scatter_add(w, idx, res.alpha * d)
        z = z + res.alpha * delta_z
        return (w, z), (res.n_steps, res.alpha)

    return step


def make_outer_iteration(problem: L1Problem, cfg: PCDNConfig):
    """jit-able: one full outer iteration (all b bundles) + diagnostics."""
    n = problem.n_features
    step = make_bundle_step(problem, cfg)

    def outer(w: Array, z: Array, key: Array):
        key, sub = jax.random.split(key)
        idxs = B.partition(sub, n, cfg.P)                  # (b, P)
        (w, z), (steps, alphas) = jax.lax.scan(step, (w, z), idxs)
        f = problem.objective_from_margins(z, w)           # incl. l2 term
        kkt = problem.kkt_violation(w, z)
        nnz = jnp.sum(w != 0)
        return w, z, key, f, kkt, nnz, jnp.mean(steps.astype(jnp.float32))

    return jax.jit(outer)


def solve(problem: L1Problem, cfg: PCDNConfig,
          w0: Optional[Array] = None,
          f_star: Optional[float] = None,
          callback: Optional[Callable] = None) -> SolveResult:
    """Run PCDN until the KKT (or relative-objective) stop or max_outer."""
    n = problem.n_features
    w = jnp.zeros((n,), problem.dtype) if w0 is None else w0
    z = problem.margins(w)
    key = jax.random.PRNGKey(cfg.seed)
    outer = make_outer_iteration(problem, cfg)

    hist = {k: [] for k in SolveHistory._fields}
    t0 = time.perf_counter()
    converged = False
    f = float(problem.objective_from_margins(z, w))
    k = 0
    for k in range(cfg.max_outer):
        w, z, key, f_, kkt, nnz, mean_q = outer(w, z, key)
        f = float(f_)
        hist["outer_iter"].append(k)
        hist["objective"].append(f)
        hist["kkt"].append(float(kkt))
        hist["nnz"].append(int(nnz))
        hist["ls_steps"].append(float(mean_q))
        hist["wall_time"].append(time.perf_counter() - t0)
        if callback is not None:
            callback(k, w, f, float(kkt))
        if float(kkt) <= cfg.tol_kkt:
            converged = True
            break
        if f_star is not None and cfg.tol_rel_obj > 0:
            if (f - f_star) <= cfg.tol_rel_obj * abs(f_star):
                converged = True
                break

    history = SolveHistory(**{k: np.asarray(v) for k, v in hist.items()})
    return SolveResult(w=w, objective=f, n_outer=k + 1,
                       converged=converged, history=history)
