"""PCDN — Parallel Coordinate Descent Newton (paper Algorithm 3).

Outer iteration k:
  1. randomly partition N into b = ceil(n/P) bundles          (Eq. 8)
  2. for each bundle B^t sequentially (Gauss-Seidel):
     a. P one-dimensional Newton directions in parallel       (Eq. 4/5/10)
     b. one P-dimensional Armijo line search along d^t        (Eq. 6/11)
     c. w += alpha d ;  z += alpha * X_B d_B                  (Alg. 4 step 5)

CDN (Yuan et al. 2010) is exactly this solver with P=1 (`cdn_config`).

The inner loop is a single `lax.scan` over bundles, so one outer iteration
is one XLA computation; per-sample intermediates z live in the carry, which
is the paper's "maintain e^{w.x_i}" technique (section 3.1) in z-space.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import bundles as B
from repro.core.design_matrix import PaddedCSCDesign, SparseSlab
from repro.core.direction import delta_decrement, newton_direction
from repro.core.linesearch import (ArmijoParams, armijo_backtracking,
                                   armijo_batched, armijo_chunked,
                                   armijo_support, candidate_alphas)
from repro.core.problem import L1Problem
# history/result containers + the host convergence loop live in the
# engine layer now (DESIGN.md section 9); re-exported here for compat.
from repro.engine.loop import SolveHistory, SolveResult  # noqa: F401

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class PCDNConfig:
    P: int                       # bundle size == degree of parallelism
    armijo: ArmijoParams = ArmijoParams()
    max_outer: int = 200
    tol_kkt: float = 1e-3        # stop when KKT violation <= tol_kkt
    tol_rel_obj: float = 0.0     # optional: stop when F <= (1+tol)(F*) given f_star
    ls_kind: str = "batched"     # "batched" (TPU-native) | "backtracking" (faithful)
    # -- line-search / margin-maintenance scope (DESIGN.md section 11) -------
    # "support": restrict the candidate grid, the u/v factors and the z
    #   update to the bundle's row support — O(P * k_max * Q) per bundle
    #   instead of O(s * Q). padded_csc layout only.
    # "full": evaluate over all s samples (the pre-support behavior; the
    #   batched variant now runs chunked with early exit).
    # "auto": support when the layout is padded_csc AND the margin rule
    #   AUTO_SUPPORT_MARGIN * P * k_max <= s holds (resolve_ls_scope).
    ls_scope: str = "auto"
    ls_chunk: int = 8            # candidate chunk of the full-scope search
    seed: int = 0
    use_kernels: bool = False    # route bundle math through Pallas kernels
    # -- mixed precision (DESIGN.md section 12) ------------------------------
    # storage dtype of the DESIGN VALUES ("float32" | "bfloat16"); solver
    # state (w, z, y) stays f32 either way — every reduction accumulates
    # in f32. Recorded here so artifacts/benchmarks can report it; the
    # design matrix itself is built with this dtype by launch/common.
    dtype: str = "float32"
    # -- active-set shrinking (CDN heritage; DESIGN.md section 8.2) ----------
    shrink: bool = False         # mask near-optimal zero features out of bundles
    shrink_tol: float = 0.01     # shrink j when w_j == 0 and |g_j| < 1 - shrink_tol
    recheck_every: int = 1       # full-set KKT recheck period (un-shrinks violators)
    # -- observability (DESIGN.md section 13.2) ------------------------------
    # surface per-bundle line-search telemetry (backtrack depth q^t and
    # accepted alpha) as a 10th outer output: (q (b,) int32, alpha (b,)).
    # Off by default so the compiled iteration is byte-identical to the
    # uninstrumented solver; the engine host loop folds the arrays into
    # SolveHistory.bundle_q / bundle_alpha at its per-iteration sync.
    record_aux: bool = False
    # -- diagnostics (DESIGN.md section 15.1) --------------------------------
    # surface the per-feature KKT violation vector (n,) as an extra outer
    # output for attribution (top-k offenders, distribution, churn). The
    # vector is already computed for the stop criterion, so the marginal
    # device cost is one (n,) transfer per iteration. Same contract as
    # record_aux: off by default, compiled step byte-identical when off.
    # The engine host loop dispatches extra outputs structurally — a
    # 2-tuple is the (q, alpha) bundle aux, a bare array is this vector —
    # so the two flags compose in any combination.
    record_kkt_vec: bool = False


def cdn_config(**kw) -> PCDNConfig:
    """CDN = PCDN with bundle size 1 (paper section 2.1)."""
    kw.setdefault("ls_kind", "backtracking")
    return PCDNConfig(P=1, **kw)


def with_bundle_size(cfg: PCDNConfig, P: int) -> PCDNConfig:
    """`cfg` at a different bundle size, everything else identical — the
    backend-rebuild hook the fault layer's P-backoff uses (DESIGN.md
    section 16.3)."""
    return dataclasses.replace(cfg, P=int(P))


def _line_search_fn(cfg: PCDNConfig) -> Callable:
    if cfg.ls_kind == "batched":
        # full-scope batched search runs chunked with early exit so the
        # (Q, s) candidate grid is never materialized (DESIGN.md §3.2)
        return functools.partial(armijo_chunked, chunk=cfg.ls_chunk)
    if cfg.ls_kind == "backtracking":
        return armijo_backtracking
    raise ValueError(f"unknown ls_kind {cfg.ls_kind!r}")


AUTO_SUPPORT_MARGIN = 4  # auto picks support iff MARGIN * P * k_max <= s


def resolve_ls_scope(cfg: PCDNConfig, problem: L1Problem) -> str:
    """Static scope decision (DESIGN.md section 11.3).

    "support" needs the padded_csc layout (a dense slab has no
    compressed row support); "auto" additionally requires the static
    support bound to beat the sample count with margin —
    AUTO_SUPPORT_MARGIN * P * k_max <= s. The margin covers the
    support build (a sort over P * k_max ids) and the gathers: the
    BENCH_bundle.json grid measures the crossover near r_max ~ s/4
    (support wins 4.5x at r_max/s ~ 0.06, loses ~0.7x at ~0.6).
    Force `ls_scope="support"` to override near the boundary.
    """
    if cfg.ls_scope == "full":
        return "full"
    sparse = isinstance(problem.design, PaddedCSCDesign)
    if cfg.ls_scope == "support":
        if not sparse:
            raise ValueError(
                "ls_scope='support' requires the padded_csc design "
                "backend; the dense layout has no compressed row support "
                "(use layout='padded_csc' or ls_scope='full'/'auto').")
        return "support"
    if cfg.ls_scope != "auto":
        raise ValueError(f"unknown ls_scope {cfg.ls_scope!r}")
    if sparse and (AUTO_SUPPORT_MARGIN * cfg.P * problem.design.k_max
                   <= problem.n_samples):
        return "support"
    return "full"


def make_bundle_step(problem: L1Problem, cfg: PCDNConfig):
    """One inner iteration t (steps 6-11 of Algorithm 3) as a scan body.

    Two shapes of the same update (identical accepted alpha; pinned by
    tests/test_bundle_support.py):

    * full scope — direction over the slab, dense (s,) margin delta,
      line search over all samples, dense z update.
    * support scope (DESIGN.md section 11) — every per-sample pass
      (u/v factors, candidate grid, z update) restricted to the
      bundle's <= P * k_max row support, so one bundle step is
      O(P * k_max * Q) and solve time stops scaling with s. With
      use_kernels the whole support step is ONE fused Pallas launch
      (kernels/pcdn_bundle).
    """
    loss = problem.loss
    gamma = cfg.armijo.gamma
    scope = resolve_ls_scope(cfg, problem)

    if cfg.use_kernels:
        from repro.kernels import ops as kops

    if scope == "support":
        design = problem.design
        fuse = cfg.use_kernels and cfg.ls_kind == "batched"

        def step(carry, idx):
            w, z = carry
            slab = design.gather_slab(idx)
            w_B, _ = B.gather_vec(w, idx)
            support, pos = design.slab_row_support(slab)
            z_R = jnp.take(z, support, mode="fill", fill_value=0)
            y_R = jnp.take(problem.y, support, mode="fill", fill_value=1)
            if fuse:
                upd_w, upd_z, alpha, n_steps = kops.pcdn_bundle(
                    slab.vals, pos, z_R, y_R, w_B,
                    candidate_alphas(cfg.armijo, z.dtype), problem.c,
                    kind=problem.loss_name, l2=problem.elastic_net_l2,
                    sigma=cfg.armijo.sigma, gamma=gamma)
                w = B.scatter_add(w, idx, upd_w)
                z = design.scatter_support(z, support, upd_z)
                return (w, z), (n_steps, alpha)
            if cfg.use_kernels:
                # backtracking search: no fused step, but the direction
                # still routes through the sparse kernel — pos is the
                # support-local row array, u/v handed over in support
                # order (same composition as the sharded backend)
                u_R = problem.grad_factor_at(z_R, y_R)
                v_R = problem.hess_factor_at(z_R, y_R)
                d, g, h = kops.pcdn_sparse_direction(
                    pos, slab.vals, u_R, v_R, w_B,
                    l2=problem.elastic_net_l2)
            else:
                g, h = problem.bundle_grad_hess_support(slab, pos, z_R,
                                                        y_R, w_B)
                d = newton_direction(g, h, w_B)
            Delta = delta_decrement(g, h, w_B, d, gamma)
            delta_R = design.slab_matvec_support(slab, pos, d)
            ls_fn = (armijo_support if cfg.ls_kind == "batched"
                     else armijo_backtracking)
            res = ls_fn(loss, problem.c, z_R, delta_R, y_R, w_B, d, Delta,
                        cfg.armijo, l2=problem.elastic_net_l2)
            w = B.scatter_add(w, idx, res.alpha * d)
            z = design.scatter_support(z, support, res.alpha * delta_R)
            return (w, z), (res.n_steps, res.alpha)

        return step

    ls = _line_search_fn(cfg)

    def step(carry, idx):
        w, z = carry
        slab = problem.design.gather_slab(idx)
        w_B, _ = B.gather_vec(w, idx)
        if cfg.use_kernels:
            u = problem.grad_factor(z)
            v = problem.hess_factor(z)
            if isinstance(slab, SparseSlab):
                d, g, h = kops.pcdn_sparse_direction(
                    slab.rows, slab.vals, u, v, w_B,
                    l2=problem.elastic_net_l2)
            else:
                d, g, h = kops.pcdn_direction(
                    slab.XB, u, v, w_B, l2=problem.elastic_net_l2)
        else:
            g, h = problem.bundle_grad_hess(z, slab, w_B)
            d = newton_direction(g, h, w_B)
        Delta = delta_decrement(g, h, w_B, d, gamma)
        delta_z = problem.design.slab_matvec(slab, d)
        res = ls(loss, problem.c, z, delta_z, problem.y, w_B, d, Delta,
                 cfg.armijo, l2=problem.elastic_net_l2)
        w = B.scatter_add(w, idx, res.alpha * d)
        z = z + res.alpha * delta_z
        return (w, z), (res.n_steps, res.alpha)

    return step


def make_outer_iteration(problem: L1Problem, cfg: PCDNConfig):
    """Legacy static-c outer iteration (all b bundles) + diagnostics.

    Kept for microbenchmarks that time one bare iteration (e.g.
    benchmarks/bench_sparse.py). Solver entry points go through the
    engine layer instead: `repro.engine.local.LocalBackend` wraps
    `make_path_outer`, whose traced-c contract subsumes this one.
    """
    n = problem.n_features
    step = make_bundle_step(problem, cfg)

    def outer(w: Array, z: Array, key: Array):
        key, sub = jax.random.split(key)
        idxs = B.partition(sub, n, cfg.P)                  # (b, P)
        (w, z), (steps, alphas) = jax.lax.scan(step, (w, z), idxs)
        f = problem.objective_from_margins(z, w)           # incl. l2 term
        kkt = problem.kkt_violation(w, z)
        nnz = jnp.sum(w != 0)
        return w, z, key, f, kkt, nnz, jnp.mean(steps.astype(jnp.float32))

    return jax.jit(outer)


def make_path_outer(problem: L1Problem, cfg: PCDNConfig):
    """The local backend's engine iteration (DESIGN.md sections 8 / 9.2).

    Implements the engine's outer-iteration contract
    (`repro.engine.loop`): a single jitted function reused across every
    path point and shrink state — none of the quantities that vary along
    a λ-sweep is baked in:

        outer(w, z, key, active, recheck, c)
          -> (w, z, key, f, kkt, nnz, mean_q, active, n_active)

    * `c` is a traced scalar (problem.with_c substitution), so a 20-point
      c-grid compiles ONCE instead of 20 times.
    * `active` is the (n,) un-shrunk mask. Bundles are drawn from the
      active set only (bundles.partition_active) and the bundle loop is a
      fori_loop with the dynamic trip count ceil(n_active / P): shrunk
      features keep their slots (static shapes) but cost zero compute.
    * `kkt` is always the FULL-set violation — the full gradient is
      already needed for the stop criterion, so the shrink bookkeeping is
      free. Shrinking masks j when w_j == 0 and |g_j| < 1 - shrink_tol
      (strictly interior to the l1 subdifferential box, per CDN's
      shrinking heritage); when `recheck` is set, any feature whose
      violation exceeds tol_kkt is un-shrunk again, so a wrongly shrunk
      feature survives at most recheck_every outer iterations.

    With cfg.shrink=False the active mask passes through untouched and
    the bundle loop covers the full feature set — the scan-based
    make_outer_iteration and this function then compute the same update
    (modulo the independent random partition draw).

    With cfg.record_aux=True a 10th output `(q (b,), alpha (b,))` carries
    the per-bundle backtrack depth and accepted step of this iteration
    (DESIGN.md section 13.2). Under shrinking, slots past the dynamic
    bundle count b_active hold sentinels q == -1 / alpha == nan.

    With cfg.record_kkt_vec=True the per-feature violation vector (n,)
    is appended after the optional aux tuple (DESIGN.md section 15.1);
    the engine dispatches extras by structure (tuple vs bare array), so
    both flags compose.
    """
    n = problem.n_features

    def outer(w: Array, z: Array, key: Array, active: Array,
              recheck: Array, c: Array):
        prob = problem.with_c(c)
        step = make_bundle_step(prob, cfg)
        key, sub = jax.random.split(key)
        if cfg.shrink:
            idxs, b_active = B.partition_active(sub, active, cfg.P)
            if cfg.record_aux:
                # preallocated sentinel slots: a bundle past the dynamic
                # trip count b_active never runs and keeps q=-1/alpha=nan
                b_max = idxs.shape[0]
                aux0 = (jnp.full((b_max,), -1, jnp.int32),
                        jnp.full((b_max,), jnp.nan, w.dtype))
            else:
                aux0 = ()

            def body(t, carry):
                (w, z), q_sum, aux = carry
                (w, z), (q, alpha) = step((w, z), idxs[t])
                if cfg.record_aux:
                    aux = (aux[0].at[t].set(q.astype(jnp.int32)),
                           aux[1].at[t].set(alpha.astype(w.dtype)))
                return (w, z), q_sum + q.astype(jnp.float32), aux

            (w, z), q_sum, aux = jax.lax.fori_loop(
                0, b_active, body, ((w, z), jnp.float32(0.0), aux0))
            if cfg.record_aux:
                qs, alphas = aux
            mean_q = q_sum / jnp.maximum(b_active, 1).astype(jnp.float32)
        else:
            idxs = B.partition(sub, n, cfg.P)
            (w, z), (steps, alphas) = jax.lax.scan(step, (w, z), idxs)
            mean_q = jnp.mean(steps.astype(jnp.float32))
            if cfg.record_aux:
                qs = steps.astype(jnp.int32)
                alphas = alphas.astype(w.dtype)

        f = prob.objective_from_margins(z, w)
        g = prob.full_grad(z, w)
        viol = prob.kkt_violation_from_grad(w, g)
        kkt = jnp.max(viol)
        if cfg.shrink:
            interior = (w == 0) & (jnp.abs(g) < 1.0 - cfg.shrink_tol)
            active = active & ~interior
            active = active | (recheck & (viol > cfg.tol_kkt))
        nnz = jnp.sum(w != 0)
        n_active = jnp.sum(active.astype(jnp.int32))
        base = (w, z, key, f, kkt, nnz, mean_q, active, n_active)
        if cfg.record_aux:
            base = base + ((qs, alphas),)
        if cfg.record_kkt_vec:
            base = base + (viol,)
        return base

    return jax.jit(outer)


def solve(problem: L1Problem, cfg: PCDNConfig,
          w0: Optional[Array] = None,
          f_star: Optional[float] = None,
          callback: Optional[Callable] = None) -> SolveResult:
    """Run PCDN until the KKT (or relative-objective) stop or max_outer.

    Thin caller of the unified engine (DESIGN.md section 9): builds a
    `LocalBackend` over this problem and hands the stop parameters to
    `engine.loop.solve` — the same loop that drives the sharded backend
    and the path sweeps.
    """
    from repro.engine import loop as engine_loop
    from repro.engine.local import LocalBackend

    backend = LocalBackend(problem, cfg)
    return engine_loop.solve(
        backend, problem.c, w0=w0,
        max_outer=cfg.max_outer, tol_kkt=cfg.tol_kkt,
        recheck_every=cfg.recheck_every, tol_rel_obj=cfg.tol_rel_obj,
        f_star=f_star, callback=callback)
