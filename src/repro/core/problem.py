"""l1-regularized ERM problem container (paper Eq. 1).

    min_w  F_c(w) = c * sum_i phi(w . x_i, y_i) + ||w||_1

Holds the design matrix behind the `DesignMatrix` backend interface
(DESIGN.md section 7) — dense (s, n) array or padded-CSC sparse — plus
labels y (s,), regularization c and the loss. All solver math is phrased
through the per-sample margin z = X @ w, the intermediate quantity of
paper section 3.1, and through the backend's slab protocol for bundle
restrictions, so every solver runs unchanged on either layout.

`elastic_net_l2` adds an optional (lambda2/2)||w||^2 smooth term (paper
section 6 extension); it folds into the gradient/Hessian diagonals.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.design_matrix import (DenseDesign, DenseSlab, DesignMatrix,
                                      PaddedCSCDesign, Slab, SparseSlab,
                                      as_design)
from repro.core.losses import HESSIAN_FLOOR, Loss, get_loss

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class L1Problem:
    """l1-regularized problem over a DesignMatrix backend. y: (s,) +-1."""

    design: DesignMatrix
    y: Array
    c: float
    loss_name: str = "logistic"
    elastic_net_l2: float = 0.0

    # -- pytree plumbing (design, y are leaves; scalars are static aux) ------
    def tree_flatten(self):
        return (self.design, self.y), (self.c, self.loss_name,
                                       self.elastic_net_l2)

    @classmethod
    def tree_unflatten(cls, aux, children):
        design, y = children
        c, loss_name, l2 = aux
        return cls(design=design, y=y, c=c, loss_name=loss_name,
                   elastic_net_l2=l2)

    # -- trace-time substitution ---------------------------------------------
    def with_c(self, c) -> "L1Problem":
        """Replace the regularization weight; `c` may be a traced scalar.

        `c` lives in the pytree's static aux data, so a problem carrying a
        tracer must NOT cross a jit boundary — but substitution *inside* a
        traced function is exactly how the path engine (DESIGN.md section
        8) reuses one compiled outer iteration across every grid point.
        """
        return dataclasses.replace(self, c=c)

    def with_labels(self, y: Array) -> "L1Problem":
        """Replace labels (same design); used by the vmapped batch solver."""
        return dataclasses.replace(self, y=y)

    # -- basic accessors -----------------------------------------------------
    @property
    def X(self) -> Array:
        """Back-compat dense view. Only the dense backend has one — the
        sparse backend refuses rather than materialize (s, n)."""
        if isinstance(self.design, DenseDesign):
            return self.design.X
        raise TypeError(
            f"L1Problem.X is dense-only; this problem uses the "
            f"{self.design.layout!r} backend. Go through problem.design.")

    @property
    def loss(self) -> Loss:
        return get_loss(self.loss_name)

    @property
    def n_samples(self) -> int:
        return self.design.n_samples

    @property
    def n_features(self) -> int:
        return self.design.n_features

    @property
    def dtype(self):
        return self.design.dtype

    @property
    def solve_dtype(self):
        """Dtype of the solver STATE (w, z, labels): f32 when the design
        stores bf16 values (mixed-precision mode — fp32 accumulation for
        the margin state, DESIGN.md section 12), identity otherwise."""
        return jnp.promote_types(self.design.dtype, jnp.float32)

    # -- objective -----------------------------------------------------------
    def margins(self, w: Array) -> Array:
        return self.design.matvec(w)

    def objective_from_margins(self, z: Array, w: Array) -> Array:
        f = self.loss.margin_objective(z, self.y, self.c) + jnp.sum(jnp.abs(w))
        if self.elastic_net_l2:
            f = f + 0.5 * self.elastic_net_l2 * jnp.sum(jnp.square(w))
        return f

    def objective(self, w: Array) -> Array:
        return self.objective_from_margins(self.margins(w), w)

    # -- per-sample factors used by every solver ------------------------------
    def grad_factor(self, z: Array) -> Array:
        """u_i = c * dphi/dz_i ; grad_j L = sum_i u_i x_ij = X[:,j] . u."""
        return self.c * self.loss.dz(z, self.y)

    def hess_factor(self, z: Array) -> Array:
        """v_i = c * d2phi/dz2_i ; hess_jj L = sum_i v_i x_ij^2."""
        return self.c * self.loss.d2z(z, self.y)

    # -- support-gathered factors (DESIGN.md section 11) ---------------------
    def grad_factor_at(self, z_R: Array, y_R: Array) -> Array:
        """`grad_factor` over explicitly gathered (z_R, y_R) — evaluated
        at a bundle's <= P * k_max support rows instead of all s samples.
        Bitwise equal to grad_factor(z)[support] (elementwise map)."""
        return self.c * self.loss.dz(z_R, y_R)

    def hess_factor_at(self, z_R: Array, y_R: Array) -> Array:
        """`hess_factor` over explicitly gathered (z_R, y_R)."""
        return self.c * self.loss.d2z(z_R, y_R)

    def bundle_grad_hess_support(self, slab: SparseSlab, pos: Array,
                                 z_R: Array, y_R: Array, w_B: Array):
        """`bundle_grad_hess` computed entirely on a bundle's row support.

        z_R/y_R: (r_max,) margins and labels gathered at the slab's
        `slab_row_support`; pos maps slab entries into them. Same l2 fold
        and Hessian floor as the full-scope path, with u/v evaluated at
        <= P * k_max rows instead of s.
        """
        u_R = self.grad_factor_at(z_R, y_R)
        v_R = self.hess_factor_at(z_R, y_R)
        g, h = self.design.slab_grad_hess_support(slab, pos, u_R, v_R)
        if self.elastic_net_l2:
            g = g + self.elastic_net_l2 * w_B
            h = h + self.elastic_net_l2
        return g, jnp.maximum(h, HESSIAN_FLOOR)

    def bundle_grad_hess(self, z: Array, slab: Union[Slab, Array],
                         w_B: Array):
        """Gradient and Hessian diagonal restricted to a bundle slab.

        slab: a DenseSlab/SparseSlab from design.gather_slab, or (legacy)
        a raw dense (s, P) column block. Returns (g_B, h_B), each (P,).
        The reductions here are the compute hot-spot that the Pallas
        kernels fuse on TPU (DESIGN.md sections 3.1 / 7.3).
        """
        u = self.grad_factor(z)
        v = self.hess_factor(z)
        if isinstance(slab, (DenseSlab, SparseSlab)):
            g, h = self.design.slab_grad_hess(slab, u, v)
        else:  # raw dense (s, P) array — legacy call sites and tests
            g = slab.T @ u
            h = jnp.square(slab).T @ v
        if self.elastic_net_l2:
            g = g + self.elastic_net_l2 * w_B
            h = h + self.elastic_net_l2
        return g, jnp.maximum(h, HESSIAN_FLOOR)

    def full_grad(self, z: Array, w: Array) -> Array:
        """grad L(w) (n,) — used by TRON and the KKT stopping criterion."""
        g = self.design.rmatvec(self.grad_factor(z))
        if self.elastic_net_l2:
            g = g + self.elastic_net_l2 * w
        return g

    # -- KKT optimality measure ----------------------------------------------
    def kkt_violation_from_grad(self, w: Array, g: Array) -> Array:
        """Per-feature |minimum-norm subgradient| of F_c at w, given the
        smooth gradient g = grad L(w). (n,) nonnegative; all-zero iff w is
        optimal. The shrinking solver and the path engine consume the
        vector; `kkt_violation` reduces it to the scalar stop."""
        pos = g + 1.0
        neg = g - 1.0
        zero = jnp.maximum(jnp.abs(g) - 1.0, 0.0)
        v = jnp.where(w > 0, pos, jnp.where(w < 0, neg, zero))
        return jnp.abs(v)

    def kkt_violation(self, w: Array, z: Optional[Array] = None) -> Array:
        """inf-norm of the minimum-norm subgradient of F_c at w.

        v_j = g_j + 1        if w_j > 0
            = g_j - 1        if w_j < 0
            = max(|g_j|-1,0) if w_j = 0
        Zero iff w is optimal. Used as the LIBLINEAR-style outer stop.
        """
        if z is None:
            z = self.margins(w)
        g = self.full_grad(z, w)
        return jnp.max(self.kkt_violation_from_grad(w, g))

    # -- regularization path quantities ---------------------------------------
    def c_max(self) -> float:
        """Largest c for which w = 0 is optimal (DESIGN.md section 8.1).

        At the origin every margin is zero, so the loss gradient is
        c * X^T phi'(0, y); w = 0 satisfies the KKT conditions iff that
        vector stays inside the l1 subdifferential box [-1, 1]^n:

            c <= c_max = 1 / || X^T phi'(0, y) ||_inf

        (the elastic-net quadratic vanishes at 0 and does not move this).
        This is the analytic start of the regularization path: the paper's
        F_c = c * L + ||w||_1 parameterization puts lambda ~ 1/c, so the
        classical lambda_max is 1 / c_max and the path sweeps c UP from
        c_max (all-zero model) toward weaker regularization.
        """
        z0 = jnp.zeros((self.n_samples,), self.solve_dtype)
        u0 = self.loss.dz(z0, self.y)
        g0 = self.design.rmatvec(u0)
        denom = float(jnp.max(jnp.abs(g0)))
        if denom <= 0.0:
            raise ValueError("degenerate problem: X^T phi'(0, y) == 0 "
                             "(no feature correlates with the labels)")
        return 1.0 / denom

    # -- Lemma 1 quantities ----------------------------------------------------
    def column_norms_sq(self) -> Array:
        """(X^T X)_jj for j in N — the lambda_j of Lemma 1 / Theorem 2."""
        return self.design.column_norms_sq()


def make_problem(
    X,
    y,
    c: float,
    loss: str = "logistic",
    elastic_net_l2: float = 0.0,
    dtype=jnp.float32,
    layout: str = "auto",
    k_max: Optional[int] = None,
) -> L1Problem:
    """Build an L1Problem from a dense array, CSRMatrix, or DesignMatrix.

    layout="auto" keeps dense input dense and CSR input padded-CSC (no
    densification); "padded_csc" forces the sparse backend (converting a
    dense array if needed — handy for equivalence tests).
    """
    design = as_design(X, dtype=dtype, layout=layout, k_max=k_max)
    # labels live with the solver state: f32 even under bf16 storage
    y = jnp.asarray(np.asarray(y),
                    dtype=jnp.promote_types(dtype, jnp.float32))
    return L1Problem(design=design, y=y, c=float(c), loss_name=loss,
                     elastic_net_l2=float(elastic_net_l2))


def validation_accuracy(design, y, w) -> float:
    """Classification accuracy of sign(X_val @ w) against +-1 labels.

    `design` may be anything `as_design` accepts (dense array, CSR,
    DesignMatrix), so held-out metrics never densify a sparse split.
    Zero margins count as +1, matching data.synthetic.train_accuracy.
    """
    d = as_design(design)
    z = np.asarray(d.matvec(jnp.asarray(np.asarray(w), d.dtype)))
    pred = np.sign(z)
    pred[pred == 0] = 1.0
    return float(np.mean(pred == np.asarray(y)))


def expected_max_column_norm(problem: L1Problem, P: int) -> float:
    """E_B[ lambda_bar(B) ] for uniform random size-P bundles (Lemma 1a).

    f(P) = (1/C(n,P)) * sum_k lambda_(k) * C(k-1, P-1)
    computed stably in log space with numpy (analysis-time only).
    """
    lam = np.sort(np.asarray(problem.column_norms_sq(), dtype=np.float64))
    return float(expected_max_of_sample(lam, P))


def expected_max_of_sample(lam_sorted: np.ndarray, P: int) -> float:
    """E[max of a uniform size-P subset] given sorted values (Lemma 1a Eq. 22).

    Weight of the k-th smallest value (1-indexed) is C(k-1,P-1)/C(n,P);
    computed in log space via cumulative log-factorials (no scipy needed).
    """
    lam_sorted = np.asarray(lam_sorted, dtype=np.float64)
    n = lam_sorted.shape[0]
    P = int(P)
    if not 1 <= P <= n:
        raise ValueError(f"P={P} out of [1, {n}]")
    if P == 1:
        return float(lam_sorted.mean())
    # log k! for k = 0..n
    logfact = np.concatenate([[0.0], np.cumsum(np.log(np.arange(1, n + 1)))])

    def logC(a: np.ndarray, b: int) -> np.ndarray:  # log C(a, b), a >= b
        return logfact[a] - logfact[b] - logfact[a - b]

    k = np.arange(P, n + 1)  # only k >= P contribute
    logw = logC(k - 1, P - 1) - logC(np.array([n]), P)
    w = np.exp(logw)
    return float(np.sum(w * lam_sorted[P - 1:]))
