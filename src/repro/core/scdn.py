"""Shotgun CDN baseline (Bradley et al. 2011; paper Algorithm 2).

SCDN picks Pbar features uniformly at random and updates them *in parallel*,
each with its own 1-D Newton direction and 1-D line search, racing on shared
memory. TPU has no shared-memory atomics (DESIGN.md section 3.5a), so we
simulate the Hogwild semantics faithfully at iteration granularity: all Pbar
updates are computed from the *same* stale (w, z), then applied together

    w <- w + sum_j alpha_j d_j e_j ,   z <- z + sum_j alpha_j d_j x^j .

This preserves the property under study — the per-coordinate line searches
do not account for each other, so the combined step can increase F_c and
the method diverges when Pbar exceeds the spectral threshold n/rho + 1
(section 2.2) — which our benchmarks reproduce.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bundles as B
from repro.core.direction import delta_decrement, newton_direction
from repro.core.linesearch import ArmijoParams, armijo_batched
from repro.core.problem import L1Problem
from repro.engine.loop import EngineState, run_outer_loop

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class SCDNConfig:
    P_bar: int = 8               # paper section 5.1 follows Bradley et al.
    armijo: ArmijoParams = ArmijoParams()
    max_rounds: int = 2000       # each round = ceil(n/P_bar) parallel updates
    tol_kkt: float = 1e-3
    seed: int = 0


class SCDNResult(NamedTuple):
    w: Array
    objective: float
    n_rounds: int
    converged: bool
    diverged: bool
    history: dict


def make_round(problem: L1Problem, cfg: SCDNConfig):
    """One epoch-equivalent: ceil(n/P_bar) batches of P_bar racing updates."""
    n = problem.n_features
    loss = problem.loss
    n_batches = -(-n // cfg.P_bar)

    def one_batch(carry, key):
        w, z = carry
        idx = jax.random.randint(key, (cfg.P_bar,), 0, n)  # with replacement
        slab = problem.design.gather_slab(idx)
        w_B, _ = B.gather_vec(w, idx)
        g, h = problem.bundle_grad_hess(z, slab, w_B)
        d = newton_direction(g, h, w_B)

        # per-coordinate 1-D line searches, each blind to the others
        deltas = problem.design.slab_coordinate_deltas(slab, d)  # (P, s)

        def ls_one(delta_j, wj, dj, gj, hj):
            Delta = delta_decrement(gj[None], hj[None], wj[None], dj[None],
                                    cfg.armijo.gamma)
            res = armijo_batched(loss, problem.c, z, delta_j, problem.y,
                                 wj[None], dj[None], Delta, cfg.armijo)
            return res.alpha

        alphas = jax.vmap(ls_one)(deltas, w_B, d, g, h)
        upd = alphas * d
        w = B.scatter_add(w, idx, upd)
        z = z + problem.design.slab_matvec(slab, upd)
        return (w, z), None

    def round_fn(w, z, key):
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, n_batches)
        (w, z), _ = jax.lax.scan(one_batch, (w, z), keys)
        f = problem.objective_from_margins(z, w)
        kkt = problem.kkt_violation(w, z)
        return w, z, key, f, kkt

    return jax.jit(round_fn)


def solve(problem: L1Problem, cfg: SCDNConfig,
          f_star: Optional[float] = None,
          divergence_factor: float = 1e3) -> SCDNResult:
    """Host-side round loop = the engine's shared stop/history/timing
    helpers (DESIGN.md section 9) + SCDN's divergence guard: the Hogwild
    semantics under study mean F_c can INCREASE, so a round whose
    objective blows past divergence_factor * F_c(0) (or goes non-finite)
    aborts the run and flags `diverged` instead of iterating to
    max_rounds."""
    n = problem.n_features
    round_fn = make_round(problem, cfg)

    def outer(w, z, key, active, recheck, c):
        """Adapt the SCDN round to the engine's outer contract; the
        racing updates have no shrinking or traced-c story, so `active`
        passes through and `c`/`recheck` are unused (the round closes
        over problem.c)."""
        w, z, key, f, kkt = round_fn(w, z, key)
        return (w, z, key, f, kkt, jnp.sum(w != 0), jnp.float32(0.0),
                active, jnp.int32(n))

    state = EngineState(
        w=jnp.zeros((n,), problem.dtype),
        z=jnp.zeros((problem.n_samples,), problem.dtype),
        key=jax.random.PRNGKey(cfg.seed),
        active=jnp.ones((n,), bool))
    f0 = float(problem.objective_from_margins(state.z, state.w))

    def guard(f: float) -> bool:
        return (not np.isfinite(f)) or f > divergence_factor * f0

    _, res = run_outer_loop(outer, state, problem.c,
                            max_outer=cfg.max_rounds, tol_kkt=cfg.tol_kkt,
                            divergence_guard=guard)
    h = res.history
    return SCDNResult(w=res.w, objective=res.objective, n_rounds=res.n_outer,
                      converged=res.converged, diverged=res.diverged,
                      history={"round": h.outer_iter,
                               "objective": h.objective, "kkt": h.kkt,
                               "wall_time": h.wall_time})
