"""Distributed PCDN — thin compatibility layer over the unified engine.

The 2-D (data x model) shard_map implementation that used to live here
(outer iteration, collective schedule, data placers, AND its own
convergence loop) moved to `repro.engine.sharded`, where it is an
*execution backend* of the engine contract (DESIGN.md section 9.3):
`ShardedBackend` exposes the same outer-iteration signature as the local
backend, so warm-started c-sweeps, active-set shrinking and Pallas
kernel routing now run on a mesh through the exact same drivers.

`solve_sharded` keeps its historical signature as a thin caller of
`engine.loop.solve` (the old hand-rolled loop/stop/history code is
gone). Prefer constructing a `ShardedBackend` directly when you need
warm starts, path sweeps, or the richer `SolveResult` history.
"""
from __future__ import annotations

import dataclasses

import numpy as np
from jax.sharding import Mesh

from repro.engine import loop as engine_loop
from repro.engine.sharded import (ShardedBackend, ShardedPCDNConfig,  # noqa: F401
                                  make_sharded_margins, make_sharded_outer,
                                  shard_problem, shard_problem_sparse)


def solve_sharded(X, y: np.ndarray, mesh: Mesh,
                  cfg: ShardedPCDNConfig, max_outer: int = 100,
                  tol_kkt: float = 1e-3, layout: str = "auto",
                  k_max: int = None):
    """Host driver mirroring repro.core.pcdn.solve on a mesh.

    layout="auto" picks padded_csc for CSR-like X and dense for arrays;
    either can be forced (forcing a CSR dense is refused upstream).
    Returns (w, objective, converged, n_outer, hist) — w is the padded
    mesh-placed vector (use `ShardedBackend.host_weights` for the real-n
    host copy).
    """
    # keep the un-shrink threshold in lockstep with the stop tolerance
    cfg = dataclasses.replace(cfg, tol_kkt=tol_kkt)
    backend = ShardedBackend(X, y, mesh, cfg, layout=layout, k_max=k_max)
    result = engine_loop.solve(backend, cfg.c,
                               max_outer=max_outer, tol_kkt=tol_kkt,
                               recheck_every=cfg.recheck_every)
    hist = {"objective": [float(v) for v in result.history.objective],
            "kkt": [float(v) for v in result.history.kkt]}
    return result.w, result.objective, result.converged, result.n_outer, \
        hist
