"""Trust Region Newton (TRON) baseline (Lin & More 1999; Yuan et al. 2010).

Comparison solver used by the paper (section 5.1/5.2). For the l1 problem we
use the standard bound-constrained reformulation with duplicated variables

    min_{v >= 0} f(v) = c sum_i phi((v+ - v-) . x_i, y_i) + sum_j v_j ,
    v = [v+; v-] in R^{2n}_+,  w = v+ - v- ,

and run projected trust-region Newton: free-set identification from the
projected gradient, truncated conjugate-gradient on the free variables,
projected (Armijo) line search with sigma = 0.01, beta = 0.1 (paper section
5.1), and the classic actual/predicted radius update.
"""
from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.losses import HESSIAN_FLOOR
from repro.core.problem import L1Problem

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TRONConfig:
    max_outer: int = 500
    max_cg: int = 50
    tol_kkt: float = 1e-3
    sigma: float = 0.01   # projected line search sufficient-decrease
    beta: float = 0.1     # projected line search backtracking factor
    eta0: float = 1e-4    # radius update thresholds (Lin-More)
    eta1: float = 0.25
    eta2: float = 0.75


class TRONResult(NamedTuple):
    w: Array
    objective: float
    n_outer: int
    converged: bool
    history: dict


def _make_oracles(problem: L1Problem):
    """All X touches go through the DesignMatrix backend (matvec/rmatvec),
    so TRON runs unchanged on dense or padded-CSC problems."""
    design, y, c = problem.design, problem.y, problem.c
    loss = problem.loss
    n = problem.n_features

    @jax.jit
    def fgrad(v):
        w = v[:n] - v[n:]
        z = design.matvec(w)
        f = c * jnp.sum(loss.value(z, y)) + jnp.sum(v)
        u = c * loss.dz(z, y)
        g = design.rmatvec(u)
        grad = jnp.concatenate([g, -g]) + 1.0
        return f, grad, z

    @jax.jit
    def hess_vec(z, p):
        pw = p[:n] - p[n:]
        hv = design.rmatvec(
            jnp.maximum(c * loss.d2z(z, y), HESSIAN_FLOOR) *
            design.matvec(pw))
        return jnp.concatenate([hv, -hv])

    return fgrad, hess_vec


def _truncated_cg(hess_vec, z, grad, free, radius, max_cg, tol=0.1):
    """CG on the free set for H p = -grad, truncated at the TR boundary."""
    g = jnp.where(free, grad, 0.0)
    p = jnp.zeros_like(g)
    r = -g
    d = r
    rr = jnp.vdot(r, r)
    gnorm = jnp.sqrt(rr)
    for _ in range(max_cg):
        if float(jnp.sqrt(rr)) <= tol * float(gnorm) + 1e-12:
            break
        Hd = jnp.where(free, hess_vec(z, jnp.where(free, d, 0.0)), 0.0)
        dHd = jnp.vdot(d, Hd)
        if float(dHd) <= 1e-16:  # nonpositive curvature: go to boundary
            tau = _boundary_tau(p, d, radius)
            return p + tau * d, True
        alpha = rr / dHd
        p_next = p + alpha * d
        if float(jnp.linalg.norm(p_next)) >= radius:
            tau = _boundary_tau(p, d, radius)
            return p + tau * d, True
        p = p_next
        r = r - alpha * Hd
        rr_next = jnp.vdot(r, r)
        d = r + (rr_next / rr) * d
        rr = rr_next
    return p, False


def _boundary_tau(p, d, radius):
    """largest tau >= 0 with ||p + tau d|| = radius."""
    pp = float(jnp.vdot(p, p))
    pd = float(jnp.vdot(p, d))
    dd = float(jnp.vdot(d, d)) + 1e-30
    disc = max(pd * pd + dd * (radius * radius - pp), 0.0)
    return (-pd + np.sqrt(disc)) / dd


def solve(problem: L1Problem, cfg: TRONConfig = TRONConfig()) -> TRONResult:
    n = problem.n_features
    fgrad, hess_vec = _make_oracles(problem)
    v = jnp.zeros((2 * n,), problem.dtype)
    f, grad, z = fgrad(v)
    radius = float(jnp.linalg.norm(grad))

    hist = {"outer_iter": [], "objective": [], "kkt": [], "wall_time": []}
    t0 = time.perf_counter()
    converged = False
    it = 0
    for it in range(cfg.max_outer):
        # projected-gradient KKT measure for v >= 0:
        pg = jnp.where((v > 0) | (grad < 0), grad, 0.0)
        kkt = float(jnp.max(jnp.abs(pg)))
        hist["outer_iter"].append(it)
        hist["objective"].append(float(f))
        hist["kkt"].append(kkt)
        hist["wall_time"].append(time.perf_counter() - t0)
        if kkt <= cfg.tol_kkt:
            converged = True
            break

        free = (v > 0) | (grad < 0)
        p, _ = _truncated_cg(hess_vec, z, grad, free, radius, cfg.max_cg)

        # projected Armijo line search (sigma, beta from paper section 5.1)
        gTp = float(jnp.vdot(grad, p))
        step = 1.0
        accepted = False
        for _ in range(30):
            v_new = jnp.maximum(v + step * p, 0.0)
            f_new, grad_new, z_new = fgrad(v_new)
            gTd = float(jnp.vdot(grad, v_new - v))
            if float(f_new) - float(f) <= cfg.sigma * gTd and gTd <= 0:
                accepted = True
                break
            step *= cfg.beta
        if not accepted:
            radius *= 0.25
            continue

        # radius update from actual vs predicted reduction
        s = v_new - v
        pred = float(jnp.vdot(grad, s) + 0.5 * jnp.vdot(s, hess_vec(z, s)))
        actual = float(f_new) - float(f)
        rho = actual / pred if pred < 0 else -1.0
        snorm = float(jnp.linalg.norm(s))
        if rho < cfg.eta1:
            radius = max(0.25 * radius, 0.5 * snorm)
        elif rho > cfg.eta2 and snorm >= 0.9 * radius:
            radius = 2.0 * radius
        if rho > cfg.eta0:
            v, f, grad, z = v_new, f_new, grad_new, z_new

    w = v[:n] - v[n:]
    return TRONResult(w=w, objective=float(f), n_outer=it + 1,
                      converged=converged,
                      history={k: np.asarray(x) for k, x in hist.items()})
