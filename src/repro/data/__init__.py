"""Data substrate: LIBSVM parsing, synthetic datasets, LM token pipeline."""
from repro.data.libsvm import (CSRMatrix, PaddedCSC, csr_to_padded_csc,
                               load_libsvm, save_libsvm)
from repro.data.synthetic import (PAPER_DATASETS, duplicate_samples,
                                  make_classification,
                                  make_sparse_classification, paper_like)

__all__ = [
    "load_libsvm", "save_libsvm", "make_classification", "paper_like",
    "duplicate_samples", "PAPER_DATASETS",
    "CSRMatrix", "PaddedCSC", "csr_to_padded_csc",
    "make_sparse_classification",
]
