"""Data substrate: LIBSVM parsing, synthetic datasets, LM token pipeline."""
from repro.data.libsvm import load_libsvm, save_libsvm
from repro.data.synthetic import (PAPER_DATASETS, duplicate_samples,
                                  make_classification, paper_like)

__all__ = [
    "load_libsvm", "save_libsvm", "make_classification", "paper_like",
    "duplicate_samples", "PAPER_DATASETS",
]
