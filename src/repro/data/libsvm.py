"""LIBSVM text format reader/writer (the paper's six datasets ship in it).

Format, one sample per line:   <label> <idx>:<val> <idx>:<val> ...
Indices are 1-based. Three output layouts (DESIGN.md sections 3.1 / 7):

    layout="dense"       (s, n) float32 array — the original TPU slab path
    layout="csr"         CSRMatrix triple, no densification
    layout="padded_csc"  (col_rows, col_vals, shape) feature-major padded
                         arrays for the sparse DesignMatrix backend —
                         zero densification end to end

Parsing is numpy-vectorized: the per-line Python work is only collecting
"idx:val" tokens; index/value conversion of the whole nnz stream happens
in two `np.array(...).astype(...)` calls, which is ~an order of magnitude
faster than float()-per-token for the paper's datasets.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class CSRMatrix:
    data: np.ndarray      # (nnz,) float32
    indices: np.ndarray   # (nnz,) int32 column ids
    indptr: np.ndarray    # (s+1,) int64
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.data.shape[0])

    def to_dense(self) -> np.ndarray:
        """Vectorized scatter — one fancy-indexed assignment, no row loop."""
        s, n = self.shape
        out = np.zeros((s, n), dtype=np.float32)
        row_ids = np.repeat(np.arange(s), np.diff(self.indptr))
        out[row_ids, self.indices] = self.data
        return out

    def sparsity(self) -> float:
        s, n = self.shape
        return 1.0 - self.nnz / float(s * n)

    def max_col_nnz(self) -> int:
        """k_max of the padded-CSC layout this matrix would convert to."""
        if self.nnz == 0:
            return 1
        return int(np.bincount(self.indices,
                               minlength=self.shape[1]).max())

    @classmethod
    def from_dense(cls, X) -> "CSRMatrix":
        """Row-major sparse view of a dense array (serving/test helper)."""
        X = np.asarray(X, np.float32)
        rows, cols = np.nonzero(X)
        indptr = np.concatenate(
            [[0], np.cumsum(np.bincount(rows, minlength=X.shape[0]))]
        ).astype(np.int64)
        return cls(data=X[rows, cols], indices=cols.astype(np.int32),
                   indptr=indptr, shape=X.shape)


@dataclasses.dataclass
class PaddedCSC:
    """Numpy-side padded feature-major layout (see core.design_matrix)."""
    col_rows: np.ndarray  # (n, k_max) int32; sentinel == s at padding
    col_vals: np.ndarray  # (n, k_max) float32; 0 at padding
    shape: Tuple[int, int]

    @property
    def k_max(self) -> int:
        return int(self.col_rows.shape[1])

    @property
    def nnz(self) -> int:
        return int(np.count_nonzero(self.col_rows < self.shape[0]))


def csr_to_padded_csc(csr: CSRMatrix,
                      k_max: Optional[int] = None) -> PaddedCSC:
    """CSR -> padded-CSC without densifying. k_max defaults to the max
    column nnz; a smaller explicit k_max raises if any column overflows
    (truncation would silently change the objective — DESIGN.md 7.2)."""
    from repro.core.design_matrix import padded_csc_arrays
    col_rows, col_vals, s, n = padded_csc_arrays(
        csr.data, csr.indices, csr.indptr, csr.shape, k_max=k_max)
    return PaddedCSC(col_rows=col_rows, col_vals=col_vals, shape=(s, n))


def _parse_libsvm_text(path: str):
    # Two flat 1-D token lists (not an (nnz, 2) unicode matrix — numpy
    # fixed-width string arrays cost max-token-width * 4 B per cell,
    # which is GBs of transient memory at paper-dataset nnz counts);
    # numeric conversion of each list is one vectorized np.asarray.
    labels, idx_tok, val_tok, ptr = [], [], [], [0]
    with open(path, "r") as fh:
        for line in fh:
            parts = line.split()
            if not parts:
                continue
            labels.append(parts[0])
            for tok in parts[1:]:
                k, _, v = tok.partition(":")
                idx_tok.append(k)
                val_tok.append(v)
            ptr.append(len(idx_tok))
    y = np.asarray(labels, dtype=np.float32)
    idx = np.asarray(idx_tok, dtype=np.int64) - 1    # 1-based on disk
    vals = np.asarray(val_tok, dtype=np.float32)
    return y, idx, vals, np.asarray(ptr, dtype=np.int64)


def normalize_labels(y: np.ndarray):
    """Raw file labels -> (y_norm, classes).

    ANY <= 2-label set normalizes to the solvers' +-1 contract with
    classes == [-1, +1]: {0, 1} and {-1, +1} map as historically (sign),
    and other two-label vocabularies ({1, 2}-style files are common in
    the wild) map smaller -> -1, larger -> +1 — never as raw codes,
    which would silently zero out the y == 0 class inside a +-1 loss.
    Three or more labels are a multiclass vocabulary: classes is the
    sorted unique label values and y_norm the float32 integer codes into
    it (what `serve.ovr.fit_ovr` and `launch.predict` consume).
    """
    uniq = np.unique(y)
    if uniq.size <= 2:
        if set(uniq.tolist()) <= {0.0, 1.0} or \
                set(uniq.tolist()) <= {-1.0, 1.0}:
            y = np.where(y > 0, 1.0, -1.0)
        else:
            y = np.where(y == uniq.max(), 1.0, -1.0)
        return y.astype(np.float32), np.asarray([-1.0, 1.0], np.float32)
    codes = np.searchsorted(uniq, y)
    return codes.astype(np.float32), uniq.astype(np.float32)


def load_libsvm(path: str, n_features: Optional[int] = None,
                dense: bool = True, layout: Optional[str] = None,
                k_max: Optional[int] = None, return_classes: bool = False):
    """-> (X, y) where X's type follows `layout` (y (s,) float32 +-1),
    or (X, y, classes) with return_classes=True.

    layout: "dense" (default; (s, n) float32 array), "csr" (CSRMatrix),
    or "padded_csc" (PaddedCSC — never materializes the dense matrix).
    The legacy `dense=False` flag maps to layout="csr".

    Labels: binary files keep the historical contract (y in {-1, +1},
    with 0/1 files mapped onto it). Multiclass integer-labeled files are
    supported with return_classes=True: y becomes the class CODES
    (0..K-1, float32) and `classes` the sorted label vocabulary — the
    exact inputs `serve.ovr.fit_ovr` takes. Loading a multiclass file
    without return_classes raises rather than silently feeding class ids
    into a +-1 solver.
    """
    if layout is None:
        layout = "dense" if dense else "csr"
    if layout not in ("dense", "csr", "padded_csc"):
        raise ValueError(f"unknown layout {layout!r}")

    y, idx, vals, ptr = _parse_libsvm_text(path)
    n = n_features or (int(idx.max()) + 1 if idx.size else 0)
    y, classes = normalize_labels(y)
    if classes.shape[0] > 2 and not return_classes:
        raise ValueError(
            f"{path!r} has {classes.shape[0]} label values "
            f"{classes.tolist()[:8]}...; pass return_classes=True to get "
            f"(X, codes, classes) for one-vs-rest training")
    csr = CSRMatrix(vals, idx.astype(np.int32), ptr, (y.shape[0], n))
    if layout == "dense":
        X = csr.to_dense()
    elif layout == "padded_csc":
        X = csr_to_padded_csc(csr, k_max=k_max)
    else:
        X = csr
    if not return_classes:
        return X, y
    if classes.shape[0] == 2:
        # uniform contract: y is always CODES into classes here, so
        # classes[codes] reconstructs the +-1 labels for binary files too
        y = (y > 0).astype(np.float32)
    return X, y, classes


def save_libsvm(path: str, X: np.ndarray, y: np.ndarray) -> None:
    with open(path, "w") as fh:
        for xi, yi in zip(X, y):
            nz = np.nonzero(xi)[0]
            feats = " ".join(f"{j + 1}:{xi[j]:.6g}" for j in nz)
            fh.write(f"{yi:g} {feats}\n")
