"""LIBSVM text format reader/writer (the paper's six datasets ship in it).

Format, one sample per line:   <label> <idx>:<val> <idx>:<val> ...
Indices are 1-based. Returns dense float32 arrays (the solver's TPU
adaptation works on dense bundle slabs — DESIGN.md section 3.1); a CSR
triple is also returned for sparsity-aware callers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class CSRMatrix:
    data: np.ndarray      # (nnz,) float32
    indices: np.ndarray   # (nnz,) int32 column ids
    indptr: np.ndarray    # (s+1,) int64
    shape: Tuple[int, int]

    @property
    def nnz(self) -> int:
        return int(self.data.shape[0])

    def to_dense(self) -> np.ndarray:
        s, n = self.shape
        out = np.zeros((s, n), dtype=np.float32)
        for i in range(s):
            lo, hi = self.indptr[i], self.indptr[i + 1]
            out[i, self.indices[lo:hi]] = self.data[lo:hi]
        return out

    def sparsity(self) -> float:
        s, n = self.shape
        return 1.0 - self.nnz / float(s * n)


def load_libsvm(path: str, n_features: Optional[int] = None,
                dense: bool = True):
    """-> (X, y) with X dense (s, n) float32, y (s,) float32 in {-1, +1};
    or (csr, y) when dense=False."""
    labels, rows_i, rows_v, ptr = [], [], [], [0]
    max_idx = 0
    with open(path, "r") as fh:
        for line in fh:
            parts = line.split()
            if not parts:
                continue
            labels.append(float(parts[0]))
            for tok in parts[1:]:
                k, v = tok.split(":")
                j = int(k) - 1
                max_idx = max(max_idx, j + 1)
                rows_i.append(j)
                rows_v.append(float(v))
            ptr.append(len(rows_i))
    n = n_features or max_idx
    y = np.asarray(labels, dtype=np.float32)
    # normalize labels to {-1, +1} (a9a-style 0/1 files appear in the wild)
    uniq = np.unique(y)
    if set(uniq.tolist()) <= {0.0, 1.0}:
        y = np.where(y > 0, 1.0, -1.0).astype(np.float32)
    csr = CSRMatrix(np.asarray(rows_v, np.float32),
                    np.asarray(rows_i, np.int32),
                    np.asarray(ptr, np.int64), (len(labels), n))
    if dense:
        return csr.to_dense(), y
    return csr, y


def save_libsvm(path: str, X: np.ndarray, y: np.ndarray) -> None:
    with open(path, "w") as fh:
        for xi, yi in zip(X, y):
            nz = np.nonzero(xi)[0]
            feats = " ".join(f"{j + 1}:{xi[j]:.6g}" for j in nz)
            fh.write(f"{yi:g} {feats}\n")
