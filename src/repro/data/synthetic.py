"""Synthetic dataset generators matched to the paper's benchmarks.

The paper's six datasets (Table 2) cannot ship offline, so each gets a
generator reproducing its *solver-relevant* profile: shape ratio s:n,
training-data sparsity, row normalization (document sets are unit-norm),
feature scaling ([-1,1] for gisette) and inter-feature correlation (the
quantity that kills SCDN — section 2.2). Sizes are scaled to CPU budgets
by default; `scale=1.0` reproduces the published dimensions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    s: int                 # published #train samples
    n: int                 # published #features
    sparsity: float        # published train sparsity (fraction of zeros)
    row_normalize: bool    # document sets are normalized to unit vectors
    scale_pm1: bool        # gisette: features linearly scaled to [-1, 1]
    c_svm: float           # best c* from Table 2
    c_logistic: float
    corr: float = 0.3      # latent-factor feature correlation strength


# Published shapes (paper Table 2); generators shrink via `scale`.
PAPER_DATASETS = {
    "a9a": DatasetSpec("a9a", 26_049, 123, 0.8872, True, False, 0.5, 2.0),
    "real-sim": DatasetSpec("real-sim", 57_848, 20_958, 0.9976, True, False,
                            1.0, 4.0),
    "news20": DatasetSpec("news20", 15_997, 1_355_191, 0.9997, True, False,
                          64.0, 64.0),
    "gisette": DatasetSpec("gisette", 6_000, 5_000, 0.009, False, True,
                           0.25, 0.25, corr=0.8),  # dense & highly correlated
    "rcv1": DatasetSpec("rcv1", 541_920, 47_236, 0.9985, True, False,
                        1.0, 4.0),
    "kdda": DatasetSpec("kdda", 8_407_752, 20_216_830, 0.9999, True, False,
                        1.0, 4.0),
}

# Default CPU-budget shapes (dense f32 X must stay well under RAM).
_CPU_SHAPES = {
    "a9a": (8_192, 123),
    "real-sim": (6_000, 2_048),
    "news20": (2_000, 16_384),
    "gisette": (2_000, 1_024),
    "rcv1": (12_000, 4_096),
    "kdda": (4_000, 16_384),
}


def make_classification(
    s: int,
    n: int,
    sparsity: float = 0.9,
    corr: float = 0.3,
    w_nnz_frac: float = 0.1,
    noise: float = 0.1,
    row_normalize: bool = True,
    scale_pm1: bool = False,
    seed: int = 0,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Sparse correlated binary classification data.

    X = mask .* (latent-factor mixture + idiosyncratic noise), labels from a
    planted sparse linear model through a logistic link. corr in [0, 1)
    drives the off-diagonal mass of X^T X (higher => SCDN diverges sooner).
    Returns (X (s,n) f32, y (s,) f32 in {-1,+1}, w_true (n,) f32).
    """
    rng = np.random.default_rng(seed)
    k = max(4, n // 64)  # latent dimension
    F = rng.standard_normal((k, n)).astype(np.float32) / np.sqrt(k)
    S = rng.standard_normal((s, k)).astype(np.float32)
    X = corr * (S @ F) + (1.0 - corr) * rng.standard_normal(
        (s, n)).astype(np.float32)
    if sparsity > 0:
        mask = rng.random((s, n)) >= sparsity
        X *= mask
    if scale_pm1:
        amax = np.abs(X).max(axis=0, keepdims=True)
        X = X / np.maximum(amax, 1e-12)
    if row_normalize:
        norms = np.linalg.norm(X, axis=1, keepdims=True)
        X = X / np.maximum(norms, 1e-12)

    w_true = np.zeros((n,), np.float32)
    nnz = max(1, int(w_nnz_frac * n))
    sup = rng.choice(n, size=nnz, replace=False)
    w_true[sup] = rng.standard_normal(nnz).astype(np.float32) * 2.0
    logits = X @ w_true + noise * rng.standard_normal(s).astype(np.float32)
    p = 1.0 / (1.0 + np.exp(-logits))
    y = np.where(rng.random(s) < p, 1.0, -1.0).astype(np.float32)
    return X.astype(np.float32), y, w_true


def paper_like(name: str, scale: Optional[float] = None, seed: int = 0,
               with_test: bool = False):
    """Generate a dataset with the profile of a paper benchmark.

    scale=None uses the CPU-budget shape; scale=1.0 the published shape.
    Returns (X, y, spec) or (Xtr, ytr, Xte, yte, spec) with with_test=True
    (paper section 5.3 splits one fifth for test).
    """
    spec = PAPER_DATASETS[name]
    if scale is None:
        s, n = _CPU_SHAPES[name]
    else:
        s, n = max(64, int(spec.s * scale)), max(16, int(spec.n * scale))
    X, y, _ = make_classification(
        s, n, sparsity=spec.sparsity, corr=spec.corr,
        row_normalize=spec.row_normalize, scale_pm1=spec.scale_pm1,
        seed=seed)
    if not with_test:
        return X, y, spec
    cut = int(0.8 * s)
    return X[:cut], y[:cut], X[cut:], y[cut:], spec


def make_sparse_classification(
    s: int,
    n: int,
    nnz_per_col: int = 16,
    w_nnz_frac: float = 0.02,
    noise: float = 0.1,
    seed: int = 0,
):
    """Directly-sparse classification data — never materializes (s, n).

    Generates the padded-CSC layout column by column (vectorized): each
    column gets 1..nnz_per_col nonzeros at rows sampled with replacement
    (duplicate (i, j) slots sum, which both backends treat identically),
    values ~ N(0, 1/sqrt(nnz)). Labels come from a planted sparse linear
    model through a logistic link, with margins computed by an O(nnz)
    scatter — so a 20k x 50k problem costs ~n*k_max*8 bytes, not the
    4 GB of its dense form (DESIGN.md section 7). Returns
    (PaddedCSC, y (s,) +-1 f32, w_true (n,) f32).
    """
    from repro.data.libsvm import PaddedCSC
    rng = np.random.default_rng(seed)
    counts = rng.integers(1, nnz_per_col + 1, size=n)
    k_max = int(nnz_per_col)
    col_rows = np.full((n, k_max), s, np.int32)
    col_vals = np.zeros((n, k_max), np.float32)
    mask = np.arange(k_max)[None, :] < counts[:, None]
    nnz = int(mask.sum())
    col_rows[mask] = rng.integers(0, s, size=nnz)
    scale = 1.0 / np.sqrt(counts.astype(np.float32))
    col_vals[mask] = rng.standard_normal(nnz).astype(np.float32) * \
        np.repeat(scale, counts)

    w_true = np.zeros((n,), np.float32)
    k_w = max(1, int(w_nnz_frac * n))
    sup = rng.choice(n, size=k_w, replace=False)
    w_true[sup] = rng.standard_normal(k_w).astype(np.float32) * 2.0
    z = np.zeros((s,), np.float32)
    np.add.at(z, col_rows[mask], col_vals[mask] * np.repeat(w_true, counts))
    z += noise * rng.standard_normal(s).astype(np.float32)
    p = 1.0 / (1.0 + np.exp(-z))
    y = np.where(rng.random(s) < p, 1.0, -1.0).astype(np.float32)
    return PaddedCSC(col_rows=col_rows, col_vals=col_vals, shape=(s, n)), \
        y, w_true


def duplicate_samples(X: np.ndarray, y: np.ndarray,
                      factor: float) -> Tuple[np.ndarray, np.ndarray]:
    """Section 5.4.1 data-size scaling: duplicate samples so the feature
    correlation structure is exactly preserved (factor may be fractional)."""
    s = X.shape[0]
    reps = int(np.floor(factor))
    rem = int(round((factor - reps) * s))
    Xs = [X] * reps + ([X[:rem]] if rem else [])
    ys = [y] * reps + ([y[:rem]] if rem else [])
    return np.concatenate(Xs, axis=0), np.concatenate(ys, axis=0)


def train_accuracy(X: np.ndarray, y: np.ndarray, w) -> float:
    pred = np.sign(X @ np.asarray(w))
    pred[pred == 0] = 1.0
    return float(np.mean(pred == y))
