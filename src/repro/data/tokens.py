"""LM token pipeline: deterministic synthetic corpus + background prefetch.

Offline container => no real corpus; the stream is a seeded Markov-ish
token generator (enough structure that loss visibly drops during the
example run). The pipeline is restart-deterministic: batch k is a pure
function of (seed, k), so checkpoint resume replays the exact stream —
the property the fault-tolerance tests assert.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig


class TokenPipeline:
    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 seed: int = 0, prefetch: int = 2):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed
        self.prefetch = prefetch

    def batch_at(self, index: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, index): restart-deterministic."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, index]))
        V = self.cfg.vocab_size
        B, S = self.batch, self.seq
        # structured stream: piecewise-linear token ramps + noise, so a
        # model can learn next-token structure quickly
        base = rng.integers(0, V, size=(B, 1))
        step = rng.integers(1, 7, size=(B, 1))
        ramp = (base + step * np.arange(S + 1)[None, :]) % V
        noise = rng.integers(0, V, size=(B, S + 1))
        keep = rng.random((B, S + 1)) < 0.85
        toks = np.where(keep, ramp, noise).astype(np.int32)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.cfg.family == "vlm":
            npatch = self.cfg.vlm.n_patches
            out["patches"] = rng.standard_normal(
                (B, npatch, self.cfg.d_model)).astype(np.float32) * 0.02
            out["labels"] = np.concatenate(
                [np.zeros((B, npatch), np.int32), out["labels"]], axis=1)
            out["loss_mask"] = np.concatenate(
                [np.zeros((B, npatch), np.float32),
                 np.ones((B, S), np.float32)], axis=1)
        if self.cfg.family == "encdec":
            fr = self.cfg.encdec.encoder_frames
            out["frames"] = rng.standard_normal(
                (B, fr, self.cfg.d_model)).astype(np.float32) * 0.02
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self.iterate(start=0)

    def iterate(self, start: int = 0,
                stop: Optional[int] = None) -> Iterator[Dict[str, np.ndarray]]:
        """Background-thread prefetch (double buffering)."""
        q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        stop_flag = threading.Event()

        def producer():
            i = start
            while not stop_flag.is_set() and (stop is None or i < stop):
                q.put((i, self.batch_at(i)))
                i += 1
            q.put(None)

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                item = q.get()
                if item is None:
                    return
                yield item[1]
        finally:
            stop_flag.set()
