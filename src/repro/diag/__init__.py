"""Solver-health diagnostics (DESIGN.md section 15).

The observability layer (repro.obs, DESIGN.md section 13) produces raw
telemetry — metrics records, traces, per-bundle (q, alpha) aux and the
opt-in per-feature KKT violation series. This package *interprets* it:

* `diag.kkt`       — per-feature KKT attribution: top-k offender tables,
                     violation distributions, active-set churn.
* `diag.forensics` — backtrack forensics: per-bundle depth heatmaps and
                     the divergence post-mortem the engine attaches to
                     `SolveResult.postmortem` when the guard trips.
* `diag.safep`     — certified safe parallelism: power-iteration
                     spectral radius of the normalized Gram matrix
                     (Bradley et al., arXiv 1105.5379) and the ω-based
                     ESO bound (Fercoq–Richtárik, arXiv 1309.5885),
                     both straight off the DesignMatrix.
* `diag.report`    — assembles everything into one markdown health
                     report (`python -m repro.diag.report`; `--diag-out`
                     on the solve/path CLIs).

Layering: diag consumes engine/core/obs and is consumed only by launch
and benchmarks; the single upward reference is the engine's local import
of `forensics.divergence_postmortem` on the divergence-trip path.
"""
from repro.diag import forensics, kkt, safep  # noqa: F401
from repro.diag.forensics import backtrack_heatmap, divergence_postmortem
from repro.diag.kkt import attribution
from repro.diag.safep import certify

__all__ = [
    "kkt", "forensics", "safep", "report",
    "attribution", "backtrack_heatmap", "divergence_postmortem",
    "certify", "build_payload", "render_markdown",
]


def __getattr__(name):
    # `report` loads lazily so `python -m repro.diag.report` does not
    # trip the runpy found-in-sys.modules warning on its own parent
    # package import.
    if name in ("report", "build_payload", "render_markdown"):
        import importlib
        # importlib, not `from repro.diag import report` — the from-form
        # re-enters this __getattr__ through _handle_fromlist and recurses
        _report = importlib.import_module("repro.diag.report")
        if name == "report":
            return _report
        return getattr(_report, name)
    raise AttributeError(f"module 'repro.diag' has no attribute {name!r}")
