"""Backtrack forensics (DESIGN.md section 15.2).

Folds the per-bundle line-search aux streams (`SolveHistory.bundle_q` /
`bundle_alpha`, DESIGN.md section 13.2) into interpretable shapes:

* `backtrack_heatmap` — the (iteration x depth) picture of where the
  Armijo search worked hard: aggregate depth distribution, per-iteration
  mean/max depth, and the fraction of bundles backtracking deep.
* `divergence_postmortem` — the record the engine attaches to
  `SolveResult.postmortem` when the divergence guard trips: objective
  growth since onset, the alpha-collapse trajectory, and the deepest
  bundles — enough to answer "which iterations/bundles drove q deep"
  without re-running the solve.

Sentinel convention (DESIGN.md 13.2): q == -1 / alpha == nan mark
bundle slots past the dynamic trip count under shrinking — both are
masked out here, never averaged in.
"""
from __future__ import annotations

import numpy as np

# a bundle that needed >= DEEP_Q halvings took a step <= beta^3 of the
# Newton step — the empirical "data fought back" threshold the report
# and the post-mortem both quote.
DEEP_Q = 3


def _mask(bundle_q) -> tuple:
    q = np.asarray(bundle_q, np.float64)
    if q.ndim == 1:
        q = q[None, :]
    return q, q >= 0  # sentinel -1 == bundle never ran


def backtrack_heatmap(bundle_q, deep_q: int = DEEP_Q) -> dict:
    """Depth heatmap of a (K, b) per-bundle backtrack-count series.

    `depth_counts[d]` counts bundle-steps across the whole run that
    backtracked exactly d times; the per-iteration series say *when*
    the deep ones happened.
    """
    q, ran = _mask(bundle_q)
    ran_q = q[ran].astype(np.int64)
    max_q = int(ran_q.max()) if ran_q.size else 0
    depth_counts = np.bincount(ran_q, minlength=max_q + 1) \
        if ran_q.size else np.zeros(1, np.int64)
    with np.errstate(invalid="ignore"):
        qm = np.where(ran, q, np.nan)
        per_iter_mean = np.nanmean(qm, axis=1)
        per_iter_max = np.nanmax(qm, axis=1)
        n_ran = ran.sum(axis=1)
        deep_frac = np.where(
            n_ran > 0, (qm >= deep_q).sum(axis=1) / np.maximum(n_ran, 1), 0.0)
    return {"n_iters": int(q.shape[0]),
            "n_bundle_slots": int(q.shape[1]),
            "bundles_ran": int(ran_q.size),
            "deep_q": int(deep_q),
            "depth_counts": depth_counts.tolist(),
            "per_iter_mean": np.nan_to_num(per_iter_mean).tolist(),
            "per_iter_max": np.nan_to_num(per_iter_max).tolist(),
            "per_iter_deep_frac": np.asarray(deep_frac).tolist()}


def alpha_trajectory(bundle_alpha) -> dict:
    """Per-iteration min/mean accepted step over the bundles that ran —
    the alpha-collapse curve a diverging high-P solve draws on its way
    to the guard."""
    a = np.asarray(bundle_alpha, np.float64)
    if a.ndim == 1:
        a = a[None, :]
    with np.errstate(invalid="ignore"):
        per_iter_min = np.nanmin(a, axis=1)
        per_iter_mean = np.nanmean(a, axis=1)
    return {"per_iter_min": np.nan_to_num(per_iter_min, nan=1.0).tolist(),
            "per_iter_mean": np.nan_to_num(per_iter_mean, nan=1.0).tolist()}


def worst_bundles(bundle_q, k: int = 5) -> list:
    """The k deepest (iteration, bundle, q) cells of the run."""
    q, ran = _mask(bundle_q)
    flat = np.where(ran, q, -1.0).ravel()
    k = min(int(k), int((flat >= 0).sum()))
    if k == 0:
        return []
    order = np.argsort(-flat, kind="stable")[:k]
    b = q.shape[1]
    return [{"iter": int(i // b), "bundle": int(i % b),
             "q": int(flat[i])} for i in order if flat[i] >= 0]


def divergence_postmortem(objective, kkt, ls_steps,
                          bundle_q=None, bundle_alpha=None) -> dict:
    """Post-mortem dict for a divergence-guard trip (engine/loop.py).

    Built from whatever history rows exist at the trip; richer when the
    per-bundle aux rode along (record_aux). Always JSON-serializable.
    Keys `objective_growth` and `deepest_mean_q` are load-bearing — the
    engine forwards them onto the trace as an instant event.
    """
    obj = np.asarray(objective, np.float64)
    kkt = np.asarray(kkt, np.float64)
    ls = np.asarray(ls_steps, np.float64)
    trip = int(obj.shape[0]) - 1
    # nanargmin/nanargmax raise on all-NaN input, which a non-finite
    # trip on the very first iteration produces — fall back to row 0
    obj_ok = obj.size and bool(np.any(np.isfinite(obj)))
    ls_ok = ls.size and bool(np.any(np.isfinite(ls)))
    onset = int(np.nanargmin(obj)) if obj_ok else 0
    pm = {
        "trip_iter": trip,
        "onset_iter": onset,
        "objective_at_onset": float(obj[onset]) if obj.size else float("nan"),
        "objective_at_trip": float(obj[-1]) if obj.size else float("nan"),
        "objective_growth": float(obj[-1] - obj[onset]) if obj.size
        else float("nan"),
        "kkt_at_trip": float(kkt[-1]) if kkt.size else float("nan"),
        "deepest_mean_q": float(np.nanmax(ls)) if ls_ok else float("nan"),
        "deepest_mean_q_iter": int(np.nanargmax(ls)) if ls_ok else 0,
    }
    if bundle_q is not None:
        pm["heatmap"] = backtrack_heatmap(bundle_q)
        pm["worst_bundles"] = worst_bundles(bundle_q)
    if bundle_alpha is not None:
        traj = alpha_trajectory(bundle_alpha)
        pm["alpha"] = traj
        mins = np.asarray(traj["per_iter_min"], np.float64)
        pm["alpha_floor"] = float(mins.min()) if mins.size else 1.0
        pm["alpha_floor_iter"] = int(mins.argmin()) if mins.size else 0
    return pm
