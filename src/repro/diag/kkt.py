"""Per-feature KKT attribution (DESIGN.md section 15.1).

Consumes the (K, n) violation series harvested by the engine when the
solver runs with `record_kkt_vec=True` (`SolveHistory.kkt_vec`): each
row k is the per-feature minimum-norm-subgradient violation |∂_j F|
after outer iteration k — the same vector whose max is the stop
criterion, so recording it costs one extra (n,) transfer per iteration
and zero extra device compute.

Everything here is host-side numpy over that series and returns plain
JSON-ready dicts (ints/floats/lists), because the consumers are the
markdown report and `--out` payloads.
"""
from __future__ import annotations

import numpy as np

# fixed log-spaced violation buckets, mirroring the obs histogram
# convention: counts has len(bounds)+1 entries, the last bucket is
# "> bounds[-1]" (and the first is "<= bounds[0]").
VIOL_BOUNDS = tuple(float(10.0 ** e) for e in range(-8, 3))  # 1e-8..1e2


def _series(kkt_vec) -> np.ndarray:
    v = np.asarray(kkt_vec, np.float64)
    if v.ndim == 1:
        v = v[None, :]
    if v.ndim != 2:
        raise ValueError(f"kkt_vec must be (K, n) or (n,), got {v.shape}")
    return v


def top_offenders(kkt_vec, k: int = 10, tol: float = 0.0) -> list:
    """Top-k features by FINAL-iteration violation.

    Each row: feature id, final violation, max violation over the run,
    and the number of iterations the feature spent above `tol` — the
    features that kept the solver from stopping, not just the ones that
    were briefly loud at iteration 0.
    """
    v = _series(kkt_vec)
    last = v[-1]
    k = min(int(k), last.shape[0])
    order = np.argsort(-last, kind="stable")[:k]
    return [{"feature": int(j),
             "viol_final": float(last[j]),
             "viol_max": float(np.max(v[:, j])),
             "iters_violating": int(np.sum(v[:, j] > tol))}
            for j in order]


def violation_histogram(kkt_vec, bounds=VIOL_BOUNDS) -> dict:
    """Distribution of the FINAL iteration's per-feature violations.

    Same shape contract as obs histograms: len(counts) == len(bounds)+1.
    Exact zeros (satisfied features — the common case at convergence)
    are counted separately so the log buckets describe the violating
    tail, not a spike at the bottom bucket.
    """
    last = _series(kkt_vec)[-1]
    nonzero = last[last > 0.0]
    edges = np.asarray(bounds, np.float64)
    counts = np.zeros(edges.shape[0] + 1, np.int64)
    if nonzero.size:
        counts += np.bincount(np.searchsorted(edges, nonzero, side="left"),
                              minlength=edges.shape[0] + 1)
    return {"count": int(last.shape[0]),
            "zeros": int(last.shape[0] - nonzero.size),
            "max": float(np.max(last)) if last.size else 0.0,
            "mean_nonzero": float(np.mean(nonzero)) if nonzero.size else 0.0,
            "bounds": [float(b) for b in edges],
            "counts": counts.tolist()}


def active_churn(kkt_vec, tol: float) -> dict:
    """Per-iteration churn of the violating set {j : viol_j > tol}.

    `entered[k]` / `left[k]` count features crossing tol between
    iterations k-1 and k (both 0 at k=0). Persistent churn late in a run
    is the signature of a bundle size the data cannot support: parallel
    updates keep re-violating features the previous iteration fixed.
    """
    v = _series(kkt_vec)
    viol = v > float(tol)
    n_violating = viol.sum(axis=1)
    flips = viol[1:] ^ viol[:-1]
    entered = np.concatenate([[0], (flips & viol[1:]).sum(axis=1)])
    left = np.concatenate([[0], (flips & ~viol[1:]).sum(axis=1)])
    return {"tol": float(tol),
            "n_violating": n_violating.astype(int).tolist(),
            "entered": entered.astype(int).tolist(),
            "left": left.astype(int).tolist(),
            "total_churn": int(entered.sum() + left.sum())}


def attribution(kkt_vec, tol: float, top_k: int = 10) -> dict:
    """The full attribution block the health report renders: offender
    table + final-iteration distribution + churn series."""
    v = _series(kkt_vec)
    return {"n_iters": int(v.shape[0]),
            "n_features": int(v.shape[1]),
            "offenders": top_offenders(v, k=top_k, tol=tol),
            "histogram": violation_histogram(v),
            "churn": active_churn(v, tol)}
