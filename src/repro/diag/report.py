"""Markdown solver-health report (DESIGN.md section 15.4).

    python -m repro.diag.report --report report.json \
        [--metrics run.jsonl] [--trace trace.json] \
        [--dataset NAME|FILE --layout auto] [-o health.md]

Assembles every diagnostics surface into one markdown document:

* run summary + convergence trajectory (from a `launch.solve --out` /
  `launch.path --out` report JSON),
* top-k per-feature KKT offenders, violation distribution and
  active-set churn (when the run recorded `history.kkt_vec` — i.e. ran
  with `--diag-out`),
* backtrack-depth forensics from `history.bundle_q / bundle_alpha`
  (when the run recorded telemetry aux) and the divergence post-mortem
  if the guard tripped,
* the certified-P table (`diag.safep`) next to the observed P — pass
  `--dataset` to recompute it from data, or it rides along pre-computed
  inside a `--diag-out` report under the `"diag"` key,
* metrics / trace summaries when the JSONL / trace files are given.

The solve/path CLIs call `build_payload` + `render_markdown` directly
for `--diag-out`; this module's CLI re-renders the same report from
saved artifacts after the fact.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

import numpy as np

from repro.diag import forensics, kkt, safep

BAR_WIDTH = 40  # widest ascii histogram bar


# ---------------------------------------------------------------------------
# payload assembly

def build_payload(report: dict | None = None,
                  metrics_records: list | None = None,
                  trace: dict | None = None,
                  safep_record: dict | None = None,
                  tol_kkt: float | None = None,
                  top_k: int = 10) -> dict:
    """One JSON-ready dict with every section the renderer knows.

    `report` is a solve/path `--out` payload (artifact schema + history);
    absent inputs simply drop their sections — the report degrades
    gracefully down to whatever artifacts exist.
    """
    payload: dict = {"sections": []}
    if report is not None:
        prov = report.get("provenance") or {}
        hist = _pick_history(report)
        tol = tol_kkt if tol_kkt is not None else prov.get("tol_kkt", 1e-3)
        payload["summary"] = {
            "dataset": prov.get("dataset"),
            "solver": prov.get("solver"),
            "backend": prov.get("backend"),
            "P": prov.get("P"),
            "loss": report.get("loss") or prov.get("loss"),
            "n_features": report.get("n_features"),
            "objective": report.get("objective"),
            "converged": report.get("converged"),
            "nnz": report.get("nnz"),
            "seconds": report.get("seconds"),
            "tol_kkt": tol,
        }
        payload["sections"].append("summary")
        if hist:
            payload["convergence"] = _convergence(hist, tol)
            payload["sections"].append("convergence")
            if hist.get("kkt_vec"):
                payload["attribution"] = kkt.attribution(
                    hist["kkt_vec"], tol=float(tol), top_k=top_k)
                payload["sections"].append("attribution")
            if hist.get("bundle_q"):
                payload["backtracks"] = forensics.backtrack_heatmap(
                    hist["bundle_q"])
                if hist.get("bundle_alpha"):
                    payload["backtracks"]["alpha"] = \
                        forensics.alpha_trajectory(hist["bundle_alpha"])
                payload["sections"].append("backtracks")
        pm = report.get("postmortem")
        if pm:
            payload["postmortem"] = pm
            payload["sections"].append("postmortem")
        if safep_record is None and isinstance(report.get("diag"), dict):
            safep_record = report["diag"].get("safep")
    if safep_record is not None:
        if payload.get("summary", {}).get("P") is not None \
                and "observed_P" not in safep_record:
            safep_record = dict(safep_record,
                                observed_P=int(payload["summary"]["P"]))
        payload["safep"] = safep_record
        payload["sections"].append("safep")
    if metrics_records:
        payload["metrics"] = _metrics_summary(metrics_records[-1])
        payload["sections"].append("metrics")
    if trace is not None:
        payload["trace"] = _trace_summary(trace)
        payload["sections"].append("trace")
    return payload


def _pick_history(report: dict) -> dict | None:
    """A solve report carries `history` directly; a path report carries
    per-point histories — take the last grid point's (the tightest c,
    where parallelism stress peaks)."""
    hist = report.get("history")
    if isinstance(hist, dict):
        return hist
    pts = report.get("points") or report.get("results")
    if isinstance(pts, list) and pts and isinstance(pts[-1], dict):
        h = pts[-1].get("history")
        if isinstance(h, dict):
            return h
    return None


def _convergence(hist: dict, tol) -> dict:
    obj = np.asarray(hist.get("objective", []), np.float64)
    kkt_s = np.asarray(hist.get("kkt", []), np.float64)
    ls = np.asarray(hist.get("ls_steps", []), np.float64)
    out = {"n_outer": int(obj.shape[0])}
    if obj.size:
        out.update(objective_first=float(obj[0]),
                   objective_final=float(obj[-1]))
    if kkt_s.size:
        out.update(kkt_final=float(kkt_s[-1]), tol_kkt=float(tol),
                   kkt_met=bool(kkt_s[-1] <= float(tol)))
    if ls.size:
        out.update(mean_q_final=float(ls[-1]),
                   mean_q_max=float(np.nanmax(ls)))
    if hist.get("n_active"):
        na = hist["n_active"]
        out.update(n_active_first=int(na[0]), n_active_final=int(na[-1]))
    return out


def _metrics_summary(record: dict) -> dict:
    m = record.get("metrics", {})
    hists = m.get("histograms", {})
    keep = {}
    for name in ("solver.iter_seconds", "solver.bundle_q",
                 "solver.bundle_alpha", "solver.mean_q"):
        h = hists.get(name)
        if h:
            keep[name] = {k: h.get(k)
                          for k in ("count", "mean", "p50", "p99", "max")}
    return {"ts": record.get("ts"), "cli": record.get("cli"),
            "counters": m.get("counters", {}),
            "gauges": m.get("gauges", {}),
            "histograms": keep}


def _trace_summary(trace: dict) -> dict:
    events = trace.get("traceEvents", [])
    by_name: dict = {}
    for ev in events:
        if not isinstance(ev, dict):
            continue
        name = ev.get("name", "?")
        rec = by_name.setdefault(name, {"events": 0, "total_ms": 0.0})
        rec["events"] += 1
        if ev.get("ph") == "X":
            rec["total_ms"] += float(ev.get("dur", 0)) / 1e3
    top = sorted(by_name.items(), key=lambda kv: -kv[1]["total_ms"])[:8]
    return {"n_events": len(events),
            "top_spans": [{"name": k, **v} for k, v in top]}


# ---------------------------------------------------------------------------
# markdown rendering

def _bar(count: int, peak: int) -> str:
    if peak <= 0:
        return ""
    return "#" * max(1, int(round(BAR_WIDTH * count / peak))) \
        if count else ""


def _fmt(x) -> str:
    if x is None:
        return "—"
    if isinstance(x, bool):
        return "yes" if x else "no"
    if isinstance(x, float):
        return f"{x:.4g}"
    return str(x)


def render_markdown(payload: dict) -> str:
    out = ["# Solver health report", ""]
    s = payload.get("summary")
    if s:
        out += ["## Run summary", "",
                "| field | value |", "|---|---|"]
        for k in ("dataset", "solver", "backend", "P", "loss",
                  "n_features", "objective", "converged", "nnz",
                  "seconds", "tol_kkt"):
            out.append(f"| {k} | {_fmt(s.get(k))} |")
        out.append("")
    c = payload.get("convergence")
    if c:
        out += ["## Convergence", ""]
        out.append(f"- {c['n_outer']} outer iterations; objective "
                   f"{_fmt(c.get('objective_first'))} → "
                   f"{_fmt(c.get('objective_final'))}")
        if "kkt_final" in c:
            verdict = "met" if c.get("kkt_met") else "NOT met"
            out.append(f"- final KKT violation {_fmt(c['kkt_final'])} vs "
                       f"tol {_fmt(c.get('tol_kkt'))} ({verdict})")
        if "mean_q_max" in c:
            out.append(f"- line search: final mean q "
                       f"{_fmt(c.get('mean_q_final'))}, deepest mean q "
                       f"{_fmt(c['mean_q_max'])}")
        if "n_active_first" in c:
            out.append(f"- active set {c['n_active_first']} → "
                       f"{c['n_active_final']} features")
        out.append("")
    a = payload.get("attribution")
    if a:
        out += ["## Top KKT offenders", "",
                "| feature | viol (final) | viol (max) | iters > tol |",
                "|---|---|---|---|"]
        for row in a["offenders"]:
            out.append(f"| {row['feature']} | {row['viol_final']:.3e} | "
                       f"{row['viol_max']:.3e} | "
                       f"{row['iters_violating']} |")
        h = a["histogram"]
        out += ["", "### Final violation distribution", "",
                f"{h['zeros']} / {h['count']} features exactly satisfied; "
                f"max violation {h['max']:.3e}.", "", "```"]
        peak = max(h["counts"]) if h["counts"] else 0
        edges = ["<=%.0e" % b for b in h["bounds"]] + \
                ["> %.0e" % h["bounds"][-1]]
        for label, cnt in zip(edges, h["counts"]):
            if cnt:
                out.append(f"{label:>10}  {cnt:>8}  {_bar(cnt, peak)}")
        out += ["```", ""]
        ch = a["churn"]
        nv = ch["n_violating"]
        out += ["### Active-set churn", "",
                f"- violating features (>{ch['tol']:g}): {nv[0]} → "
                f"{nv[-1]} over {len(nv)} iterations",
                f"- total churn (tol crossings): {ch['total_churn']} "
                f"(entered {sum(ch['entered'])}, left {sum(ch['left'])})",
                ""]
    b = payload.get("backtracks")
    if b:
        out += ["## Backtrack forensics", "",
                f"{b['bundles_ran']} bundle steps over {b['n_iters']} "
                f"iterations.", "", "```"]
        peak = max(b["depth_counts"]) if b["depth_counts"] else 0
        for d, cnt in enumerate(b["depth_counts"]):
            if cnt:
                out.append(f"q={d:<3} {cnt:>8}  {_bar(cnt, peak)}")
        out += ["```", ""]
        deep = np.asarray(b["per_iter_deep_frac"], np.float64)
        if deep.size:
            out.append(f"- deep bundles (q >= {b['deep_q']}): "
                       f"{100 * float(deep.mean()):.2f}% of bundles on "
                       f"average, worst iteration "
                       f"{100 * float(deep.max()):.2f}%")
        alpha = b.get("alpha")
        if alpha and alpha["per_iter_min"]:
            mins = np.asarray(alpha["per_iter_min"], np.float64)
            out.append(f"- accepted alpha floor {float(mins.min()):.3g} "
                       f"(iteration {int(mins.argmin())})")
        out.append("")
    pm = payload.get("postmortem")
    if pm:
        out += ["## Divergence post-mortem", "",
                f"- guard tripped at iteration {pm.get('trip_iter')}; "
                f"objective grew {_fmt(pm.get('objective_growth'))} since "
                f"its minimum at iteration {pm.get('onset_iter')}",
                f"- deepest mean backtrack depth "
                f"{_fmt(pm.get('deepest_mean_q'))} at iteration "
                f"{pm.get('deepest_mean_q_iter')}"]
        if pm.get("alpha_floor") is not None:
            out.append(f"- accepted alpha collapsed to "
                       f"{_fmt(pm['alpha_floor'])} at iteration "
                       f"{pm.get('alpha_floor_iter')}")
        for wb in pm.get("worst_bundles", [])[:5]:
            out.append(f"  - iteration {wb['iter']}, bundle "
                       f"{wb['bundle']}: q = {wb['q']}")
        out.append("")
    sp = payload.get("safep")
    if sp:
        out += ["## Certified parallelism", "",
                "| quantity | value |", "|---|---|",
                f"| n_features | {sp['n_features']} |",
                f"| rho (normalized Gram) | {sp['rho_normalized']:.4g} |",
                f"| P_spectral = n / rho | {sp['P_spectral']} |",
                f"| omega (max row support) | {sp['omega']} |",
                f"| P_eso (beta <= {sp['beta_max']:g}) | {sp['P_eso']} |",
                f"| **P_cert** | **{sp['P_cert']}** |"]
        if "observed_P" in sp:
            obs_p = sp["observed_P"]
            out.append(f"| observed P (divergence-free) | {obs_p} |")
            out.append("")
            if obs_p > sp["P_cert"]:
                out.append(
                    f"Observed P {obs_p} exceeds the certified bound "
                    f"{sp['P_cert']}: convergence rests on the Armijo "
                    f"backtrack, not on theory — expect deep q at this "
                    f"or larger P.")
            else:
                out.append(
                    f"Observed P {obs_p} is within the certified bound "
                    f"{sp['P_cert']}: the step sizes are theory-safe "
                    f"before the line search even runs.")
        if not sp.get("power_converged", True):
            out.append("")
            out.append(f"(power iteration stopped at {sp['power_iters']} "
                       f"iterations without meeting tolerance — rho is a "
                       f"lower bound)")
        out.append("")
    m = payload.get("metrics")
    if m:
        out += ["## Metrics summary", ""]
        ctr = m.get("counters", {})
        if ctr:
            shown = ", ".join(f"{k}={_fmt(v)}" for k, v in
                              sorted(ctr.items())[:8])
            out.append(f"- counters: {shown}")
        for name, h in m.get("histograms", {}).items():
            out.append(f"- {name}: count={h.get('count')} "
                       f"mean={_fmt(h.get('mean'))} "
                       f"p50={_fmt(h.get('p50'))} p99={_fmt(h.get('p99'))}")
        out.append("")
    t = payload.get("trace")
    if t:
        out += ["## Trace summary", "",
                f"{t['n_events']} trace events; busiest spans:", ""]
        for row in t["top_spans"]:
            out.append(f"- {row['name']}: {row['events']} events, "
                       f"{row['total_ms']:.1f} ms total")
        out.append("")
    return "\n".join(out).rstrip() + "\n"


# ---------------------------------------------------------------------------
# CLI

def _load_design(dataset: str, layout: str, seed: int):
    """Rebuild just the DesignMatrix for `--dataset` (profile name or
    libsvm file) so the CLI can recompute the certified-P table."""
    from repro.core import as_design
    from repro.data import load_libsvm, paper_like
    if os.path.exists(dataset):
        file_layout = "padded_csc" if layout == "padded_csc" else "dense"
        X, _ = load_libsvm(dataset, layout=file_layout)
    else:
        X, _, _ = paper_like(dataset, seed=seed)
    return as_design(X, layout=layout)


def _read_jsonl(path: str) -> list:
    records = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                records.append(json.loads(line))
    return records


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.diag.report",
        description="Render a markdown solver-health report from saved "
                    "artifacts (DESIGN.md section 15.4)")
    ap.add_argument("--report", default=None, metavar="JSON",
                    help="a launch.solve/path --out report (history, "
                         "provenance, optional diag block)")
    ap.add_argument("--metrics", default=None, metavar="JSONL",
                    help="metrics run-record log (--metrics-out); the "
                         "last record is summarized")
    ap.add_argument("--trace", default=None, metavar="JSON",
                    help="Chrome-trace file (--trace-out)")
    ap.add_argument("--dataset", default=None,
                    help="recompute the certified-P table from this "
                         "dataset (profile name or libsvm file)")
    ap.add_argument("--layout", default="auto",
                    choices=["auto", "dense", "padded_csc"])
    ap.add_argument("--beta-max", type=float, default=2.0,
                    help="ESO overapproximation budget (default 2.0)")
    ap.add_argument("--top-k", type=int, default=10,
                    help="offender-table size")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tol", type=float, default=None,
                    help="KKT tolerance for attribution (default: the "
                         "report's provenance tol_kkt)")
    ap.add_argument("-o", "--out", default=None, metavar="MD",
                    help="write the report here (default: stdout)")
    args = ap.parse_args(argv)
    if not (args.report or args.metrics or args.trace or args.dataset):
        ap.error("nothing to report on: pass --report, --metrics, "
                 "--trace and/or --dataset")

    report = None
    if args.report:
        with open(args.report) as fh:
            report = json.load(fh)
    metrics_records = _read_jsonl(args.metrics) if args.metrics else None
    trace = None
    if args.trace:
        with open(args.trace) as fh:
            trace = json.load(fh)
    safep_record = None
    if args.dataset:
        design = _load_design(args.dataset, args.layout, args.seed)
        safep_record = safep.certify(design, beta_max=args.beta_max,
                                     seed=args.seed)

    payload = build_payload(report=report, metrics_records=metrics_records,
                            trace=trace, safep_record=safep_record,
                            tol_kkt=args.tol, top_k=args.top_k)
    md = render_markdown(payload)
    if args.out:
        with open(args.out, "w") as fh:
            fh.write(md)
        print(f"[diag] health report written to {args.out}")
    else:
        sys.stdout.write(md)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
