"""Certified safe parallelism (DESIGN.md section 15.3).

PCDN's bundle size P is a raw knob: too large and the parallel updates
fight (deep backtracks, then the divergence guard). Two theory lines
certify a safe P directly from data quantities the repo already holds:

* **Spectral (Bradley et al., arXiv 1105.5379 — Shotgun).** With
  unit-normalized columns, parallel coordinate descent is
  near-guaranteed up to P* ≈ n / ρ where ρ is the spectral radius of
  the normalized Gram matrix M = D^{-1/2} X'X D^{-1/2},
  D = diag(‖x_j‖²). ρ ∈ [1, n]: orthogonal designs give ρ = 1
  (every coordinate independent → P* = n); perfectly correlated ones
  give ρ = n (P* = 1). M is PSD, so its spectral radius is its top
  eigenvalue and plain power iteration on matvec/rmatvec converges —
  no dense Gram is ever formed, so this runs at padded-CSC scale.

* **ESO (Fercoq–Richtárik, arXiv 1309.5885).** For uniform τ-nice
  sampling, β(τ) = 1 + (τ-1)(ω-1)/(n-1) is an expected separable
  overapproximation parameter, where ω is the max number of features
  any single sample touches — sitting in the padded-CSC row metadata.
  The largest τ with β(τ) ≤ β_max is certified convergent with step
  scaling 1/β_max; β_max = 2 matches the classical "halved steps are
  always safe" operating point.

`certify(design)` reports both and `P_cert = max` of the two (each is a
*sufficient* condition under its own sampling model, so the best one
stands). The report renders it next to the observed divergence-free P.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np


def _col_scale(design) -> np.ndarray:
    """1/‖x_j‖ per column with zeros for empty columns (which contribute
    a zero eigendirection, not a division blow-up)."""
    d = np.asarray(design.column_norms_sq(), np.float64)
    scale = np.zeros_like(d)
    np.divide(1.0, np.sqrt(d), out=scale, where=d > 0)
    return scale


def power_iteration_rho(design, n_iter: int = 1000, tol: float = 1e-9,
                        seed: int = 0) -> dict:
    """Top eigenvalue of the normalized Gram M = D^{-1/2} X'X D^{-1/2}.

    One matvec + one rmatvec per step through the DesignMatrix protocol
    (dense or padded-CSC — never densifies), Rayleigh-quotient estimate,
    stop at relative change <= tol. Deterministic start from `seed`.
    """
    n = design.n_features
    scale = _col_scale(design)
    rng = np.random.default_rng(seed)
    v = rng.standard_normal(n)
    v /= np.linalg.norm(v)
    rho_prev = 0.0
    converged = False
    it = 0
    for it in range(1, n_iter + 1):
        u = np.asarray(design.matvec(jnp.asarray(v * scale, jnp.float32)),
                       np.float64)
        mv = scale * np.asarray(design.rmatvec(jnp.asarray(u, jnp.float32)),
                                np.float64)
        rho = float(v @ mv)                      # Rayleigh quotient
        nrm = np.linalg.norm(mv)
        if nrm == 0.0:                           # X == 0: rho is 0
            rho, converged = 0.0, True
            break
        v = mv / nrm
        if abs(rho - rho_prev) <= tol * max(abs(rho), 1.0):
            converged = True
            rho_prev = rho
            break
        rho_prev = rho
    return {"rho": float(rho_prev), "n_iter": int(it),
            "converged": bool(converged)}


def omega_row_support(design) -> int:
    """ω = max features any single sample touches (max per-row nnz).

    Padded-CSC: histogram the col_rows ids, excluding the sentinel
    (== n_samples) padding slots AND explicit zero values (a stored zero
    exerts no coupling). Dense: count nonzeros per row.
    """
    layout = getattr(design, "layout", "dense")
    if layout == "padded_csc":
        rows = np.asarray(design.col_rows).ravel()
        vals = np.asarray(design.col_vals, np.float64).ravel()
        keep = (rows != design.n_samples) & (vals != 0.0)
        if not np.any(keep):
            return 0
        return int(np.bincount(rows[keep],
                               minlength=design.n_samples).max())
    X = np.asarray(design.X)
    if X.size == 0:
        return 0
    return int(np.max(np.sum(X != 0, axis=1)))


def eso_safe_p(omega: int, n_features: int, beta_max: float = 2.0) -> int:
    """Largest τ with β(τ) = 1 + (τ-1)(ω-1)/(n-1) <= beta_max.

    ω <= 1 means no sample couples two features — every coordinate is
    independent and τ = n is safe. n == 1 is trivially τ = 1.
    """
    n = int(n_features)
    if n <= 1:
        return max(n, 1)
    if omega <= 1:
        return n
    tau = 1.0 + (float(beta_max) - 1.0) * (n - 1) / (omega - 1)
    return int(np.clip(np.floor(tau), 1, n))


def spectral_safe_p(rho: float, n_features: int) -> int:
    """Shotgun's P* = n / ρ (ρ of the column-normalized Gram)."""
    n = int(n_features)
    if rho <= 0.0:
        return n
    return int(np.clip(np.floor(n / rho), 1, n))


def certify(design, beta_max: float = 2.0, n_iter: int = 1000,
            tol: float = 1e-9, seed: int = 0,
            observed_p: Optional[int] = None) -> dict:
    """The full certified-parallelism record the health report renders.

    `P_cert` is the best (largest) of the two certified bounds;
    `observed_p` — the P a solve actually ran divergence-free — rides
    along for the report's certified-vs-observed comparison.
    """
    power = power_iteration_rho(design, n_iter=n_iter, tol=tol, seed=seed)
    omega = omega_row_support(design)
    n = int(design.n_features)
    p_spec = spectral_safe_p(power["rho"], n)
    p_eso = eso_safe_p(omega, n, beta_max)
    out = {"n_samples": int(design.n_samples), "n_features": n,
           "rho_normalized": power["rho"],
           "power_iters": power["n_iter"],
           "power_converged": power["converged"],
           "P_spectral": p_spec,
           "omega": int(omega), "beta_max": float(beta_max),
           "P_eso": p_eso,
           "P_cert": max(p_spec, p_eso)}
    if observed_p is not None:
        out["observed_P"] = int(observed_p)
    return out
