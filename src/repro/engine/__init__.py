"""Unified solver engine (DESIGN.md section 9): ONE outer-iteration
contract — carry (w, z, key, active), full-gradient KKT stopping, history
and wall-clock bookkeeping — behind pluggable execution backends, so path
sweeps, active-set shrinking, warm starts and Pallas kernels compose with
both the single-program and the sharded-mesh substrates."""
from repro.engine.loop import (EngineState, ExecutionBackend, SolveHistory,
                               SolveResult, run_lockstep_loop,
                               run_outer_loop, solve)
from repro.engine.local import LocalBackend
from repro.engine.sharded import (ShardedBackend, ShardedPCDNConfig,
                                  make_sharded_margins, make_sharded_outer,
                                  shard_problem, shard_problem_sparse)

__all__ = [
    "EngineState", "ExecutionBackend", "SolveHistory", "SolveResult",
    "run_outer_loop", "run_lockstep_loop", "solve",
    "LocalBackend",
    "ShardedBackend", "ShardedPCDNConfig", "make_sharded_outer",
    "make_sharded_margins", "shard_problem", "shard_problem_sparse",
]
