"""Local execution backend: one single-program XLA computation per outer
iteration (DESIGN.md section 9.2).

Wraps `pcdn.make_bundle_step` / `pcdn.make_path_outer` — dense or
padded-CSC design matrices, optional fused Pallas kernels, active-set
shrinking — behind the engine's backend contract, so the same drivers
(`engine.loop.solve`, `path.driver.run_path`) run here or on a sharded
mesh without change.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.problem import L1Problem
from repro.engine.loop import EngineState

Array = jax.Array


class LocalBackend:
    """Execution backend over a host-resident `L1Problem`.

    cfg: a `pcdn.PCDNConfig`. outer=: optional prebuilt
    `pcdn.make_path_outer(problem, cfg)` — benchmarks pass an
    already-compiled one so warm-vs-cold timings compare solver work,
    not XLA compile time.
    """

    def __init__(self, problem: L1Problem, cfg, outer=None):
        # deferred import: core.pcdn re-exports engine types, and the
        # engine package initializes this module — a top-level import
        # here would close the cycle before either side finishes.
        from repro.core import pcdn
        self.problem = problem
        self.cfg = cfg
        self.outer = (outer if outer is not None
                      else pcdn.make_path_outer(problem, cfg))

    @property
    def n_features(self) -> int:
        return self.problem.n_features

    @property
    def n_samples(self) -> int:
        return self.problem.n_samples

    @property
    def dtype(self):
        """Solver-state dtype: f32 even when the design stores bf16
        values (margin state accumulates in f32 — DESIGN.md section 12)."""
        return self.problem.solve_dtype

    def init_state(self, w0: Optional[Array] = None) -> EngineState:
        n, s = self.n_features, self.n_samples
        if w0 is None:
            w = jnp.zeros((n,), self.dtype)
            z = jnp.zeros((s,), self.dtype)
        else:
            w = jnp.asarray(w0, self.dtype)
            z = self.problem.margins(w)
        return EngineState(w=w, z=z, key=jax.random.PRNGKey(self.cfg.seed),
                           active=jnp.ones((n,), bool))

    def margins(self, w: Array) -> Array:
        return self.problem.margins(w)

    def c_max(self) -> float:
        return self.problem.c_max()

    def host_weights(self, w: Array) -> np.ndarray:
        return np.asarray(w)

    def host_margins(self, z: Array) -> np.ndarray:
        """(n_samples,) host margins — the checkpoint image of z."""
        return np.asarray(z)

    def restore_state(self, w, z=None, active=None, key=None) -> EngineState:
        """EngineState from host arrays (a `fault.checkpoint` snapshot,
        possibly written by a DIFFERENT backend/mesh — checkpoints store
        full unpadded host arrays precisely so this works). Missing
        pieces fall back to init_state semantics: z is recomputed from
        w, active to all-True, key to the config seed chain."""
        n, s = self.n_features, self.n_samples
        w = jnp.asarray(w, self.dtype)
        if w.shape[0] != n:
            raise ValueError(f"checkpoint has {w.shape[0]} features, "
                             f"problem has {n}")
        z = (self.problem.margins(w) if z is None
             else jnp.asarray(np.asarray(z).reshape(s), self.dtype))
        active = (jnp.ones((n,), bool) if active is None
                  else jnp.asarray(np.asarray(active).reshape(n), bool))
        key = (jax.random.PRNGKey(self.cfg.seed) if key is None
               else jnp.asarray(np.asarray(key), jnp.uint32))
        return EngineState(w=w, z=z, key=key, active=active)
