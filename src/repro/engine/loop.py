"""Unified solver engine: the outer-iteration contract (DESIGN.md section 9).

Every PCDN-family solver in this repo is a host-side convergence loop
around one jitted "outer iteration". Before the engine existed that loop
— carry threading, full-gradient KKT stopping, history recording,
wall-clock bookkeeping — was re-implemented by pcdn.solve, the sharded
solver, SCDN and the path driver. It now exists ONCE, here, behind a
pluggable *execution backend* interface:

    outer(w, z, key, active, recheck, c)
      -> (w, z, key, f, kkt, nnz, mean_q, active, n_active)

* ``(w, z, key, active)`` is the solver carry (`EngineState`): weights,
  per-sample margins z = X w, the PRNG chain for bundle partitions, and
  the un-shrunk feature mask.
* ``recheck`` (traced bool) asks the iteration to un-shrink any feature
  whose full-set KKT violation exceeds tolerance.
* ``c`` is a TRACED regularization scalar, so one compiled program
  serves a whole warm-started c-sweep (the dynamic-c contract of
  DESIGN.md section 8).
* ``kkt`` must be the FULL-set violation — the stop criterion is
  backend-independent.

Backends (duck-typed; see `ExecutionBackend`):

* `repro.engine.local.LocalBackend` — single XLA program wrapping
  `pcdn.make_bundle_step` / `pcdn.make_path_outer` (dense or padded-CSC
  design, optional fused Pallas kernels).
* `repro.engine.sharded.ShardedBackend` — the 2-D (data x model)
  shard_map implementation, same contract, so path sweeps, shrinking
  and warm starts run unchanged on a multi-device mesh.

`pcdn.solve`, `core.sharded.solve_sharded`, `path.driver.run_path`,
`path.batch.solve_batch`, and `scdn.solve` are all thin callers of the
helpers in this module.
"""
from __future__ import annotations

import time
from typing import Callable, NamedTuple, Optional, Protocol, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

Array = jax.Array


class EngineState(NamedTuple):
    """The backend-independent solver carry."""

    w: Array        # (n,) weights (backend-native placement)
    z: Array        # (s,) margins X w
    key: Array      # PRNG key chain for bundle partitions
    active: Array   # (n,) bool un-shrunk mask (all-True without shrinking)


class SolveHistory(NamedTuple):
    outer_iter: np.ndarray     # (K,)
    objective: np.ndarray      # (K,) F_c(w) after each outer iteration
    kkt: np.ndarray            # (K,)
    nnz: np.ndarray            # (K,) number of nonzeros in w
    ls_steps: np.ndarray       # (K,) mean line-search steps per bundle
    wall_time: np.ndarray      # (K,) cumulative seconds
    n_active: np.ndarray       # (K,) un-shrunk features (== n without shrink)
    # per-bundle series (DESIGN.md section 13.2): present only when the
    # backend was built with record_aux=True — the outer iteration then
    # returns a 10th output (q (b,), alpha (b,)) and these are (K, b)
    # with sentinel q == -1 / alpha == nan on bundles that did not run
    # (the shrinking solver's unused trailing slots).
    bundle_q: Optional[np.ndarray] = None       # (K, b) int32
    bundle_alpha: Optional[np.ndarray] = None   # (K, b)
    # per-feature KKT attribution series (DESIGN.md section 15.1):
    # present only with record_kkt_vec=True — the outer then also
    # returns the (n,) per-feature violation vector (the same
    # kkt_violation_from_grad the scalar stop reduces), harvested at
    # the per-iteration host sync into a (K, n) array.
    kkt_vec: Optional[np.ndarray] = None        # (K, n)


class SolveResult(NamedTuple):
    w: Array
    objective: float
    n_outer: int
    converged: bool
    history: SolveHistory
    diverged: bool = False     # divergence guard OR non-finite detector
    # divergence post-mortem (DESIGN.md section 15.2): attached when the
    # divergence guard trips — which iterations/bundles drove the deep
    # backtracks and how alpha collapsed, from whatever series the run
    # recorded (richer with record_aux). None on non-diverged solves.
    postmortem: Optional[dict] = None
    # non-finite trip (DESIGN.md section 16.3): the always-on detector
    # caught NaN/inf in (f, kkt) — the objective-growth guard alone
    # cannot (NaN fails every comparison). When set, `w`/`objective` and
    # the returned EngineState are the LAST GOOD iterate, so a caller
    # (fault.resilient_solve) can roll back and retry at damped P.
    nonfinite: bool = False
    # rollback/P-backoff record attached by fault.resilient_solve:
    # {"rollbacks", "p_schedule", "p_cert", "resumed_from"}. None on
    # fault-free solves.
    faults: Optional[dict] = None


class ExecutionBackend(Protocol):
    """What the engine needs from an execution substrate (duck-typed).

    `outer` is the jitted iteration described in the module docstring.
    The remaining methods let drivers stay placement-agnostic: a local
    backend hands out plain jnp arrays, the sharded backend hands out
    mesh-placed (and feature-padded) arrays — callers never see the
    difference.
    """

    outer: Callable  # (w, z, key, active, recheck, c) -> 9-tuple

    @property
    def n_features(self) -> int: ...          # REAL feature count (unpadded)

    @property
    def dtype(self): ...

    def init_state(self, w0=None) -> EngineState: ...

    def margins(self, w: Array) -> Array: ...  # recompute z = X w

    def c_max(self) -> float: ...              # analytic path start

    def host_weights(self, w: Array) -> np.ndarray: ...  # (n_features,) host


def _build_postmortem(hist: dict, aux_q: list, aux_alpha: list,
                      k: int) -> dict:
    """Divergence post-mortem (DESIGN.md section 15.2) from the rows
    recorded so far, richer when per-bundle aux rode along. Local
    import — diag consumes the engine, so a top-level import would close
    the layering cycle. Shared by the guard trip and the non-finite
    detector."""
    from repro.diag import forensics
    postmortem = forensics.divergence_postmortem(
        objective=np.asarray(hist["objective"]),
        kkt=np.asarray(hist["kkt"]),
        ls_steps=np.asarray(hist["ls_steps"]),
        bundle_q=np.asarray(aux_q) if aux_q else None,
        bundle_alpha=np.asarray(aux_alpha) if aux_alpha else None)
    obs.instant("engine.divergence_postmortem", "engine",
                args={"k": k,
                      "objective_growth": postmortem["objective_growth"],
                      "deepest_mean_q": postmortem["deepest_mean_q"]})
    return postmortem


def run_outer_loop(outer: Callable, state: EngineState, c: float, *,
                   max_outer: int, tol_kkt: float,
                   recheck_every: int = 1, tol_rel_obj: float = 0.0,
                   f_star: Optional[float] = None,
                   callback: Optional[Callable] = None,
                   divergence_guard: Optional[Callable[[float], bool]] = None,
                   start_iter: int = 0,
                   state_callback: Optional[Callable] = None,
                   check_finite_w: bool = False,
                   ) -> Tuple[EngineState, SolveResult]:
    """Host-side convergence loop around a backend outer iteration.

    The single implementation of the stop logic (full-set KKT, optional
    relative-objective, optional divergence guard) and of history /
    wall-clock recording. Returns (state, SolveResult).

    divergence_guard(f) -> True aborts the loop and flags the result as
    diverged (SCDN's Hogwild semantics); converged stays False. On a
    trip the result carries a `postmortem` dict (repro.diag.forensics)
    built from the recorded series — richer when the backend also
    recorded per-bundle aux.

    Non-finite detection is ALWAYS on (DESIGN.md section 16.3): a NaN/inf
    objective or KKT — which `divergence_guard(f)`'s growth comparison
    can never catch, NaN compares False — aborts the loop with
    `diverged=True, nonfinite=True`, a postmortem, and the LAST GOOD
    iterate as the returned state/weights (the poisoned carry is
    discarded — it is what the caller must NOT keep). The detector reads
    only the f/kkt host floats the loop already syncs, so the fault-free
    hot path gains zero device work; `check_finite_w=True` additionally
    scans the weight vector each iteration (one device all-reduce — the
    belt-and-braces mode `fault.resilient_solve` runs retries under).

    start_iter shifts the iteration counter: the loop runs iterations
    [start_iter, max_outer) with GLOBAL indices, so a resumed solve
    replays the exact recheck cadence (k % recheck_every) and history
    numbering of the uninterrupted run — max_outer stays the TOTAL
    budget, not a per-resume increment.

    state_callback(k, EngineState, f, kkt) fires after each FINITE
    iteration's host sync — the periodic-checkpoint hook
    (fault.SolveCheckpointer.solve_callback); it never sees a poisoned
    carry.

    Outputs past the 9-tuple are dispatched STRUCTURALLY, so the two
    opt-in device-aux planes compose in any combination:

      * a 2-tuple of arrays — per-bundle (q (b,), alpha (b,)), the
        `record_aux` contract of DESIGN.md section 13.2 — harvested
        into `SolveHistory.bundle_q/bundle_alpha` (and, when the
        metrics registry is enabled, into the solver.bundle_q /
        solver.bundle_alpha histograms) at the same host sync that
        reads f/kkt.
      * a single array — the (n,) per-feature KKT violation vector,
        the `record_kkt_vec` contract of DESIGN.md section 15.1 —
        harvested into `SolveHistory.kkt_vec`.

    A 9-tuple outer records exactly the history it always did.

    callback(k, w, f, kkt, mean_q) fires after every iteration's host
    sync (mean_q is the iteration's mean line-search depth — the
    `--progress` CLI consumes it).
    """
    w, z, key, active = state
    c_arr = jnp.asarray(c, w.dtype)
    base_fields = ("outer_iter", "objective", "kkt", "nnz", "ls_steps",
                   "wall_time", "n_active")
    hist = {k: [] for k in base_fields}
    aux_q: list = []
    aux_alpha: list = []
    kkt_rows: list = []
    t0 = time.perf_counter()
    converged = diverged = nonfinite = False
    postmortem = None
    f = f_good = float("nan")
    prev_active = None
    k = start_iter - 1
    for k in range(start_iter, max_outer):
        # iteration 0 always rechecks so a stale warm-started active set
        # (e.g. carried across path points) is repaired immediately.
        recheck = jnp.asarray(k == 0 or recheck_every <= 1
                              or k % recheck_every == 0)
        t_iter = time.perf_counter_ns()
        # the pre-iteration carry is the rollback target should this
        # iteration come back non-finite
        prev_state = (w, z, key, active)
        out = outer(w, z, key, active, recheck, c_arr)
        w, z, key, f_, kkt, nnz, mean_q, active, n_active = out[:9]
        aux = kkt_vec = None
        for extra in out[9:]:
            if isinstance(extra, tuple):
                aux = extra
            else:
                kkt_vec = extra
        # sync BEFORE timestamping: float(f_) below only blocks on f_,
        # and a backend dispatching asynchronously would otherwise get
        # this iteration's device time attributed to a later row
        # (tests/test_obs.py pins monotone per-iteration times that sum
        # to ~ the loop total).
        jax.block_until_ready((w, z, active))
        t_now = time.perf_counter_ns()
        f = float(f_)
        kkt_f = float(kkt)
        n_active_i = int(n_active)
        hist["outer_iter"].append(k)
        hist["objective"].append(f)
        hist["kkt"].append(kkt_f)
        hist["nnz"].append(int(nnz))
        hist["ls_steps"].append(float(mean_q))
        hist["wall_time"].append(time.perf_counter() - t0)
        hist["n_active"].append(n_active_i)
        if aux is not None:
            q_np = np.asarray(aux[0])
            a_np = np.asarray(aux[1])
            aux_q.append(q_np)
            aux_alpha.append(a_np)
            if obs.metrics_enabled():
                ran = q_np >= 0          # sentinel -1: bundle did not run
                obs.observe_many("solver.bundle_q", q_np[ran],
                                 bounds=obs.Q_BOUNDS)
                obs.observe_many("solver.bundle_alpha", a_np[ran],
                                 bounds=obs.ALPHA_BOUNDS)
        if kkt_vec is not None:
            kkt_rows.append(np.asarray(kkt_vec))
        if obs.metrics_enabled():
            obs.inc("solver.outer_iters")
            obs.observe("solver.iter_seconds", (t_now - t_iter) / 1e9)
            obs.observe("solver.mean_q", float(mean_q), bounds=obs.Q_BOUNDS)
            obs.set_gauge("solver.n_active", n_active_i)
            obs.set_gauge("solver.kkt", kkt_f)
            if prev_active is not None and n_active_i != prev_active:
                if n_active_i < prev_active:
                    obs.inc("solver.shrink_events",
                            prev_active - n_active_i)
                else:
                    obs.inc("solver.unshrink_events",
                            n_active_i - prev_active)
        prev_active = n_active_i
        obs.complete("engine.outer", "engine", t_iter, t_now,
                     args={"k": k, "objective": f, "kkt": kkt_f,
                           "mean_q": float(mean_q),
                           "n_active": n_active_i})
        if callback is not None:
            callback(k, w, f, kkt_f, float(mean_q))
        # non-finite detector (DESIGN.md section 16.3): always on, free
        # on the hot path (f/kkt are already host floats here)
        finite = np.isfinite(f) and np.isfinite(kkt_f)
        if finite and check_finite_w:
            finite = bool(jnp.all(jnp.isfinite(w)))
        if not finite:
            diverged = nonfinite = True
            obs.inc("solver.nonfinite_trips")
            obs.instant("engine.nonfinite_guard", "engine",
                        args={"k": k, "objective": f, "kkt": kkt_f})
            postmortem = _build_postmortem(hist, aux_q, aux_alpha, k)
            # roll the carry back to the last good iterate: the poisoned
            # state must never leak into warm starts, checkpoints or the
            # returned weights
            w, z, key, active = prev_state
            f = f_good
            break
        f_good = f
        if state_callback is not None:
            state_callback(k, EngineState(w, z, key, active), f, kkt_f)
        if divergence_guard is not None and divergence_guard(f):
            diverged = True
            obs.inc("solver.divergence_trips")
            obs.instant("engine.divergence_guard", "engine",
                        args={"k": k, "objective": f})
            postmortem = _build_postmortem(hist, aux_q, aux_alpha, k)
            break
        if kkt_f <= tol_kkt:
            converged = True
            break
        if f_star is not None and tol_rel_obj > 0:
            if (f - f_star) <= tol_rel_obj * abs(f_star):
                converged = True
                break
    history = SolveHistory(
        **{k_: np.asarray(v) for k_, v in hist.items()},
        bundle_q=np.asarray(aux_q) if aux_q else None,
        bundle_alpha=np.asarray(aux_alpha) if aux_alpha else None,
        kkt_vec=np.asarray(kkt_rows) if kkt_rows else None)
    result = SolveResult(w=w, objective=f, n_outer=k + 1,
                         converged=converged, history=history,
                         diverged=diverged, postmortem=postmortem,
                         nonfinite=nonfinite)
    return EngineState(w, z, key, active), result


def check_shrink_stop_consistency(backend, tol_kkt: float):
    """A shrinking backend bakes its UN-shrink threshold (cfg.tol_kkt)
    into the compiled iteration; driving it with a TIGHTER stop tolerance
    would let a feature with violation in (tol_kkt, cfg.tol_kkt] stay
    shrunk forever while the loop never reaches its stop — a silent
    max_outer burn. Refuse loudly instead."""
    cfg = getattr(backend, "cfg", None)
    if cfg is None or not getattr(cfg, "shrink", False):
        return
    un_shrink = getattr(cfg, "tol_kkt", None)
    if un_shrink is not None and tol_kkt < un_shrink:
        raise ValueError(
            f"stop tol_kkt={tol_kkt} is tighter than the backend's "
            f"compiled un-shrink threshold cfg.tol_kkt={un_shrink}; a "
            f"shrunk feature between them would never be reactivated. "
            f"Rebuild the backend with cfg.tol_kkt <= the stop tolerance.")


def solve(backend, c: float, w0=None, *,
          max_outer: int, tol_kkt: float, recheck_every: int = 1,
          tol_rel_obj: float = 0.0, f_star: Optional[float] = None,
          callback: Optional[Callable] = None) -> SolveResult:
    """One full solve on a backend: init state, loop to the KKT stop."""
    check_shrink_stop_consistency(backend, tol_kkt)
    state = backend.init_state(w0)
    _, result = run_outer_loop(
        backend.outer, state, c, max_outer=max_outer, tol_kkt=tol_kkt,
        recheck_every=recheck_every, tol_rel_obj=tol_rel_obj,
        f_star=f_star, callback=callback)
    return result


def run_lockstep_loop(outer: Callable, carry: Sequence[Array],
                      extra: Sequence, *, max_outer: int, tol_kkt: float,
                      dtype):
    """Freeze-on-convergence lockstep loop over B problems (vmap batching
    contract, DESIGN.md section 8.3).

    outer(*carry, *extra) must return (*carry', f, kkt, nnz), every array
    B-leading. A problem whose KKT drops below tol is frozen: its carry
    is re-selected (not updated) on later iterations, so its result is
    bit-identical to stopping while stragglers keep iterating.

    Returns (carry, f, kkt, nnz, n_outer, done).
    """
    carry = tuple(carry)
    batch = carry[0].shape[0]
    done = jnp.zeros((batch,), bool)
    n_outer = jnp.zeros((batch,), jnp.int32)
    f = jnp.full((batch,), jnp.inf, dtype)
    kkt = jnp.full((batch,), jnp.inf, dtype)
    nnz = jnp.zeros((batch,), jnp.int32)
    for _ in range(max_outer):
        out = outer(*carry, *extra)
        new_carry, (f_n, kkt_n, nnz_n) = out[:-3], out[-3:]
        carry = tuple(
            jnp.where(done.reshape((batch,) + (1,) * (old.ndim - 1)),
                      old, new)
            for old, new in zip(carry, new_carry))
        f = jnp.where(done, f, f_n)
        kkt = jnp.where(done, kkt, kkt_n)
        nnz = jnp.where(done, nnz, nnz_n)
        n_outer = jnp.where(done, n_outer, n_outer + 1)
        done = done | (kkt <= tol_kkt)
        if bool(jnp.all(done)):
            break
    return carry, f, kkt, nnz, n_outer, done
