"""Sharded execution backend: 2-D (data x model) shard_map PCDN
(DESIGN.md sections 3.4 / 4 / 9.3).

Layout:

    X : (s, n)  sharded  P(("pod","data"), "model")   - samples x features
    y : (s,)    sharded  P(("pod","data"))
    z : (s,)    sharded  P(("pod","data"))            - margins, replicated
                                                        over "model"
    w : (n,)    sharded  P("model")                   - replicated over data
    active:(n,) sharded  P("model")                   - un-shrunk mask

Each bundle draws P_local = P / n_model features *per model shard*
(stratified random partition — still a disjoint cover of N per outer
iteration, i.e. a valid Gauss-Seidel rule; see DESIGN.md section 3.4).

Collective schedule per bundle iteration (3 phases, all fused to the
minimum payload):

    1. psum over data-like axes of [g_part ; h_part]   (2*P_local floats)
    2. psum over "model" of the partial margins X_B d_B (s_local floats)
    3. ONE psum over ALL axes of the (Q,) per-candidate Armijo vector
       (loss part pre-divided by n_model, l1 part by n_data, so a single
       all-axes psum yields loss-sum-over-samples + l1-sum-over-features)

Phase 2 is the paper's footnote-3 reduction-sum for d.x_i, mapped onto the
ICI; phases 1+3 carry O(P + Q) floats — the paper's low-communication
property preserved at pod scale.

Both design-matrix layouts ride the same schedule: layout="dense" shards
the raw (s, n) array as above, layout="padded_csc" shards the padded
feature-major sparse arrays from `shard_problem_sparse` — each shard holds
its own columns' nonzeros with row ids local to its sample range, so the
shard-local bundle math drops from O(s_l * P_local) to O(P_local * k_max)
while every collective payload stays identical (DESIGN.md section 7.4).

This module used to be a standalone solver (`core/sharded.py`) with its
own outer loop, stop criterion and history code. It is now an *execution
backend* implementing the engine contract of `repro.engine.loop`:

    outer(w, z, key, active, recheck, c)
      -> (w, z, key, f, kkt, nnz, mean_q, active, n_active)

with `c` TRACED (one compiled program serves a whole c-sweep), active-set
shrinking (per-shard `bundles.partition_active`; the fori_loop trip count
is the pmax over model shards of the local active bundle counts, so every
shard runs the same number of collectives while shrunk features cost zero
compute), and optional routing of the shard-local bundle reductions
through the fused Pallas direction kernels (`use_kernels` — the kernels
compute the g/h PARTIALS per shard; the Newton direction is formed after
the phase-1 psum, so the collective schedule is unchanged).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.utils.compat import shard_map as _shard_map

from repro.core import bundles as B
from repro.core.design_matrix import padded_row_support
from repro.core.direction import delta_decrement, newton_direction
from repro.core.linesearch import (ArmijoParams, candidate_alphas,
                                   select_first_satisfying)
from repro.core.losses import HESSIAN_FLOOR, get_loss
from repro.engine.loop import EngineState

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ShardedPCDNConfig:
    P_local: int                   # bundle features per model shard
    c: float
    loss_name: str = "logistic"
    armijo: ArmijoParams = ArmijoParams()
    elastic_net_l2: float = 0.0
    data_axes: Sequence[str] = ("data",)   # ("pod","data") multi-pod
    model_axis: str = "model"
    seed: int = 0
    # --- perf variants (EXPERIMENTS.md section Perf) ---
    # "batched": one fused psum carries all Q Armijo candidates (TPU-native)
    # "backtracking": paper-faithful sequential loop — one scalar psum per
    #                 backtracking step (the OpenMP structure, kept as the
    #                 reproduction baseline)
    ls_kind: str = "batched"
    # fuse [g;h] into one collective and [Xd;Delta] into another; the
    # unfused variant issues 4 separate psums per bundle (baseline)
    fuse_collectives: bool = True
    # route the shard-local bundle reductions through the fused Pallas
    # direction kernels (partials only; see module docstring)
    use_kernels: bool = False
    # -- line-search / margin scope (DESIGN.md section 11.4) -----------------
    # "support" restricts the phase-3 loss evaluation, the u/v factors
    # and the z_l update to the bundle's shard-local row support. Valid
    # when the model axis has size 1 (data-sharded meshes): the local
    # slab support then IS the true support of the post-psum margin
    # delta. With model parallelism the bundle's rows span shards whose
    # supports are unknown locally, so full scope is kept (the
    # allgather-merge of per-shard supports is the documented follow-up).
    # The (Q,) phase-3 psum payload is IDENTICAL in both scopes.
    ls_scope: str = "auto"
    # -- active-set shrinking (same semantics as PCDNConfig; DESIGN.md 8.2)
    shrink: bool = False
    shrink_tol: float = 0.01
    recheck_every: int = 1
    tol_kkt: float = 1e-3          # un-shrink threshold (keep == stop tol)
    # -- observability (DESIGN.md section 13.2; same contract as
    # PCDNConfig.record_aux): surface per-bundle (q, alpha) as a 10th
    # outer output. Both are derived from all-axes psums (the phase-3
    # Armijo vector), so they are replicated and leave the shard_map
    # with P() out_specs — no extra collectives.
    record_aux: bool = False
    # -- diagnostics (DESIGN.md section 15.1; same contract as
    # PCDNConfig.record_kkt_vec): surface the per-feature KKT violation
    # vector as an extra outer output for attribution. `viol` is (n_local,)
    # per model shard and already replicated over data axes (it derives
    # from the data-psummed gradient), so it exits the shard_map with a
    # P(model_axis) spec and concatenates to the global (n_pad,) vector —
    # padded columns carry exactly zero violation (w == 0, g == 0).
    record_kkt_vec: bool = False

    @property
    def all_axes(self):
        return tuple(self.data_axes) + (self.model_axis,)


def _axis_size(axis) -> Array:
    return jax.lax.psum(1, axis)


def _dspec(cfg: ShardedPCDNConfig):
    return (tuple(cfg.data_axes) if len(cfg.data_axes) > 1
            else cfg.data_axes[0])


def make_sharded_outer(cfg: ShardedPCDNConfig, mesh: Mesh,
                       n_local: int, layout: str = "dense"):
    """Build the jitted sharded engine iteration.

    layout="dense": fn(X, y, w, z, key, active, recheck, c);
    layout="padded_csc": fn(col_rows, col_vals, y, w, z, key, active,
    recheck, c) where col_rows/col_vals are the (n, D*k_max) packed
    per-(column, data-shard) local-row arrays from `shard_problem_sparse`
    (DESIGN.md section 7.4). Both return the engine 9-tuple
    (w, z, key, f, kkt, nnz, mean_q, active, n_active) with identical
    collective schedules — only the shard-local bundle math differs.
    n_local = features per model shard (static). `c` and `recheck` are
    traced scalars. With cfg.record_aux a 10th output (q (b,), alpha
    (b,)) carries the per-bundle line-search telemetry (DESIGN.md
    section 13.2); under shrinking, slots past the pmax trip count hold
    sentinels q == -1 / alpha == nan. With cfg.record_kkt_vec the
    per-feature violation vector (n_pad,) follows the aux tuple
    (DESIGN.md section 15.1); extras are dispatched by structure.
    """
    loss = get_loss(cfg.loss_name)
    gamma = cfg.armijo.gamma
    sigma = cfg.armijo.sigma
    P_local = cfg.P_local
    data_axes = tuple(cfg.data_axes)
    model_axis = cfg.model_axis
    if layout not in ("dense", "padded_csc"):
        raise ValueError(f"unknown layout {layout!r}")
    if cfg.use_kernels:
        from repro.kernels import ops as kops

    # static support-scope eligibility (DESIGN.md section 11.4)
    n_model_static = int(mesh.shape[model_axis])
    support_ok = (layout == "padded_csc" and cfg.ls_kind == "batched"
                  and n_model_static == 1)
    if cfg.ls_scope == "support" and not support_ok:
        raise ValueError(
            "ls_scope='support' on the sharded backend requires "
            "layout='padded_csc', ls_kind='batched' and a model axis of "
            f"size 1 (got layout={layout!r}, ls_kind={cfg.ls_kind!r}, "
            f"model={n_model_static}); with model parallelism a bundle's "
            "row support spans shards and is unknown locally — use "
            "ls_scope='auto' to fall back to full scope.")
    elif cfg.ls_scope not in ("support", "auto", "full"):
        raise ValueError(f"unknown ls_scope {cfg.ls_scope!r}")

    def outer_local(*args):
        """Runs inside shard_map: every array is this shard's block."""
        if layout == "dense":
            X_l, y_l, w_l, z_l, active_l, key, recheck, c = args
        else:
            rows_l, vals_l, y_l, w_l, z_l, active_l, key, recheck, c = args
        s_l = z_l.shape[0]
        n_model = _axis_size(model_axis)
        n_data = _axis_size(data_axes)
        m_idx = jax.lax.axis_index(model_axis)
        # identical permutation across data shards of one model column:
        key, sub = jax.random.split(key)
        sub = jax.random.fold_in(sub, m_idx)
        alphas = candidate_alphas(cfg.armijo, z_l.dtype)   # (Q,)

        def gather_local(idx):
            """-> layout-specific slab for this shard's rows of bundle B."""
            if layout == "dense":
                XB, _ = B.gather_slab(X_l, idx)            # (s_l, P_local)
                return XB
            valid = idx < n_local
            safe = jnp.minimum(idx, n_local - 1)
            rB = jnp.where(valid[:, None], jnp.take(rows_l, safe, axis=0),
                           s_l)                            # (P_local, k)
            vB = jnp.take(vals_l, safe, axis=0) * \
                valid[:, None].astype(vals_l.dtype)
            return rB, vB

        def grad_hess_parts(slab, u, v, w_B):
            """Shard-local partial [g ; h] of one bundle (pre-psum)."""
            if cfg.use_kernels:
                # fused Pallas reduction; l2=0 keeps the g partial raw
                # (the elastic-net diagonal is applied after the phase-1
                # psum). The kernel floors each h PARTIAL at its internal
                # 1e-12, so the psum carries up to n_data extra floors —
                # bounded by D*1e-12, below f32 resolution of any
                # meaningful h. The kernel's locally-formed d is
                # discarded — the direction needs the GLOBAL g, h.
                if layout == "dense":
                    _, g, h = kops.pcdn_direction(slab, u, v, w_B, l2=0.0)
                else:
                    rB, vB = slab
                    _, g, h = kops.pcdn_sparse_direction(rB, vB, u, v, w_B,
                                                         l2=0.0)
                return g, h
            if layout == "dense":
                return slab.T @ u, jnp.square(slab).T @ v
            rB, vB = slab
            ug = jnp.take(u, rB, mode="fill", fill_value=0)
            vg = jnp.take(v, rB, mode="fill", fill_value=0)
            return (jnp.sum(ug * vB, axis=1),
                    jnp.sum(vg * jnp.square(vB), axis=1))

        def margin_delta_part(slab, d):
            if layout == "dense":
                return slab @ d
            rB, vB = slab
            return jnp.zeros((s_l,), vB.dtype).at[rB].add(
                vB * d[:, None], mode="drop")

        def full_grad_part(u):
            if layout == "dense":
                return X_l.T @ u
            ug = jnp.take(u, rows_l, mode="fill", fill_value=0)
            return jnp.sum(ug * vals_l, axis=1)

        # static per-shard scope decision ("auto" needs the local slab
        # bound P_local * k_max to beat the local sample count with the
        # same margin as the local backend — pcdn.AUTO_SUPPORT_MARGIN)
        if layout == "padded_csc":
            from repro.core.pcdn import AUTO_SUPPORT_MARGIN
            use_support = support_ok and (
                cfg.ls_scope == "support" or
                (cfg.ls_scope == "auto" and
                 AUTO_SUPPORT_MARGIN * P_local * rows_l.shape[1] <= s_l))
        else:
            use_support = False

        def bundle_step_support(carry, idx):
            """Support-restricted bundle step (DESIGN.md section 11.4):
            same phase-1 [g;h] psum and phase-3 (Q,) psum as the full-
            scope step; the per-sample passes between them touch only
            the bundle's shard-local row support."""
            w_l, z_l = carry
            rB, vB = gather_local(idx)
            w_B, _ = B.gather_vec(w_l, idx)
            support, pos = padded_row_support(rB, s_l)
            z_R = jnp.take(z_l, support, mode="fill", fill_value=0)
            y_R = jnp.take(y_l, support, mode="fill", fill_value=1)
            u_R = c * loss.dz(z_R, y_R)
            v_R = c * loss.d2z(z_R, y_R)
            if cfg.use_kernels:
                # pos is the support-local row id array: same kernel,
                # u/v handed over in support order (all gathers in
                # bounds; padding entries carry value 0)
                _, g_part, h_part = kops.pcdn_sparse_direction(
                    pos, vB, u_R, v_R, w_B, l2=0.0)
            else:
                g_part = jnp.sum(jnp.take(u_R, pos) * vB, axis=1)
                h_part = jnp.sum(jnp.take(v_R, pos) * jnp.square(vB),
                                 axis=1)
            # -- phase 1: grad/hess psum over sample shards (unchanged)
            if cfg.fuse_collectives:
                gh = jax.lax.psum(jnp.concatenate([g_part, h_part]),
                                  data_axes)
                g, h = gh[:P_local], gh[P_local:]
            else:
                g = jax.lax.psum(g_part, data_axes)
                h = jax.lax.psum(h_part, data_axes)
            if cfg.elastic_net_l2:
                g = g + cfg.elastic_net_l2 * w_B
                h = h + cfg.elastic_net_l2
            h = jnp.maximum(h, HESSIAN_FLOOR)
            d = newton_direction(g, h, w_B)
            # -- phase 2: model axis has size 1, so the margin-delta
            # psum is the identity and only the scalar Delta crosses it;
            # the (s_l,) dense delta is never built.
            Delta = jax.lax.psum(delta_decrement(g, h, w_B, d, gamma),
                                 model_axis)
            delta_R = jnp.zeros_like(z_R).at[pos].add(vB * d[:, None])
            # -- phase 3: the SAME (Q,) all-axes psum, loss part now
            # reduced over the support rows only
            zq = z_R[None, :] + alphas[:, None] * delta_R[None, :]
            loss_part = c * jnp.sum(
                loss.value(zq, y_R[None, :]) -
                loss.value(z_R, y_R)[None, :], axis=-1)
            l1_part = (jnp.sum(
                jnp.abs(w_B[None, :] + alphas[:, None] * d[None, :]),
                axis=-1) - jnp.sum(jnp.abs(w_B)))
            fused = loss_part / jnp.asarray(n_model, z_l.dtype) + \
                l1_part / jnp.asarray(n_data, z_l.dtype)
            f_deltas = jax.lax.psum(fused, cfg.all_axes)
            res = select_first_satisfying(f_deltas, alphas, Delta, sigma)
            w_l = B.scatter_add(w_l, idx, res.alpha * d)
            z_l = z_l.at[support].add(res.alpha * delta_R, mode="drop")
            return (w_l, z_l), (res.n_steps, res.alpha)

        def bundle_step(carry, idx):
            w_l, z_l = carry
            slab = gather_local(idx)
            w_B, _ = B.gather_vec(w_l, idx)
            u = c * loss.dz(z_l, y_l)
            v = c * loss.d2z(z_l, y_l)
            g_part, h_part = grad_hess_parts(slab, u, v, w_B)
            # -- phase 1: grad/hess psum over sample shards
            if cfg.fuse_collectives:
                gh = jax.lax.psum(jnp.concatenate([g_part, h_part]),
                                  data_axes)
                g, h = gh[:P_local], gh[P_local:]
            else:  # baseline: two separate collectives
                g = jax.lax.psum(g_part, data_axes)
                h = jax.lax.psum(h_part, data_axes)
            if cfg.elastic_net_l2:
                g = g + cfg.elastic_net_l2 * w_B
                h = h + cfg.elastic_net_l2
            h = jnp.maximum(h, HESSIAN_FLOOR)
            d = newton_direction(g, h, w_B)
            # Delta (Eq. 7) sums over the *global* bundle -> psum over model
            Delta_part = delta_decrement(g, h, w_B, d, gamma)
            dz_part = margin_delta_part(slab, d)           # (s_l,)
            # -- phase 2: margins of the bundle step (+ Delta when fused)
            if cfg.fuse_collectives:
                packed = jax.lax.psum(
                    jnp.concatenate([dz_part, Delta_part[None]]), model_axis)
                delta_z, Delta = packed[:-1], packed[-1]
            else:
                delta_z = jax.lax.psum(dz_part, model_axis)
                Delta = jax.lax.psum(Delta_part, model_axis)

            if cfg.ls_kind == "batched":
                # -- phase 3: ONE all-axes psum of the Q-candidate vector
                zq = z_l[None, :] + alphas[:, None] * delta_z[None, :]
                loss_part = c * jnp.sum(
                    loss.value(zq, y_l[None, :]) -
                    loss.value(z_l, y_l)[None, :], axis=-1)
                l1_part = (jnp.sum(
                    jnp.abs(w_B[None, :] + alphas[:, None] * d[None, :]),
                    axis=-1) - jnp.sum(jnp.abs(w_B)))
                fused = loss_part / jnp.asarray(n_model, z_l.dtype) + \
                    l1_part / jnp.asarray(n_data, z_l.dtype)
                f_deltas = jax.lax.psum(fused, cfg.all_axes)
                res = select_first_satisfying(f_deltas, alphas, Delta, sigma)
                alpha, n_steps = res.alpha, res.n_steps
            else:
                # paper-faithful Algorithm 4: sequential backtracking, one
                # scalar psum PER candidate — the latency baseline.
                f_base = c * jnp.sum(loss.value(z_l, y_l))

                def cond(st):
                    q, alpha_, done = st
                    return jnp.logical_and(~done, q < cfg.armijo.max_steps)

                def body(st):
                    q, alpha_, _ = st
                    lo = c * jnp.sum(loss.value(z_l + alpha_ * delta_z,
                                                y_l)) - f_base
                    l1 = jnp.sum(jnp.abs(w_B + alpha_ * d)) - \
                        jnp.sum(jnp.abs(w_B))
                    fd = jax.lax.psum(
                        lo / jnp.asarray(n_model, z_l.dtype) +
                        l1 / jnp.asarray(n_data, z_l.dtype), cfg.all_axes)
                    ok = fd <= sigma * alpha_ * Delta
                    return (q + 1,
                            jnp.where(ok, alpha_, alpha_ * cfg.armijo.beta),
                            ok)

                q, alpha, ok = jax.lax.while_loop(
                    cond, body, (jnp.int32(0),
                                 jnp.asarray(1.0, z_l.dtype),
                                 jnp.asarray(False)))
                alpha = jnp.where(ok, alpha, 0.0)
                n_steps = q
            w_l = B.scatter_add(w_l, idx, alpha * d)
            z_l = z_l + alpha * delta_z
            return (w_l, z_l), (n_steps, alpha)

        step_fn = bundle_step_support if use_support else bundle_step

        if cfg.shrink:
            # Per-shard active partition; the trip count is the pmax over
            # model shards, so every shard executes the same collective
            # schedule — shards with fewer active bundles run sentinel-
            # only bundles (zero contribution, zero update).
            idxs, b_active = B.partition_active(sub, active_l, P_local)
            trip = jax.lax.pmax(b_active, model_axis)
            if cfg.record_aux:
                b_max = idxs.shape[0]
                aux0 = (jnp.full((b_max,), -1, jnp.int32),
                        jnp.full((b_max,), jnp.nan, z_l.dtype))
            else:
                aux0 = ()

            def body(t, carry):
                wz, q_sum, aux = carry
                wz, (n_steps, alpha) = step_fn(wz, idxs[t])
                if cfg.record_aux:
                    aux = (aux[0].at[t].set(n_steps.astype(jnp.int32)),
                           aux[1].at[t].set(alpha.astype(z_l.dtype)))
                return wz, q_sum + n_steps.astype(jnp.float32), aux

            (w_l, z_l), q_sum, aux = jax.lax.fori_loop(
                0, trip, body, ((w_l, z_l), jnp.float32(0.0), aux0))
            if cfg.record_aux:
                aux_q, aux_alpha = aux
            mean_q = q_sum / jnp.maximum(trip, 1).astype(jnp.float32)
        else:
            idxs = B.partition(sub, n_local, P_local)      # (b, P_local)
            (w_l, z_l), (steps, step_alphas) = jax.lax.scan(
                step_fn, (w_l, z_l), idxs)
            mean_q = jnp.mean(steps.astype(jnp.float32))
            if cfg.record_aux:
                aux_q = steps.astype(jnp.int32)
                aux_alpha = step_alphas.astype(z_l.dtype)

        # diagnostics: objective + FULL-set KKT violation (replicated)
        f_loss = jax.lax.psum(c * jnp.sum(loss.value(z_l, y_l)), data_axes)
        f_l1 = jax.lax.psum(jnp.sum(jnp.abs(w_l)), model_axis)
        f = f_loss + f_l1
        if cfg.elastic_net_l2:
            f = f + 0.5 * cfg.elastic_net_l2 * jax.lax.psum(
                jnp.sum(jnp.square(w_l)), model_axis)
        # full local gradient for KKT: (n_local,) psum over data
        u = c * loss.dz(z_l, y_l)
        g_full = jax.lax.psum(full_grad_part(u), data_axes)
        if cfg.elastic_net_l2:
            g_full = g_full + cfg.elastic_net_l2 * w_l
        viol = jnp.abs(jnp.where(
            w_l > 0, g_full + 1.0,
            jnp.where(w_l < 0, g_full - 1.0,
                      jnp.maximum(jnp.abs(g_full) - 1.0, 0.0))))
        kkt = jax.lax.pmax(jnp.max(viol), cfg.all_axes)
        if cfg.shrink:
            interior = (w_l == 0) & (jnp.abs(g_full) < 1.0 - cfg.shrink_tol)
            active_l = active_l & ~interior
            active_l = active_l | (recheck & (viol > cfg.tol_kkt))
        nnz = jax.lax.psum(jnp.sum((w_l != 0).astype(jnp.int32)),
                           model_axis)
        n_active = jax.lax.psum(jnp.sum(active_l.astype(jnp.int32)),
                                model_axis)
        base = (w_l, z_l, f, kkt, nnz, mean_q, active_l, n_active)
        if cfg.record_aux:
            # q/alpha come out of the all-axes phase-3 psum: replicated
            # on every shard, so they exit the shard_map with P() specs.
            base = base + ((aux_q, aux_alpha),)
        if cfg.record_kkt_vec:
            base = base + (viol,)
        return base

    dspec = _dspec(cfg)

    if layout == "dense":
        design_specs = (P(dspec, model_axis),)   # X
    else:
        design_specs = (P(model_axis, dspec),    # col_rows (n, D*k_max)
                        P(model_axis, dspec))    # col_vals
    in_specs = design_specs + (
        P(dspec),               # y
        P(model_axis),          # w
        P(dspec),               # z
        P(model_axis),          # active
        P(),                    # key (replicated)
        P(),                    # recheck
        P(),                    # c
    )

    out_specs = (P(model_axis), P(dspec), P(), P(), P(), P(),
                 P(model_axis), P())
    if cfg.record_aux:
        out_specs = out_specs + ((P(), P()),)
    if cfg.record_kkt_vec:
        # viol is (n_local,) per model shard, replicated over data axes
        out_specs = out_specs + (P(model_axis),)

    mapped = _shard_map(
        outer_local, mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
    )

    def outer(*args):
        *design_y, w, z, key, active, recheck, c = args
        key, sub = jax.random.split(key)
        out = mapped(*design_y, w, z, active, sub, recheck, c)
        w, z, f, kkt, nnz, mean_q, active, n_active = out[:8]
        base = (w, z, key, f, kkt, nnz, mean_q, active, n_active)
        # pass extras (aux tuple and/or kkt vector) through in protocol
        # order; the engine host loop dispatches them by structure.
        return base + tuple(out[8:])

    return jax.jit(outer)


def make_sharded_margins(cfg: ShardedPCDNConfig, mesh: Mesh, s_local: int,
                         layout: str = "dense"):
    """Jitted z = X w on the mesh (warm-start refresh between path points).

    dense: fn(X, w) -> z; padded_csc: fn(col_rows, col_vals, w) -> z.
    """
    model_axis = cfg.model_axis
    dspec = _dspec(cfg)

    def margins_local(*args):
        if layout == "dense":
            X_l, w_l = args
            z_part = X_l @ w_l
        else:
            rows_l, vals_l, w_l = args
            z_part = jnp.zeros((s_local,), vals_l.dtype).at[rows_l].add(
                vals_l * w_l[:, None], mode="drop")
        return jax.lax.psum(z_part, model_axis)

    if layout == "dense":
        in_specs = (P(dspec, model_axis), P(model_axis))
    else:
        in_specs = (P(model_axis, dspec), P(model_axis, dspec),
                    P(model_axis))
    mapped = _shard_map(margins_local, mesh=mesh, in_specs=in_specs,
                        out_specs=P(dspec))
    return jax.jit(mapped)


def shard_problem(X: np.ndarray, y: np.ndarray, mesh: Mesh,
                  cfg: ShardedPCDNConfig):
    """Place (X, y) and fresh (w, z) onto the mesh with the PCDN layout.
    Pads s and n so shards are equal-sized. Returns device arrays."""
    dspec = _dspec(cfg)
    d_sz = int(np.prod([mesh.shape[a] for a in cfg.data_axes]))
    m_sz = mesh.shape[cfg.model_axis]
    s, n = X.shape
    s_pad = (-s) % d_sz
    n_pad = (-n) % m_sz
    if s_pad or n_pad:
        X = np.pad(X, ((0, s_pad), (0, n_pad)))
        y = np.pad(y, (0, s_pad), constant_values=1.0)  # zero rows: no grad
    Xs = jax.device_put(X, NamedSharding(mesh, P(dspec, cfg.model_axis)))
    ys = jax.device_put(y, NamedSharding(mesh, P(dspec)))
    w = jax.device_put(np.zeros(X.shape[1], X.dtype),
                       NamedSharding(mesh, P(cfg.model_axis)))
    z = jax.device_put(np.zeros(X.shape[0], X.dtype),
                       NamedSharding(mesh, P(dspec)))
    return Xs, ys, w, z


def _is_csr_like(X) -> bool:
    return all(hasattr(X, a) for a in ("data", "indices", "indptr", "shape"))


def _host_c_max(X, y, loss_name: str) -> float:
    """Analytic path start 1 / ||X^T phi'(0, y)||_inf from the host-side
    data (one rmatvec; matches L1Problem.c_max — DESIGN.md section 8.1)."""
    loss = get_loss(loss_name)
    s, n = int(X.shape[0]), int(X.shape[1])
    y32 = jnp.asarray(np.asarray(y), jnp.float32)
    u0 = np.asarray(loss.dz(jnp.zeros((s,), jnp.float32), y32), np.float32)
    if _is_csr_like(X):
        rows = np.repeat(np.arange(s, dtype=np.int64),
                         np.diff(np.asarray(X.indptr)))
        g0 = np.zeros((n,), np.float32)
        np.add.at(g0, np.asarray(X.indices),
                  np.asarray(X.data, np.float32) * u0[rows])
    else:
        g0 = np.asarray(X, np.float32).T @ u0
    denom = float(np.max(np.abs(g0)))
    if denom <= 0.0:
        raise ValueError("degenerate problem: X^T phi'(0, y) == 0 "
                         "(no feature correlates with the labels)")
    return 1.0 / denom


def shard_problem_sparse(X, y: np.ndarray, mesh: Mesh,
                         cfg: ShardedPCDNConfig, k_max: int = None):
    """Sparse placer: per-(model column, data shard) padded local rows.

    X: dense np array or CSR-like (.data/.indices/.indptr/.shape) — the
    latter never densifies. Builds

        col_rows : (n_pad, D * k_max) int32   local row id or sentinel s_l
        col_vals : (n_pad, D * k_max) float32

    packed so shard (di, mi) sees the (n_local, k_max) block of its own
    columns with row ids local to its sample range — axis 0 is sharded
    over "model", axis 1 over the data axes (DESIGN.md section 7.4).
    k_max = max nnz of any (column, data-shard) cell unless given.
    Returns (col_rows, col_vals, ys, w, z) device arrays.
    """
    dspec = _dspec(cfg)
    d_sz = int(np.prod([mesh.shape[a] for a in cfg.data_axes]))
    m_sz = mesh.shape[cfg.model_axis]

    if _is_csr_like(X):
        s, n = X.shape
        vals = np.asarray(X.data, dtype=np.float32)
        cols = np.asarray(X.indices, dtype=np.int64)
        rows = np.repeat(np.arange(s, dtype=np.int64),
                         np.diff(np.asarray(X.indptr)))
    else:
        X = np.asarray(X)
        s, n = X.shape
        rows, cols = np.nonzero(X)
        vals = X[rows, cols].astype(np.float32)

    s_pad = s + (-s) % d_sz
    n_pad = n + (-n) % m_sz
    s_l = s_pad // d_sz
    y_full = np.ones((s_pad,), np.float32)  # zero rows: no gradient
    y_full[:s] = y

    # group nnz by (column, data shard) and rank within each group
    di = rows // s_l
    local_r = (rows % s_l).astype(np.int32)
    group = cols * d_sz + di
    order = np.argsort(group, kind="stable")
    group, local_r, cols_s, vals_s = (group[order], local_r[order],
                                      cols[order], vals[order])
    counts = np.bincount(group, minlength=n_pad * d_sz).astype(np.int64)
    k = int(max(1, counts.max() if counts.size else 1))
    if k_max is not None:
        if k > int(k_max):
            raise ValueError(f"k_max={k_max} < max (column, shard) nnz {k}")
        k = int(k_max)
    start = np.concatenate([[0], np.cumsum(counts)])
    pos = np.arange(group.shape[0], dtype=np.int64) - start[group]
    col_rows = np.full((n_pad, d_sz * k), s_l, np.int32)
    col_vals = np.zeros((n_pad, d_sz * k), np.float32)
    slot = (group % d_sz) * k + pos
    col_rows[cols_s, slot] = local_r
    col_vals[cols_s, slot] = vals_s

    rows_d = jax.device_put(
        col_rows, NamedSharding(mesh, P(cfg.model_axis, dspec)))
    vals_d = jax.device_put(
        col_vals, NamedSharding(mesh, P(cfg.model_axis, dspec)))
    ys = jax.device_put(y_full, NamedSharding(mesh, P(dspec)))
    w = jax.device_put(np.zeros(n_pad, np.float32),
                       NamedSharding(mesh, P(cfg.model_axis)))
    z = jax.device_put(np.zeros(s_pad, np.float32),
                       NamedSharding(mesh, P(dspec)))
    return rows_d, vals_d, ys, w, z


class ShardedBackend:
    """Engine execution backend over a multi-device mesh.

    Places (X, y) once at construction (the expensive host->device step),
    compiles one dynamic-c outer iteration and one margins program, and
    then serves any number of solves / path points against them — the
    composition that makes warm-started c-sweeps with shrinking run on a
    mesh (DESIGN.md section 9.3).

    Note: feature-count padding (n -> n_pad, multiple of the model-axis
    size) is internal; `n_features`/`host_weights` speak the REAL n.
    """

    def __init__(self, X, y: np.ndarray, mesh: Mesh,
                 cfg: ShardedPCDNConfig, layout: str = "auto",
                 k_max: Optional[int] = None):
        is_csr = _is_csr_like(X)
        if layout == "auto":
            layout = "padded_csc" if is_csr else "dense"
        if layout == "dense" and is_csr:
            raise ValueError("CSR input with layout='dense' would densify")
        self.mesh = mesh
        self.cfg = cfg
        self.layout = layout
        self._n = int(X.shape[1])
        self._s = int(X.shape[0])
        # eager: one host rmatvec now, so no reference to the (possibly
        # multi-GiB) host arrays survives construction
        self._c_max = _host_c_max(X, y, cfg.loss_name)
        d_sz = int(np.prod([mesh.shape[a] for a in cfg.data_axes]))

        if layout == "dense":
            Xs, ys, w0, z0 = shard_problem(np.asarray(X), np.asarray(y),
                                           mesh, cfg)
            self._design = (Xs,)
            n_pad, s_pad = Xs.shape[1], Xs.shape[0]
        else:
            rows_d, vals_d, ys, w0, z0 = shard_problem_sparse(
                X, np.asarray(y), mesh, cfg, k_max=k_max)
            self._design = (rows_d, vals_d)
            n_pad, s_pad = rows_d.shape[0], z0.shape[0]
        self._y = ys
        self._w0, self._z0 = w0, z0
        self.n_pad, self.s_pad = n_pad, s_pad
        self.n_local = n_pad // mesh.shape[cfg.model_axis]
        self._active0 = jax.device_put(
            np.ones((n_pad,), bool),
            NamedSharding(mesh, P(cfg.model_axis)))

        outer_fn = make_sharded_outer(cfg, mesh, self.n_local, layout)
        design, ys_ = self._design, self._y

        def outer(w, z, key, active, recheck, c):
            return outer_fn(*design, ys_, w, z, key, active, recheck, c)

        self.outer = outer
        self._margins_fn = make_sharded_margins(cfg, mesh, s_pad // d_sz,
                                                layout)

    @property
    def n_features(self) -> int:
        return self._n

    @property
    def n_samples(self) -> int:
        return self._s

    @property
    def dtype(self):
        return jnp.float32

    def init_state(self, w0: Optional[np.ndarray] = None) -> EngineState:
        if w0 is None:
            w, z = self._w0, self._z0
        else:
            wf = np.zeros((self.n_pad,), np.float32)
            wf[:self._n] = np.asarray(w0, np.float32)
            w = jax.device_put(
                wf, NamedSharding(self.mesh, P(self.cfg.model_axis)))
            z = self.margins(w)
        return EngineState(w=w, z=z,
                           key=jax.random.PRNGKey(self.cfg.seed),
                           active=self._active0)

    def margins(self, w: Array) -> Array:
        return self._margins_fn(*self._design, w)

    def c_max(self) -> float:
        return self._c_max

    def host_weights(self, w: Array) -> np.ndarray:
        return np.asarray(w)[:self._n]

    def host_margins(self, z: Array) -> np.ndarray:
        """(n_samples,) host margins with the sample padding stripped —
        the mesh-agnostic checkpoint image of z."""
        return np.asarray(z)[:self._s]

    def restore_state(self, w, z=None, active=None, key=None) -> EngineState:
        """EngineState from UNPADDED host arrays (a `fault.checkpoint`
        snapshot — possibly written under a different device count or by
        the local backend). Re-pads to this mesh's n_pad/s_pad and
        device_puts with the PCDN layout; padded sample rows carry z = 0
        exactly as the margins program produces for zero-padded X rows,
        so a restored carry is bit-identical to a recomputed one."""
        n, s = self._n, self._s
        wf = np.zeros((self.n_pad,), np.float32)
        wf[:n] = np.asarray(w, np.float32).reshape(n)
        w_d = jax.device_put(
            wf, NamedSharding(self.mesh, P(self.cfg.model_axis)))
        if active is None:
            act_d = self._active0
        else:
            af = np.zeros((self.n_pad,), bool)
            af[:n] = np.asarray(active).reshape(n).astype(bool)
            act_d = jax.device_put(
                af, NamedSharding(self.mesh, P(self.cfg.model_axis)))
        if z is None:
            z_d = self.margins(w_d)
        else:
            zf = np.zeros((self.s_pad,), np.float32)
            zf[:s] = np.asarray(z, np.float32).reshape(s)
            z_d = jax.device_put(
                zf, NamedSharding(self.mesh, P(_dspec(self.cfg))))
        key_d = (jax.random.PRNGKey(self.cfg.seed) if key is None
                 else jnp.asarray(np.asarray(key), jnp.uint32))
        return EngineState(w=w_d, z=z_d, key=key_d, active=act_d)
