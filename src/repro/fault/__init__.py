"""Fault tolerance subsystem (DESIGN.md section 16).

Crash-safe checkpoint/resume for solves and path sweeps, non-finite
rollback with automatic P-backoff toward the certified safe bundle size,
a deterministic fault-injection harness, and the generic step-loop
runner (promoted from the legacy `repro.train` demo, which now shims
here).
"""
from repro.fault.atomic import (atomic_write_bytes, atomic_write_json,
                                atomic_write_text, fsync_dir)
from repro.fault.checkpoint import (CheckpointManager, SolveCheckpointer,
                                    host_state)
from repro.fault.inject import (CRASH_KINDS, ENV_VAR, NAN_TARGETS,
                                FaultPlan, InjectedCrash,
                                corrupt_checkpoint, plan_from_env,
                                wrap_outer)
from repro.fault.resilient import next_bundle_size, resilient_solve
from repro.fault.runner import (ElasticMeshProvider, FaultTolerantRunner,
                                RunnerConfig, StepFailure)

__all__ = [
    "atomic_write_bytes", "atomic_write_json", "atomic_write_text",
    "fsync_dir",
    "CheckpointManager", "SolveCheckpointer", "host_state",
    "CRASH_KINDS", "ENV_VAR", "NAN_TARGETS", "FaultPlan", "InjectedCrash",
    "corrupt_checkpoint", "plan_from_env", "wrap_outer",
    "next_bundle_size", "resilient_solve",
    "ElasticMeshProvider", "FaultTolerantRunner", "RunnerConfig",
    "StepFailure",
]
