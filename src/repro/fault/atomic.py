"""Crash-safe file writes: tmp + fsync + rename (DESIGN.md section 16.1).

One helper family shared by every durable artifact in the repo — the
solver/sweep checkpoints (`fault.checkpoint`), the serve model artifacts
(`serve.artifact.save_model`) and anything else that must never be read
torn. The contract is the classic POSIX one:

    1. write the full payload to a temp file IN THE SAME DIRECTORY,
    2. flush + fsync the temp file (data hits the disk, not the page
       cache),
    3. os.replace() it over the destination (atomic on POSIX: readers
       see the old complete file or the new complete file, never bytes
       of both),
    4. best-effort fsync the parent directory so the rename itself
       survives a power cut.

A crash at any step leaves the destination untouched; stale ``.tmp-*``
siblings are the only debris and are safe to delete.
"""
from __future__ import annotations

import json
import os
import tempfile


def fsync_dir(path: str) -> None:
    """Best-effort fsync of a directory (persists a completed rename).
    Some filesystems/platforms refuse O_RDONLY dir fsync — that only
    weakens durability, not atomicity, so failures are swallowed."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def atomic_write_bytes(path: str, data: bytes) -> None:
    """Write `data` to `path` atomically (tmp + fsync + rename)."""
    path = os.fspath(path)
    parent = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=parent, prefix=".tmp-",
                               suffix=os.path.basename(path))
    try:
        with os.fdopen(fd, "wb") as fh:
            fh.write(data)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    fsync_dir(parent)


def atomic_write_text(path: str, text: str) -> None:
    atomic_write_bytes(path, text.encode("utf-8"))


def atomic_write_json(path: str, obj, **dump_kwargs) -> None:
    """Atomic `json.dump`. Serialization happens BEFORE the temp file is
    created, so an unserializable object leaves no debris at all."""
    atomic_write_text(path, json.dumps(obj, **dump_kwargs))
