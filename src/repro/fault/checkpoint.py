"""Crash-safe, mesh-shape-agnostic checkpointing (DESIGN.md section 16.2).

Layout:  <dir>/step_<N>/
            manifest.json     — tree structure, shapes, dtypes, step
            arrays.npz        — one entry per flattened leaf
            COMMITTED         — written last; a checkpoint without it is
                                incomplete and ignored on restore
Leaves are gathered to host (full arrays) so restore can re-shard onto
any mesh (elastic scaling). Every file is fsynced, the step dir lands
via atomic rename, and old steps are garbage-collected keeping `keep`
newest.

Two layers live here:

* `CheckpointManager` — the generic pytree store (promoted from the
  seed-era `repro.train.checkpoint`, which now re-exports it).
* `SolveCheckpointer` — the solver/sweep-specific layer the engine and
  `path.driver.run_path` consume: it snapshots the `EngineState` carry
  as UNPADDED host arrays (via the backend's `host_weights` /
  `host_margins`), so a checkpoint written by a sharded solve on one
  mesh restores onto a different device count — or onto the local
  backend — via `backend.restore_state`.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Callable, Optional, Tuple

import jax
import numpy as np

from repro import obs
from repro.fault.atomic import fsync_dir

_SEP = "§"


def _flatten_with_names(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in path)
        out.append((name or "leaf", leaf))
    return out


def _fsync_file(path: str) -> None:
    with open(path, "rb") as fh:
        os.fsync(fh.fileno())


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        treedef = jax.tree_util.tree_structure(tree)
        named = _flatten_with_names(tree)
        arrays = {}
        for i, (name, leaf) in enumerate(named):
            arrays[f"{i:05d}{_SEP}{name}"] = np.asarray(
                jax.device_get(leaf))
        tmp = tempfile.mkdtemp(dir=self.directory, prefix=".tmp_ckpt_")
        try:
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            manifest = {
                "step": int(step),
                "treedef": str(treedef),
                "n_leaves": len(named),
                "extra": extra or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as fh:
                json.dump(manifest, fh)
            # COMMITTED is written (and synced) LAST: its presence means
            # every other file in the dir already hit the disk
            _fsync_file(os.path.join(tmp, "arrays.npz"))
            _fsync_file(os.path.join(tmp, "manifest.json"))
            with open(os.path.join(tmp, "COMMITTED"), "w") as fh:
                fh.write("ok")
                fh.flush()
                os.fsync(fh.fileno())
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            fsync_dir(self.directory)
        except Exception:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        obs.inc("fault.ckpt_saves")
        return self._step_dir(step)

    # -- restore --------------------------------------------------------------
    def steps(self) -> list[int]:
        """All committed step numbers, ascending."""
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.directory, d, "COMMITTED")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self._step_dir(step),
                               "manifest.json")) as fh:
            return json.load(fh)

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[int, Any]:
        """`like` provides the tree structure (+ dtypes for casting).
        `shardings` (optional pytree of NamedSharding) re-shards on load —
        works across mesh shapes (elastic restart)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in "
                                    f"{self.directory}")
        d = self._step_dir(step)
        data = np.load(os.path.join(d, "arrays.npz"))
        keys = sorted(data.files, key=lambda s: int(s.split(_SEP)[0]))
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        assert len(keys) == len(leaves_like), \
            f"leaf count mismatch: {len(keys)} vs {len(leaves_like)}"
        shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                        if shardings is not None else [None] * len(keys))
        out = []
        for key, ref, shd in zip(keys, leaves_like, shard_leaves):
            arr = data[key]
            dtype = getattr(ref, "dtype", arr.dtype)
            a = jax.numpy.asarray(arr, dtype=dtype)
            if shd is not None:
                a = jax.device_put(a, shd)
            out.append(a)
        return step, jax.tree_util.tree_unflatten(treedef, out)

    def load_raw(self, step: int) -> dict:
        """The step's leaves as a {name: host array} dict — the natural
        form for the flat dict trees `SolveCheckpointer` writes."""
        d = self._step_dir(step)
        with np.load(os.path.join(d, "arrays.npz")) as data:
            out = {}
            for key in data.files:
                _, name = key.split(_SEP, 1)
                out[name] = data[key]
        return out

    def restore_latest_valid_raw(self) -> Optional[Tuple[int, dict, dict]]:
        """Newest checkpoint that actually LOADS, as (step, raw leaves,
        manifest extra): a committed step whose arrays were later
        corrupted (bit rot, torn copy) is skipped with a warning — the
        same degrade-don't-die posture as the missing-COMMITTED skip.
        Returns None when nothing restores."""
        for step in reversed(self.steps()):
            try:
                leaves = self.load_raw(step)
                meta = self.manifest(step).get("extra", {})
                return step, leaves, meta
            except Exception as e:  # zip/OSError/KeyError/json errors
                obs.inc("fault.ckpt_unreadable")
                print(f"[fault] checkpoint step {step} unreadable "
                      f"({type(e).__name__}: {e}); trying older one")
        return None

    # -- internals --------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{int(step):08d}")

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
        # clean stale tmp dirs from crashed writers
        for d in os.listdir(self.directory):
            if d.startswith(".tmp_ckpt_"):
                shutil.rmtree(os.path.join(self.directory, d),
                              ignore_errors=True)


def host_state(backend, state) -> dict:
    """The mesh-agnostic host image of an `EngineState`: full UNPADDED
    arrays, so any backend (any device count) can `restore_state` it."""
    n = backend.n_features
    return {
        "w": backend.host_weights(state.w),
        "z": backend.host_margins(state.z),
        "active": np.asarray(state.active)[:n],
        "key": np.asarray(state.key),
    }


class SolveCheckpointer:
    """Periodic EngineState snapshots for solves and path sweeps.

    `every` applies to the per-iteration solve callback; the path driver
    checkpoints at every grid-point boundary (a point is the natural
    resume unit — resuming mid-point would replay a partial iteration
    stream and break bit-exact parity with the uninterrupted run).
    """

    KIND_SOLVE = "solve"
    KIND_PATH = "path"

    def __init__(self, directory: str, every: int = 10, keep: int = 3):
        if every < 1:
            raise ValueError(f"ckpt every must be >= 1, got {every}")
        self.manager = CheckpointManager(directory, keep=keep)
        self.every = int(every)

    # -- single solves -------------------------------------------------------
    def save_solve(self, backend, state, *, outer_iter: int,
                   extra: Optional[dict] = None) -> str:
        meta = {"kind": self.KIND_SOLVE, "outer_iter": int(outer_iter),
                **(extra or {})}
        return self.manager.save(int(outer_iter), host_state(backend, state),
                                 extra=meta)

    def restore_solve(self, backend):
        """-> (EngineState on the backend, meta dict) or None."""
        got = self._restore(self.KIND_SOLVE)
        if got is None:
            return None
        tree, meta = got
        return backend.restore_state(**tree), meta

    def latest_meta(self) -> Optional[dict]:
        step = self.manager.latest_step()
        if step is None:
            return None
        return self.manager.manifest(step).get("extra", {})

    def solve_callback(self, backend, **extra) -> Callable:
        """The engine `state_callback`: checkpoint every `every`-th
        finished (finite) iteration."""
        def cb(k: int, state, f: float, kkt: float) -> None:
            if (k + 1) % self.every:
                return
            self.save_solve(backend, state, outer_iter=k,
                            extra={"objective": float(f),
                                   "kkt": float(kkt), **extra})
        return cb

    # -- path sweeps ---------------------------------------------------------
    def save_path(self, backend, state, *, point_index: int, cs, c_max,
                  points, weights, extra: Optional[dict] = None) -> str:
        tree = {**host_state(backend, state),
                "weights": np.asarray(weights)}
        meta = {"kind": self.KIND_PATH, "point_index": int(point_index),
                "c_max": float(c_max),
                "cs": [float(c) for c in np.asarray(cs)],
                "points": [dict(p._asdict()) for p in points],
                **(extra or {})}
        return self.manager.save(int(point_index), tree, extra=meta)

    def restore_path(self, backend, *, cs, c_max):
        """-> (EngineState, meta, weights) or None. Validates the stored
        c-grid against the live one — a checkpoint from a different
        dataset/grid must fail loudly, not resume onto wrong points."""
        got = self._restore(self.KIND_PATH)
        if got is None:
            return None
        tree, meta = got
        stored = np.asarray(meta["cs"], np.float64)
        live = np.asarray(cs, np.float64)
        if stored.shape != live.shape or not np.allclose(
                stored, live, rtol=1e-9, atol=0.0):
            raise ValueError(
                f"checkpoint in {self.manager.directory} was written for "
                f"a different c-grid ({stored.shape[0]} points, "
                f"c_max={meta['c_max']:.6g}) than this sweep "
                f"({live.shape[0]} points, c_max={float(c_max):.6g}); "
                f"point a fresh --ckpt-dir at this run")
        weights = tree.pop("weights")
        state = backend.restore_state(**tree)
        obs.inc("fault.resumes")
        return state, meta, np.asarray(weights)

    # -- shared --------------------------------------------------------------
    def _restore(self, kind: str):
        got = self.manager.restore_latest_valid_raw()
        if got is None:
            return None
        _step, leaves, meta = got
        if meta.get("kind") != kind:
            raise ValueError(
                f"checkpoint in {self.manager.directory} is a "
                f"{meta.get('kind')!r} checkpoint, not {kind!r} — solve "
                f"and path runs need separate --ckpt-dir directories")
        return leaves, meta
