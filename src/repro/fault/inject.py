"""Deterministic fault injection (DESIGN.md section 16.4).

A `FaultPlan` is a declarative, seed-keyed schedule of faults; the
hooks fire at EXACT iteration / path-point indices, so every failure a
test or benchmark provokes is reproducible bit-for-bit:

* ``crash_at_iter`` / ``crash_at_point`` — kill the host right there,
  either by raising `InjectedCrash` (in-process tests) or via
  ``os.kill(SIGKILL)`` (subprocess kill-resume tests — no atexit, no
  flushing, the real thing).
* ``nan_at_iter`` — poison the iteration's OUTPUT (margins, weights or
  the KKT scalar) with NaNs, the physically faithful model of a
  divergence blow-up: a NaN entering z makes the same iteration's
  objective/KKT non-finite while the PREVIOUS state — what the engine
  rolls back to — stays clean.
* ``delay_at_iter`` — sleep `delay_s` inside one iteration (straggler
  deadline exercises).

Every hook fires AT MOST ONCE (the plan tracks what it already fired),
so a retried/rolled-back iteration re-executes clean — which is exactly
what lets the non-finite rollback tests assert recovery.

`plan_from_env` reads the ``REPRO_FAULT_PLAN`` JSON env var, the channel
the subprocess tests and the CI kill-resume smoke use to drive faults
through the real CLIs without test-only flags.
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import time
from typing import Optional

import jax.numpy as jnp
import numpy as np

ENV_VAR = "REPRO_FAULT_PLAN"

NAN_TARGETS = ("margins", "weights", "kkt")
CRASH_KINDS = ("exception", "sigkill")


class InjectedCrash(RuntimeError):
    """An in-process injected crash (crash_kind='exception')."""


@dataclasses.dataclass
class FaultPlan:
    """Declarative fault schedule. Indices are GLOBAL: `crash_at_iter`
    counts solver outer iterations (resume-aware — a run resumed at
    iteration k starts counting there), `crash_at_point` counts path
    grid points and fires AFTER the point's checkpoint is written."""

    crash_at_iter: Optional[int] = None
    crash_at_point: Optional[int] = None
    crash_kind: str = "exception"
    nan_at_iter: Optional[int] = None
    nan_target: str = "margins"
    nan_count: int = 4
    delay_at_iter: Optional[int] = None
    delay_s: float = 0.0
    seed: int = 0

    def __post_init__(self):
        if self.crash_kind not in CRASH_KINDS:
            raise ValueError(f"crash_kind must be one of {CRASH_KINDS}, "
                             f"got {self.crash_kind!r}")
        if self.nan_target not in NAN_TARGETS:
            raise ValueError(f"nan_target must be one of {NAN_TARGETS}, "
                             f"got {self.nan_target!r}")
        self._fired: set = set()

    # -- firing --------------------------------------------------------------
    def _once(self, tag) -> bool:
        if tag in self._fired:
            return False
        self._fired.add(tag)
        return True

    def _crash(self, what: str) -> None:
        if self.crash_kind == "sigkill":
            # the real thing: no exception propagation, no atexit, no
            # stream flushing — the process is simply gone
            os.kill(os.getpid(), signal.SIGKILL)
        raise InjectedCrash(what)

    def fire_point(self, point_index: int) -> None:
        """Path-driver hook, called after each point's checkpoint."""
        if (self.crash_at_point == point_index
                and self._once(("point", point_index))):
            self._crash(f"injected crash after path point {point_index}")

    # -- outer-iteration wrapper ---------------------------------------------
    def poison(self, out: tuple) -> tuple:
        """Poison one engine 9(+)-tuple according to `nan_target`."""
        out = list(out)
        w, z, f, kkt = out[0], out[1], out[3], out[4]
        nan = jnp.asarray(float("nan"), f.dtype)
        if self.nan_target == "kkt":
            out[4] = jnp.full_like(kkt, nan)
            return tuple(out)
        if self.nan_target == "margins":
            tgt, slot = z, 1
        else:
            tgt, slot = w, 0
        rng = np.random.default_rng(self.seed)
        count = int(min(max(self.nan_count, 1), tgt.shape[0]))
        idx = rng.choice(tgt.shape[0], size=count, replace=False)
        out[slot] = tgt.at[jnp.asarray(np.sort(idx))].set(nan)
        # a NaN margin/weight makes the SAME iteration's objective and
        # KKT non-finite (they are reductions over z / w)
        out[3] = jnp.full_like(f, nan)
        out[4] = jnp.full_like(kkt, nan)
        return tuple(out)


def wrap_outer(outer, plan: FaultPlan, start_iter: int = 0):
    """Wrap a backend `outer` with the plan's iteration-indexed hooks.

    The wrapper counts calls starting at `start_iter` so iteration
    indices stay global across resumes and rollback retries (the
    resilient driver re-wraps from the redo point; one-shot firing
    keeps a retried index from re-poisoning)."""
    counter = {"k": int(start_iter)}

    def wrapped(w, z, key, active, recheck, c):
        k = counter["k"]
        counter["k"] = k + 1
        if plan.delay_at_iter == k and plan._once(("delay", k)):
            time.sleep(plan.delay_s)
        if plan.crash_at_iter == k and plan._once(("crash", k)):
            plan._crash(f"injected crash at outer iteration {k}")
        out = outer(w, z, key, active, recheck, c)
        if plan.nan_at_iter == k and plan._once(("nan", k)):
            out = plan.poison(out)
        return out

    return wrapped


def plan_from_env(var: str = ENV_VAR) -> Optional[FaultPlan]:
    """FaultPlan from the `REPRO_FAULT_PLAN` JSON env var, or None.
    Unknown keys are rejected — a typoed fault that silently never fires
    would make a red test green."""
    raw = os.environ.get(var)
    if not raw:
        return None
    obj = json.loads(raw)
    if not isinstance(obj, dict):
        raise ValueError(f"{var} must be a JSON object, got {type(obj)}")
    fields = {f.name for f in dataclasses.fields(FaultPlan)}
    unknown = set(obj) - fields
    if unknown:
        raise ValueError(f"{var} has unknown keys {sorted(unknown)} "
                         f"(known: {sorted(fields)})")
    return FaultPlan(**obj)


def corrupt_checkpoint(directory: str, step: Optional[int] = None,
                       mode: str = "uncommit") -> str:
    """Damage a checkpoint for recovery tests. mode='uncommit' removes
    the COMMITTED marker (simulates a crash between the array write and
    the commit); mode='truncate' overwrites arrays.npz with garbage
    while LEAVING the marker (simulates later corruption of a committed
    step). Returns the damaged step dir."""
    from repro.fault.checkpoint import CheckpointManager
    mgr = CheckpointManager(directory)
    if step is None:
        step = mgr.latest_step()
    if step is None:
        raise FileNotFoundError(f"no committed checkpoint in {directory}")
    d = mgr._step_dir(step)
    if mode == "uncommit":
        os.remove(os.path.join(d, "COMMITTED"))
    elif mode == "truncate":
        with open(os.path.join(d, "arrays.npz"), "wb") as fh:
            fh.write(b"not a zip file")
    else:
        raise ValueError(f"unknown mode {mode!r}")
    return d
