"""Non-finite rollback with automatic P-backoff (DESIGN.md section 16.3).

The paper's central tension: parallelism P accelerates convergence right
up to the point it destroys it (Bradley et al., arXiv 1105.5379).
PR 9's `diag/safep.py` MEASURES the certified safe bundle size; this
module is its first consumer — it ACTS on it.

`resilient_solve` wraps the engine loop in a bounded retry state
machine:

    RUN ── finite ───────────────────────────► DONE (converged/budget)
     │
     └─ non-finite (engine detector) ──► ROLLBACK to last good iterate
            │                              (the engine already returns it)
            ├─ retries left: halve P toward P_cert (never below), rebuild
            │  the backend, re-enter RUN at the poisoned iteration index
            └─ retries exhausted: surface the last good iterate + the
               PR 9 post-mortem (diverged=True, nonfinite=True)

The backoff target is `max(P // 2, P_cert)` (plain halving once below
P_cert, floor 1): the certified bound is a *sufficient* safe point, so
there is no reason to damp past it in one step, and no reason to stop
halving above it. P_cert is computed lazily (one power iteration over
the design) only when a rollback actually happens — fault-free solves
never pay for it.

Checkpoint/resume rides the same driver: pass a
`fault.SolveCheckpointer` and the engine's `state_callback` snapshots
every N-th iterate; `resume=True` restarts from the newest committed
snapshot — including onto a different device count, the checkpoints are
mesh-agnostic host arrays.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro import obs
from repro.engine import loop as engine_loop
from repro.fault import inject as inject_mod
from repro.fault.checkpoint import SolveCheckpointer, host_state


def next_bundle_size(P: int, p_cert: Optional[int] = None) -> int:
    """The backoff schedule: halve toward (but not below) the certified
    safe bundle size; plain halving with floor 1 when no certificate."""
    half = max(int(P) // 2, 1)
    if p_cert is not None and 0 < int(p_cert) < int(P):
        return max(half, int(p_cert))
    return half


def _merge_histories(histories) -> engine_loop.SolveHistory:
    """Concatenate per-attempt histories into one global-iteration
    record. Attempts overlap at the redo boundary (the rolled-back
    iteration is re-run), so later rows supersede earlier ones at the
    same outer_iter index. Aux series of different widths (P changed
    across retries ⇒ different bundle counts) are padded to the widest
    with the engine's sentinels (q = -1, alpha = NaN)."""
    histories = [h for h in histories if h.outer_iter.size]
    if not histories:
        return engine_loop.SolveHistory(
            *(np.asarray([]) for _ in range(7)))
    rows: dict = {}
    for h in histories:
        d = h._asdict()
        for i, it in enumerate(np.asarray(h.outer_iter)):
            rows[int(it)] = {k: (None if v is None else np.asarray(v)[i])
                             for k, v in d.items()}
    order = sorted(rows)
    fields = {}
    for name in engine_loop.SolveHistory._fields:
        vals = [rows[it][name] for it in order]
        if any(v is None for v in vals):
            fields[name] = None
            continue
        if name in ("bundle_q", "bundle_alpha"):
            width = max(np.asarray(v).shape[0] for v in vals)
            pad_val = -1 if name == "bundle_q" else np.nan
            out = np.full((len(vals), width),
                          pad_val, np.asarray(vals[0]).dtype)
            for i, v in enumerate(vals):
                out[i, :np.asarray(v).shape[0]] = v
            fields[name] = out
        else:
            fields[name] = np.asarray(vals)
    return engine_loop.SolveHistory(**fields)


def resilient_solve(factory: Callable, c: float, *, P: int,
                    w0=None, max_outer: int, tol_kkt: float,
                    recheck_every: int = 1, tol_rel_obj: float = 0.0,
                    f_star: Optional[float] = None,
                    callback: Optional[Callable] = None,
                    checkpointer: Optional[SolveCheckpointer] = None,
                    resume: bool = False, max_retries: int = 2,
                    design=None, p_cert: Optional[int] = None,
                    plan: Optional[inject_mod.FaultPlan] = None,
                    ) -> engine_loop.SolveResult:
    """One fault-tolerant solve. `factory(P) -> backend` rebuilds the
    execution backend at a damped bundle size after a rollback (the
    bundle partition is baked into the compiled iteration, so backoff IS
    a rebuild). Returns a SolveResult whose `w` is the HOST weight
    vector (`backend.host_weights`) — the backend that produced it may
    not be the one the caller built. `design` (anything the diag layer's
    `certify` accepts, or a zero-arg callable returning one) enables the
    certified-P floor; `plan` threads the deterministic fault-injection
    hooks into every attempt."""
    backend = factory(int(P))
    engine_loop.check_shrink_stop_consistency(backend, tol_kkt)

    start_iter = 0
    resumed_from = None
    state = None
    if resume and checkpointer is not None:
        meta = checkpointer.latest_meta()
        if meta is not None and "P" in meta and int(meta["P"]) != int(P):
            # continue the P schedule the crashed run had backed off to
            P = int(meta["P"])
            backend = factory(P)
        got = checkpointer.restore_solve(backend)
        if got is not None:
            state, meta = got
            resumed_from = int(meta["outer_iter"])
            start_iter = resumed_from + 1
            obs.inc("fault.resumes")
            print(f"[fault] resuming solve at outer iteration "
                  f"{start_iter} (checkpoint {checkpointer.manager.directory})")
    if state is None:
        state = backend.init_state(w0)

    p_schedule = [int(P)]
    rollbacks = 0
    histories = []
    res = None
    while True:
        outer = backend.outer
        if plan is not None:
            outer = inject_mod.wrap_outer(outer, plan, start_iter=start_iter)
        state_cb = (checkpointer.solve_callback(backend, P=int(P))
                    if checkpointer is not None else None)
        if start_iter >= max_outer:
            break
        state, res = engine_loop.run_outer_loop(
            outer, state, c, max_outer=max_outer, tol_kkt=tol_kkt,
            recheck_every=recheck_every, tol_rel_obj=tol_rel_obj,
            f_star=f_star, callback=callback, start_iter=start_iter,
            state_callback=state_cb, check_finite_w=rollbacks > 0)
        histories.append(res.history)
        if not res.nonfinite:
            break
        rollbacks += 1
        obs.inc("fault.rollbacks")
        if rollbacks > max_retries:
            print(f"[fault] non-finite iterate persisted through "
                  f"{max_retries} rollback(s); surfacing post-mortem")
            break
        # the engine handed back the LAST GOOD state; redo the poisoned
        # iteration (its global index is the last recorded history row)
        k_bad = int(res.history.outer_iter[-1])
        start_iter = k_bad
        if p_cert is None and design is not None:
            from repro.diag import safep
            # a callable defers design-matrix construction to the first
            # rollback — fault-free runs never build it
            d = design() if callable(design) else design
            p_cert = int(safep.certify(d, observed_p=int(P))["P_cert"])
            print(f"[fault] certified safe bundle size P_cert={p_cert}")
        new_p = next_bundle_size(P, p_cert)
        print(f"[fault] non-finite at outer iteration {k_bad}: rolling "
              f"back and retrying with P={new_p} (was {P})")
        if new_p != P:
            obs.inc("fault.p_backoff")
            snap = host_state(backend, state)
            P = new_p
            backend = factory(int(P))
            engine_loop.check_shrink_stop_consistency(backend, tol_kkt)
            state = backend.restore_state(**snap)
        p_schedule.append(int(P))

    if res is None:        # resume landed at/after the budget: 0 new iters
        res = engine_loop.SolveResult(
            w=state.w, objective=float("nan"), n_outer=start_iter,
            converged=False, history=_merge_histories([]))
    faults = None
    if rollbacks or resumed_from is not None or len(p_schedule) > 1:
        faults = {"rollbacks": rollbacks, "p_schedule": p_schedule,
                  "p_cert": p_cert, "resumed_from": resumed_from}
    return res._replace(w=backend.host_weights(res.w),
                        history=_merge_histories(histories) if histories
                        else res.history,
                        faults=faults)
