"""Fault tolerance + straggler mitigation + elastic scaling for step
loops (DESIGN.md sections 4 / 16.5).

`FaultTolerantRunner` wraps a generic step loop with:
  * periodic checkpointing (every `ckpt_every` steps, atomic via
    CheckpointManager),
  * crash recovery: on any step exception the latest committed checkpoint
    is restored and the loop resumes (with bounded retries per step),
  * straggler mitigation: each step gets a wall-clock deadline derived
    from a running median (deadline = median * `straggler_factor`); a
    straggling step is re-issued (safe: steps are deterministic functions
    of their inputs — bundle steps and train steps both are). On a real
    fleet the re-issue lands on a hot-spare host; here the retry itself
    demonstrates and tests the control flow.
  * elastic re-mesh: `ElasticMeshProvider` recomputes the mesh from the
    currently visible device count; checkpoints are mesh-agnostic (full
    host arrays), so restore re-shards onto the new mesh.

Fault injection hooks (`inject_fault`) let the test suite simulate crashes
and stragglers deterministically (see also `fault.inject.FaultPlan` for
the solver/sweep-level harness).

This machinery started life wired only to the legacy LM demo
(`repro.train`, which now re-exports it); the solver/sweep product path
uses `fault.resilient_solve` + `fault.SolveCheckpointer` built on the
same CheckpointManager.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.fault.checkpoint import CheckpointManager


@dataclasses.dataclass
class RunnerConfig:
    ckpt_every: int = 50
    max_retries_per_step: int = 3
    straggler_factor: float = 5.0   # deadline = median_step_time * factor
    min_deadline_s: float = 2.0
    warmup_steps: int = 3           # exclude compile-time steps from median


class StepFailure(RuntimeError):
    pass


class FaultTolerantRunner:
    def __init__(self, step_fn: Callable, state: Any,
                 ckpt: CheckpointManager, cfg: RunnerConfig = RunnerConfig(),
                 inject_fault: Optional[Callable[[int, int], None]] = None):
        """step_fn(state, step_idx) -> (state, metrics). state is any pytree
        (params + opt state + data cursor). inject_fault(step, attempt) may
        raise to simulate a crash (test hook)."""
        self.step_fn = step_fn
        self.state = state
        self.ckpt = ckpt
        self.cfg = cfg
        self.inject_fault = inject_fault
        self.step_times: list[float] = []
        self.events: list[dict] = []      # fault/straggler/restore log
        self.start_step = 0
        # auto-resume if a checkpoint exists
        latest = ckpt.latest_step()
        if latest is not None:
            self.start_step, self.state = ckpt.restore(self.state)
            self.events.append({"kind": "resume", "step": latest})

    # -- deadline logic -----------------------------------------------------
    def _deadline(self) -> float:
        if len(self.step_times) < self.cfg.warmup_steps:
            return float("inf")
        med = float(np.median(self.step_times))
        return max(med * self.cfg.straggler_factor, self.cfg.min_deadline_s)

    def _attempt(self, step: int, attempt: int):
        if self.inject_fault is not None:
            self.inject_fault(step, attempt)
        t0 = time.perf_counter()
        state, metrics = self.step_fn(self.state, step)
        # block so the deadline measures real execution, not dispatch
        jax.block_until_ready(jax.tree.leaves(state)[0])
        dt = time.perf_counter() - t0
        if dt > self._deadline():
            self.events.append({"kind": "straggler", "step": step,
                                "attempt": attempt, "seconds": dt})
            raise StepFailure(f"straggler: step {step} took {dt:.2f}s "
                              f"(deadline {self._deadline():.2f}s)")
        return state, metrics, dt

    # -- main loop -------------------------------------------------------------
    def run(self, n_steps: int, metrics_cb: Optional[Callable] = None):
        step = self.start_step
        end = self.start_step + n_steps
        while step < end:
            ok = False
            for attempt in range(self.cfg.max_retries_per_step):
                try:
                    state, metrics, dt = self._attempt(step, attempt)
                    self.state = state
                    self.step_times.append(dt)
                    if len(self.step_times) > 64:
                        self.step_times.pop(0)
                    ok = True
                    break
                except StepFailure:
                    continue  # re-issue the same step (speculative retry)
                except Exception as e:  # crash: restore + retry
                    self.events.append({"kind": "crash", "step": step,
                                        "attempt": attempt,
                                        "error": repr(e)})
                    latest = self.ckpt.latest_step()
                    if latest is not None:
                        restored, self.state = self.ckpt.restore(self.state)
                        step = restored
                        self.events.append({"kind": "restore",
                                            "step": restored})
                    continue
            if not ok:
                raise StepFailure(
                    f"step {step} failed {self.cfg.max_retries_per_step}x")
            if metrics_cb is not None:
                metrics_cb(step, metrics)
            step += 1
            if step % self.cfg.ckpt_every == 0:
                self.ckpt.save(step, self.state)
        self.ckpt.save(step, self.state)
        return self.state


@dataclasses.dataclass
class ElasticMeshProvider:
    """Recompute the mesh from whatever devices are visible. Checkpoints
    are host-array based, so params re-shard transparently after a
    device-count change (lost host / added pod)."""
    model_parallel: int = 1

    def make(self):
        n = len(jax.devices())
        model = self.model_parallel
        while model > 1 and n % model != 0:
            model //= 2  # degrade TP gracefully if devices were lost
        data = n // model
        return jax.make_mesh((data, model), ("data", "model"))
