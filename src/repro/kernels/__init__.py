"""Pallas TPU kernels for the paper's compute hot-spots (+ the LM stack's).

  pcdn_direction.py  — fused bundle grad/Hessian/Eq.-5 direction: reads the
                       (s, P) slab from HBM once (the paper's section 3.1
                       "touch x^j twice" cache argument, TPU-native)
  pcdn_bundle.py     — fused support-restricted bundle STEP: factors,
                       direction, Delta, support margin delta, all-Q
                       Armijo and the accepted update in ONE launch —
                       O(P * k_max * Q), s-independent (DESIGN.md §11)
  pcdn_linesearch.py — batched multi-candidate Armijo objective deltas
                       (replaces Algorithm 4's sequential backtracking)
  pcdn_margin.py     — batched serving margins over sparse-model active
                       sets (dense and padded-CSC request layouts; the
                       prediction engine of DESIGN.md section 10)
  flash_attention.py — online-softmax tiled attention for the model zoo

Each kernel ships with `ops.py` (jit'd, padding-safe public wrapper;
custom_vjp for attention) and `ref.py` (pure-jnp oracle). Interpreter
mode is resolved from the ``REPRO_KERNELS_INTERPRET`` env var (default
"auto": compiled on TPU, interpreter elsewhere — see `ops.interpret_mode`).
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
