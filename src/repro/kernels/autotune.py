"""Block-size / layout / implementation autotuner for the hot kernels
(DESIGN.md section 12).

All committed BENCH numbers used to run the Pallas kernels with
hard-coded grids, block shapes and fp32 everywhere — "fast as the
hardware allows" was a hope, not a measurement. Richtárik–Takáč (arXiv
1212.0873) and Scherrer et al. (arXiv 1206.6409) both argue the win of
parallel CD is data/shape-dependent (per-row sparsity omega, memory-
system behavior), so kernel parameters must adapt to the problem
instance rather than being fixed at authorship time. This module makes
them adapt, once per problem shape:

  * every tunable kernel declares a DEFAULT config (exactly the
    pre-autotuner hard-coded behavior) and a SEARCH SPACE of candidate
    configs — block sizes along each tileable axis plus an ``impl``
    axis ("pallas": the Pallas kernel; "xla": the jnp oracle in
    `kernels/ref.py`, which is also the fastest route on backends
    where Pallas runs in interpreter mode);
  * `tune(kernel, runner, ...)` measures the candidates (exhaustive
    for small spaces, greedy coordinate hillclimb for larger ones —
    `benchmarks/hillclimb.py` drives and logs the climb) and persists
    the winner in an on-disk JSON cache keyed by
    ``(kernel, shape-bucket, dtype, backend)``;
  * `resolve(kernel, ...)` — called by every `kernels/ops.py` wrapper
    at trace time — merges defaults, the cached winner and explicit
    per-call overrides, so `make_bundle_step`, the sharded backend's
    kernel routing and the serving `ModelBank` all pick tuned configs
    transparently. Tuning itself NEVER happens implicitly: a cache
    miss costs a dict lookup and returns the defaults.

Shapes are bucketed to the next power of two per axis, so one tuning
run covers a neighborhood of problem shapes and a warm cache is hit by
every later solve/serve call at that scale.

Robustness contract (pinned by tests/test_autotune.py): a corrupt cache
file, a stale entry (unknown kernel, config keys outside the search
space, wrong value types) or an unwritable cache directory NEVER crash
a solve — every failure path falls back to the defaults silently.

Env knobs (README "Autotuner" section):

  REPRO_AUTOTUNE        "auto"/"on" (default) read the cache; "off"
                        ignore it entirely (defaults everywhere).
  REPRO_AUTOTUNE_CACHE  cache file path (default
                        ~/.cache/repro/autotune.json).
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro import obs

CACHE_VERSION = 1

# ---------------------------------------------------------------------------
# per-kernel defaults and search spaces
#
# The DEFAULTS are bit-for-bit the pre-autotuner hard-coded launches; a
# cold cache (or REPRO_AUTOTUNE=off) reproduces the old behavior exactly.
# `None` for a block size means "do not tile this axis" (the full extent
# in one program), matching the original single-slab kernels.

DEFAULTS: Dict[str, Dict[str, object]] = {
    "pcdn_bundle": {"impl": "pallas", "block_q": None},
    "pcdn_direction": {"impl": "pallas", "block_s": 512, "block_p": 128},
    "pcdn_sparse_direction": {"impl": "pallas", "block_p": 128,
                              "block_k": None},
    "pcdn_linesearch": {"impl": "pallas", "block_s": 1024},
    "serve_margins_dense": {"impl": "pallas", "block_b": 128,
                            "block_a": None},
    "serve_margins_csc": {"impl": "pallas"},
}

SEARCH_SPACES: Dict[str, Dict[str, Tuple[object, ...]]] = {
    "pcdn_bundle": {
        "impl": ("pallas", "xla"),
        "block_q": (None, 8, 16),
    },
    "pcdn_direction": {
        "impl": ("pallas", "xla"),
        "block_s": (128, 256, 512, 1024),
        "block_p": (32, 64, 128, 256),
    },
    "pcdn_sparse_direction": {
        "impl": ("pallas", "xla"),
        "block_p": (32, 64, 128, 256),
        "block_k": (None, 64, 256),
    },
    "pcdn_linesearch": {
        "impl": ("pallas", "xla"),
        "block_s": (256, 512, 1024, 2048),
    },
    "serve_margins_dense": {
        "impl": ("pallas", "xla"),
        "block_b": (32, 64, 128, 256),
        "block_a": (None, 128, 512),
    },
    "serve_margins_csc": {
        "impl": ("pallas", "xla"),
    },
}


# ---------------------------------------------------------------------------
# shape bucketing and cache keys


def next_pow2(x: int) -> int:
    x = max(1, int(x))
    return 1 << (x - 1).bit_length()


def shape_bucket(**dims) -> Tuple[Tuple[str, int], ...]:
    """Deterministic (name, pow2-rounded-size) tuple — the shape part of
    a cache key. One tuning run covers every shape in the bucket."""
    return tuple(sorted((k, next_pow2(v)) for k, v in dims.items()))


def backend_tag() -> str:
    """'cpu-interp' / 'tpu' / ... — winners differ by backend AND by
    whether Pallas runs compiled or interpreted, so both are in the key.
    Resolved lazily (first kernel dispatch initializes jax anyway)."""
    import jax

    from repro.kernels import ops
    tag = jax.default_backend()
    if ops.interpret_mode():
        tag += "-interp"
    return tag


def cache_key(kernel: str, bucket, dtype, backend: Optional[str] = None
              ) -> str:
    backend = backend or backend_tag()
    shp = ",".join(f"{k}{v}" for k, v in bucket)
    return f"{kernel}|{shp}|{_dtype_name(dtype)}|{backend}"


def _dtype_name(dtype) -> str:
    try:
        import jax.numpy as jnp  # noqa: F401
        import numpy as np
        return np.dtype(dtype).name
    except Exception:
        return str(dtype)


# ---------------------------------------------------------------------------
# persistent cache


def enabled() -> bool:
    return os.environ.get("REPRO_AUTOTUNE", "auto").strip().lower() not in (
        "0", "off", "false", "no")


def cache_path() -> str:
    return os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(os.path.expanduser("~"), ".cache", "repro",
                     "autotune.json"))


# module-level cache state: (path, mtime_ns, entries). Reloaded when the
# path changes or the file is rewritten — cheap enough for trace time.
_cache_state: Optional[Tuple[str, int, dict]] = None


def _load_cache() -> dict:
    global _cache_state
    path = cache_path()
    try:
        mtime = os.stat(path).st_mtime_ns
    except OSError:
        _cache_state = (path, -1, {})
        return {}
    if _cache_state is not None and _cache_state[0] == path \
            and _cache_state[1] == mtime:
        return _cache_state[2]
    try:
        with open(path) as fh:
            obj = json.load(fh)
        if not isinstance(obj, dict) or obj.get("version") != CACHE_VERSION:
            raise ValueError("version mismatch")
        entries = obj.get("entries", {})
        if not isinstance(entries, dict):
            raise ValueError("entries not a dict")
    except Exception:
        # corrupt / unreadable / wrong version: behave as empty, never raise
        entries = {}
    _cache_state = (path, mtime, entries)
    return entries


def invalidate_cache() -> None:
    """Drop the in-memory cache view (tests; after env changes)."""
    global _cache_state
    _cache_state = None


def _validate(kernel: str, config: dict) -> Optional[dict]:
    """A cached config is usable iff every key belongs to the kernel's
    search space and every value is one of the declared candidates (the
    'stale entry' contract: a config written by an older search space
    that no longer exists falls back to defaults, it does not crash)."""
    space = SEARCH_SPACES.get(kernel)
    if space is None or not isinstance(config, dict):
        return None
    out = {}
    for k, v in config.items():
        if k not in space:
            return None
        if v not in space[k]:
            return None
        out[k] = v
    return out


def lookup(kernel: str, bucket, dtype, backend: Optional[str] = None
           ) -> Optional[dict]:
    """Validated cached winner for this cell, or None.

    Metrics (registry enabled): autotune.lookup_hits counts lookups
    that return a usable cached winner; autotune.lookup_misses counts
    everything else (disabled tuner, empty cache, absent or stale
    entry) — the miss path is exactly "defaults were used".
    """
    cfg = _lookup(kernel, bucket, dtype, backend)
    obs.inc("autotune.lookup_hits" if cfg is not None
            else "autotune.lookup_misses")
    return cfg


def _lookup(kernel: str, bucket, dtype, backend: Optional[str] = None
            ) -> Optional[dict]:
    if not enabled():
        return None
    entries = _load_cache()
    if not entries:
        return None
    rec = entries.get(cache_key(kernel, bucket, dtype, backend))
    if not isinstance(rec, dict):
        return None
    return _validate(kernel, rec.get("config"))


def record(kernel: str, bucket, dtype, config: dict,
           us: Optional[float] = None, default_us: Optional[float] = None,
           backend: Optional[str] = None) -> bool:
    """Persist a tuned winner. Returns False (without raising) when the
    cache file cannot be written."""
    key = cache_key(kernel, bucket, dtype, backend)
    path = cache_path()
    try:
        entries = dict(_load_cache())
        entries[key] = {"config": dict(config), "us": us,
                        "default_us": default_us,
                        "when": time.strftime("%Y-%m-%dT%H:%M:%S")}
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as fh:
            json.dump({"version": CACHE_VERSION, "entries": entries}, fh,
                      indent=1)
        os.replace(tmp, path)
    except Exception:
        return False
    invalidate_cache()
    return True


def resolve(kernel: str, bucket, dtype,
            overrides: Optional[dict] = None) -> dict:
    """The trace-time dispatch decision of every ops.py wrapper.

    defaults <- cached winner <- explicit per-call overrides (a non-None
    kwarg always wins — callers who pass block sizes keep exact control).
    """
    cfg = dict(DEFAULTS[kernel])
    cached = lookup(kernel, bucket, dtype)
    if cached:
        cfg.update(cached)
    if overrides:
        for k, v in overrides.items():
            if v is not None:
                cfg[k] = v
    return cfg


# ---------------------------------------------------------------------------
# tuning


@dataclasses.dataclass(frozen=True)
class TuneResult:
    kernel: str
    config: dict                 # the winner
    us: float                    # winner's measured microseconds/call
    default_us: float            # the DEFAULT config's microseconds/call
    table: Tuple[dict, ...]      # every measured candidate {config, us}
    trajectory: Tuple[dict, ...]  # hillclimb steps {config, us} (exhaustive:
    #                               the winner only)

    @property
    def speedup(self) -> float:
        return self.default_us / max(self.us, 1e-9)


def time_call(fn: Callable[[], object], repeats: int = 5,
              warmup: int = 1) -> float:
    """Median microseconds per call; blocks on jax arrays."""
    import jax

    def run():
        out = fn()
        jax.block_until_ready(out)

    for _ in range(warmup):
        run()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        run()
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def candidate_configs(kernel: str) -> List[dict]:
    """The full cartesian search space (DEFAULT config always included)."""
    space = SEARCH_SPACES[kernel]
    keys = sorted(space)
    configs = [dict(zip(keys, vals))
               for vals in itertools.product(*(space[k] for k in keys))]
    default = DEFAULTS[kernel]
    if default not in configs:
        configs.insert(0, dict(default))
    return configs


def _measure(runner: Callable[[dict], Callable], config: dict,
             repeats: int) -> Optional[float]:
    """Build + time one candidate; an infeasible candidate (runner or the
    launch raises) is skipped, not fatal."""
    try:
        fn = runner(config)
        return time_call(fn, repeats=repeats)
    except Exception:
        return None


def tune(kernel: str, runner: Callable[[dict], Callable], bucket, dtype,
         strategy: str = "exhaustive", repeats: int = 5,
         persist: bool = True, backend: Optional[str] = None) -> TuneResult:
    """Measure candidates and persist the winner for this cache cell.

    runner(config) -> zero-arg callable executing one kernel call with
    that config (the benchmark builds it around fixed random operands).
    strategy: "exhaustive" times the whole cartesian space; "hillclimb"
    starts from the defaults and greedily improves one axis at a time
    (the classic autotuner climb — `benchmarks/hillclimb.py` logs the
    trajectory). The DEFAULT config is always measured, so the recorded
    winner is never slower than the default by construction.
    """
    t_tune = time.perf_counter_ns()
    default = dict(DEFAULTS[kernel])
    table: List[dict] = []
    measured: Dict[str, float] = {}

    def key_of(cfg: dict) -> str:
        return json.dumps(cfg, sort_keys=True)

    def measure(cfg: dict) -> Optional[float]:
        k = key_of(cfg)
        if k in measured:
            return measured[k]
        us = _measure(runner, cfg, repeats)
        if us is not None:
            measured[k] = us
            table.append({"config": dict(cfg), "us": us})
        return us

    default_us = measure(default)
    if default_us is None:
        raise RuntimeError(
            f"autotune[{kernel}]: the default config {default} failed to "
            f"run — nothing to tune against")

    trajectory = [{"config": dict(default), "us": default_us}]
    if strategy == "exhaustive":
        for cfg in candidate_configs(kernel):
            measure(cfg)
        best = min(table, key=lambda r: r["us"])
        trajectory.append({"config": dict(best["config"]),
                           "us": best["us"]})
    elif strategy == "hillclimb":
        space = SEARCH_SPACES[kernel]
        current, current_us = dict(default), default_us
        improved = True
        while improved:
            improved = False
            for axis in sorted(space):
                for v in space[axis]:
                    if current.get(axis) == v:
                        continue
                    cand = dict(current)
                    cand[axis] = v
                    us = measure(cand)
                    if us is not None and us < current_us:
                        current, current_us = cand, us
                        trajectory.append({"config": dict(cand), "us": us})
                        improved = True
        best = {"config": current, "us": current_us}
    else:
        raise ValueError(f"unknown strategy {strategy!r}")

    result = TuneResult(kernel=kernel, config=dict(best["config"]),
                        us=float(best["us"]), default_us=float(default_us),
                        table=tuple(table), trajectory=tuple(trajectory))
    if persist:
        record(kernel, bucket, dtype, result.config, us=result.us,
               default_us=result.default_us, backend=backend)
    t_done = time.perf_counter_ns()
    obs.inc("autotune.tunes")
    obs.observe("autotune.tune_seconds", (t_done - t_tune) / 1e9)
    obs.complete("autotune.tune", "kernels", t_tune, t_done,
                 args={"kernel": kernel, "strategy": strategy,
                       "candidates": len(table),
                       "speedup": result.speedup})
    return result
