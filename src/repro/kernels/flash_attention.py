"""Pallas TPU kernel: FlashAttention-style fused attention (LM hot-spot).

Online-softmax tiled attention: never materializes the (S, S) score matrix
in HBM. Grid = (batch*heads, q_tiles, kv_tiles) with the kv dimension
innermost; running max / normalizer / output accumulator live in VMEM
scratch across kv tiles. Causal tiles strictly above the diagonal are
skipped (no matmul issued). GQA is handled by the ops.py wrapper (kv heads
are broadcast to q heads before the launch; the kernel sees matched heads).

Block shapes default to (128, 128) q x kv tiles — MXU-aligned for every
head_dim in the assigned archs (64, 128, 256).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m_scr, l_scr,
            *, causal: bool, block_q: int, block_k: int, n_kv: int,
            sm_scale: float):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)

    # causal: skip tiles entirely above the diagonal
    should_run = True
    if causal:
        should_run = j * block_k <= i * block_q + block_q - 1

    @pl.when(should_run)
    def _compute():
        q = q_ref[0]                       # (Bq, D)
        k = k_ref[0]                       # (Bk, D)
        v = v_ref[0]                       # (Bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale   # (Bq, Bk)
        if causal:
            qi = i * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kj = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qi >= kj, s, NEG_INF)
        m_prev = m_scr[...]                # (Bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)             # (Bq, Bk)
        corr = jnp.exp(m_prev - m_new)     # (Bq, 1)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc[...] = acc[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == n_kv - 1)
    def _write():
        # fully-masked rows (padding) have l == 0; guard the division
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc[...] / l).astype(o_ref.dtype)


def flash_attention_kernel(
    q: Array, k: Array, v: Array,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> Array:
    """Raw launch. q: (BH, Sq, D), k/v: (BH, Skv, D); Sq % block_q == 0,
    Skv % block_k == 0. Returns (BH, Sq, D) in q.dtype."""
    BH, Sq, D = q.shape
    _, Skv, _ = k.shape
    assert Sq % block_q == 0 and Skv % block_k == 0, (Sq, Skv)
    n_q = Sq // block_q
    n_kv = Skv // block_k
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)

    kernel = functools.partial(
        _kernel, causal=causal, block_q=block_q, block_k=block_k,
        n_kv=n_kv, sm_scale=float(sm_scale))
    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        interpret=interpret,
    )(q, k, v)
