"""jit'd public wrappers around the Pallas kernels.

Handle padding to tile-aligned shapes, dtype plumbing, GQA head broadcast,
and the custom_vjp for attention (forward = Pallas, backward = recompute
with the jnp oracle — standard flash recomputation strategy).

Interpreter mode is controlled by the ``REPRO_KERNELS_INTERPRET`` env
var: "auto" (default) runs compiled kernels on TPU and the interpreter
everywhere else, "1"/"true" forces the interpreter, "0"/"false" forces
compiled kernels. Resolution is lazy (first kernel trace), so importing
this module never initializes a jax backend and no import-order-
sensitive monkeypatching is needed on real TPU. Assigning the legacy
``repro.kernels.ops.INTERPRET = False`` still works: a non-None value
short-circuits the env lookup.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.pcdn_bundle import pcdn_bundle_kernel
from repro.kernels.pcdn_direction import pcdn_direction_kernel
from repro.kernels.pcdn_linesearch import pcdn_linesearch_kernel
from repro.kernels.pcdn_margin import (serve_margins_csc_kernel,
                                       serve_margins_dense_kernel)
from repro.kernels.pcdn_sparse_direction import pcdn_sparse_direction_kernel

Array = jax.Array

# tri-state: None = resolve from REPRO_KERNELS_INTERPRET / backend on
# first use; assigning True/False here (legacy API) overrides both.
INTERPRET = None


def interpret_mode() -> bool:
    """Resolve (and cache) whether kernels run in interpreter mode."""
    global INTERPRET
    if INTERPRET is None:
        env = os.environ.get("REPRO_KERNELS_INTERPRET", "auto")
        env = env.strip().lower()
        if env in ("auto", ""):
            INTERPRET = jax.default_backend() != "tpu"
        else:
            INTERPRET = env not in ("0", "false", "no", "off")
    return INTERPRET


def _pad_to(x: Array, axis: int, multiple: int, value=0.0) -> Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit, static_argnames=("l2", "block_s", "block_p"))
def pcdn_direction(XB: Array, u: Array, v: Array, w_B: Array,
                   l2: float = 0.0, block_s: int = 512,
                   block_p: int = 128):
    """Fused bundle direction. XB (s, P) any float dtype -> (d, g, h) (P,).

    Pads s and P to tile multiples; padded samples carry u = v = 0 (no
    contribution), padded features get w = 0 / g = 0 -> d = 0 and are
    sliced away.
    """
    s, P = XB.shape
    bs = min(block_s, max(8, s))
    XBp = _pad_to(_pad_to(XB, 0, bs), 1, block_p)
    up = _pad_to(u, 0, bs)
    vp = _pad_to(v, 0, bs)
    wp = _pad_to(w_B, 0, block_p)
    d, g, h = pcdn_direction_kernel(XBp, up, vp, wp, l2=l2, block_s=bs,
                                    block_p=block_p, interpret=interpret_mode())
    return d[:P], g[:P], h[:P]


@functools.partial(jax.jit, static_argnames=("l2", "block_p"))
def pcdn_sparse_direction(rows: Array, vals: Array, u: Array, v: Array,
                          w_B: Array, l2: float = 0.0,
                          block_p: int = 128):
    """Fused sparse bundle direction over the padded-CSC slab layout.

    rows/vals (P, k_max) from PaddedCSCDesign.gather_slab -> (d, g, h),
    each (P,). Pads P to a tile multiple; padded features carry sentinel
    rows (gather fills 0) and w = 0, so g = 0 -> d = 0, and are sliced
    away. k_max is left unpadded — the kernel reduces over it in full.
    """
    P, _ = rows.shape
    s = u.shape[0]
    bp = min(block_p, max(8, P))
    rowsp = _pad_to(rows, 0, bp, value=s)
    valsp = _pad_to(vals, 0, bp)
    wp = _pad_to(w_B, 0, bp)
    d, g, h = pcdn_sparse_direction_kernel(rowsp, valsp, u, v, wp, l2=l2,
                                           block_p=bp, interpret=interpret_mode())
    return d[:P], g[:P], h[:P]


@functools.partial(jax.jit, static_argnames=("kind", "block_s"))
def pcdn_linesearch(z: Array, delta: Array, y: Array, alphas: Array,
                    kind: str = "logistic", block_s: int = 1024) -> Array:
    """Batched candidate loss deltas (Q,). Pads s; padding contributes 0
    because z = delta = y = 0 rows give phi(z+a*d) - phi(z) = 0."""
    s = z.shape[0]
    bs = min(block_s, max(8, s))
    zp = _pad_to(z, 0, bs)
    dp = _pad_to(delta, 0, bs)
    yp = _pad_to(y, 0, bs)
    return pcdn_linesearch_kernel(zp, dp, yp, alphas, kind=kind,
                                  block_s=bs, interpret=interpret_mode())


@functools.partial(jax.jit,
                   static_argnames=("kind", "l2", "sigma", "gamma"))
def pcdn_bundle(vals: Array, pos: Array, z_R: Array, y_R: Array,
                w_B: Array, alphas: Array, c,
                kind: str = "logistic", l2: float = 0.0,
                sigma: float = 0.01, gamma: float = 0.0):
    """Fused support-restricted bundle step (DESIGN.md section 11).

    vals/pos (P, k_max) from `PaddedCSCDesign.gather_slab` +
    `slab_row_support`; z_R/y_R (r_max,) margins and labels gathered at
    the support rows (sentinel slots: z = 0, y = 1); alphas (Q,); `c`
    may be a traced scalar (path sweeps). Returns (upd_w (P,),
    upd_z (r_max,), alpha, n_steps) with upd_* pre-scaled by the
    accepted alpha — the caller only scatters them at the bundle
    indices / support rows.

    Pads P and r_max to lane multiples: padded features carry vals = 0
    and w = 0 (d = 0, no l1/Delta contribution), padded support slots
    z = 0 / y = 1 / delta = 0 (loss delta exactly 0). pos is NOT
    re-targeted — padded slab entries keep pointing at real slots with
    value 0. Single-program launch: VMEM caps the (Q, r_max) candidate
    grid at ~2M f32, i.e. P * k_max * Q within ~8 MB — solver bundle
    sizes, not a constraint at the repro's scales.
    """
    P, _ = vals.shape
    R = z_R.shape[0]
    valsp = _pad_to(vals, 0, 8)
    posp = _pad_to(pos, 0, 8, value=0)
    wp = _pad_to(w_B, 0, 8)
    zp = _pad_to(z_R, 0, 128)
    yp = _pad_to(y_R, 0, 128, value=1.0)
    upd_w, upd_z, alpha, q = pcdn_bundle_kernel(
        valsp, posp, zp, yp, wp, alphas, c, kind=kind, l2=l2,
        sigma=sigma, gamma=gamma, interpret=interpret_mode())
    return upd_w[:P], upd_z[:R], alpha, q


@functools.partial(jax.jit, static_argnames=("block_b",))
def serve_margins_dense(X: Array, idx: Array, val: Array,
                        block_b: int = 128) -> Array:
    """Serving margins over a dense request slab (DESIGN.md section 10.3).

    X (B, n), idx/val (K, A) stacked model active sets with sentinel
    idx == n -> (B, K) float32. Pads B to a tile multiple with zero
    rows (their margins are sliced away).
    """
    B, _ = X.shape
    bb = min(block_b, max(8, B))
    Xp = _pad_to(X, 0, bb)
    z = serve_margins_dense_kernel(Xp, idx, val, block_b=bb,
                                   interpret=interpret_mode())
    return z[:B]


@functools.partial(jax.jit, static_argnames=("n_requests",))
def serve_margins_csc(col_rows: Array, col_vals: Array, idx: Array,
                      val: Array, n_requests: int) -> Array:
    """Serving margins over a padded-CSC request batch.

    col_rows/col_vals (n, k_max) feature-major request layout (sentinel
    row id == n_requests), idx/val (K, A) -> (n_requests, K) float32.
    No padding needed: the grid is over models and the scatter output is
    already request-shaped.
    """
    return serve_margins_csc_kernel(col_rows, col_vals, idx, val,
                                    n_requests=n_requests,
                                    interpret=interpret_mode())


# ---------------------------------------------------------------------------
# attention with flash forward + recompute backward


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q: Array, k: Array, v: Array, causal: bool = True,
                    sm_scale: float | None = None) -> Array:
    """q: (BH, Sq, D), k/v: (BH, Skv, D) -> (BH, Sq, D)."""
    return _flash_fwd_impl(q, k, v, causal, sm_scale)


def _flash_fwd_impl(q, k, v, causal, sm_scale):
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    bq = min(128, max(8, Sq))
    bk = min(128, max(8, Skv))
    qp = _pad_to(q, 1, bq)
    kp = _pad_to(k, 1, bk)
    vp = _pad_to(v, 1, bk)
    # padded kv columns must not attend: causal mask handles the q side;
    # for kv we rely on padded k rows producing score 0*scale at m==0 —
    # instead mask explicitly by pushing padded keys to -inf via a huge
    # negative first component trick is brittle, so pad k with zeros and
    # mask via length: simplest correct route is slicing when no padding
    # was needed, else fall back to masked reference.
    if qp.shape[1] != Sq or kp.shape[1] != Skv:
        return ref.attention_ref(q, k, v, causal=causal, sm_scale=sm_scale)
    out = flash_attention_kernel(qp, kp, vp, causal=causal,
                                 sm_scale=sm_scale, block_q=bq, block_k=bk,
                                 interpret=interpret_mode())
    return out[:, :Sq]


def _flash_fwd(q, k, v, causal, sm_scale):
    return _flash_fwd_impl(q, k, v, causal, sm_scale), (q, k, v)


def _flash_bwd(causal, sm_scale, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention_ref(q_, k_, v_, causal=causal,
                                             sm_scale=sm_scale), q, k, v)
    return vjp(g)


flash_attention.defvjp(_flash_fwd, _flash_bwd)
