"""Public wrappers around the Pallas kernels: autotune-aware dispatchers.

Each wrapper resolves its launch configuration at TRACE time through
`kernels/autotune.resolve` — defaults (the historical hard-coded
launches) <- the persisted autotune cache winner for this
(kernel, shape-bucket, dtype, backend) cell <- explicit per-call
overrides (a caller passing `block_*`/`impl` keeps exact control) — and
then routes to one of two jitted implementations:

  * impl="pallas": the Pallas kernel (padding to tile-aligned shapes
    handled here);
  * impl="xla":    the jnp oracle from `kernels/ref.py` under jit — the
    same contract bit-for-bit at f32, and the measured winner on
    backends where Pallas runs in interpreter mode.

Because dispatch happens where the wrapper is CALLED (eagerly or inside
an outer jit trace), `make_bundle_step`, the sharded backend's kernel
routing and the serving `ModelBank` all pick tuned configs with no code
changes. Set REPRO_AUTOTUNE=off to pin every wrapper to the defaults
(tests/conftest.py does, so kernel-vs-oracle tests always exercise the
Pallas route).

Interpreter mode is controlled by the ``REPRO_KERNELS_INTERPRET`` env
var: "auto" (default) runs compiled kernels on TPU and the interpreter
everywhere else, "1"/"true" forces the interpreter, "0"/"false" forces
compiled kernels. Resolution is lazy (first kernel trace), so importing
this module never initializes a jax backend and no import-order-
sensitive monkeypatching is needed on real TPU. Assigning the legacy
``repro.kernels.ops.INTERPRET = False`` still works: a non-None value
short-circuits the env lookup.

Also here: the custom_vjp for attention (forward = Pallas, backward =
recompute with the jnp oracle — standard flash recomputation strategy).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels import autotune, ref
from repro.kernels.flash_attention import flash_attention_kernel
from repro.kernels.pcdn_bundle import pcdn_bundle_kernel
from repro.kernels.pcdn_direction import pcdn_direction_kernel
from repro.kernels.pcdn_linesearch import pcdn_linesearch_kernel
from repro.kernels.pcdn_margin import (serve_margins_csc_kernel,
                                       serve_margins_dense_kernel)
from repro.kernels.pcdn_sparse_direction import pcdn_sparse_direction_kernel

Array = jax.Array

# tri-state: None = resolve from REPRO_KERNELS_INTERPRET / backend on
# first use; assigning True/False here (legacy API) overrides both.
INTERPRET = None


def interpret_mode() -> bool:
    """Resolve (and cache) whether kernels run in interpreter mode."""
    global INTERPRET
    if INTERPRET is None:
        env = os.environ.get("REPRO_KERNELS_INTERPRET", "auto")
        env = env.strip().lower()
        if env in ("auto", ""):
            INTERPRET = jax.default_backend() != "tpu"
        else:
            INTERPRET = env not in ("0", "false", "no", "off")
    return INTERPRET


def _launch_span(kernel: str, impl: str):
    """Per-launch trace span (DESIGN.md section 13.3) for EAGER kernel
    dispatches only. Inside an outer jit trace `trace_state_clean()` is
    False and host timing would measure tracing, not execution — the
    span is suppressed there (the enclosing engine/serve span already
    covers the compiled program). Eager spans measure dispatch; the
    serving and benchmark callers block right after, so nesting under
    their spans stays proper."""
    if (obs.metrics_enabled() or obs.trace_enabled()) \
            and jax.core.trace_state_clean():
        obs.inc(f"kernels.{kernel}.launches")
        return obs.span(f"kernels.{kernel}", "kernels", args={"impl": impl})
    return obs.trace._NULL_SPAN


def _pad_to(x: Array, axis: int, multiple: int, value=0.0) -> Array:
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# pcdn_direction


@functools.partial(jax.jit, static_argnames=("l2", "block_s", "block_p"))
def _direction_pallas(XB: Array, u: Array, v: Array, w_B: Array,
                      l2: float, block_s: int, block_p: int):
    """Pads s and P to tile multiples; padded samples carry u = v = 0 (no
    contribution), padded features get w = 0 / g = 0 -> d = 0 and are
    sliced away."""
    s, P = XB.shape
    bs = min(block_s, max(8, s))
    XBp = _pad_to(_pad_to(XB, 0, bs), 1, block_p)
    up = _pad_to(u, 0, bs)
    vp = _pad_to(v, 0, bs)
    wp = _pad_to(w_B, 0, block_p)
    d, g, h = pcdn_direction_kernel(XBp, up, vp, wp, l2=l2, block_s=bs,
                                    block_p=block_p,
                                    interpret=interpret_mode())
    return d[:P], g[:P], h[:P]


_direction_xla = jax.jit(ref.pcdn_direction_ref, static_argnames=("l2",))


def pcdn_direction(XB: Array, u: Array, v: Array, w_B: Array,
                   l2: float = 0.0, block_s: int | None = None,
                   block_p: int | None = None, impl: str | None = None):
    """Fused bundle direction. XB (s, P) any float dtype -> (d, g, h) (P,)."""
    s, P = XB.shape
    cfg = autotune.resolve(
        "pcdn_direction", autotune.shape_bucket(s=s, p=P), XB.dtype,
        {"impl": impl, "block_s": block_s, "block_p": block_p})
    with _launch_span("pcdn_direction", cfg["impl"]):
        if cfg["impl"] == "xla":
            return _direction_xla(XB, u, v, w_B, l2=l2)
        return _direction_pallas(XB, u, v, w_B, l2=l2,
                                 block_s=cfg["block_s"],
                                 block_p=cfg["block_p"])


# ---------------------------------------------------------------------------
# pcdn_sparse_direction


@functools.partial(jax.jit,
                   static_argnames=("l2", "block_p", "block_k"))
def _sparse_direction_pallas(rows: Array, vals: Array, u: Array, v: Array,
                             w_B: Array, l2: float, block_p: int,
                             block_k: int | None):
    """Pads P to a tile multiple; padded features carry sentinel rows
    (gather fills 0) and w = 0, so g = 0 -> d = 0, and are sliced away.
    The k axis is padded inside the raw launch when tiled."""
    P, _ = rows.shape
    s = u.shape[0]
    bp = min(block_p, max(8, P))
    rowsp = _pad_to(rows, 0, bp, value=s)
    valsp = _pad_to(vals, 0, bp)
    wp = _pad_to(w_B, 0, bp)
    d, g, h = pcdn_sparse_direction_kernel(rowsp, valsp, u, v, wp, l2=l2,
                                           block_p=bp, block_k=block_k,
                                           interpret=interpret_mode())
    return d[:P], g[:P], h[:P]


_sparse_direction_xla = jax.jit(ref.pcdn_sparse_direction_ref,
                                static_argnames=("l2",))


def pcdn_sparse_direction(rows: Array, vals: Array, u: Array, v: Array,
                          w_B: Array, l2: float = 0.0,
                          block_p: int | None = None,
                          block_k: int | None = None,
                          impl: str | None = None):
    """Fused sparse bundle direction over the padded-CSC slab layout.

    rows/vals (P, k_max) from PaddedCSCDesign.gather_slab -> (d, g, h),
    each (P,) float32. vals may be bf16 storage (in-kernel f32 upcast).
    """
    P, K = rows.shape
    s = u.shape[0]
    cfg = autotune.resolve(
        "pcdn_sparse_direction", autotune.shape_bucket(p=P, k=K, s=s),
        vals.dtype,
        {"impl": impl, "block_p": block_p, "block_k": block_k})
    with _launch_span("pcdn_sparse_direction", cfg["impl"]):
        if cfg["impl"] == "xla":
            return _sparse_direction_xla(rows, vals, u, v, w_B, l2=l2)
        return _sparse_direction_pallas(rows, vals, u, v, w_B, l2=l2,
                                        block_p=cfg["block_p"],
                                        block_k=cfg["block_k"])


# ---------------------------------------------------------------------------
# pcdn_linesearch


@functools.partial(jax.jit, static_argnames=("kind", "block_s"))
def _linesearch_pallas(z: Array, delta: Array, y: Array, alphas: Array,
                       kind: str, block_s: int) -> Array:
    """Pads s; padding contributes 0 because z = delta = y = 0 rows give
    phi(z+a*d) - phi(z) = 0."""
    s = z.shape[0]
    bs = min(block_s, max(8, s))
    zp = _pad_to(z, 0, bs)
    dp = _pad_to(delta, 0, bs)
    yp = _pad_to(y, 0, bs)
    return pcdn_linesearch_kernel(zp, dp, yp, alphas, kind=kind,
                                  block_s=bs, interpret=interpret_mode())


_linesearch_xla = jax.jit(ref.pcdn_linesearch_ref,
                          static_argnames=("kind",))


def pcdn_linesearch(z: Array, delta: Array, y: Array, alphas: Array,
                    kind: str = "logistic", block_s: int | None = None,
                    impl: str | None = None) -> Array:
    """Batched candidate loss deltas (Q,)."""
    s = z.shape[0]
    cfg = autotune.resolve(
        "pcdn_linesearch", autotune.shape_bucket(s=s, q=alphas.shape[0]),
        z.dtype, {"impl": impl, "block_s": block_s})
    with _launch_span("pcdn_linesearch", cfg["impl"]):
        if cfg["impl"] == "xla":
            return _linesearch_xla(z, delta, y, alphas, kind=kind)
        return _linesearch_pallas(z, delta, y, alphas, kind=kind,
                                  block_s=cfg["block_s"])


# ---------------------------------------------------------------------------
# pcdn_bundle


@functools.partial(
    jax.jit, static_argnames=("kind", "l2", "sigma", "gamma", "block_q"))
def _bundle_pallas(vals: Array, pos: Array, z_R: Array, y_R: Array,
                   w_B: Array, alphas: Array, c, kind: str, l2: float,
                   sigma: float, gamma: float, block_q: int | None):
    """Pads P and r_max to lane multiples: padded features carry vals = 0
    and w = 0 (d = 0, no l1/Delta contribution), padded support slots
    z = 0 / y = 1 / delta = 0 (loss delta exactly 0). pos is NOT
    re-targeted — padded slab entries keep pointing at real slots with
    value 0."""
    P, _ = vals.shape
    R = z_R.shape[0]
    valsp = _pad_to(vals, 0, 8)
    posp = _pad_to(pos, 0, 8, value=0)
    wp = _pad_to(w_B, 0, 8)
    zp = _pad_to(z_R, 0, 128)
    yp = _pad_to(y_R, 0, 128, value=1.0)
    upd_w, upd_z, alpha, q = pcdn_bundle_kernel(
        valsp, posp, zp, yp, wp, alphas, c, kind=kind, l2=l2,
        sigma=sigma, gamma=gamma, block_q=block_q,
        interpret=interpret_mode())
    return upd_w[:P], upd_z[:R], alpha, q


_bundle_xla = jax.jit(ref.pcdn_bundle_ref,
                      static_argnames=("kind", "l2", "sigma", "gamma"))


def pcdn_bundle(vals: Array, pos: Array, z_R: Array, y_R: Array,
                w_B: Array, alphas: Array, c,
                kind: str = "logistic", l2: float = 0.0,
                sigma: float = 0.01, gamma: float = 0.0,
                block_q: int | None = None, impl: str | None = None):
    """Fused support-restricted bundle step (DESIGN.md section 11).

    vals/pos (P, k_max) from `PaddedCSCDesign.gather_slab` +
    `slab_row_support`; z_R/y_R (r_max,) margins and labels gathered at
    the support rows (sentinel slots: z = 0, y = 1); alphas (Q,); `c`
    may be a traced scalar (path sweeps). vals may be bf16 storage
    (in-kernel f32 upcast). Returns (upd_w (P,), upd_z (r_max,), alpha,
    n_steps) with upd_* pre-scaled by the accepted alpha — the caller
    only scatters them at the bundle indices / support rows.

    The default single-program launch keeps the whole (Q, r_max)
    candidate grid in VMEM (~2M f32 cap); a tuned block_q tiles the
    candidate axis and lifts that cap (kernels/pcdn_bundle).
    """
    P, K = vals.shape
    cfg = autotune.resolve(
        "pcdn_bundle",
        autotune.shape_bucket(p=P, k=K, r=z_R.shape[0], q=alphas.shape[0]),
        vals.dtype, {"impl": impl, "block_q": block_q})
    with _launch_span("pcdn_bundle", cfg["impl"]):
        if cfg["impl"] == "xla":
            return _bundle_xla(vals, pos, z_R, y_R, w_B, alphas, c,
                               kind=kind, l2=l2, sigma=sigma, gamma=gamma)
        return _bundle_pallas(vals, pos, z_R, y_R, w_B, alphas, c,
                              kind=kind, l2=l2, sigma=sigma, gamma=gamma,
                              block_q=cfg["block_q"])


# ---------------------------------------------------------------------------
# serving margins


@functools.partial(jax.jit, static_argnames=("block_b", "block_a"))
def _margins_dense_pallas(X: Array, idx: Array, val: Array, block_b: int,
                          block_a: int | None) -> Array:
    """Pads B to a tile multiple with zero rows (margins sliced away)."""
    B, _ = X.shape
    bb = min(block_b, max(8, B))
    Xp = _pad_to(X, 0, bb)
    z = serve_margins_dense_kernel(Xp, idx, val, block_b=bb,
                                   block_a=block_a,
                                   interpret=interpret_mode())
    return z[:B]


_margins_dense_xla = jax.jit(ref.serve_margins_dense_ref)


def serve_margins_dense(X: Array, idx: Array, val: Array,
                        block_b: int | None = None,
                        block_a: int | None = None,
                        impl: str | None = None) -> Array:
    """Serving margins over a dense request slab (DESIGN.md section 10.3).

    X (B, n), idx/val (K, A) stacked model active sets with sentinel
    idx == n -> (B, K) float32. X and val may be bf16 storage.
    """
    B, n = X.shape
    K, A = idx.shape
    cfg = autotune.resolve(
        "serve_margins_dense", autotune.shape_bucket(b=B, n=n, k=K, a=A),
        val.dtype, {"impl": impl, "block_b": block_b, "block_a": block_a})
    with _launch_span("serve_margins_dense", cfg["impl"]):
        if cfg["impl"] == "xla":
            return _margins_dense_xla(X, idx, val)
        return _margins_dense_pallas(X, idx, val, block_b=cfg["block_b"],
                                     block_a=cfg["block_a"])


@functools.partial(jax.jit, static_argnames=("n_requests",))
def _margins_csc_pallas(col_rows: Array, col_vals: Array, idx: Array,
                        val: Array, n_requests: int) -> Array:
    return serve_margins_csc_kernel(col_rows, col_vals, idx, val,
                                    n_requests=n_requests,
                                    interpret=interpret_mode())


_margins_csc_xla = jax.jit(ref.serve_margins_csc_ref,
                           static_argnames=("n_requests",))


def serve_margins_csc(col_rows: Array, col_vals: Array, idx: Array,
                      val: Array, n_requests: int,
                      impl: str | None = None) -> Array:
    """Serving margins over a padded-CSC request batch.

    col_rows/col_vals (n, k_max) feature-major request layout (sentinel
    row id == n_requests), idx/val (K, A) -> (n_requests, K) float32.
    No padding needed: the grid is over models and the scatter output is
    already request-shaped. col_vals/val may be bf16 storage.
    """
    n, k_max = col_rows.shape
    K, A = idx.shape
    cfg = autotune.resolve(
        "serve_margins_csc",
        autotune.shape_bucket(n=n, kmax=k_max, k=K, a=A, b=n_requests),
        val.dtype, {"impl": impl})
    with _launch_span("serve_margins_csc", cfg["impl"]):
        if cfg["impl"] == "xla":
            return _margins_csc_xla(col_rows, col_vals, idx, val,
                                    n_requests=n_requests)
        return _margins_csc_pallas(col_rows, col_vals, idx, val,
                                   n_requests=n_requests)


# ---------------------------------------------------------------------------
# attention with flash forward + recompute backward


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q: Array, k: Array, v: Array, causal: bool = True,
                    sm_scale: float | None = None) -> Array:
    """q: (BH, Sq, D), k/v: (BH, Skv, D) -> (BH, Sq, D)."""
    return _flash_fwd_impl(q, k, v, causal, sm_scale)


def _flash_fwd_impl(q, k, v, causal, sm_scale):
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    bq = min(128, max(8, Sq))
    bk = min(128, max(8, Skv))
    qp = _pad_to(q, 1, bq)
    kp = _pad_to(k, 1, bk)
    vp = _pad_to(v, 1, bk)
    # padded kv columns must not attend: causal mask handles the q side;
    # for kv we rely on padded k rows producing score 0*scale at m==0 —
    # instead mask explicitly by pushing padded keys to -inf via a huge
    # negative first component trick is brittle, so pad k with zeros and
    # mask via length: simplest correct route is slicing when no padding
    # was needed, else fall back to masked reference.
    if qp.shape[1] != Sq or kp.shape[1] != Skv:
        return ref.attention_ref(q, k, v, causal=causal, sm_scale=sm_scale)
    out = flash_attention_kernel(qp, kp, vp, causal=causal,
                                 sm_scale=sm_scale, block_q=bq, block_k=bk,
                                 interpret=interpret_mode())
    return out[:, :Sq]


def _flash_fwd(q, k, v, causal, sm_scale):
    return _flash_fwd_impl(q, k, v, causal, sm_scale), (q, k, v)


def _flash_bwd(causal, sm_scale, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.attention_ref(q_, k_, v_, causal=causal,
                                             sm_scale=sm_scale), q, k, v)
    return vjp(g)


flash_attention.defvjp(_flash_fwd, _flash_bwd)
