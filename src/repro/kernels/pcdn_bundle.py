"""Pallas TPU kernel: the fused support-restricted PCDN bundle step.

One launch per bundle replaces the previous 3-kernel + 2 dense-vector
round-trip sequence (sparse direction kernel -> dense (s,) slab_matvec ->
line-search kernel -> dense (s,) z update). Working entirely on the
bundle's row support (DESIGN.md section 11), the kernel:

    1. forms u_R = c * dphi(z_R), v_R = c * d2phi(z_R) at the (r_max,)
       support rows (NOT the (s,) margin vector),
    2. reduces g_j = sum_k u_R[pos_jk] * vals_jk and the Hessian
       diagonal over the (P, k_max) slab,
    3. applies the Eq. 5 soft-threshold epilogue -> d and the Eq. 7
       Armijo decrement Delta,
    4. scatter-adds the support-compressed margin delta
       delta_R = (X_B d_B)[support],
    5. evaluates the Q Armijo candidates on the (Q, r_max) support grid
       (loss + l1 + optional elastic-net parts).

Every intermediate between the slab read and the update emission stays
in VMEM — no HBM round trip of a (P,)-direction or an (s,) margin delta
between launches, which is the section 3.1 "minimize data transfer and
synchronization" argument applied to the whole bundle step. Total work
is O(P * k_max * Q): independent of the sample count s.

The candidate axis is TILEABLE (`block_q`, DESIGN.md section 12): with
grid=(Q_tiles,), each program recomputes the cheap deterministic steps
1-4 (O(P * k_max), bitwise identical across programs — the d / delta /
Delta output blocks have constant index maps and every program writes
the same values) and evaluates only its (block_q, r_max) slice of the
candidate grid, capping the largest VMEM intermediate at
block_q * r_max instead of Q * r_max. The first-satisfying-alpha
selection (previous in-kernel step 5b) now runs as a tiny XLA epilogue
over the (Q,) f_deltas — the same math on the same f32 values, so the
accepted alpha is unchanged for every block_q including the
single-program default (block_q=None reproduces the old launch
exactly).

Slab values may arrive in bf16 storage (mixed-precision mode): they are
upcast to f32 INSIDE the kernel, so all reductions and the candidate
grid accumulate in f32 while the HBM->VMEM slab transfer moves half the
bytes.

The support gather itself (z_R = z[support], y_R = y[support]) runs as
an XLA gather feeding the launch: a VMEM-resident (s,) operand with a
constant index map — how the unfused kernels hold u/v — would
reintroduce the O(s) per-launch transfer this kernel exists to
eliminate. Moving that gather in-kernel needs scalar-prefetched DMA
from HBM (PrefetchScalarGridSpec) and is the documented follow-up.

Scalars: `c` is TRACED (SMEM input) so one compiled step serves a whole
regularization-path sweep; l2/sigma/gamma/loss kind are static.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

HESSIAN_FLOOR = 1e-12


def _phi(kind: str, z, y):
    if kind == "logistic":
        m = -y * z
        return jnp.maximum(m, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(m)))
    if kind == "squared_hinge":
        return jnp.square(jnp.maximum(0.0, 1.0 - y * z))
    if kind == "squared":
        return 0.5 * jnp.square(z - y)
    raise ValueError(kind)


def _dphi(kind: str, z, y):
    if kind == "logistic":
        return (jax.nn.sigmoid(y * z) - 1.0) * y
    if kind == "squared_hinge":
        return -2.0 * y * jnp.maximum(0.0, 1.0 - y * z)
    if kind == "squared":
        return z - y
    raise ValueError(kind)


def _d2phi(kind: str, z, y):
    if kind == "logistic":
        t = jax.nn.sigmoid(y * z)
        return t * (1.0 - t)
    if kind == "squared_hinge":
        return 2.0 * (y * z < 1.0).astype(z.dtype)
    if kind == "squared":
        return jnp.ones_like(z)
    raise ValueError(kind)


def _kernel(vals_ref, pos_ref, zR_ref, yR_ref, w_ref, alphas_ref, c_ref,
            d_ref, delta_ref, Delta_ref, fd_ref, *,
            kind: str, l2: float, gamma: float):
    z = zR_ref[0, :]                       # (R,) support margins
    yv = yR_ref[0, :]                      # (R,)
    c = c_ref[0, 0]
    # step 1: per-sample factors at the support rows only
    u = c * _dphi(kind, z, yv)
    v = c * _d2phi(kind, z, yv)
    # step 2: slab reductions through the support positions (in-bounds by
    # construction; padding entries carry value 0). bf16 storage upcasts
    # here — every reduction below accumulates in f32.
    pos = pos_ref[...]                     # (P, K) int32
    vals = vals_ref[...].astype(jnp.float32)
    ug = jnp.take(u, pos)
    vg = jnp.take(v, pos)
    w = w_ref[0, :]                        # (P,)
    g = jnp.sum(ug * vals, axis=1) + l2 * w
    h = jnp.maximum(jnp.sum(vg * vals * vals, axis=1) + l2, HESSIAN_FLOOR)
    # step 3: Eq. 5 soft-threshold Newton direction + Eq. 7 decrement
    d = jnp.where(g + 1.0 <= h * w, -(g + 1.0) / h,
                  jnp.where(g - 1.0 >= h * w, -(g - 1.0) / h, -w))
    Delta = (jnp.sum(g * d) + gamma * jnp.sum(h * d * d) +
             jnp.sum(jnp.abs(w + d)) - jnp.sum(jnp.abs(w)))
    # step 4: support-compressed margin delta (scatter within VMEM)
    delta = jnp.zeros_like(z).at[pos].add(vals * d[:, None])
    # step 5: this program's tile of Armijo candidates on the
    # (block_q, R) support grid
    alphas = alphas_ref[...]               # (BQ, 1)
    zq = z[None, :] + alphas * delta[None, :]
    lo = c * jnp.sum(_phi(kind, zq, yv[None, :]) -
                     _phi(kind, z, yv)[None, :], axis=1)      # (BQ,)
    wq = w[None, :] + alphas * d[None, :]
    f_deltas = lo + jnp.sum(jnp.abs(wq), axis=1) - jnp.sum(jnp.abs(w))
    if l2:
        f_deltas = f_deltas + 0.5 * l2 * (jnp.sum(jnp.square(wq), axis=1) -
                                          jnp.sum(jnp.square(w)))
    # deterministic recompute: every program writes the same d/delta/Delta
    # into the constant-index-map blocks; fd is the per-tile output
    d_ref[0, :] = d
    delta_ref[0, :] = delta
    Delta_ref[0, 0] = Delta
    fd_ref[:, 0] = f_deltas


def pcdn_bundle_kernel(
    vals: Array, pos: Array, z_R: Array, y_R: Array, w_B: Array,
    alphas: Array, c: Array,
    kind: str = "logistic", l2: float = 0.0, sigma: float = 0.01,
    gamma: float = 0.0, block_q: int | None = None, interpret: bool = True,
):
    """Raw launch. vals/pos (P, K); z_R/y_R (R,); w_B (P,); alphas (Q,);
    c a scalar (may be traced). vals may be bf16 (in-kernel upcast).
    block_q=None runs the whole candidate grid in one program (the
    pre-autotuner behavior); block_q=b tiles it into ceil(Q/b) programs.
    Returns (upd_w (P,), upd_z (R,), alpha scalar, n_steps int32 scalar)
    — upd_* already scaled by the accepted alpha."""
    P, K = vals.shape
    R = z_R.shape[0]
    Q = alphas.shape[0]
    bq = Q if block_q is None else max(1, min(int(block_q), Q))
    n_q = -(-Q // bq)
    Qp = n_q * bq
    alphas_f = alphas.astype(jnp.float32)
    # alpha = 0 padding candidates give f_delta = 0; sliced away before
    # the selection epilogue, so they can never be picked
    alphas_p = jnp.pad(alphas_f, (0, Qp - Q))
    kernel = functools.partial(_kernel, kind=kind, l2=float(l2),
                               gamma=float(gamma))
    out_shape = [
        jax.ShapeDtypeStruct((1, P), jnp.float32),     # d
        jax.ShapeDtypeStruct((1, R), jnp.float32),     # delta
        jax.ShapeDtypeStruct((1, 1), jnp.float32),     # Delta
        jax.ShapeDtypeStruct((Qp, 1), jnp.float32),    # f_deltas
    ]
    d, delta, Delta, fd = pl.pallas_call(
        kernel,
        grid=(n_q,),
        in_specs=[
            pl.BlockSpec((P, K), lambda i: (0, 0)),        # vals
            pl.BlockSpec((P, K), lambda i: (0, 0)),        # pos
            pl.BlockSpec((1, R), lambda i: (0, 0)),        # z_R
            pl.BlockSpec((1, R), lambda i: (0, 0)),        # y_R
            pl.BlockSpec((1, P), lambda i: (0, 0)),        # w_B
            pl.BlockSpec((bq, 1), lambda i: (i, 0)),       # alpha tile
            pl.BlockSpec(memory_space=pltpu.SMEM),         # c (traced)
        ],
        out_specs=[
            pl.BlockSpec((1, P), lambda i: (0, 0)),
            pl.BlockSpec((1, R), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((bq, 1), lambda i: (i, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(vals, pos,
      z_R.reshape(1, R).astype(jnp.float32),
      y_R.reshape(1, R).astype(jnp.float32),
      w_B.reshape(1, P).astype(jnp.float32),
      alphas_p.reshape(Qp, 1),
      jnp.asarray(c, jnp.float32).reshape(1, 1))
    # selection epilogue (the previous in-kernel step 5b, same f32 math):
    # first candidate with f_delta <= sigma * alpha * Delta
    d = d.reshape(P)
    delta = delta.reshape(R)
    Delta = Delta.reshape(())
    f_deltas = fd.reshape(Qp)[:Q]
    ok = f_deltas <= sigma * alphas_f * Delta
    first = jnp.argmax(ok)
    alpha = jnp.where(jnp.any(ok), alphas_f[first], 0.0)
    return (alpha * d, alpha * delta, alpha,
            jnp.asarray(first + 1, jnp.int32))
