"""Pallas TPU kernel: fused PCDN bundle direction (DESIGN.md section 3.1).

For a dense bundle slab X_B (s, P) and per-sample factors u = c*dphi/dz,
v = c*d2phi/dz2 this computes, in ONE pass over X_B:

    g_j = sum_i u_i X_ij            (bundle gradient,   Eq. 12 first line)
    h_j = max(sum_i v_i X_ij^2, nu) (diag Hessian,      Eq. 12 second line)
    d_j = Eq. 5 soft-threshold Newton direction

The slab is read from HBM once; the three reductions + the elementwise
epilogue run out of VMEM. The un-fused jnp path reads X_B twice (g then h).
Grid = (P_tiles, s_tiles) with the sample dimension innermost so partial
(g, h) accumulate in VMEM scratch across s-tiles; the epilogue fires on the
last s-tile. MXU alignment: block shapes are (BS, BP) = (512, 128) by
default — both multiples of the 128-lane register tiling; the two
reductions are expressed as (1, BS) @ (BS, BP) matmuls so they map onto the
MXU rather than the VPU reduction tree.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_BLOCK_S = 512
DEFAULT_BLOCK_P = 128
HESSIAN_FLOOR = 1e-12


def _kernel(xb_ref, u_ref, v_ref, w_ref, l2_ref,
            d_ref, g_ref, h_ref, acc_g, acc_h, *, n_s_tiles: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_g[...] = jnp.zeros_like(acc_g)
        acc_h[...] = jnp.zeros_like(acc_h)

    # bf16 storage upcasts here; both reductions accumulate in f32
    xb = xb_ref[...].astype(jnp.float32)  # (BS, BP)
    u = u_ref[...]                        # (1, BS)
    v = v_ref[...]                        # (1, BS)
    # (1, BS) @ (BS, BP) -> (1, BP): MXU-shaped reductions over samples.
    acc_g[...] += jnp.dot(u, xb, preferred_element_type=jnp.float32)
    acc_h[...] += jnp.dot(v, xb * xb, preferred_element_type=jnp.float32)

    @pl.when(k == n_s_tiles - 1)
    def _epilogue():
        w = w_ref[...]                    # (1, BP)
        l2 = l2_ref[0, 0]
        g = acc_g[...] + l2 * w
        h = jnp.maximum(acc_h[...] + l2, HESSIAN_FLOOR)
        # Eq. 5 closed form
        d_neg = -(g + 1.0) / h
        d_pos = -(g - 1.0) / h
        d = jnp.where(g + 1.0 <= h * w, d_neg,
                      jnp.where(g - 1.0 >= h * w, d_pos, -w))
        d_ref[...] = d
        g_ref[...] = g
        h_ref[...] = h


def pcdn_direction_kernel(
    XB: Array, u: Array, v: Array, w_B: Array,
    l2: float = 0.0,
    block_s: int = DEFAULT_BLOCK_S,
    block_p: int = DEFAULT_BLOCK_P,
    interpret: bool = True,
):
    """Raw kernel launch. Shapes must already be tile-aligned:
    XB (s, P) with s % block_s == 0 and P % block_p == 0.
    Returns (d, g, h), each (P,) float32.
    """
    s, P = XB.shape
    assert s % block_s == 0 and P % block_p == 0, (s, P, block_s, block_p)
    n_s = s // block_s
    n_p = P // block_p
    u2 = u.reshape(1, s).astype(jnp.float32)
    v2 = v.reshape(1, s).astype(jnp.float32)
    w2 = w_B.reshape(1, P).astype(jnp.float32)
    l2a = jnp.full((1, 1), l2, jnp.float32)

    kernel = functools.partial(_kernel, n_s_tiles=n_s)
    out_shape = [jax.ShapeDtypeStruct((1, P), jnp.float32)] * 3
    d, g, h = pl.pallas_call(
        kernel,
        grid=(n_p, n_s),
        in_specs=[
            pl.BlockSpec((block_s, block_p), lambda i, k: (k, i)),  # XB
            pl.BlockSpec((1, block_s), lambda i, k: (0, k)),        # u
            pl.BlockSpec((1, block_s), lambda i, k: (0, k)),        # v
            pl.BlockSpec((1, block_p), lambda i, k: (0, i)),        # w_B
            pl.BlockSpec(memory_space=pltpu.SMEM),                  # l2
        ],
        out_specs=[
            pl.BlockSpec((1, block_p), lambda i, k: (0, i)),
            pl.BlockSpec((1, block_p), lambda i, k: (0, i)),
            pl.BlockSpec((1, block_p), lambda i, k: (0, i)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, block_p), jnp.float32),
            pltpu.VMEM((1, block_p), jnp.float32),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(XB, u2, v2, w2, l2a)
    return d.reshape(P), g.reshape(P), h.reshape(P)
