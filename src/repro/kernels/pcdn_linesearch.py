"""Pallas TPU kernel: batched multi-candidate Armijo evaluation.

The paper's Algorithm 4 backtracks sequentially (q = 0, 1, 2, ...), each
step touching the per-sample intermediates. On TPU that is a chain of tiny
launches + host syncs, so we instead evaluate ALL Q candidates
alpha_q = beta^q in one pass (DESIGN.md section 3.2):

    out[q] = sum_i  phi(z_i + alpha_q * delta_i, y_i) - phi(z_i, y_i)

Grid = (s_tiles,); each tile loads (z, delta, y) slices once into VMEM,
broadcasts against the (Q,) candidate vector held in VMEM across the whole
launch, and accumulates the (1, Q) partial sums in scratch. The l1 part of
Eq. 11 is P-dimensional and trivially cheap — the jit wrapper adds it
outside. Loss selection is static (logistic / squared_hinge / squared).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_BLOCK_S = 1024


def _phi(kind: str, z, y):
    if kind == "logistic":
        m = -y * z
        return jnp.maximum(m, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(m)))
    if kind == "squared_hinge":
        return jnp.square(jnp.maximum(0.0, 1.0 - y * z))
    if kind == "squared":
        return 0.5 * jnp.square(z - y)
    raise ValueError(kind)


def _kernel(z_ref, delta_ref, y_ref, alphas_ref, out_ref, acc,
            *, kind: str, n_s_tiles: int):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    z = z_ref[...]            # (1, BS)
    dlt = delta_ref[...]      # (1, BS)
    y = y_ref[...]            # (1, BS)
    alphas = alphas_ref[...]  # (Q, 1)
    zq = z + alphas * dlt     # (Q, BS) broadcast
    vals = _phi(kind, zq, y) - _phi(kind, z, y)
    acc[...] += jnp.sum(vals, axis=1, keepdims=True)  # (Q, 1)

    @pl.when(k == n_s_tiles - 1)
    def _write():
        out_ref[...] = acc[...]


def pcdn_linesearch_kernel(
    z: Array, delta: Array, y: Array, alphas: Array,
    kind: str = "logistic",
    block_s: int = DEFAULT_BLOCK_S,
    interpret: bool = True,
) -> Array:
    """Raw launch. z, delta, y: (s,) with s % block_s == 0; alphas: (Q,).
    Returns (Q,) float32 loss deltas (caller scales by c, adds l1 part)."""
    s = z.shape[0]
    Q = alphas.shape[0]
    assert s % block_s == 0, (s, block_s)
    n_s = s // block_s

    kernel = functools.partial(_kernel, kind=kind, n_s_tiles=n_s)
    out = pl.pallas_call(
        kernel,
        grid=(n_s,),
        in_specs=[
            pl.BlockSpec((1, block_s), lambda k: (0, k)),
            pl.BlockSpec((1, block_s), lambda k: (0, k)),
            pl.BlockSpec((1, block_s), lambda k: (0, k)),
            pl.BlockSpec((Q, 1), lambda k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((Q, 1), lambda k: (0, 0)),
        scratch_shapes=[pltpu.VMEM((Q, 1), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((Q, 1), jnp.float32),
        interpret=interpret,
    )(z.reshape(1, s).astype(jnp.float32),
      delta.reshape(1, s).astype(jnp.float32),
      y.reshape(1, s).astype(jnp.float32),
      alphas.reshape(Q, 1).astype(jnp.float32))
    return out.reshape(Q)
