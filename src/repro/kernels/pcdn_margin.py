"""Pallas TPU kernels: batched serving margins over sparse models.

Scoring side of the repo (DESIGN.md section 10.3). A served l1 model is
its active set — indices ``idx`` and values ``val`` of the nonzero
weights, padded to a static width A with sentinel ``idx == n`` — and a
request batch arrives in one of two layouts. Both kernels touch ONLY the
active coordinates of each model, which is where the serving speedup
comes from: work is O(A) per request instead of O(n), and solutions on
the paper's datasets are >= 99% sparse.

Dense request layout  — X (B, n) row-major request slab:

    z[b, k] = sum_a val[k, a] * X[b, idx[k, a]]

  Grid (K, B_tiles, A_tiles): each program owns one model's (idx, val)
  tile and a (block_b, n) request tile; the gather X[:, idx] and the
  (BB, BA) x (BA,) contraction run out of VMEM, accumulating into a
  resident (block_b, 1) column of z (constant index map along the a
  axis, the fastest grid axis: zero-init at a == 0, partial dot per
  tile). block_a=None keeps the original whole-active-width single
  tile; tiling caps the gather window for wide models (DESIGN.md
  section 12).

Padded-CSC request layout — the repo's feature-major sparse layout
(col_rows/col_vals of the REQUEST matrix, sentinel row id == B):

    z[:, k] = sum_a val[k, a] * X_csc[:, idx[k, a]]     (scatter-add)

  Grid (K,): gather the model's active columns from the resident
  (n, k_max) arrays, scale by val, scatter-add into the (B,) margin
  vector — the exact serving-side mirror of the solver's
  ``slab_matvec`` bundle update. Work is O(A * k_max) per model,
  independent of both B density and n.

Model values and request slabs may arrive in bf16 storage
(mixed-precision serve banks): both kernels upcast INSIDE the kernel,
so every contraction accumulates in f32.

Sentinel handling matches the direction kernels: model padding slots
(idx == n) gather out of bounds and fill 0 (dense) or scatter out of
bounds and drop (sparse), so padding contributes exactly nothing.
VMEM residency caps (n * k_max and block_b * n) follow the same
scalar-prefetch follow-up note as kernels/pcdn_sparse_direction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

DEFAULT_BLOCK_B = 128


def _dense_kernel(x_ref, idx_ref, val_ref, z_ref, *, n_a: int):
    a = pl.program_id(2)
    idx = idx_ref[0, :]                    # (BA,) int32, sentinel == n
    val = val_ref[0, :].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)     # (BB, n) request tile
    # OOB sentinel columns fill 0 -> padding contributes nothing
    xg = jnp.take(x, idx, axis=1, mode="fill", fill_value=0.0)
    part = jnp.dot(xg, val, preferred_element_type=jnp.float32)

    @pl.when(a == 0)
    def _init():
        z_ref[:, 0] = jnp.zeros_like(part)

    z_ref[:, 0] += part


def serve_margins_dense_kernel(X: Array, idx: Array, val: Array,
                               block_b: int = DEFAULT_BLOCK_B,
                               block_a: int | None = None,
                               interpret: bool = True) -> Array:
    """Raw launch. X (B, n) with B % block_b == 0, idx/val (K, A).
    block_a=None contracts each model's whole active width in one tile;
    block_a=b tiles it (A padded with sentinel idx / zero val here).
    Returns margins (B, K) float32."""
    B, n = X.shape
    K, A = idx.shape
    assert B % block_b == 0, (B, block_b)
    ba = A if block_a is None else max(1, min(int(block_a), A))
    n_a = -(-A // ba)
    Ap = n_a * ba
    if Ap != A:
        idx = jnp.pad(idx, ((0, 0), (0, Ap - A)), constant_values=n)
        val = jnp.pad(val, ((0, 0), (0, Ap - A)))
    z = pl.pallas_call(
        functools.partial(_dense_kernel, n_a=n_a),
        grid=(K, B // block_b, n_a),
        in_specs=[
            pl.BlockSpec((block_b, n), lambda k, j, a: (j, 0)),   # X tile
            pl.BlockSpec((1, ba), lambda k, j, a: (k, a)),        # idx
            pl.BlockSpec((1, ba), lambda k, j, a: (k, a)),        # val
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda k, j, a: (j, k)),
        out_shape=jax.ShapeDtypeStruct((B, K), jnp.float32),
        interpret=interpret,
    )(X, idx, val)
    return z


def _csc_kernel(rows_ref, vals_ref, idx_ref, val_ref, z_ref, *,
                n_requests: int):
    idx = idx_ref[0, :]                    # (A,) sentinel == n
    val = val_ref[0, :].astype(jnp.float32)
    # gather the model's active request-matrix columns; sentinel models
    # fill row id == n_requests (dropped by the scatter) and value 0
    rows = jnp.take(rows_ref[...], idx, axis=0, mode="fill",
                    fill_value=n_requests)                     # (A, k_max)
    vals = jnp.take(vals_ref[...].astype(jnp.float32), idx, axis=0,
                    mode="fill", fill_value=0.0)               # (A, k_max)
    contrib = vals * val[:, None]
    z = jnp.zeros((n_requests,), jnp.float32)
    z_ref[0, :] = z.at[rows].add(contrib, mode="drop")


def serve_margins_csc_kernel(col_rows: Array, col_vals: Array, idx: Array,
                             val: Array, n_requests: int,
                             interpret: bool = True) -> Array:
    """Raw launch over a padded-CSC request batch.

    col_rows/col_vals (n, k_max) with sentinel row id == n_requests;
    idx/val (K, A) with sentinel idx == n. Returns margins
    (n_requests, K) float32.
    """
    n, k_max = col_rows.shape
    K, A = idx.shape
    kern = functools.partial(_csc_kernel, n_requests=int(n_requests))
    z = pl.pallas_call(
        kern,
        grid=(K,),
        in_specs=[
            pl.BlockSpec((n, k_max), lambda k: (0, 0)),        # resident
            pl.BlockSpec((n, k_max), lambda k: (0, 0)),        # resident
            pl.BlockSpec((1, A), lambda k: (k, 0)),
            pl.BlockSpec((1, A), lambda k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((1, n_requests), lambda k: (k, 0)),
        out_shape=jax.ShapeDtypeStruct((K, n_requests), jnp.float32),
        interpret=interpret,
    )(col_rows, col_vals, idx, val)
    return z.T
