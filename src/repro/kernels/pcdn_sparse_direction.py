"""Pallas TPU kernel: fused PCDN bundle direction, padded-CSC layout.

Sparse sibling of kernels/pcdn_direction (DESIGN.md section 7.3). For a
bundle's padded column slab — rows (P, k_max) int32 with sentinel == s at
padding slots, vals (P, k_max) float — and per-sample factors
u = c*dphi/dz, v = c*d2phi/dz2 this computes, in ONE pass over the slab:

    g_j = sum_k u[rows_jk] * vals_jk          (bundle gradient, Eq. 12)
    h_j = max(sum_k v[rows_jk] * vals_jk^2, nu)
    d_j = Eq. 5 soft-threshold Newton direction

The slab is read once; the gather of u/v at rows, both reductions and the
elementwise epilogue all run out of VMEM. Work is O(P * k_max) instead of
the dense kernel's O(s * P) — the entire point of the sparse backend.

Grid = (P_tiles, K_tiles): each program owns a (BP, BK) tile of the slab
plus the whole u and v vectors, which stay resident in VMEM across tiles
(constant index map). The k axis is tileable (`block_k`, DESIGN.md
section 12): the g/h output blocks are resident across the inner k loop
(constant index map in k, the fastest grid axis), zero-initialized at
k == 0, accumulated per tile, and finalized (l2 fold, Hessian floor,
Eq. 5 direction) at the last k tile — so wide slabs no longer force a
(BP, k_max) VMEM window. block_k=None keeps the original whole-k_max
single-tile reduction. Slab values may arrive in bf16 storage: upcast
in-kernel, all accumulation in f32.

u/v residency caps s at VMEM scale (~2M f32 per vector); beyond that
the sample axis must move to an HBM-resident gather via
scalar-prefetched DMA (PrefetchScalarGridSpec) — documented follow-up,
not needed at the repro's scales. Rows are int32 and the gather is
expressed as `jnp.take(..., mode="fill", fill_value=0)`, so sentinel
(== s, out of bounds) slots contribute exactly 0 to both reductions.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

DEFAULT_BLOCK_P = 128
HESSIAN_FLOOR = 1e-12


def _kernel(rows_ref, vals_ref, u_ref, v_ref, w_ref, l2_ref,
            d_ref, g_ref, h_ref, *, n_k: int):
    j = pl.program_id(1)
    rows = rows_ref[...]                  # (BP, BK) int32
    vals = vals_ref[...].astype(jnp.float32)
    u = u_ref[0, :]                       # (s,) resident across tiles
    v = v_ref[0, :]
    # gather + masked segment reduction; OOB (sentinel) rows fill 0
    ug = jnp.take(u, rows, mode="fill", fill_value=0.0)
    vg = jnp.take(v, rows, mode="fill", fill_value=0.0)
    g_part = jnp.sum(ug * vals, axis=1)   # (BP,)
    h_part = jnp.sum(vg * vals * vals, axis=1)

    @pl.when(j == 0)
    def _init():
        g_ref[0, :] = jnp.zeros_like(g_part)
        h_ref[0, :] = jnp.zeros_like(h_part)

    g_ref[0, :] += g_part
    h_ref[0, :] += h_part

    @pl.when(j == n_k - 1)
    def _finalize():
        w = w_ref[0, :]                   # (BP,)
        l2 = l2_ref[0, 0]
        g = g_ref[0, :] + l2 * w
        h = jnp.maximum(h_ref[0, :] + l2, HESSIAN_FLOOR)
        # Eq. 5 closed form
        d_neg = -(g + 1.0) / h
        d_pos = -(g - 1.0) / h
        d_ref[0, :] = jnp.where(g + 1.0 <= h * w, d_neg,
                                jnp.where(g - 1.0 >= h * w, d_pos, -w))
        g_ref[0, :] = g
        h_ref[0, :] = h


def pcdn_sparse_direction_kernel(
    rows: Array, vals: Array, u: Array, v: Array, w_B: Array,
    l2: float = 0.0,
    block_p: int = DEFAULT_BLOCK_P,
    block_k: int | None = None,
    interpret: bool = True,
):
    """Raw kernel launch. rows/vals (P, K) with P % block_p == 0.
    block_k=None reduces the whole k_max axis in one tile; block_k=b
    tiles it (K is padded here: sentinel rows, zero vals — exactly the
    existing padding convention, so padding contributes 0). Returns
    (d, g, h), each (P,) float32.
    """
    P, K = rows.shape
    assert P % block_p == 0, (P, block_p)
    s = u.shape[0]
    bk = K if block_k is None else max(1, min(int(block_k), K))
    n_k = -(-K // bk)
    Kp = n_k * bk
    if Kp != K:
        rows = jnp.pad(rows, ((0, 0), (0, Kp - K)), constant_values=s)
        vals = jnp.pad(vals, ((0, 0), (0, Kp - K)))
    n_p = P // block_p
    u2 = u.reshape(1, s).astype(jnp.float32)
    v2 = v.reshape(1, s).astype(jnp.float32)
    w2 = w_B.reshape(1, P).astype(jnp.float32)
    l2a = jnp.full((1, 1), l2, jnp.float32)

    out_shape = [jax.ShapeDtypeStruct((1, P), jnp.float32)] * 3
    d, g, h = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=(n_p, n_k),
        in_specs=[
            pl.BlockSpec((block_p, bk), lambda i, j: (i, j)),   # rows
            pl.BlockSpec((block_p, bk), lambda i, j: (i, j)),   # vals
            pl.BlockSpec((1, s), lambda i, j: (0, 0)),          # u (resident)
            pl.BlockSpec((1, s), lambda i, j: (0, 0)),          # v (resident)
            pl.BlockSpec((1, block_p), lambda i, j: (0, i)),    # w_B
            pl.BlockSpec(memory_space=pltpu.SMEM),              # l2
        ],
        out_specs=[
            pl.BlockSpec((1, block_p), lambda i, j: (0, i)),
            pl.BlockSpec((1, block_p), lambda i, j: (0, i)),
            pl.BlockSpec((1, block_p), lambda i, j: (0, i)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(rows, vals, u2, v2, w2, l2a)
    return d.reshape(P), g.reshape(P), h.reshape(P)
