"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.direction import newton_direction
from repro.core.losses import HESSIAN_FLOOR, get_loss

Array = jax.Array


def pcdn_direction_ref(XB: Array, u: Array, v: Array, w_B: Array,
                       l2: float = 0.0):
    """(d, g, h) for a bundle slab — mirrors L1Problem.bundle_grad_hess +
    newton_direction, computed in float32."""
    XB = XB.astype(jnp.float32)
    g = XB.T @ u.astype(jnp.float32)
    h = jnp.square(XB).T @ v.astype(jnp.float32)
    g = g + l2 * w_B
    h = jnp.maximum(h + l2, HESSIAN_FLOOR)
    d = newton_direction(g, h, w_B.astype(jnp.float32))
    return d, g, h


def pcdn_sparse_direction_ref(rows: Array, vals: Array, u: Array, v: Array,
                              w_B: Array, l2: float = 0.0):
    """(d, g, h) for a padded-CSC slab (rows sentinel == len(u) drops)."""
    vals = vals.astype(jnp.float32)
    ug = jnp.take(u.astype(jnp.float32), rows, mode="fill", fill_value=0)
    vg = jnp.take(v.astype(jnp.float32), rows, mode="fill", fill_value=0)
    g = jnp.sum(ug * vals, axis=1) + l2 * w_B
    h = jnp.maximum(jnp.sum(vg * jnp.square(vals), axis=1) + l2,
                    HESSIAN_FLOOR)
    d = newton_direction(g, h, w_B.astype(jnp.float32))
    return d, g, h


def pcdn_bundle_ref(vals: Array, pos: Array, z_R: Array, y_R: Array,
                    w_B: Array, alphas: Array, c,
                    kind: str = "logistic", l2: float = 0.0,
                    sigma: float = 0.01, gamma: float = 0.0):
    """Oracle for the fused support-restricted bundle step
    (kernels/pcdn_bundle): the unfused pipeline — support-gathered
    factors -> g/h -> Eq. 5 direction -> Delta -> support-compressed
    margin delta -> batched Armijo — in plain f32 jnp. Returns
    (upd_w, upd_z, alpha, n_steps) matching the kernel."""
    loss = get_loss(kind)
    z_R = z_R.astype(jnp.float32)
    y_R = y_R.astype(jnp.float32)
    vals = vals.astype(jnp.float32)
    w_B = w_B.astype(jnp.float32)
    c = jnp.asarray(c, jnp.float32)
    u_R = c * loss.dz(z_R, y_R)
    v_R = c * loss.d2z(z_R, y_R)
    g = jnp.sum(jnp.take(u_R, pos) * vals, axis=1) + l2 * w_B
    h = jnp.maximum(jnp.sum(jnp.take(v_R, pos) * jnp.square(vals), axis=1)
                    + l2, HESSIAN_FLOOR)
    d = newton_direction(g, h, w_B)
    Delta = (jnp.sum(g * d) + gamma * jnp.sum(h * jnp.square(d)) +
             jnp.sum(jnp.abs(w_B + d)) - jnp.sum(jnp.abs(w_B)))
    delta_R = jnp.zeros_like(z_R).at[pos].add(vals * d[:, None])
    alphas = alphas.astype(jnp.float32)
    zq = z_R[None, :] + alphas[:, None] * delta_R[None, :]
    lo = c * jnp.sum(loss.value(zq, y_R[None, :]) -
                     loss.value(z_R, y_R)[None, :], axis=1)
    wq = w_B[None, :] + alphas[:, None] * d[None, :]
    f_deltas = lo + jnp.sum(jnp.abs(wq), axis=1) - jnp.sum(jnp.abs(w_B))
    if l2:
        f_deltas = f_deltas + 0.5 * l2 * (
            jnp.sum(jnp.square(wq), axis=1) - jnp.sum(jnp.square(w_B)))
    ok = f_deltas <= sigma * alphas * Delta
    first = jnp.argmax(ok)
    alpha = jnp.where(jnp.any(ok), alphas[first], 0.0)
    return (alpha * d, alpha * delta_R, alpha,
            jnp.asarray(first + 1, jnp.int32))


def pcdn_linesearch_ref(z: Array, delta: Array, y: Array, alphas: Array,
                        kind: str = "logistic") -> Array:
    """(Q,) per-candidate loss deltas: sum_i phi(z + a*delta) - phi(z)."""
    loss = get_loss(kind)
    z = z.astype(jnp.float32)
    zq = z[None, :] + alphas.astype(jnp.float32)[:, None] * \
        delta.astype(jnp.float32)[None, :]
    return jnp.sum(loss.value(zq, y[None, :]) - loss.value(z, y)[None, :],
                   axis=-1)


def serve_margins_dense_ref(X: Array, idx: Array, val: Array) -> Array:
    """(B, K) serving margins over a dense request slab: for each model k,
    gather only its active columns of X (sentinel idx == n fills 0) and
    contract with the active values — the jnp oracle of the dense-layout
    margin kernel AND the engine's own XLA sparse-gather scorer."""
    xg = jnp.take(X.astype(jnp.float32), idx, axis=1, mode="fill",
                  fill_value=0.0)                       # (B, K, A)
    return jnp.einsum("bka,ka->bk", xg, val.astype(jnp.float32))


def serve_margins_csc_ref(col_rows: Array, col_vals: Array, idx: Array,
                          val: Array, n_requests: int) -> Array:
    """(B, K) serving margins over a padded-CSC request batch: gather each
    model's active columns of the request matrix, scale, scatter-add over
    request rows (sentinels drop) — mirror of PaddedCSCDesign.slab_matvec."""
    def one(idx_k, val_k):
        rows = jnp.take(col_rows, idx_k, axis=0, mode="fill",
                        fill_value=n_requests)
        vals = jnp.take(col_vals.astype(jnp.float32), idx_k, axis=0,
                        mode="fill", fill_value=0.0)
        z = jnp.zeros((n_requests,), jnp.float32)
        return z.at[rows].add(vals * val_k[:, None].astype(jnp.float32),
                              mode="drop")

    return jax.vmap(one)(idx, val).T


def attention_ref(q: Array, k: Array, v: Array, causal: bool = True,
                  sm_scale: float | None = None) -> Array:
    """Dense softmax attention. q: (BH, Sq, D), k/v: (BH, Skv, D)."""
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * sm_scale
    if causal:
        qi = jnp.arange(Sq)[:, None]
        kj = jnp.arange(Skv)[None, :]
        s = jnp.where(qi >= kj, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
