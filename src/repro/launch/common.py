"""Shared CLI plumbing for `repro.launch.solve` and `repro.launch.path`.

One place defines the flags both drivers share — `--backend / --layout /
--shrink / --warm-start / --use-kernels` plus the solver stop knobs and
the mesh shape — and one place builds the solver configs and execution
backends from them, so the flags behave identically in both CLIs
(DESIGN.md section 9.4).
"""
from __future__ import annotations

import argparse
import json
import os

import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import PCDNConfig, make_problem
from repro.data import load_libsvm, paper_like
from repro.engine import LocalBackend, ShardedBackend, ShardedPCDNConfig
from repro.launch.mesh import make_host_mesh

# --dtype values -> storage dtype of the design values / serve bank
# (solver state stays f32 either way — DESIGN.md section 12)
DTYPES = {"fp32": jnp.float32, "bf16": jnp.bfloat16}
DTYPE_NAMES = {"fp32": "float32", "bf16": "bfloat16"}

# the studied bf16 equivalence envelope (the BENCH_kernels.json
# trajectory study, DESIGN.md section 12): losses it covers and the
# tightest stopping tolerance the measured objective rel-diff supports
BF16_LOSSES = ("logistic", "squared_hinge")
BF16_MIN_TOL = 1e-3


def check_dtype_envelope(args, ap: argparse.ArgumentParser,
                         loss: str | None = None):
    """Refuse bf16 outside the studied equivalence envelope.

    The bf16-vs-fp32 trajectory study (BENCH_kernels.json, DESIGN.md
    section 12) covers the LOCAL backend with the logistic and
    squared-hinge losses down to a max objective rel-diff of ~1e-3 at
    matched iteration counts; anything beyond that is unvalidated, so
    the CLI rejects it instead of silently returning drifted solutions.
    """
    if getattr(args, "dtype", "fp32") != "bf16":
        return
    if getattr(args, "backend", "local") == "sharded":
        ap.error("--dtype bf16 is unstudied on --backend sharded "
                 "(the equivalence study covers the local backend only); "
                 "use --dtype fp32 or --backend local")
    if loss is not None and loss not in BF16_LOSSES:
        ap.error(f"--dtype bf16 is unstudied for loss {loss!r} "
                 f"(studied envelope: {', '.join(BF16_LOSSES)})")
    tol = getattr(args, "tol", None)
    if tol is not None and tol < BF16_MIN_TOL:
        ap.error(f"--tol {tol:g} is tighter than the bf16 equivalence "
                 f"envelope (max objective rel-diff ~{BF16_MIN_TOL:g}); "
                 f"use --tol >= {BF16_MIN_TOL:g} or --dtype fp32")


def add_backend_args(ap: argparse.ArgumentParser):
    """Execution-backend selection, identical in both CLIs."""
    ap.add_argument("--backend", default="local",
                    choices=["local", "sharded"],
                    help="execution backend (DESIGN.md section 9): local "
                         "single-program XLA, or the shard_map mesh "
                         "implementation")
    ap.add_argument("--layout", default="auto",
                    choices=["auto", "dense", "padded_csc"],
                    help="design-matrix backend; padded_csc never "
                         "densifies a .libsvm input (DESIGN.md section 7)")
    ap.add_argument("--data-parallel", type=int, default=1,
                    help="mesh data-axis size (--backend sharded)")
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="mesh model-axis size (--backend sharded)")
    ap.add_argument("--use-kernels", action="store_true",
                    help="route bundle math through the fused Pallas "
                         "direction kernels (both backends)")
    ap.add_argument("--dtype", default="fp32", choices=["fp32", "bf16"],
                    help="storage dtype of the design values (DESIGN.md "
                         "section 12): bf16 halves design memory/HBM "
                         "traffic with f32 accumulation everywhere; "
                         "gated to the studied equivalence envelope "
                         "(local backend, logistic/squared_hinge, "
                         "--tol >= 1e-3)")


def add_solver_args(ap: argparse.ArgumentParser):
    """PCDN knobs shared by the single-solve and the path drivers."""
    ap.add_argument("--P", type=int, default=256, help="bundle size")
    ap.add_argument("--tol", type=float, default=1e-3)
    ap.add_argument("--max-outer", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shrink", action="store_true",
                    help="active-set shrinking (DESIGN.md section 8.2; "
                         "both backends)")
    ap.add_argument("--ls-scope", default="auto",
                    choices=["auto", "support", "full"],
                    help="line-search / margin-maintenance scope "
                         "(DESIGN.md section 11): 'support' restricts "
                         "every per-sample pass of a bundle step to the "
                         "bundle's row support (padded_csc layout; "
                         "O(P*k_max*Q) instead of O(s*Q)); 'auto' picks "
                         "it whenever it wins; both backends")
    ap.add_argument("--warm-start", default=None, metavar="CKPT",
                    help="w0 from a .npy vector or a JSON file (a dense "
                         "list or the sparse weight record a previous "
                         "--out report carries); both backends")


def add_obs_args(ap: argparse.ArgumentParser):
    """Telemetry flags, identical in the solve / path / predict CLIs
    (README "Observability"; DESIGN.md section 13)."""
    ap.add_argument("--metrics-out", default=None, metavar="JSONL",
                    help="enable the metrics registry and append one "
                         "JSONL run record (counters, gauges, p50/p99 "
                         "histograms) to this file on exit; "
                         "REPRO_METRICS=off force-disables")
    ap.add_argument("--trace-out", default=None, metavar="JSON",
                    help="record a Chrome-trace / Perfetto trace-event "
                         "file of the run (load at ui.perfetto.dev); "
                         "validate with `python -m repro.obs.validate`")


def add_diag_args(ap: argparse.ArgumentParser):
    """Diagnostics flags, identical in the solve / path CLIs
    (README "Diagnostics"; DESIGN.md section 15)."""
    ap.add_argument("--diag-out", default=None, metavar="MD",
                    help="write a markdown solver-health report here "
                         "(top-k KKT offenders, backtrack forensics, "
                         "certified-P table); turns on the per-feature "
                         "KKT attribution harvest (record_kkt_vec) and "
                         "the per-bundle aux for this run")
    ap.add_argument("--progress", action="store_true",
                    help="live one-line solve status on stderr (iter, "
                         "objective, KKT, mean_q); off by default so CI "
                         "logs stay clean")


def add_fault_args(ap: argparse.ArgumentParser):
    """Fault-tolerance flags, identical in the solve / path CLIs
    (README "Robustness"; DESIGN.md section 16)."""
    ap.add_argument("--ckpt-dir", default=None, metavar="DIR",
                    help="crash-safe checkpoint directory (atomic "
                         "write-then-rename with a COMMITTED marker); "
                         "solve runs snapshot every --ckpt-every "
                         "iterations, path sweeps after every grid "
                         "point; checkpoints are mesh-agnostic host "
                         "arrays, so a run can resume on a different "
                         "device count")
    ap.add_argument("--ckpt-every", type=int, default=10, metavar="N",
                    help="solve-checkpoint cadence in outer iterations "
                         "(default 10; path sweeps always checkpoint "
                         "per point)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the newest committed checkpoint "
                         "in --ckpt-dir (incomplete or corrupted steps "
                         "are skipped); the resumed run reproduces the "
                         "uninterrupted one bit-for-bit")
    ap.add_argument("--retries", type=int, default=2, metavar="K",
                    help="max non-finite rollbacks before the solve "
                         "surfaces the post-mortem (each retry halves "
                         "the bundle size toward the certified safe P; "
                         "DESIGN.md section 16.3)")


def make_checkpointer(args, ap: argparse.ArgumentParser):
    """The `fault.SolveCheckpointer` behind --ckpt-dir, or None."""
    if getattr(args, "resume", False) and not getattr(args, "ckpt_dir", None):
        ap.error("--resume needs --ckpt-dir")
    if not getattr(args, "ckpt_dir", None):
        return None
    from repro.fault import SolveCheckpointer
    if args.ckpt_every < 1:
        ap.error(f"--ckpt-every must be >= 1, got {args.ckpt_every}")
    return SolveCheckpointer(args.ckpt_dir, every=args.ckpt_every)


def make_progress_callback(args):
    """The engine callback behind `--progress`: one stderr status line,
    rewritten in place (carriage return, no scroll). Returns None when
    the flag is off so the engine loop skips the call entirely."""
    if not getattr(args, "progress", False):
        return None
    import sys

    def cb(k, w, f, kkt, mean_q):
        print(f"\r[progress] iter {k:4d}  F={f:.6f}  kkt={kkt:.3e}  "
              f"mean_q={mean_q:5.2f}", end="", file=sys.stderr, flush=True)
    return cb


def finish_progress(args) -> None:
    """Terminate the in-place `--progress` line before normal output."""
    if getattr(args, "progress", False):
        import sys
        print(file=sys.stderr, flush=True)


def write_diag(args, report: dict, design=None, tol_kkt=None) -> None:
    """Render the `--diag-out` health report (DESIGN.md section 15.4).

    `report` is the same payload `--out` writes (history + provenance +
    optional postmortem); when `design` is given the certified-P table
    is computed here — the CLI already holds the design matrix, so the
    report never reloads the dataset.
    """
    if not getattr(args, "diag_out", None):
        return
    from repro import diag
    safep_record = None
    if design is not None:
        safep_record = diag.safep.certify(
            design, seed=getattr(args, "seed", 0),
            observed_p=getattr(args, "P", None))
        report.setdefault("diag", {})["safep"] = safep_record
    payload = diag.build_payload(report=report,
                                 safep_record=safep_record,
                                 tol_kkt=tol_kkt)
    with open(args.diag_out, "w") as fh:
        fh.write(diag.render_markdown(payload))
    print(f"[diag] health report written to {args.diag_out}")


def setup_obs(args) -> None:
    """Switch the telemetry planes on per the CLI flags (before any
    instrumented work runs)."""
    if getattr(args, "metrics_out", None):
        obs.registry.enable()
        obs.registry.reset()
    if getattr(args, "trace_out", None):
        obs.trace.enable(process_name="repro")


def finish_obs(args, meta: dict | None = None) -> None:
    """Flush the telemetry outputs the CLI flags requested."""
    if getattr(args, "metrics_out", None):
        obs.write_metrics(args.metrics_out, meta)
        print(f"[obs] metrics appended to {args.metrics_out}")
        obs.registry.disable()
    if getattr(args, "trace_out", None):
        if obs.trace.save(args.trace_out):
            print(f"[obs] trace written to {args.trace_out}")


def load_dataset(args, with_test: bool = False):
    """-> (X, y, Xte, yte, spec). File datasets have no test split and a
    None spec; profile names go through `paper_like`. Honors the layout /
    backend interplay: a padded_csc file load stays CSR for the sharded
    placer (which re-packs per shard) and pre-packs padded-CSC locally.
    """
    scale = getattr(args, "scale", None)
    if os.path.exists(args.dataset):
        if args.layout == "padded_csc":
            file_layout = "csr" if args.backend == "sharded" \
                else "padded_csc"
        else:
            file_layout = "dense"
        X, y = load_libsvm(args.dataset, layout=file_layout)
        return X, y, None, None, None
    if with_test:
        Xtr, ytr, Xte, yte, spec = paper_like(args.dataset, with_test=True,
                                              seed=args.seed, scale=scale)
        return Xtr, ytr, Xte, yte, spec
    X, y, spec = paper_like(args.dataset, seed=args.seed, scale=scale)
    return X, y, None, None, spec


def build_pcdn_config(args, **overrides) -> PCDNConfig:
    """The local-backend solver config (also the stop parameters every
    backend uses — max_outer / tol_kkt come from here)."""
    kw = dict(P=args.P, max_outer=args.max_outer, tol_kkt=args.tol,
              seed=args.seed, shrink=args.shrink,
              use_kernels=args.use_kernels,
              ls_scope=getattr(args, "ls_scope", "auto"),
              dtype=DTYPE_NAMES[getattr(args, "dtype", "fp32")],
              record_aux=_record_aux(args),
              record_kkt_vec=_record_kkt_vec(args))
    kw.update(overrides)
    return PCDNConfig(**kw)


def _record_aux(args) -> bool:
    """Per-bundle (q, alpha) aux outputs ride along exactly when the CLI
    asked for telemetry OR diagnostics (the health report's backtrack
    forensics consume them) — without the flags the compiled iteration
    stays byte-identical to the uninstrumented solver (DESIGN.md 13.2)."""
    return bool(getattr(args, "metrics_out", None)
                or getattr(args, "trace_out", None)
                or getattr(args, "diag_out", None))


def _record_kkt_vec(args) -> bool:
    """Per-feature KKT attribution rides along exactly when `--diag-out`
    asked for a health report (DESIGN.md section 15.1)."""
    return bool(getattr(args, "diag_out", None))


def build_sharded_config(args, c: float, loss: str) -> ShardedPCDNConfig:
    """Mirror the CLI flags onto the sharded backend's config so
    --shrink / --use-kernels / --tol mean the same thing on a mesh."""
    return ShardedPCDNConfig(
        P_local=max(args.P // max(args.model_parallel, 1), 1), c=c,
        loss_name=loss, seed=args.seed, shrink=args.shrink,
        use_kernels=args.use_kernels, tol_kkt=args.tol,
        ls_scope=getattr(args, "ls_scope", "auto"),
        record_aux=_record_aux(args),
        record_kkt_vec=_record_kkt_vec(args))


def make_backend(args, X, y, c: float, loss: str, outer=None):
    """Build the execution backend the flags describe.

    local: an `L1Problem` + `LocalBackend`; sharded: a host mesh of
    --data-parallel x --model-parallel devices + `ShardedBackend`.
    Returns (backend, problem_or_None).
    """
    if args.backend == "sharded":
        mesh = make_host_mesh(args.data_parallel, args.model_parallel)
        cfg = build_sharded_config(args, c, loss)
        return ShardedBackend(X, y, mesh, cfg, layout=args.layout), None
    prob = make_problem(X, y, c=c, loss=loss, layout=args.layout,
                        dtype=DTYPES[getattr(args, "dtype", "fp32")])
    return LocalBackend(prob, build_pcdn_config(args), outer=outer), prob


def load_warm_start(path: str, n: int, dtype) -> jnp.ndarray:
    """Load a w0 vector from .npy, or from JSON: a dense list, or the
    sparse {n_features, w_indices, w_values} record `--out` writes — so
    solve runs chain."""
    if path.endswith(".npy"):
        w = np.asarray(np.load(path), np.float64).reshape(-1)
    else:
        with open(path) as fh:
            obj = json.load(fh)
        if isinstance(obj, dict):
            if "w_indices" not in obj:
                raise ValueError(
                    f"warm start {path!r} has no weight record "
                    f"(w_indices/w_values) — reports written by older "
                    f"--out versions lack it; re-run the source solve "
                    f"or pass a .npy")
            w = np.zeros((int(obj["n_features"]),), np.float64)
            w[np.asarray(obj["w_indices"], np.int64)] = obj["w_values"]
        else:
            w = np.asarray(obj, np.float64).reshape(-1)
    if w.shape[0] != n:
        raise ValueError(
            f"warm start {path!r} has {w.shape[0]} features, problem "
            f"has {n}")
    return jnp.asarray(w, dtype)


def history_dict(history) -> dict:
    """JSON-ready SolveHistory: absent optional series (bundle_q /
    bundle_alpha are None unless the backend ran with record_aux) are
    dropped, not serialized as null."""
    return {k: np.asarray(v).tolist() for k, v in history._asdict().items()
            if v is not None}


def sparse_weight_record(w) -> dict:
    """JSON-compact (indices, values) form of an l1 solution — nnz-sized,
    so a news20-scale report stays small where a dense float list would
    be tens of MB of decimal text."""
    w = np.asarray(w, np.float64)
    idx = np.flatnonzero(w)
    return {"n_features": int(w.shape[0]),
            "w_indices": idx.tolist(),
            "w_values": w[idx].tolist()}
