import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this:
  1. builds the FULL-size ModelConfig and abstract params/opt-state/cache
     (ShapeDtypeStruct everywhere — nothing is allocated),
  2. jits train_step / serve_step with explicit in/out shardings on the
     production mesh ((16,16) 'data','model'; multi-pod (2,16,16) adds
     'pod'),
  3. ``.lower().compile()`` — failures here (sharding mismatch, bad
     collective) are bugs,
  4. records memory_analysis(), cost_analysis() and the collective-byte
     parse of the optimized HLO into benchmarks/results/dryrun/<cell>.json
     for the roofline analysis (EXPERIMENTS.md section Dry-run / Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --cell train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --multi-pod --skip-done
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import cell_input_specs
from repro.models import decode as dec
from repro.models.config import SHAPE_CELLS, cell_applicable, get_shape_cell
from repro.models.transformer import Model
from repro.optim.adamw import AdamWConfig, adamw_abstract_state
from repro.train.steps import (_batch_spec, cache_specs, make_serve_step,
                               make_train_step, opt_state_specs)
from repro.utils import compat
from repro.utils import hlo as hlo_util
from repro.utils import hlo_cost

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "benchmarks", "results", "dryrun")


def _named(mesh, tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda x: isinstance(x, P))


def model_flops_estimate(cfg, cell) -> float:
    """MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) for train;
    2*N(_active)*D for inference cells."""
    from repro.utils.params import active_param_count
    n_active = active_param_count(cfg)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * cell.global_batch


def lower_cell(arch: str, cell_name: str, multi_pod: bool,
               opt_overrides: dict | None = None):
    """-> result dict (raises on lowering/compile failure)."""
    cfg = get_config(arch)
    cell = get_shape_cell(cell_name)
    ok, reason = cell_applicable(cfg, cell)
    if not ok:
        return {"arch": arch, "cell": cell_name,
                "multi_pod": multi_pod, "status": "SKIP", "reason": reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = Model(cfg, mesh)
    t0 = time.perf_counter()

    if cell.kind in ("train",):
        opt_cfg = AdamWConfig(**(opt_overrides or {}))
        step_fn, p_specs, o_specs = make_train_step(model, opt_cfg)
        params = model.abstract_params()
        opt = adamw_abstract_state(params, opt_cfg)
        batch = cell_input_specs(cfg, cell)
        b_specs = _batch_spec(mesh, batch, model.rules)
        jitted = jax.jit(
            step_fn,
            in_shardings=(_named(mesh, p_specs), _named(mesh, o_specs),
                          _named(mesh, b_specs)),
            out_shardings=(_named(mesh, p_specs), _named(mesh, o_specs),
                           None),
        )
        lowered = jitted.lower(params, opt, batch)
    elif cell.kind == "prefill":
        from repro.train.steps import make_prefill_step
        step_fn = make_prefill_step(model)
        params = model.abstract_params()
        p_specs = model.param_specs()
        batch = cell_input_specs(cfg, cell)
        b_specs = _batch_spec(mesh, batch, model.rules)
        out_spec = NamedSharding(mesh, P(
            tuple(a for a in ("pod", "data") if a in mesh.shape), None,
            "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None))
        jitted = jax.jit(step_fn,
                         in_shardings=(_named(mesh, p_specs),
                                       _named(mesh, b_specs)),
                         out_shardings=out_spec)
        lowered = jitted.lower(params, batch)
    else:  # decode
        step_fn = make_serve_step(model)
        params = model.abstract_params()
        p_specs = model.param_specs()
        cache = dec.init_cache(model, cell.global_batch, cell.seq_len,
                               concrete=False)
        c_specs = cache_specs(model, cache)
        batch = cell_input_specs(cfg, cell)
        b_specs = _batch_spec(mesh, batch, model.rules)
        jitted = jax.jit(
            step_fn,
            in_shardings=(_named(mesh, p_specs), _named(mesh, c_specs),
                          _named(mesh, b_specs)["tokens"]),
            out_shardings=(None, _named(mesh, c_specs)),
        )
        lowered = jitted.lower(params, cache, batch["tokens"])

    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    raw_cost = compat.cost_analysis(compiled)
    hlo_text = compiled.as_text()
    # trip-count-aware accounting (XLA's cost_analysis counts while bodies
    # once — see utils/hlo_cost.py). All numbers below are PER DEVICE: the
    # compiled module is the per-partition SPMD program.
    mc = hlo_cost.analyze(hlo_text)
    n_chips = 1
    for s in mesh.shape.values():
        n_chips *= s

    mf = model_flops_estimate(cfg, cell)
    result = {
        "arch": arch, "cell": cell_name, "multi_pod": multi_pod,
        "status": "OK",
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        # totals across chips = per-device * n_chips (SPMD symmetric)
        "flops": mc.flops * n_chips,
        "hbm_bytes": mc.bytes * n_chips,
        "collective_bytes": mc.total_coll_bytes * n_chips,
        "collectives": {k: [mc.coll_bytes[k] * n_chips,
                            mc.coll_count.get(k, 0)]
                        for k in mc.coll_bytes},
        "trip_counts": mc.trip_counts,
        "raw_cost_analysis": {k: float(v) for k, v in raw_cost.items()
                              if isinstance(v, (int, float))
                              and "{" not in k},
        "model_flops": mf,
        "memory": {
            "bytes_per_device": getattr(
                mem, "temp_size_in_bytes", 0) + getattr(
                mem, "argument_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
    }
    roof = hlo_util.Roofline(
        flops=result["flops"], hbm_bytes=result["hbm_bytes"],
        coll_bytes=result["collective_bytes"], n_chips=n_chips,
        model_flops=mf, coll_count=sum(mc.coll_count.values()))
    result["roofline"] = roof.as_dict()
    return result


def lower_solver_cell(loss_name: str = "logistic", multi_pod: bool = False,
                      ls_kind: str = "batched", fuse: bool = True,
                      s: int = 2 ** 19, n: int = 2 ** 20,
                      P_local: int = 64):
    """Dry-run the paper's own technique at production scale: one sharded
    PCDN outer iteration over a dense (s, n) problem (kdda-class scale in
    the dense adaptation; X f32 = s*n*4 bytes sharded (data x model))."""
    from repro.engine.sharded import ShardedPCDNConfig, make_sharded_outer
    mesh = make_production_mesh(multi_pod=multi_pod)
    daxes = ("pod", "data") if multi_pod else ("data",)
    cfg = ShardedPCDNConfig(P_local=P_local, c=1.0, loss_name=loss_name,
                            data_axes=daxes, ls_kind=ls_kind,
                            fuse_collectives=fuse)
    d_sz = 1
    for a in daxes:
        d_sz *= mesh.shape[a]
    m_sz = mesh.shape[cfg.model_axis]
    n_local = n // m_sz
    outer = make_sharded_outer(cfg, mesh, n_local)

    dspec = daxes if len(daxes) > 1 else daxes[0]
    Xs = jax.ShapeDtypeStruct((s, n), jnp.float32)
    ys = jax.ShapeDtypeStruct((s,), jnp.float32)
    ws = jax.ShapeDtypeStruct((n,), jnp.float32)
    zs = jax.ShapeDtypeStruct((s,), jnp.float32)
    ks = jax.ShapeDtypeStruct((2,), jnp.uint32)
    # engine-contract extras: active mask, recheck flag, traced c
    acts = jax.ShapeDtypeStruct((n,), jnp.bool_)
    rs = jax.ShapeDtypeStruct((), jnp.bool_)
    cs_ = jax.ShapeDtypeStruct((), jnp.float32)
    shardings = (NamedSharding(mesh, P(dspec, "model")),
                 NamedSharding(mesh, P(dspec)),
                 NamedSharding(mesh, P("model")),
                 NamedSharding(mesh, P(dspec)),
                 NamedSharding(mesh, P()),
                 NamedSharding(mesh, P("model")),
                 NamedSharding(mesh, P()),
                 NamedSharding(mesh, P()))
    t0 = time.perf_counter()
    lowered = jax.jit(
        lambda X, y, w, z, k, a, r, c: outer(X, y, w, z, k, a, r, c),
        in_shardings=shardings).lower(Xs, ys, ws, zs, ks, acts, rs, cs_)
    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower
    mem = compiled.memory_analysis()
    mc = hlo_cost.analyze(compiled.as_text())
    n_chips = 1
    for v in mesh.shape.values():
        n_chips *= v
    # useful flops per outer iteration: every feature's grad/hess + Xd =
    # 6 s n (dense adaptation; matches the paper's O(s n) per outer pass)
    mf = 6.0 * s * n
    result = {
        "arch": f"pcdn-{loss_name}", "cell": f"solve_{s}x{n}",
        "multi_pod": multi_pod, "status": "OK",
        "variant": {"ls_kind": ls_kind, "fuse_collectives": fuse},
        "n_chips": n_chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "flops": mc.flops * n_chips,
        "hbm_bytes": mc.bytes * n_chips,
        "collective_bytes": mc.total_coll_bytes * n_chips,
        "collectives": {k: [mc.coll_bytes[k] * n_chips,
                            mc.coll_count.get(k, 0)]
                        for k in mc.coll_bytes},
        "trip_counts": mc.trip_counts,
        "model_flops": mf,
        "memory": {"bytes_per_device": getattr(
            mem, "temp_size_in_bytes", 0) + getattr(
            mem, "argument_size_in_bytes", 0)},
    }
    roof = hlo_util.Roofline(
        flops=result["flops"], hbm_bytes=result["hbm_bytes"],
        coll_bytes=result["collective_bytes"], n_chips=n_chips,
        model_flops=mf, coll_count=sum(mc.coll_count.values()))
    result["roofline"] = roof.as_dict()
    return result


def cell_path(arch, cell, multi_pod):
    tag = "mp" if multi_pod else "sp"
    return os.path.join(RESULTS_DIR, f"{arch}__{cell}__{tag}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--cell", default=None,
                    choices=[c.name for c in SHAPE_CELLS])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--solver", action="store_true",
                    help="dry-run the sharded PCDN solver cell instead")
    ap.add_argument("--ls-kind", default="batched",
                    choices=["batched", "backtracking"])
    ap.add_argument("--no-fuse", action="store_true")
    args = ap.parse_args()

    if args.solver:
        os.makedirs(RESULTS_DIR, exist_ok=True)
        for mp in ([False, True] if args.both_meshes else [args.multi_pod]):
            res = lower_solver_cell(multi_pod=mp, ls_kind=args.ls_kind,
                                    fuse=not args.no_fuse)
            tag = "mp" if mp else "sp"
            variant = f"{args.ls_kind}{'_nofuse' if args.no_fuse else ''}"
            path = os.path.join(RESULTS_DIR,
                                f"pcdn-solver__{variant}__{tag}.json")
            with open(path, "w") as fh:
                json.dump(res, fh, indent=1)
            r = res["roofline"]
            print(f"[dryrun] pcdn-solver {variant} mp={mp}: "
                  f"comp={r['t_compute_s']:.3f}s mem={r['t_memory_s']:.3f}s "
                  f"coll={r['t_collective_s']:.3f}s "
                  f"bottleneck={r['bottleneck']} "
                  f"useful={r['useful_flops_ratio']:.2f}")
        return 0

    os.makedirs(RESULTS_DIR, exist_ok=True)
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    cells = [args.cell] if args.cell else [c.name for c in SHAPE_CELLS]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for mp in meshes:
        for arch in archs:
            for cell in cells:
                path = cell_path(arch, cell, mp)
                if args.skip_done and os.path.exists(path):
                    print(f"[dryrun] cached {arch} {cell} mp={mp}")
                    continue
                tag = "multi-pod" if mp else "single-pod"
                print(f"[dryrun] {arch} x {cell} ({tag}) ...", flush=True)
                try:
                    res = lower_cell(arch, cell, mp)
                except Exception as e:
                    failures += 1
                    res = {"arch": arch, "cell": cell, "multi_pod": mp,
                           "status": "FAIL", "error": repr(e),
                           "traceback": traceback.format_exc()}
                    print(f"  FAIL: {e}")
                with open(path, "w") as fh:
                    json.dump(res, fh, indent=1)
                if res["status"] == "OK":
                    r = res["roofline"]
                    print(f"  OK lower={res['lower_s']}s "
                          f"compile={res['compile_s']}s "
                          f"flops={res['flops']:.3e} "
                          f"coll={res['collective_bytes']/1e9:.2f}GB "
                          f"bottleneck={r['bottleneck']} "
                          f"mem/dev={res['memory']['bytes_per_device']/1e9:.1f}GB",
                          flush=True)
                elif res["status"] == "SKIP":
                    print(f"  SKIP: {res['reason']}")
    print(f"[dryrun] done, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
