"""Production mesh construction (a FUNCTION — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis.

    Requires 512 placeholder devices for the dry-run
    (``XLA_FLAGS=--xla_force_host_platform_device_count=512`` — set by
    launch/dryrun.py only); single-pod uses the first 256.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — run "
            f"under launch/dryrun.py (it forces 512 host devices) or on "
            f"real hardware")
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=jax.devices()[:data * model])
