"""Regularization-path driver (DESIGN.md sections 8 / 9):

    python -m repro.launch.path --dataset real-sim --points 20 --shrink
    python -m repro.launch.path --backend sharded --data-parallel 2 \
        --model-parallel 4          # warm-started sweep on a device mesh

Builds the geometric c-grid from the analytic c_max, runs the
warm-started sweep on the selected execution backend (or, with --mode
batch, solves every grid point simultaneously in one vmapped program),
reports per-point objective / nnz / KKT / validation accuracy, and picks
the best c by held-out accuracy. Writes a JSON report with --out (+ a
.npy weight matrix next to it with --save-weights).
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core.problem import validation_accuracy
from repro.launch import common
from repro.serve import artifact as art
from repro.path import PathConfig, PathPoint, PathResult, path_summary, \
    pick_best, problem_grid, run_path, solve_batch


def _load(args):
    """-> (X, y, val_design, val_y) honoring --val-frac."""
    X, y, _Xte, _yte, spec = common.load_dataset(args)
    if spec is None:
        if args.val_frac > 0:
            # sparse row-split would need CSR re-packing; not wired yet
            print("[path] --val-frac ignored for file datasets "
                  "(no validation split, best-c pick disabled)")
        return X, y, None, None
    if args.val_frac <= 0:
        return X, y, None, None
    cut = max(1, int(round((1.0 - args.val_frac) * X.shape[0])))
    if cut >= X.shape[0]:
        raise SystemExit(f"--val-frac {args.val_frac} leaves no "
                         f"validation rows (s={X.shape[0]})")
    return X[:cut], y[:cut], X[cut:], y[cut:]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="real-sim",
                    help="paper dataset profile name or a .libsvm path")
    ap.add_argument("--loss", default="logistic",
                    choices=["logistic", "squared_hinge"])
    ap.add_argument("--points", type=int, default=20)
    ap.add_argument("--span", type=float, default=100.0,
                    help="c_final = span * c_max (ignored with --c-final)")
    ap.add_argument("--c-final", type=float, default=None)
    ap.add_argument("--cold", action="store_true",
                    help="disable warm starting (ablation)")
    ap.add_argument("--mode", default="sweep", choices=["sweep", "batch"],
                    help="sweep: sequential warm-started path; batch: "
                         "solve all grid points at once via vmap")
    ap.add_argument("--scale", type=float, default=None,
                    help="paper_like size scale (None = CPU-budget shape)")
    ap.add_argument("--val-frac", type=float, default=0.2,
                    help="held-out row fraction for the best-c pick "
                         "(profile datasets; 0 disables)")
    common.add_solver_args(ap)
    common.add_backend_args(ap)
    ap.add_argument("--out", default=None, help="write path JSON here")
    ap.add_argument("--save-weights", action="store_true",
                    help="also write <out>.weights.npy")
    ap.add_argument("--save-model", default=None, metavar="PATH",
                    help="write the whole sweep as ONE kind='path' serve "
                         "artifact family — every grid point becomes a "
                         "servable model (DESIGN.md section 10.1)")
    common.add_obs_args(ap)
    common.add_diag_args(ap)
    common.add_fault_args(ap)
    args = ap.parse_args(argv)
    if args.mode == "batch" and (args.ckpt_dir or args.resume):
        ap.error("--ckpt-dir/--resume require --mode sweep (the lockstep "
                 "batch engine solves all points at once — there is no "
                 "point cursor to checkpoint)")
    if args.mode == "batch" and args.shrink:
        ap.error("--shrink requires --mode sweep (the vmapped batch "
                 "engine has no active-set masking)")
    if args.mode == "batch" and args.backend == "sharded":
        ap.error("--mode batch is local-only (the vmapped batch solver "
                 "has no sharded execution backend yet)")
    if args.mode == "batch" and args.diag_out:
        ap.error("--diag-out requires --mode sweep (the lockstep batch "
                 "engine keeps no per-iteration history)")
    common.check_dtype_envelope(args, ap, loss=args.loss)

    X, y, Xval, yval = _load(args)
    common.setup_obs(args)
    solver = common.build_pcdn_config(args)
    backend, prob = common.make_backend(args, X, y, 1.0, args.loss)
    print(f"[path] dataset={args.dataset} s={X.shape[0]} "
          f"n={backend.n_features} c_max={backend.c_max():.5g} "
          f"points={args.points} mode={args.mode} shrink={args.shrink} "
          f"warm={not args.cold} backend={args.backend}")

    if args.mode == "batch":
        cs = problem_grid(prob, c_final=args.c_final,
                          n_points=args.points, span=args.span)
        t0 = time.perf_counter()
        bres = solve_batch(prob, solver, cs)
        total_s = time.perf_counter() - t0
        points = []
        for i, c in enumerate(cs):
            acc = (validation_accuracy(Xval, yval, np.asarray(bres.w[i]))
                   if Xval is not None else None)
            p = PathPoint(c=float(c), objective=float(bres.objective[i]),
                          nnz=int(bres.nnz[i]), kkt=float(bres.kkt[i]),
                          n_outer=int(bres.n_outer[i]),
                          seconds=None,   # lockstep: no per-point timing
                          converged=bool(bres.converged[i]),
                          val_accuracy=acc)
            points.append(p)
            print(f"[path] c={p.c:.5g} F={p.objective:.5f} nnz={p.nnz} "
                  f"kkt={p.kkt:.2e} iters={p.n_outer}"
                  + (f" val_acc={acc:.4f}" if acc is not None else ""))
        weights = np.asarray(bres.w)
        # synthesize a PathResult so the report schema (and the best-c
        # tie-break inside it) is shared with sweep mode
        res = PathResult(c_max=float(cs[0]), cs=cs, points=points,
                         weights=weights, best_index=pick_best(points),
                         total_seconds=total_s)
        payload = {"mode": "batch", **path_summary(res)}
    else:
        cfg = PathConfig(solver=solver, n_points=args.points,
                         span=args.span, c_final=args.c_final,
                         warm_start=not args.cold)
        from repro import fault
        res = run_path(prob, cfg, val_design=Xval, val_y=yval,
                       verbose=True, backend=backend,
                       callback=common.make_progress_callback(args),
                       ckpt=common.make_checkpointer(args, ap),
                       resume=args.resume,
                       fault_plan=fault.plan_from_env())
        common.finish_progress(args)
        payload = {"mode": "sweep", "backend": args.backend,
                   **path_summary(res)}
        weights = res.weights
        if res.best is not None:
            print(f"[path] best c={res.best.c:.5g} "
                  f"val_acc={res.best.val_accuracy:.4f} nnz={res.best.nnz}")

    if args.out:
        with open(args.out, "w") as fh:
            json.dump(payload, fh, indent=1, default=float)
        if args.save_weights:
            np.save(args.out + ".weights.npy", weights)
        print(f"[path] wrote {args.out}")
    if args.save_model:
        metas = [{"objective": p.objective, "kkt": p.kkt, "nnz": p.nnz,
                  "n_outer": p.n_outer, "converged": p.converged,
                  "val_accuracy": p.val_accuracy} for p in res.points]
        family = art.path_family(
            weights, res.cs, args.loss, metas=metas,
            provenance=art.solver_provenance(
                solver="pcdn", dataset=args.dataset, backend=args.backend,
                mode=args.mode, P=args.P, tol_kkt=args.tol, seed=args.seed,
                shrink=bool(args.shrink), loss=args.loss,
                dtype=args.dtype, best_index=res.best_index))
        art.save_model(args.save_model, family)
        print(f"[path] wrote model family ({len(family)} points) to "
              f"{args.save_model}")
    if args.diag_out:
        from repro.core import as_design
        best = res.best
        diag_report = {
            "provenance": art.solver_provenance(
                solver="pcdn", dataset=args.dataset, backend=args.backend,
                mode=args.mode, P=args.P, tol_kkt=args.tol, seed=args.seed,
                shrink=bool(args.shrink), loss=args.loss, dtype=args.dtype),
            "loss": args.loss, "n_features": int(backend.n_features),
            "objective": res.points[-1].objective if res.points else None,
            "converged": res.points[-1].converged if res.points else None,
            "nnz": res.points[-1].nnz if res.points else None,
            "seconds": res.total_seconds,
            "history": (common.history_dict(res.last_history)
                        if res.last_history is not None else None),
            "postmortem": res.last_postmortem}
        if best is not None:
            diag_report["best_c"] = best.c
        common.write_diag(args, diag_report, design=as_design(X),
                          tol_kkt=args.tol)
    common.finish_obs(args, meta={
        "cli": "path", "dataset": args.dataset, "mode": args.mode,
        "backend": args.backend, "points": len(res.points),
        "total_seconds": res.total_seconds})
    return payload


if __name__ == "__main__":
    main()
