"""Regularization-path driver (DESIGN.md section 8):

    python -m repro.launch.path --dataset real-sim --points 20 --shrink

Builds the geometric c-grid from the analytic c_max, runs the
warm-started sweep (or, with --mode batch, solves every grid point
simultaneously in one vmapped program), reports per-point
objective / nnz / KKT / validation accuracy, and picks the best c by
held-out accuracy. Writes a JSON report with --out (+ a .npy weight
matrix next to it with --save-weights).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import PCDNConfig, make_problem
from repro.core.problem import validation_accuracy
from repro.data import load_libsvm, paper_like
from repro.path import PathConfig, PathPoint, PathResult, path_summary, \
    pick_best, problem_grid, run_path, solve_batch


def _load(args):
    """-> (Xtr, ytr, val_design, val_y) honoring --val-frac."""
    if os.path.exists(args.dataset):
        layout = "padded_csc" if args.layout == "padded_csc" else "dense"
        X, y = load_libsvm(args.dataset, layout=layout)
        if args.val_frac > 0:
            # sparse row-split would need CSR re-packing; not wired yet
            print("[path] --val-frac ignored for file datasets "
                  "(no validation split, best-c pick disabled)")
        return X, y, None, None
    X, y, _spec = paper_like(args.dataset, scale=args.scale,
                             seed=args.seed)
    if args.val_frac <= 0:
        return X, y, None, None
    cut = max(1, int(round((1.0 - args.val_frac) * X.shape[0])))
    if cut >= X.shape[0]:
        raise SystemExit(f"--val-frac {args.val_frac} leaves no "
                         f"validation rows (s={X.shape[0]})")
    return X[:cut], y[:cut], X[cut:], y[cut:]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="real-sim",
                    help="paper dataset profile name or a .libsvm path")
    ap.add_argument("--loss", default="logistic",
                    choices=["logistic", "squared_hinge"])
    ap.add_argument("--P", type=int, default=256, help="bundle size")
    ap.add_argument("--points", type=int, default=20)
    ap.add_argument("--span", type=float, default=100.0,
                    help="c_final = span * c_max (ignored with --c-final)")
    ap.add_argument("--c-final", type=float, default=None)
    ap.add_argument("--cold", action="store_true",
                    help="disable warm starting (ablation)")
    ap.add_argument("--shrink", action="store_true",
                    help="active-set shrinking (PCDNConfig(shrink=True))")
    ap.add_argument("--mode", default="sweep", choices=["sweep", "batch"],
                    help="sweep: sequential warm-started path; batch: "
                         "solve all grid points at once via vmap")
    ap.add_argument("--tol", type=float, default=1e-3)
    ap.add_argument("--max-outer", type=int, default=100)
    ap.add_argument("--layout", default="auto",
                    choices=["auto", "dense", "padded_csc"])
    ap.add_argument("--scale", type=float, default=None,
                    help="paper_like size scale (None = CPU-budget shape)")
    ap.add_argument("--val-frac", type=float, default=0.2,
                    help="held-out row fraction for the best-c pick "
                         "(profile datasets; 0 disables)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None, help="write path JSON here")
    ap.add_argument("--save-weights", action="store_true",
                    help="also write <out>.weights.npy")
    args = ap.parse_args(argv)
    if args.mode == "batch" and args.shrink:
        ap.error("--shrink requires --mode sweep (the vmapped batch "
                 "engine has no active-set masking)")

    X, y, Xval, yval = _load(args)
    prob = make_problem(X, y, c=1.0, loss=args.loss, layout=args.layout)
    solver = PCDNConfig(P=args.P, max_outer=args.max_outer,
                        tol_kkt=args.tol, seed=args.seed,
                        shrink=args.shrink)
    print(f"[path] dataset={args.dataset} s={prob.n_samples} "
          f"n={prob.n_features} c_max={prob.c_max():.5g} "
          f"points={args.points} mode={args.mode} shrink={args.shrink} "
          f"warm={not args.cold}")

    if args.mode == "batch":
        cs = problem_grid(prob, c_final=args.c_final,
                          n_points=args.points, span=args.span)
        t0 = time.perf_counter()
        bres = solve_batch(prob, solver, cs)
        total_s = time.perf_counter() - t0
        points = []
        for i, c in enumerate(cs):
            acc = (validation_accuracy(Xval, yval, np.asarray(bres.w[i]))
                   if Xval is not None else None)
            p = PathPoint(c=float(c), objective=float(bres.objective[i]),
                          nnz=int(bres.nnz[i]), kkt=float(bres.kkt[i]),
                          n_outer=int(bres.n_outer[i]),
                          seconds=None,   # lockstep: no per-point timing
                          converged=bool(bres.converged[i]),
                          val_accuracy=acc)
            points.append(p)
            print(f"[path] c={p.c:.5g} F={p.objective:.5f} nnz={p.nnz} "
                  f"kkt={p.kkt:.2e} iters={p.n_outer}"
                  + (f" val_acc={acc:.4f}" if acc is not None else ""))
        weights = np.asarray(bres.w)
        # synthesize a PathResult so the report schema (and the best-c
        # tie-break inside it) is shared with sweep mode
        res = PathResult(c_max=float(cs[0]), cs=cs, points=points,
                         weights=weights, best_index=pick_best(points),
                         total_seconds=total_s)
        payload = {"mode": "batch", **path_summary(res)}
    else:
        cfg = PathConfig(solver=solver, n_points=args.points,
                         span=args.span, c_final=args.c_final,
                         warm_start=not args.cold)
        res = run_path(prob, cfg, val_design=Xval, val_y=yval, verbose=True)
        payload = {"mode": "sweep", **path_summary(res)}
        weights = res.weights
        if res.best is not None:
            print(f"[path] best c={res.best.c:.5g} "
                  f"val_acc={res.best.val_accuracy:.4f} nnz={res.best.nnz}")

    if args.out:
        with open(args.out, "w") as fh:
            json.dump(payload, fh, indent=1, default=float)
        if args.save_weights:
            np.save(args.out + ".weights.npy", weights)
        print(f"[path] wrote {args.out}")
    return payload


if __name__ == "__main__":
    main()
