"""Serving driver: load a model artifact, score traffic, report latency.

    python -m repro.launch.solve --dataset a9a --save-model m.json
    python -m repro.launch.predict --model m.json --dataset a9a

Loads a `repro.serve` artifact (binary model, OVR head, or path family),
stacks it into a `ModelBank`, and streams the dataset's rows through the
microbatched prediction engine (DESIGN.md section 10.4): requests are
padded to bucket shapes so only the first call per bucket compiles, and
per-bucket latency / throughput are reported. `--layout padded_csc`
serves the feature-major sparse request path; `--use-kernels` routes
margins through the Pallas kernels (kernels/pcdn_margin.py), whose
outputs are checked against the XLA reference scorer on the first batch.

`--route` picks the dense-layout scorer: "sparse" (union-gather),
"dense" (densified matmul), or "auto", which reads the measured
crossover table committed in BENCH_serve.json (DESIGN.md 14.6).
`--best-c` reduces a kind="path" artifact to its best grid point
(serve.artifact.pick_best_c) before serving.

`--serve` switches to the continuous-batching loop (DESIGN.md 14):
open-loop Poisson traffic at `--rate` rps with per-request budget
`--slo-ms`, reporting admission-to-response p50/p99, padding
efficiency and SLO violations. `--swap-model` hot-swaps a second
artifact in mid-stream (best-c selected live for path artifacts) at
`--swap-at` of the run, demonstrating the zero-recompile swap.
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time

import numpy as np

from repro.data import load_libsvm, paper_like
from repro.data.libsvm import CSRMatrix
from repro.serve.artifact import ModelFamily, load_model, pick_best_c
from repro.serve.batcher import MicroBatcher, default_buckets
from repro.serve.loop import ServeLoop, drive_poisson
from repro.serve.predict import (ModelBank, decide, predict,
                                 scorer_cache_sizes)


def _load_requests(args, n_features: int):
    """-> (requests, y_raw, codes) — y_raw in the loader's normalized
    vocabulary (+-1 for <= 2 labels), codes the sorted-vocabulary class
    codes, both None when unlabeled. File datasets honor --layout;
    profile names score the held-out test split of the generator."""
    if os.path.exists(args.dataset):
        csr, codes, classes = load_libsvm(args.dataset,
                                          n_features=n_features,
                                          layout="csr",
                                          return_classes=True)
        codes = np.asarray(codes, np.int64)
        y_raw = np.asarray(classes)[codes]
        if args.layout == "padded_csc":
            return csr, y_raw, codes
        return csr.to_dense(), y_raw, codes
    _, _, Xte, yte, _ = paper_like(args.dataset, with_test=True,
                                   seed=args.seed)
    codes = (np.asarray(yte) > 0).astype(np.int64)
    if args.layout == "padded_csc":
        return CSRMatrix.from_dense(Xte), yte, codes
    return Xte, yte, codes


def _accuracy(bank: ModelBank, preds: np.ndarray, y_raw, codes) -> dict:
    """Per-kind accuracy: one scalar for binary/ovr, per-point for path.

    OVR banks compare on class CODES: both the loader's vocabulary and
    `bank.classes` are sorted ascending, so codes align even when the
    bank was trained on raw labels a binary file normalizes to +-1
    (the {3, 7}-labeled two-class case).
    """
    if bank.kind == "ovr":
        pred_codes = np.searchsorted(np.asarray(bank.classes), preds)
        return {"accuracy": float(np.mean(pred_codes == codes))}
    if bank.kind == "path":
        accs = [float(np.mean(preds[:, k] == y_raw))
                for k in range(bank.n_models)]
        best = int(np.argmax(accs))
        return {"per_point": accs, "best_index": best,
                "best_accuracy": accs[best]}
    return {"accuracy": float(np.mean(preds == y_raw))}


def _run_serve(args, family) -> dict:
    """--serve: the continuous-batching loop under open-loop Poisson
    load (DESIGN.md section 14), with an optional mid-stream hot-swap."""
    from repro.launch.common import DTYPES, finish_obs
    if args.layout != "dense":
        raise SystemExit("--serve admits dense request rows only "
                         "(--layout dense)")
    # the per-request budget (the internal flush deadline) gets headroom
    # under the SLO so deadline-flush jitter still lands responses under
    # it — the SLO is what we report p99 against, the budget is the knob
    budget_s = 0.8 * args.slo_ms / 1e3
    loop = ServeLoop(family, max_batch=args.max_batch,
                     buckets=([int(b) for b in args.buckets.split(",")]
                              if args.buckets else None),
                     default_budget_s=budget_s,
                     max_queue=args.max_queue, route=args.route,
                     use_kernels=args.use_kernels,
                     dtype=DTYPES[args.dtype])
    bank = loop.bank()
    print(f"[serve] model={args.model} kind={bank.kind} K={bank.n_models} "
          f"n={bank.n_features} sparsity={bank.sparsity():.4f} "
          f"routes={loop.stats()['models']['default']['routes']} "
          f"warm compiles={loop.stats()['compiles']}")

    requests, y_raw, codes = _load_requests(args, bank.n_features)
    X = np.asarray(requests, np.float32)     # loop serves dense rows
    n_req = min(args.serve_requests,
                X.shape[0] if args.limit is None else args.limit)

    caches0 = scorer_cache_sizes()
    swap_state = {}
    swapper = None
    if args.swap_model:
        swap_family = load_model(args.swap_model)
        delay = args.swap_at * args.serve_requests / args.rate

        def _fire():
            time.sleep(delay)
            swap_state["ticket"] = loop.swap(model=swap_family)

        swapper = threading.Thread(target=_fire, daemon=True)
        swapper.start()

    drive = drive_poisson(loop, X[:n_req], rate_rps=args.rate,
                          n_requests=args.serve_requests,
                          budget_s=budget_s)
    if swapper is not None:
        swapper.join()
        swap_state["ticket"].installed.wait(10.0)
    loop.stop()
    caches1 = scorer_cache_sizes()
    recompiles = sum(caches1.values()) - sum(caches0.values())

    results = drive.pop("results")
    stats = loop.stats()
    slot = stats["models"]["default"]
    pad_total = slot["rows"] + slot["pad_rows"]
    slo_violations = sum(r.latency_s > args.slo_ms / 1e3 for r in results)
    payload = {"model": args.model, "kind": bank.kind, "mode": "serve",
               "rate_rps": args.rate, "slo_ms": args.slo_ms,
               "route": args.route, **drive,
               "padding_efficiency": (slot["rows"] / pad_total
                                      if pad_total else None),
               "slo_violations": slo_violations,
               "recompiles": recompiles, "stats": stats}
    if args.swap_model:
        versions = sorted({r.version for r in results})
        payload["swap"] = {"model": args.swap_model,
                           "installed_version": swap_state["ticket"].version,
                           "response_versions": versions}
        print(f"[serve] hot-swap -> version "
              f"{swap_state['ticket'].version}, response versions "
              f"{versions}, recompiles={recompiles}")
    if y_raw is not None and drive["rejects"] == 0 and results \
            and bank.kind == "binary" and not args.swap_model:
        preds = decide(bank, np.stack([r.margins for r in results]))
        # arrivals cycle the first n_req rows in submit order
        sel = np.arange(len(results)) % n_req
        payload["accuracy"] = float(np.mean(preds == y_raw[sel]))
        print(f"[serve] accuracy={payload['accuracy']:.4f}")
    print(f"[serve] {drive['responses']} responses at "
          f"{drive['offered_rps']:.0f} rps offered: "
          f"p50={1e3 * (drive['p50_s'] or 0):.2f}ms "
          f"p99={1e3 * (drive['p99_s'] or 0):.2f}ms "
          f"rejects={drive['rejects']} "
          f"slo_violations={slo_violations} "
          f"padding_eff={payload['padding_efficiency']:.3f} "
          f"flushes={slot['flushes']}")
    if args.out:
        with open(args.out, "w") as fh:
            json.dump(payload, fh, indent=1, default=float)
        print(f"[serve] wrote {args.out}")
    finish_obs(args, meta={
        "cli": "predict--serve", "model": args.model,
        "dataset": args.dataset, "rate_rps": args.rate,
        "p99_s": drive["p99_s"], "rejects": drive["rejects"],
        "recompiles": recompiles})
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", required=True,
                    help="artifact JSON from --save-model (solve or path)")
    ap.add_argument("--dataset", required=True,
                    help="paper dataset profile name or a .libsvm path")
    ap.add_argument("--layout", default="dense",
                    choices=["dense", "padded_csc"],
                    help="request layout served to the margin engine")
    ap.add_argument("--use-kernels", action="store_true",
                    help="route margins through the Pallas kernels")
    ap.add_argument("--dtype", default="fp32", choices=["fp32", "bf16"],
                    help="bank storage dtype: bf16 halves bank memory "
                         "and scorer HBM traffic; margins still "
                         "accumulate in f32 (DESIGN.md section 12)")
    ap.add_argument("--buckets", default=None,
                    help="comma-separated bucket sizes (default: powers "
                         "of two up to --max-batch)")
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--limit", type=int, default=None,
                    help="serve only the first N requests")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write predictions + bucket stats JSON here")
    ap.add_argument("--route", default="sparse",
                    choices=["sparse", "dense", "auto"],
                    help="dense-layout scorer: union-gather, densified "
                         "matmul, or the measured BENCH_serve.json "
                         "crossover (DESIGN.md 14.6)")
    ap.add_argument("--best-c", nargs="?", const="val_accuracy",
                    default=None, metavar="METRIC",
                    help="serve only the best grid point of a path "
                         "artifact, selected by METRIC "
                         "(default val_accuracy; 'nnz' = sparsest)")
    ap.add_argument("--serve", action="store_true",
                    help="continuous-batching loop under Poisson load "
                         "instead of the synchronous batcher")
    ap.add_argument("--rate", type=float, default=500.0,
                    help="[--serve] offered load, requests/s")
    ap.add_argument("--slo-ms", type=float, default=50.0,
                    help="[--serve] per-request latency budget")
    ap.add_argument("--serve-requests", type=int, default=512,
                    help="[--serve] total Poisson arrivals to drive")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="[--serve] admission-control queue bound "
                         "(default: unbounded)")
    ap.add_argument("--swap-model", default=None,
                    help="[--serve] artifact to hot-swap in mid-stream "
                         "(path artifacts: best-c selected live)")
    ap.add_argument("--swap-at", type=float, default=0.5,
                    help="[--serve] fire the swap at this fraction of "
                         "the run")
    from repro.launch.common import add_obs_args, finish_obs, setup_obs
    add_obs_args(ap)
    args = ap.parse_args(argv)
    setup_obs(args)

    from repro.launch.common import DTYPES
    family = load_model(args.model)
    if args.best_c is not None:
        i, best = pick_best_c(family, metric=args.best_c)
        print(f"[predict] --best-c {args.best_c}: grid point {i} "
              f"(c={best.c:.4g}, nnz={best.nnz}, "
              f"meta={best.meta.get(args.best_c)})")
        family = ModelFamily(kind="binary", models=(best,),
                             provenance=family.provenance)
    if args.serve:
        return _run_serve(args, family)
    bank = ModelBank.from_family(family, dtype=DTYPES[args.dtype])
    print(f"[predict] model={args.model} kind={bank.kind} "
          f"K={bank.n_models} n={bank.n_features} a_max={bank.a_max} "
          f"sparsity={bank.sparsity():.4f} dtype={args.dtype}")

    requests, y_raw, codes = _load_requests(args, bank.n_features)
    n_req = requests.shape[0]
    if args.limit is not None and args.limit < n_req:
        if isinstance(requests, CSRMatrix):
            hi = requests.indptr[args.limit]
            requests = CSRMatrix(requests.data[:hi], requests.indices[:hi],
                                 requests.indptr[:args.limit + 1],
                                 (args.limit, requests.shape[1]))
        else:
            requests = requests[:args.limit]
        y_raw = None if y_raw is None else y_raw[:args.limit]
        codes = None if codes is None else codes[:args.limit]
        n_req = args.limit

    buckets = ([int(b) for b in args.buckets.split(",")] if args.buckets
               else default_buckets(args.max_batch))
    k_max = (requests.max_col_nnz()
             if isinstance(requests, CSRMatrix) else None)
    batcher = MicroBatcher(bank, buckets=buckets, layout=args.layout,
                           use_kernels=args.use_kernels, k_max=k_max,
                           route=args.route)

    # kernel-vs-reference guard on the first bucket's worth of traffic
    if args.use_kernels:
        head = min(n_req, buckets[0])
        if args.layout == "dense":
            probe = np.asarray(requests[:head], np.float32)
        else:
            probe = CSRMatrix(
                requests.data[:requests.indptr[head]],
                requests.indices[:requests.indptr[head]],
                requests.indptr[:head + 1], (head, requests.shape[1]))
            from repro.data.libsvm import csr_to_padded_csc
            probe = csr_to_padded_csc(probe, k_max=k_max)
        zk = np.asarray(predict(bank, probe, use_kernels=True))
        zr = np.asarray(predict(bank, probe, use_kernels=False))
        err = float(np.abs(zk - zr).max()) if zk.size else 0.0
        print(f"[predict] kernel-vs-reference max |err| = {err:.2e}")
        # bf16 banks: both scorers read identically-rounded bf16 weights
        # but reduce in different orders, so allow a looser (still f32-
        # accumulation-sized) band than the fp32 path
        rtol = 1e-4 if args.dtype == "fp32" else 1e-3
        if err > rtol * max(1.0, float(np.abs(zr).max())):
            raise SystemExit("Pallas margin kernel disagrees with the "
                             "reference scorer")

    margins = batcher.predict(requests)
    stats = batcher.stats()
    preds = decide(bank, margins)
    payload = {"model": args.model, "kind": bank.kind,
               "n_requests": int(n_req), "layout": args.layout,
               "use_kernels": args.use_kernels, "stats": stats}
    if y_raw is not None:
        payload.update(_accuracy(bank, preds, y_raw, codes))
        acc = payload.get("accuracy", payload.get("best_accuracy"))
        print(f"[predict] accuracy={acc:.4f} over {n_req} requests")
    for b in stats["buckets"]:
        rps = b["rows_per_s"]
        print(f"[predict] bucket={b['bucket']:>5} calls={b['calls']} "
              f"rows={b['rows']} pad={b['pad_rows']} "
              f"warmup={b['warmup_seconds'] * 1e3:.1f}ms "
              + (f"steady={rps:.0f} rows/s" if rps else "steady=n/a"))
    print(f"[predict] compiles={stats['compiles']} "
          f"(one warmup per bucket shape)")

    if args.out:
        payload["predictions"] = np.asarray(preds).tolist()
        with open(args.out, "w") as fh:
            json.dump(payload, fh, indent=1, default=float)
        print(f"[predict] wrote {args.out}")
    finish_obs(args, meta={
        "cli": "predict", "model": args.model, "dataset": args.dataset,
        "layout": args.layout, "n_requests": int(n_req),
        "steady_rows_per_s": stats.get("steady_rows_per_s")})
    return payload


if __name__ == "__main__":
    main()
