"""Serving driver: load a model artifact, score traffic, report latency.

    python -m repro.launch.solve --dataset a9a --save-model m.json
    python -m repro.launch.predict --model m.json --dataset a9a

Loads a `repro.serve` artifact (binary model, OVR head, or path family),
stacks it into a `ModelBank`, and streams the dataset's rows through the
microbatched prediction engine (DESIGN.md section 10.4): requests are
padded to bucket shapes so only the first call per bucket compiles, and
per-bucket latency / throughput are reported. `--layout padded_csc`
serves the feature-major sparse request path; `--use-kernels` routes
margins through the Pallas kernels (kernels/pcdn_margin.py), whose
outputs are checked against the XLA reference scorer on the first batch.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.data import load_libsvm, paper_like
from repro.data.libsvm import CSRMatrix
from repro.serve.artifact import load_model
from repro.serve.batcher import MicroBatcher, default_buckets
from repro.serve.predict import ModelBank, decide, predict


def _load_requests(args, n_features: int):
    """-> (requests, y_raw, codes) — y_raw in the loader's normalized
    vocabulary (+-1 for <= 2 labels), codes the sorted-vocabulary class
    codes, both None when unlabeled. File datasets honor --layout;
    profile names score the held-out test split of the generator."""
    if os.path.exists(args.dataset):
        csr, codes, classes = load_libsvm(args.dataset,
                                          n_features=n_features,
                                          layout="csr",
                                          return_classes=True)
        codes = np.asarray(codes, np.int64)
        y_raw = np.asarray(classes)[codes]
        if args.layout == "padded_csc":
            return csr, y_raw, codes
        return csr.to_dense(), y_raw, codes
    _, _, Xte, yte, _ = paper_like(args.dataset, with_test=True,
                                   seed=args.seed)
    codes = (np.asarray(yte) > 0).astype(np.int64)
    if args.layout == "padded_csc":
        return CSRMatrix.from_dense(Xte), yte, codes
    return Xte, yte, codes


def _accuracy(bank: ModelBank, preds: np.ndarray, y_raw, codes) -> dict:
    """Per-kind accuracy: one scalar for binary/ovr, per-point for path.

    OVR banks compare on class CODES: both the loader's vocabulary and
    `bank.classes` are sorted ascending, so codes align even when the
    bank was trained on raw labels a binary file normalizes to +-1
    (the {3, 7}-labeled two-class case).
    """
    if bank.kind == "ovr":
        pred_codes = np.searchsorted(np.asarray(bank.classes), preds)
        return {"accuracy": float(np.mean(pred_codes == codes))}
    if bank.kind == "path":
        accs = [float(np.mean(preds[:, k] == y_raw))
                for k in range(bank.n_models)]
        best = int(np.argmax(accs))
        return {"per_point": accs, "best_index": best,
                "best_accuracy": accs[best]}
    return {"accuracy": float(np.mean(preds == y_raw))}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", required=True,
                    help="artifact JSON from --save-model (solve or path)")
    ap.add_argument("--dataset", required=True,
                    help="paper dataset profile name or a .libsvm path")
    ap.add_argument("--layout", default="dense",
                    choices=["dense", "padded_csc"],
                    help="request layout served to the margin engine")
    ap.add_argument("--use-kernels", action="store_true",
                    help="route margins through the Pallas kernels")
    ap.add_argument("--dtype", default="fp32", choices=["fp32", "bf16"],
                    help="bank storage dtype: bf16 halves bank memory "
                         "and scorer HBM traffic; margins still "
                         "accumulate in f32 (DESIGN.md section 12)")
    ap.add_argument("--buckets", default=None,
                    help="comma-separated bucket sizes (default: powers "
                         "of two up to --max-batch)")
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--limit", type=int, default=None,
                    help="serve only the first N requests")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write predictions + bucket stats JSON here")
    from repro.launch.common import add_obs_args, finish_obs, setup_obs
    add_obs_args(ap)
    args = ap.parse_args(argv)
    setup_obs(args)

    from repro.launch.common import DTYPES
    family = load_model(args.model)
    bank = ModelBank.from_family(family, dtype=DTYPES[args.dtype])
    print(f"[predict] model={args.model} kind={bank.kind} "
          f"K={bank.n_models} n={bank.n_features} a_max={bank.a_max} "
          f"sparsity={bank.sparsity():.4f} dtype={args.dtype}")

    requests, y_raw, codes = _load_requests(args, bank.n_features)
    n_req = requests.shape[0]
    if args.limit is not None and args.limit < n_req:
        if isinstance(requests, CSRMatrix):
            hi = requests.indptr[args.limit]
            requests = CSRMatrix(requests.data[:hi], requests.indices[:hi],
                                 requests.indptr[:args.limit + 1],
                                 (args.limit, requests.shape[1]))
        else:
            requests = requests[:args.limit]
        y_raw = None if y_raw is None else y_raw[:args.limit]
        codes = None if codes is None else codes[:args.limit]
        n_req = args.limit

    buckets = ([int(b) for b in args.buckets.split(",")] if args.buckets
               else default_buckets(args.max_batch))
    k_max = (requests.max_col_nnz()
             if isinstance(requests, CSRMatrix) else None)
    batcher = MicroBatcher(bank, buckets=buckets, layout=args.layout,
                           use_kernels=args.use_kernels, k_max=k_max)

    # kernel-vs-reference guard on the first bucket's worth of traffic
    if args.use_kernels:
        head = min(n_req, buckets[0])
        if args.layout == "dense":
            probe = np.asarray(requests[:head], np.float32)
        else:
            probe = CSRMatrix(
                requests.data[:requests.indptr[head]],
                requests.indices[:requests.indptr[head]],
                requests.indptr[:head + 1], (head, requests.shape[1]))
            from repro.data.libsvm import csr_to_padded_csc
            probe = csr_to_padded_csc(probe, k_max=k_max)
        zk = np.asarray(predict(bank, probe, use_kernels=True))
        zr = np.asarray(predict(bank, probe, use_kernels=False))
        err = float(np.abs(zk - zr).max()) if zk.size else 0.0
        print(f"[predict] kernel-vs-reference max |err| = {err:.2e}")
        # bf16 banks: both scorers read identically-rounded bf16 weights
        # but reduce in different orders, so allow a looser (still f32-
        # accumulation-sized) band than the fp32 path
        rtol = 1e-4 if args.dtype == "fp32" else 1e-3
        if err > rtol * max(1.0, float(np.abs(zr).max())):
            raise SystemExit("Pallas margin kernel disagrees with the "
                             "reference scorer")

    margins = batcher.predict(requests)
    stats = batcher.stats()
    preds = decide(bank, margins)
    payload = {"model": args.model, "kind": bank.kind,
               "n_requests": int(n_req), "layout": args.layout,
               "use_kernels": args.use_kernels, "stats": stats}
    if y_raw is not None:
        payload.update(_accuracy(bank, preds, y_raw, codes))
        acc = payload.get("accuracy", payload.get("best_accuracy"))
        print(f"[predict] accuracy={acc:.4f} over {n_req} requests")
    for b in stats["buckets"]:
        rps = b["rows_per_s"]
        print(f"[predict] bucket={b['bucket']:>5} calls={b['calls']} "
              f"rows={b['rows']} pad={b['pad_rows']} "
              f"warmup={b['warmup_seconds'] * 1e3:.1f}ms "
              + (f"steady={rps:.0f} rows/s" if rps else "steady=n/a"))
    print(f"[predict] compiles={stats['compiles']} "
          f"(one warmup per bucket shape)")

    if args.out:
        payload["predictions"] = np.asarray(preds).tolist()
        with open(args.out, "w") as fh:
            json.dump(payload, fh, indent=1, default=float)
        print(f"[predict] wrote {args.out}")
    finish_obs(args, meta={
        "cli": "predict", "model": args.model, "dataset": args.dataset,
        "layout": args.layout, "n_requests": int(n_req),
        "steady_rows_per_s": stats.get("steady_rows_per_s")})
    return payload


if __name__ == "__main__":
    main()
