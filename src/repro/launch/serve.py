"""Batched serving driver: prefill a batch of prompts, decode greedily.

``python -m repro.launch.serve --arch qwen2-0.5b --batch 4 --new-tokens 16``
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import make_host_mesh
from repro.models import decode as dec
from repro.models.transformer import Model
from repro.train.steps import make_serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=list(ARCH_IDS))
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=not args.full)
    mesh = make_host_mesh()
    model = Model(cfg, mesh)
    params = model.init_params(jax.random.PRNGKey(args.seed))

    key = jax.random.PRNGKey(args.seed + 1)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (args.batch, cfg.vlm.n_patches, cfg.d_model),
            jnp.float32).astype(cfg.jnp_dtype) * 0.02
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (args.batch, cfg.encdec.encoder_frames, cfg.d_model),
            jnp.float32).astype(cfg.jnp_dtype) * 0.02

    max_len = args.prompt_len + args.new_tokens + \
        (cfg.vlm.n_patches if cfg.family == "vlm" else 0)
    t0 = time.time()
    logits, cache = dec.prefill(model, params, batch, max_len=max_len)
    serve_step = jax.jit(make_serve_step(model))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    generated = [tok]
    for _ in range(args.new_tokens - 1):
        logits, cache = serve_step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        generated.append(tok)
    out = jnp.concatenate(generated, axis=1)
    dt = time.time() - t0
    tps = args.batch * args.new_tokens / dt
    print(f"[serve] {args.arch}: batch={args.batch} "
          f"prompt={args.prompt_len} new={args.new_tokens} "
          f"-> {tps:.1f} tok/s (incl. compile)")
    print("[serve] sample continuations:", np.asarray(out[:2, :8]))
    return out


if __name__ == "__main__":
    main()
