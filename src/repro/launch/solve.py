"""PCDN solver driver (the paper's end-to-end path):
``python -m repro.launch.solve --dataset real-sim --loss logistic --P 512``

Loads/generates an l1 classification problem, runs the selected solver
(pcdn / cdn / scdn / tron), reports the Fig. 4-style trace, and
checkpoints solver state every outer iteration (restart-safe).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (PCDNConfig, cdn_config, make_problem, scdn, solve,
                        tron)
from repro.core.scdn import SCDNConfig
from repro.core.sharded import ShardedPCDNConfig, solve_sharded
from repro.data import load_libsvm, paper_like
from repro.data.synthetic import train_accuracy
from repro.launch.mesh import make_host_mesh


def sparse_weight_record(w) -> dict:
    """JSON-compact (indices, values) form of an l1 solution — nnz-sized,
    so a news20-scale report stays small where a dense float list would
    be tens of MB of decimal text."""
    w = np.asarray(w, np.float64)
    idx = np.flatnonzero(w)
    return {"n_features": int(w.shape[0]),
            "w_indices": idx.tolist(),
            "w_values": w[idx].tolist()}


def load_warm_start(path: str, n: int, dtype) -> jnp.ndarray:
    """Load a w0 vector from .npy, or from JSON: a dense list, or the
    sparse {n_features, w_indices, w_values} record `--out` writes — so
    solve runs chain."""
    if path.endswith(".npy"):
        w = np.asarray(np.load(path), np.float64).reshape(-1)
    else:
        with open(path) as fh:
            obj = json.load(fh)
        if isinstance(obj, dict):
            if "w_indices" not in obj:
                raise ValueError(
                    f"warm start {path!r} has no weight record "
                    f"(w_indices/w_values) — reports written by older "
                    f"--out versions lack it; re-run the source solve "
                    f"or pass a .npy")
            w = np.zeros((int(obj["n_features"]),), np.float64)
            w[np.asarray(obj["w_indices"], np.int64)] = obj["w_values"]
        else:
            w = np.asarray(obj, np.float64).reshape(-1)
    if w.shape[0] != n:
        raise ValueError(
            f"warm start {path!r} has {w.shape[0]} features, problem "
            f"has {n}")
    return jnp.asarray(w, dtype)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="real-sim",
                    help="paper dataset profile name or a .libsvm path")
    ap.add_argument("--solver", default="pcdn",
                    choices=["pcdn", "cdn", "scdn", "tron"])
    ap.add_argument("--loss", default="logistic",
                    choices=["logistic", "squared_hinge"])
    ap.add_argument("--P", type=int, default=256, help="bundle size")
    ap.add_argument("--c", type=float, default=None,
                    help="regularization (default: paper's c* per dataset)")
    ap.add_argument("--tol", type=float, default=1e-3)
    ap.add_argument("--max-outer", type=int, default=100)
    ap.add_argument("--layout", default="auto",
                    choices=["auto", "dense", "padded_csc"],
                    help="design-matrix backend; padded_csc never "
                         "densifies a .libsvm input (DESIGN.md section 7)")
    ap.add_argument("--sharded", action="store_true",
                    help="run the distributed (shard_map) implementation")
    ap.add_argument("--data-parallel", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--warm-start", default=None, metavar="CKPT",
                    help="w0 from a .npy vector or a JSON file (a list or "
                         "an object with a 'w' key, e.g. a previous --out "
                         "report); pcdn/cdn only")
    ap.add_argument("--shrink", action="store_true",
                    help="active-set shrinking (pcdn/cdn; DESIGN.md "
                         "section 8.2)")
    ap.add_argument("--out", default=None, help="write history JSON here")
    args = ap.parse_args(argv)
    if args.warm_start and args.solver not in ("pcdn", "cdn"):
        ap.error("--warm-start requires --solver pcdn or cdn")
    if args.shrink and args.solver not in ("pcdn", "cdn"):
        ap.error("--shrink requires --solver pcdn or cdn")
    if (args.warm_start or args.shrink) and args.sharded:
        ap.error("--warm-start/--shrink are not wired into --sharded yet")

    if os.path.exists(args.dataset):
        # padded_csc: load sparse (csr for the sharded placer, which
        # re-pads per shard) and never touch the dense (s, n) form.
        if args.layout == "padded_csc":
            file_layout = "csr" if args.sharded else "padded_csc"
        else:
            file_layout = "dense"
        X, y = load_libsvm(args.dataset, layout=file_layout)
        c = args.c or 1.0
        Xte = yte = None
    else:
        Xtr, ytr, Xte, yte, spec = paper_like(args.dataset, with_test=True,
                                              seed=args.seed)
        X, y = Xtr, ytr
        c = args.c or (spec.c_logistic if args.loss == "logistic"
                       else spec.c_svm)
    print(f"[solve] dataset={args.dataset} s={X.shape[0]} n={X.shape[1]} "
          f"c={c} loss={args.loss} solver={args.solver} P={args.P}")

    t0 = time.time()
    if args.sharded:
        mesh = make_host_mesh(args.data_parallel, args.model_parallel)
        cfg = ShardedPCDNConfig(
            P_local=max(args.P // max(args.model_parallel, 1), 1), c=c,
            loss_name=args.loss, seed=args.seed)
        w, f, conv, k, hist = solve_sharded(X, y, mesh, cfg,
                                            max_outer=args.max_outer,
                                            tol_kkt=args.tol,
                                            layout=args.layout)
        history = hist
        nnz = int(np.sum(np.asarray(w) != 0))
    else:
        prob = make_problem(X, y, c=c, loss=args.loss,
                            layout=args.layout)
        w0 = (load_warm_start(args.warm_start, prob.n_features, prob.dtype)
              if args.warm_start else None)
        if args.solver == "pcdn":
            res = solve(prob, PCDNConfig(P=args.P, max_outer=args.max_outer,
                                         tol_kkt=args.tol, seed=args.seed,
                                         shrink=args.shrink), w0=w0)
        elif args.solver == "cdn":
            res = solve(prob, cdn_config(max_outer=args.max_outer,
                                         tol_kkt=args.tol, seed=args.seed,
                                         shrink=args.shrink), w0=w0)
        elif args.solver == "scdn":
            res = scdn.solve(prob, SCDNConfig(max_rounds=args.max_outer,
                                              tol_kkt=args.tol,
                                              seed=args.seed))
        else:
            res = tron.solve(prob, tron.TRONConfig(max_outer=args.max_outer,
                                                   tol_kkt=args.tol))
        w, f, conv = res.w, res.objective, res.converged
        history = {k_: v.tolist() for k_, v in
                   getattr(res, "history")._asdict().items()} \
            if hasattr(getattr(res, "history"), "_asdict") else res.history
        nnz = int(np.sum(np.asarray(w) != 0))
    dt = time.time() - t0

    print(f"[solve] F={f:.6f} converged={conv} nnz={nnz} time={dt:.1f}s")
    if Xte is not None:
        acc = train_accuracy(Xte, yte, np.asarray(w))
        print(f"[solve] test accuracy: {acc:.4f}")
    if args.out:
        with open(args.out, "w") as fh:
            # the sparse weight record makes the report a valid
            # --warm-start input for the next solve (e.g. the next point
            # of a manual c-sweep) at nnz-sized cost
            json.dump({"objective": float(f), "converged": bool(conv),
                       "nnz": nnz, "seconds": dt,
                       **sparse_weight_record(w),
                       "history": history if isinstance(history, dict)
                       else None}, fh, indent=1)
    return f


if __name__ == "__main__":
    main()
