"""PCDN solver driver (the paper's end-to-end path):
``python -m repro.launch.solve --dataset real-sim --loss logistic --P 512``

Loads/generates an l1 classification problem, runs the selected solver
(pcdn / cdn / scdn / tron) on the selected execution backend
(``--backend local|sharded`` — DESIGN.md section 9), reports the
Fig. 4-style trace, and writes a chaining-ready report with ``--out``.
``--warm-start`` and ``--shrink`` work on BOTH backends.

``--out`` reports are simultaneously (a) a servable model artifact
(``repro.serve`` schema — DESIGN.md section 10.1), (b) a ``--warm-start``
input (top-level sparse weight record), and (c) a history log.
``--save-model`` writes just the artifact.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.core import cdn_config, make_problem, scdn, tron, with_bundle_size
from repro.core.scdn import SCDNConfig
from repro.data.synthetic import train_accuracy
from repro.engine import LocalBackend, ShardedBackend
from repro.launch import common
from repro.serve import artifact as art


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="real-sim",
                    help="paper dataset profile name or a .libsvm path")
    ap.add_argument("--solver", default="pcdn",
                    choices=["pcdn", "cdn", "scdn", "tron"])
    ap.add_argument("--loss", default="logistic",
                    choices=["logistic", "squared_hinge"])
    ap.add_argument("--c", type=float, default=None,
                    help="regularization (default: paper's c* per dataset)")
    common.add_solver_args(ap)
    common.add_backend_args(ap)
    ap.add_argument("--sharded", action="store_true",
                    help="deprecated alias for --backend sharded")
    ap.add_argument("--out", default=None,
                    help="write the combined report (model artifact + "
                         "warm-start record + history) here")
    ap.add_argument("--save-model", default=None, metavar="PATH",
                    help="write just the serve artifact (no history)")
    common.add_obs_args(ap)
    common.add_diag_args(ap)
    common.add_fault_args(ap)
    args = ap.parse_args(argv)
    if args.sharded:
        args.backend = "sharded"
    if ((args.ckpt_dir or args.resume)
            and args.solver not in ("pcdn", "cdn")):
        ap.error("--ckpt-dir/--resume require --solver pcdn or cdn (the "
                 "checkpoint image is the bundle solver's EngineState)")
    if args.diag_out and args.solver not in ("pcdn", "cdn"):
        ap.error("--diag-out requires --solver pcdn or cdn (the KKT "
                 "attribution harvest is a bundle-solver output)")
    if args.warm_start and args.solver not in ("pcdn", "cdn"):
        ap.error("--warm-start requires --solver pcdn or cdn")
    if args.shrink and args.solver not in ("pcdn", "cdn"):
        ap.error("--shrink requires --solver pcdn or cdn")
    if args.backend == "sharded" and args.solver != "pcdn":
        ap.error("--backend sharded supports --solver pcdn only")
    if args.dtype == "bf16" and args.solver not in ("pcdn", "cdn"):
        ap.error("--dtype bf16 is studied for --solver pcdn/cdn only")
    common.check_dtype_envelope(args, ap, loss=args.loss)

    X, y, Xte, yte, spec = common.load_dataset(args, with_test=True)
    if spec is not None:
        c = args.c or (spec.c_logistic if args.loss == "logistic"
                       else spec.c_svm)
    else:
        c = args.c or 1.0
    print(f"[solve] dataset={args.dataset} s={X.shape[0]} n={X.shape[1]} "
          f"c={c} loss={args.loss} solver={args.solver} P={args.P} "
          f"backend={args.backend}")
    common.setup_obs(args)
    progress = common.make_progress_callback(args)
    ckpt = common.make_checkpointer(args, ap)
    from repro import fault
    plan = fault.plan_from_env()

    t0 = time.time()
    if args.backend == "sharded":
        # pcdn on a mesh: resilient_solve owns the backend (its factory
        # rebuilds at a damped P_local after a rollback, on the SAME mesh)
        backend0, _ = common.make_backend(args, X, y, c, args.loss)

        def factory(P):
            if int(P) == int(args.P):
                return backend0
            import dataclasses as _dc
            cfg = _dc.replace(
                common.build_sharded_config(args, c, args.loss),
                P_local=max(int(P) // max(args.model_parallel, 1), 1))
            return ShardedBackend(X, y, backend0.mesh, cfg,
                                  layout=args.layout)

        w0 = (common.load_warm_start(args.warm_start, backend0.n_features,
                                     backend0.dtype)
              if args.warm_start else None)
        res = fault.resilient_solve(
            factory, c, P=args.P, w0=w0, max_outer=args.max_outer,
            tol_kkt=args.tol, callback=progress, checkpointer=ckpt,
            resume=args.resume, max_retries=args.retries, plan=plan)
        w = res.w                      # resilient_solve returns host w
        f, conv = res.objective, res.converged
        history = common.history_dict(res.history)
    else:
        prob = make_problem(X, y, c=c, loss=args.loss,
                            layout=args.layout,
                            dtype=common.DTYPES[args.dtype])
        w0 = (common.load_warm_start(args.warm_start, prob.n_features,
                                     prob.dtype)
              if args.warm_start else None)
        if args.solver in ("pcdn", "cdn"):
            base_cfg = (common.build_pcdn_config(args)
                        if args.solver == "pcdn" else
                        cdn_config(max_outer=args.max_outer,
                                   tol_kkt=args.tol, seed=args.seed,
                                   shrink=args.shrink,
                                   use_kernels=args.use_kernels,
                                   record_aux=common._record_aux(args),
                                   record_kkt_vec=
                                   common._record_kkt_vec(args)))

            def factory(P):
                return LocalBackend(prob, with_bundle_size(base_cfg, P))

            res = fault.resilient_solve(
                factory, c, P=base_cfg.P, w0=w0,
                max_outer=base_cfg.max_outer, tol_kkt=base_cfg.tol_kkt,
                recheck_every=base_cfg.recheck_every,
                tol_rel_obj=base_cfg.tol_rel_obj, callback=progress,
                checkpointer=ckpt, resume=args.resume,
                max_retries=args.retries, design=prob.design, plan=plan)
        elif args.solver == "scdn":
            res = scdn.solve(prob, SCDNConfig(max_rounds=args.max_outer,
                                              tol_kkt=args.tol,
                                              seed=args.seed))
        else:
            res = tron.solve(prob, tron.TRONConfig(max_outer=args.max_outer,
                                                   tol_kkt=args.tol))
        w, f, conv = res.w, res.objective, res.converged
        history = common.history_dict(getattr(res, "history")) \
            if hasattr(getattr(res, "history"), "_asdict") else \
            {k_: np.asarray(v).tolist()
             for k_, v in res.history.items()}
    nnz = int(np.sum(np.asarray(w) != 0))
    dt = time.time() - t0
    common.finish_progress(args)

    faults = getattr(res, "faults", None)
    if faults:
        print(f"[fault] rollbacks={faults['rollbacks']} "
              f"p_schedule={faults['p_schedule']} "
              f"p_cert={faults['p_cert']} "
              f"resumed_from={faults['resumed_from']}")
    print(f"[solve] F={f:.6f} converged={conv} nnz={nnz} time={dt:.1f}s")
    if Xte is not None:
        acc = train_accuracy(Xte, yte, np.asarray(w))
        print(f"[solve] test accuracy: {acc:.4f}")
    if args.out or args.save_model:
        meta = {"objective": float(f), "converged": bool(conv), "nnz": nnz}
        if isinstance(history, dict) and history.get("kkt"):
            meta["kkt"] = float(history["kkt"][-1])
            meta["n_outer"] = len(history["kkt"])
        family = art.ModelFamily(
            kind="binary",
            models=(art.artifact_from_solution(w, args.loss, c, meta=meta),),
            provenance=art.solver_provenance(
                solver=args.solver, dataset=args.dataset, backend=args.backend,
                P=args.P, tol_kkt=args.tol, seed=args.seed,
                shrink=bool(args.shrink), loss=args.loss,
                dtype=args.dtype))
        if args.save_model:
            art.save_model(args.save_model, family)
        if args.out:
            # the top-level sparse weight record keeps the report a valid
            # --warm-start input (launch.common.load_warm_start) exactly
            # as before the artifact schema existed; n_features comes
            # from the artifact block itself
            record = common.sparse_weight_record(w)
            record.pop("n_features")
            extra = {
                "objective": float(f), "converged": bool(conv),
                "nnz": nnz, "seconds": dt, **record,
                "history": history if isinstance(history, dict) else None}
            pm = getattr(res, "postmortem", None)
            if pm:
                extra["postmortem"] = pm
            art.save_model(args.out, family, extra=extra)
    if args.diag_out:
        from repro.core import as_design
        prov = art.solver_provenance(
            solver=args.solver, dataset=args.dataset, backend=args.backend,
            P=args.P, tol_kkt=args.tol, seed=args.seed,
            shrink=bool(args.shrink), loss=args.loss, dtype=args.dtype)
        diag_report = {
            "provenance": prov, "loss": args.loss,
            "n_features": int(np.asarray(w).shape[0]),
            "objective": float(f), "converged": bool(conv), "nnz": nnz,
            "seconds": dt,
            "history": history if isinstance(history, dict) else None,
            "postmortem": getattr(res, "postmortem", None)}
        common.write_diag(args, diag_report, design=as_design(X),
                          tol_kkt=args.tol)
    common.finish_obs(args, meta={
        "cli": "solve", "dataset": args.dataset, "solver": args.solver,
        "backend": args.backend, "objective": float(f),
        "converged": bool(conv), "nnz": nnz, "seconds": dt})
    return f


if __name__ == "__main__":
    main()
