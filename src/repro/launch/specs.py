"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, no device allocation — the dry-run lowers
train_step / serve_step against these. `concrete=True` materializes small
random batches for smoke tests.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig, ShapeCell

Array = jax.Array


def _mk(concrete, key, shape, dtype, high=None):
    if not concrete:
        return jax.ShapeDtypeStruct(shape, dtype)
    if dtype == jnp.int32:
        return jax.random.randint(key, shape, 0, high or 2, jnp.int32)
    return jax.random.normal(key, shape, jnp.float32).astype(dtype) * 0.02


def train_batch_specs(cfg: ModelConfig, batch: int, seq: int,
                      concrete: bool = False, seed: int = 0) -> Dict[str, Any]:
    """Inputs of `train_step`: tokens + labels (+ modality stubs)."""
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    V = cfg.vocab_size
    out = {
        "tokens": _mk(concrete, keys[0], (batch, seq), jnp.int32, V),
        "labels": _mk(concrete, keys[1], (batch, seq), jnp.int32, V),
    }
    if cfg.family == "vlm":
        # ViT frontend stub: precomputed patch embeddings, already projected
        # to d_model; they occupy the first n_patches positions, so text
        # length is seq - n_patches (total sequence == the assigned seq).
        npatch = cfg.vlm.n_patches
        text = max(seq - npatch, 1)
        out["tokens"] = _mk(concrete, keys[0], (batch, text), jnp.int32, V)
        out["labels"] = _mk(concrete, keys[1], (batch, npatch + text),
                            jnp.int32, V)
        out["patches"] = _mk(concrete, keys[2], (batch, npatch, cfg.d_model),
                             cfg.jnp_dtype)
        mask = np.concatenate([np.zeros((batch, npatch), np.float32),
                               np.ones((batch, text), np.float32)], axis=1)
        out["loss_mask"] = (jnp.asarray(mask) if concrete
                            else jax.ShapeDtypeStruct((batch, npatch + text),
                                                      jnp.float32))
    if cfg.family == "encdec":
        # audio frontend stub: precomputed frame embeddings
        out["frames"] = _mk(concrete, keys[3],
                            (batch, cfg.encdec.encoder_frames, cfg.d_model),
                            cfg.jnp_dtype)
    return out


def decode_batch_specs(cfg: ModelConfig, batch: int,
                       concrete: bool = False, seed: int = 0):
    """Inputs of `serve_step`: one new token per sequence."""
    key = jax.random.PRNGKey(seed)
    return {"tokens": _mk(concrete, key, (batch, 1), jnp.int32,
                          cfg.vocab_size)}


def cell_input_specs(cfg: ModelConfig, cell: ShapeCell,
                     concrete: bool = False):
    if cell.kind in ("train", "prefill"):
        return train_batch_specs(cfg, cell.global_batch, cell.seq_len,
                                 concrete)
    return decode_batch_specs(cfg, cell.global_batch, concrete)
