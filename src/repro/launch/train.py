"""LM training driver: ``python -m repro.launch.train --arch <id> ...``

End-to-end: config -> mesh -> sharded params -> AdamW + schedule ->
token pipeline -> fault-tolerant step loop with checkpointing.
CPU-sized by default (reduced configs); pass --full on real hardware.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.data.tokens import TokenPipeline
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.transformer import Model
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.optim.schedules import linear_warmup_cosine
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import FaultTolerantRunner, RunnerConfig
from repro.train.steps import _batch_spec, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b", choices=list(ARCH_IDS))
    ap.add_argument("--full", action="store_true",
                    help="full-size config (needs real hardware)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=not args.full)
    mesh = (make_production_mesh() if args.full
            else make_host_mesh(args.data, args.model_parallel))
    model = Model(cfg, mesh)

    opt_cfg = AdamWConfig(lr=args.lr, weight_decay=0.01)
    sched = linear_warmup_cosine(args.lr, warmup_steps=max(args.steps // 20,
                                                           2),
                                 total_steps=args.steps)
    step_fn, p_specs, o_specs = make_train_step(model, opt_cfg, sched)

    params = model.shard_params(model.init_params(
        jax.random.PRNGKey(args.seed)))
    opt = adamw_init(params, opt_cfg)
    pipe = TokenPipeline(cfg, args.batch, args.seq, seed=args.seed)

    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)

    def loop_step(state, idx):
        params, opt = state
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(idx).items()}
        params, opt, metrics = jit_step(params, opt, batch)
        return (params, opt), metrics

    runner = FaultTolerantRunner(
        loop_step, (params, opt), ckpt,
        RunnerConfig(ckpt_every=args.ckpt_every))

    losses = []

    def cb(step, metrics):
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % 10 == 0 or step == runner.start_step:
            print(f"step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):8.3f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)

    t0 = time.time()
    runner.run(args.steps, metrics_cb=cb)
    dt = time.time() - t0
    print(f"[train] {args.arch}: {args.steps} steps in {dt:.1f}s; "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    assert np.isfinite(losses[-1]), "training diverged"
    return losses


if __name__ == "__main__":
    main()
