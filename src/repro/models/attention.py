"""GQA/MQA attention with RoPE, causal / sliding-window / bidirectional
masks, cross-attention, and a KV cache for decode.

Head layout: q (B, S, Kv, G, Dh) where H = Kv * G (grouped-query);
k/v (B, S, Kv, Dh). The scores einsum keeps the kv-head axis so GQA does
no materialized repeat. Sharding: heads axes carry the "heads"/"kv_heads"
logical names and resolve onto the model mesh axis when divisible.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import sharding as sh
from repro.models.config import ModelConfig
from repro.models.layers import rope

Array = jax.Array
NEG_INF = -1e30


def _head_padded(decl: sh.ParamDecl, dim: int, real: int):
    """Zero-initialize padded head slices so padding is output-exact."""
    inner = decl.init

    def init(key, shape, dtype):
        w = inner(key, shape, dtype)
        idx = jnp.arange(shape[dim])
        mask = (idx < real).reshape(
            [-1 if i == dim else 1 for i in range(len(shape))])
        return w * mask.astype(dtype)

    return sh.ParamDecl(decl.shape, decl.dtype, decl.logical_axes, init)


def attn_decls(cfg: ModelConfig, cross: bool = False):
    d, dt = cfg.d_model, cfg.jnp_dtype
    H, Kv, Dh = cfg.eff_heads, cfg.eff_kv_heads, cfg.resolved_head_dim
    rH, rKv = cfg.n_heads, cfg.n_kv_heads
    assert H % Kv == 0, (H, Kv)
    if cfg.fused_qkv and not cross:
        decls = {
            "wqkv": sh.dense((d, H + 2 * Kv, Dh),
                             ("embed", "heads", "head_dim"), dt),
            "wo": sh.dense((H, Dh, d), ("heads", "head_dim", "embed"), dt,
                           fan_in=rH * Dh),
        }
        if cfg.qkv_bias:
            decls["bqkv"] = sh.zeros((H + 2 * Kv, Dh),
                                     ("heads", "head_dim"), dt)
        if H != rH:
            decls["wo"] = _head_padded(decls["wo"], 0, rH)
        return decls
    decls = {
        "wq": sh.dense((d, H, Dh), ("embed", "heads", "head_dim"), dt),
        "wk": sh.dense((d, Kv, Dh), ("embed", "kv_heads", "head_dim"), dt),
        "wv": sh.dense((d, Kv, Dh), ("embed", "kv_heads", "head_dim"), dt),
        "wo": sh.dense((H, Dh, d), ("heads", "head_dim", "embed"), dt,
                       fan_in=rH * Dh),
    }
    if cfg.qkv_bias:
        decls["bq"] = sh.zeros((H, Dh), ("heads", "head_dim"), dt)
        decls["bk"] = sh.zeros((Kv, Dh), ("kv_heads", "head_dim"), dt)
        decls["bv"] = sh.zeros((Kv, Dh), ("kv_heads", "head_dim"), dt)
    if H != rH:
        decls["wq"] = _head_padded(decls["wq"], 1, rH)
        decls["wo"] = _head_padded(decls["wo"], 0, rH)
    if Kv != rKv:
        decls["wk"] = _head_padded(decls["wk"], 1, rKv)
        decls["wv"] = _head_padded(decls["wv"], 1, rKv)
    return decls


def _split_fused(cfg, out):
    H, Kv = cfg.eff_heads, cfg.eff_kv_heads
    return out[..., :H, :], out[..., H:H + Kv, :], out[..., H + Kv:, :]


def _project_qkv(cfg, p, x):
    """(q, k, v) — single einsum when fused (one bwd all-reduce of dx)."""
    if "wqkv" in p:
        out = jnp.einsum("bsd,dhk->bshk", x, p["wqkv"])
        if "bqkv" in p:
            out = out + p["bqkv"]
        return _split_fused(cfg, out)
    q = _project_q(cfg, p, x)
    k, v = _project_kv(cfg, p, x)
    return q, k, v


def _project_q(cfg, p, x):
    if "wqkv" in p:
        return _project_qkv(cfg, p, x)[0]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if "bq" in p:
        q = q + p["bq"]
    return q


def _project_kv(cfg, p, x):
    if "wqkv" in p:
        return _project_qkv(cfg, p, x)[1:]
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bk" in p:
        k, v = k + p["bk"], v + p["bv"]
    return k, v


def _mask_bias(q_pos: Array, k_pos: Array, causal: bool, window: int,
               k_valid: Optional[Array] = None) -> Array:
    """(..., Sq, Sk) additive bias from positions."""
    qp = q_pos[..., :, None]
    kp = k_pos[..., None, :]
    ok = jnp.broadcast_to(jnp.ones((), bool),
                          jnp.broadcast_shapes(qp.shape, kp.shape))
    if causal:
        ok = ok & (qp >= kp)
    if window > 0:
        ok = ok & (qp - kp < window)
    if k_valid is not None:
        ok = ok & k_valid[..., None, :]
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa(cfg: ModelConfig, q: Array, k: Array, v: Array,
          bias: Array) -> Array:
    """q (B,Sq,H,Dh), k/v (B,Sk,Kv,Dh), bias (B?,Sq,Sk) -> (B,Sq,H,Dh)."""
    B, Sq, H, Dh = q.shape
    Kv = k.shape[2]
    G = H // Kv
    qg = q.reshape(B, Sq, Kv, G, Dh)
    scale = Dh ** -0.5
    # keep operands in storage dtype; accumulate f32 on the MXU. An
    # .astype(f32) on k here would materialize an f32 copy of the whole
    # KV cache every decode step (measured 4.3 GB/step on grok decode).
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * scale
    if bias.ndim == 2:           # (Sq, Sk) -> broadcast over batch
        bias = bias[None]
    while bias.ndim < s.ndim:    # (B, Sq, Sk) -> (B, 1, 1, Sq, Sk)
        bias = bias[:, None]
    s = s + bias
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return o.reshape(B, Sq, H, Dh)


class KVCache(NamedTuple):
    k: Array          # (B, S_max, Kv, Dh)
    v: Array          # (B, S_max, Kv, Dh)
    length: Array     # () int32 — filled prefix length (uniform batch)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               n_layers: int = 0) -> KVCache:
    """Stacked-over-layers cache (leading layer dim when n_layers > 0)."""
    Kv, Dh = cfg.eff_kv_heads, cfg.resolved_head_dim
    if cfg.attn_window > 0:
        max_len = min(max_len, cfg.attn_window)
    shape = (batch, max_len, Kv, Dh)
    if n_layers:
        shape = (n_layers,) + shape
    dt = cfg.jnp_dtype
    return KVCache(jnp.zeros(shape, dt), jnp.zeros(shape, dt),
                   jnp.zeros((), jnp.int32))


# blockwise (flash-style) attention: never materializes (S, S) scores.
# This is the jnp twin of kernels/flash_attention (which targets real TPU);
# the dry-run lowers this version. Chunk sizes bound live memory to
# (B, H, CQ, CKV) per block.
BLOCK_Q = 512
BLOCK_KV = 512
BLOCKWISE_MIN_KV = 2048   # dense is fine (and faster to compile) below this


import functools as _functools


def _block_mask(q_pos, k_pos, causal, window, Sq, Skv):
    """(cq, ckv) bool validity from position vectors computed off loop
    indices — NEVER from precomputed position arrays, which XLA constant-
    folds into (nq x nk x ...) mask tensors that dwarf the activations."""
    ok = (k_pos < Skv)[None, :] & (q_pos < Sq)[:, None]
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if window > 0:
        ok &= q_pos[:, None] - k_pos[None, :] < window
    return ok


def _flash_fwd_scan(q, k, v, cq, ckv, causal, window, Sq, Skv):
    """-> (out (B,Sq',H,Dh), lse (B,Kv,G,Sq')). Online-softmax over kv
    chunks; the jnp twin of kernels/flash_attention."""
    B, Sq_p, H, Dh = q.shape
    Skv_p, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    nq, nk = Sq_p // cq, Skv_p // ckv
    scale = Dh ** -0.5
    qc = jnp.moveaxis(q.reshape(B, nq, cq, Kv, G, Dh), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, nk, ckv, Kv, Dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, ckv, Kv, Dh), 1, 0)

    def q_chunk(_, qs):
        qb, qi = qs
        q_pos = qi * cq + jnp.arange(cq)

        def kv_chunk(carry, ks):
            m, l, acc = carry
            kb, vb, ki = ks
            k_pos = ki * ckv + jnp.arange(ckv)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb, kb,
                           preferred_element_type=jnp.float32) * scale
            ok = _block_mask(q_pos, k_pos, causal, window, Sq, Skv)
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            m_cur = jnp.max(s, axis=-1, keepdims=True)
            m_new = jnp.maximum(m, m_cur)
            pexp = jnp.exp(s - m_new)
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(pexp, axis=-1, keepdims=True)
            acc = acc * corr + jnp.einsum(
                "bkgqs,bskd->bkgqd", pexp.astype(vb.dtype), vb,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        m0 = jnp.full((B, Kv, G, cq, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Kv, G, cq, 1), jnp.float32)
        a0 = jnp.zeros((B, Kv, G, cq, Dh), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_chunk, (m0, l0, a0),
                                      (kc, vc, jnp.arange(nk)))
        lsafe = jnp.maximum(l, 1e-30)
        out = acc / lsafe                                # (B,Kv,G,cq,Dh)
        lse = (m + jnp.log(lsafe))[..., 0]               # (B,Kv,G,cq)
        out = jnp.moveaxis(out, 3, 1).reshape(B, cq, Kv * G, Dh)
        return None, (out.astype(q.dtype), lse)

    _, (outs, lses) = jax.lax.scan(q_chunk, None,
                                   (qc, jnp.arange(nq)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * cq, H, Dh)
    lse = jnp.moveaxis(lses, 0, 3).reshape(B, Kv, G, nq * cq)
    return out, lse


@_functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash_mha(q, k, v, cq, ckv, causal, window, Sq, Skv):
    out, _ = _flash_fwd_scan(q, k, v, cq, ckv, causal, window, Sq, Skv)
    return out


def _flash_mha_fwd(q, k, v, cq, ckv, causal, window, Sq, Skv):
    out, lse = _flash_fwd_scan(q, k, v, cq, ckv, causal, window, Sq, Skv)
    return out, (q, k, v, out, lse)


def _flash_mha_bwd(cq, ckv, causal, window, Sq, Skv, res, do):
    """True flash backward: recompute p per block from (q, k, lse); O(S)
    residuals instead of the O(S^2 / chunks) scan residuals autodiff would
    save through the forward scans."""
    q, k, v, out, lse = res
    B, Sq_p, H, Dh = q.shape
    Skv_p, Kv = k.shape[1], k.shape[2]
    G = H // Kv
    nq, nk = Sq_p // cq, Skv_p // ckv
    scale = Dh ** -0.5

    # delta_i = sum_d do_i * out_i  (per q row)
    dof = do.astype(jnp.float32).reshape(B, Sq_p, Kv, G, Dh)
    outf = out.astype(jnp.float32).reshape(B, Sq_p, Kv, G, Dh)
    delta = jnp.einsum("bqkgd,bqkgd->bkgq", dof, outf)   # (B,Kv,G,Sq')

    qc = jnp.moveaxis(q.reshape(B, nq, cq, Kv, G, Dh), 1, 0)
    doc = jnp.moveaxis(dof.reshape(B, nq, cq, Kv, G, Dh), 1, 0)
    lsec = jnp.moveaxis(lse.reshape(B, Kv, G, nq, cq), 3, 0)
    dlc = jnp.moveaxis(delta.reshape(B, Kv, G, nq, cq), 3, 0)
    kc = jnp.moveaxis(k.reshape(B, nk, ckv, Kv, Dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, ckv, Kv, Dh), 1, 0)

    def q_loop(carry, qs):
        dk_full, dv_full = carry       # (nk,B,ckv,Kv,Dh) each
        qb, dob, lseb, dlb, qi = qs
        q_pos = qi * cq + jnp.arange(cq)

        def kv_loop(dq_acc_and_kv, ks):
            dq_acc, dk_full, dv_full = dq_acc_and_kv
            kb, vb, ki = ks
            k_pos = ki * ckv + jnp.arange(ckv)
            s = jnp.einsum("bqkgd,bskd->bkgqs", qb.astype(jnp.float32),
                           kb.astype(jnp.float32)) * scale
            ok = _block_mask(q_pos, k_pos, causal, window, Sq, Skv)
            s = jnp.where(ok[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lseb[..., None])             # (B,Kv,G,cq,ckv)
            dp = jnp.einsum("bqkgd,bskd->bkgqs", dob, vb.astype(jnp.float32))
            ds = p * (dp - dlb[..., None])               # (B,Kv,G,cq,ckv)
            dq_c = jnp.einsum("bkgqs,bskd->bqkgd", ds,
                              kb.astype(jnp.float32)) * scale
            dk_c = jnp.einsum("bkgqs,bqkgd->bskd", ds,
                              qb.astype(jnp.float32)) * scale
            dv_c = jnp.einsum("bkgqs,bqkgd->bskd", p, dob)
            dk_full = dk_full.at[ki].add(dk_c)
            dv_full = dv_full.at[ki].add(dv_c)
            return (dq_acc + dq_c, dk_full, dv_full), None

        dq0 = jnp.zeros((B, cq, Kv, G, Dh), jnp.float32)
        (dq_b, dk_full, dv_full), _ = jax.lax.scan(
            kv_loop, (dq0, dk_full, dv_full), (kc, vc, jnp.arange(nk)))
        return (dk_full, dv_full), dq_b

    dk0 = jnp.zeros((nk, B, ckv, Kv, Dh), jnp.float32)
    dv0 = jnp.zeros((nk, B, ckv, Kv, Dh), jnp.float32)
    (dks, dvs), dqs = jax.lax.scan(
        q_loop, (dk0, dv0),
        (qc, doc, lsec, dlc, jnp.arange(nq)))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(B, Sq_p, H, Dh).astype(q.dtype)
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, Skv_p, Kv, Dh).astype(k.dtype)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Skv_p, Kv, Dh).astype(v.dtype)
    return dq, dk, dv


_flash_mha.defvjp(_flash_mha_fwd, _flash_mha_bwd)


def _blockwise_sdpa(cfg: ModelConfig, q: Array, k: Array, v: Array,
                    q_pos: Array, k_pos: Array, causal: bool,
                    window: int) -> Array:
    """q (B,Sq,H,Dh), k/v (B,Skv,Kv,Dh); contiguous positions assumed
    (q and kv both starting at position 0)."""
    B, Sq, H, Dh = q.shape
    Skv = k.shape[1]
    cq = min(BLOCK_Q, Sq)
    ckv = min(BLOCK_KV, Skv)
    pq, pk = (-Sq) % cq, (-Skv) % ckv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    out = _flash_mha(q, k, v, cq, ckv, causal, window, Sq, Skv)
    return out[:, :Sq]


def attend_full(cfg: ModelConfig, p, x: Array, positions: Array,
                causal: bool = True, window: int = 0,
                kv_x: Optional[Array] = None,
                kv_positions: Optional[Array] = None) -> Array:
    """Training / prefill attention (no cache). Cross-attn when kv_x given.

    Dispatches to blockwise (flash-style) attention when the kv length
    crosses BLOCKWISE_MIN_KV — dense (S, S) scores do not fit HBM at the
    assigned 32k shapes."""
    if kv_x is None:
        kv_x, kv_positions = x, positions
        q, k, v = _project_qkv(cfg, p, x)
    else:
        q = _project_q(cfg, p, x)
        k, v = _project_kv(cfg, p, kv_x)
    if kv_positions is None:
        kv_positions = jnp.arange(kv_x.shape[1])
    if cfg.rope_theta > 0 and kv_x is x:  # rope for self-attn only
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_positions, cfg.rope_theta)
    if kv_x.shape[1] >= BLOCKWISE_MIN_KV:
        qpos = jnp.broadcast_to(positions, (x.shape[1],)) \
            if positions.ndim == 1 else positions[0]
        kpos = jnp.broadcast_to(kv_positions, (kv_x.shape[1],)) \
            if kv_positions.ndim == 1 else kv_positions[0]
        o = _blockwise_sdpa(cfg, q, k, v, qpos, kpos, causal, window)
    else:
        bias = _mask_bias(positions, kv_positions, causal, window)
        o = _sdpa(cfg, q, k, v, bias)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def decode_step(cfg: ModelConfig, p, x: Array, cache: KVCache,
                window: int = 0, constrain_fn=None) -> tuple[Array, KVCache]:
    """One-token decode: x (B, 1, D). Updates the (possibly rolling) cache.

    `constrain_fn(t)` (optional) re-shards the tiny per-step q/k/v tensors
    to batch-only sharding. When the KV cache is SEQUENCE-sharded over the
    model axis (kv_heads don't divide it), head-sharded q would make GSPMD
    all-gather the whole cache (measured 20 TB/step on grok decode_32k);
    replicated q instead yields flash-decoding: local scores per seq shard
    + small softmax-stat reductions."""
    B = x.shape[0]
    S_max = cache.k.shape[1]
    pos = cache.length                        # scalar current position
    q, k_new, v_new = _project_qkv(cfg, p, x)
    if constrain_fn is not None:
        q, k_new, v_new = (constrain_fn(t) for t in (q, k_new, v_new))
    if cfg.rope_theta > 0:
        posv = jnp.full((B, 1), pos, jnp.int32)
        q = rope(q, posv, cfg.rope_theta)
        k_new = rope(k_new, posv, cfg.rope_theta)
    # rolling write for windowed caches, plain write otherwise
    slot = jnp.where(window > 0, pos % S_max, jnp.minimum(pos, S_max - 1))
    k = jax.lax.dynamic_update_slice(cache.k, k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new, (0, slot, 0, 0))
    # key positions: for a rolling cache, slot i holds position
    # pos - ((slot - i) mod S_max); for a plain cache, position i.
    idx = jnp.arange(S_max)
    if window > 0:
        k_pos = pos - ((slot - idx) % S_max)
        valid = (k_pos >= 0) & (k_pos >= pos - window + 1) & (k_pos <= pos)
    else:
        k_pos = idx
        valid = idx <= pos
    q_pos = jnp.full((B, 1), pos, jnp.int32)
    bias = _mask_bias(q_pos, jnp.broadcast_to(k_pos, (B, S_max)),
                      causal=False, window=0,
                      k_valid=jnp.broadcast_to(valid, (B, S_max)))
    o = _sdpa(cfg, q, k, v, bias)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return out, KVCache(k, v, pos + 1)


def prefill(cfg: ModelConfig, p, x: Array, positions: Array,
            cache: KVCache, window: int = 0) -> tuple[Array, KVCache]:
    """Prefill S tokens into an empty cache and return outputs + cache."""
    S = x.shape[1]
    out = attend_full(cfg, p, x, positions, causal=True, window=window)
    k, v = _project_kv(cfg, p, x)
    if cfg.rope_theta > 0:
        k = rope(k, positions, cfg.rope_theta)
    S_max = cache.k.shape[1]
    if window > 0 and S > S_max:
        # ring invariant: slot j holds the key of position p with
        # p % S_max == j; the last S_max keys land rolled by S % S_max.
        k, v = k[:, -S_max:], v[:, -S_max:]
        shift = S % S_max
        k = jnp.roll(k, shift, axis=1)
        v = jnp.roll(v, shift, axis=1)
        kc = jax.lax.dynamic_update_slice(cache.k, k, (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache.v, v, (0, 0, 0, 0))
    else:
        kc = jax.lax.dynamic_update_slice(cache.k, k, (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache.v, v, (0, 0, 0, 0))
    return out, KVCache(kc, vc, jnp.asarray(S, jnp.int32))
