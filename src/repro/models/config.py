"""Model configuration dataclasses shared by all 10 assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0            # deepseek: shared experts always active
    d_ff_expert: int = 0         # per-expert hidden
    d_ff_shared: int = 0         # total shared hidden (n_shared * d_ff_expert)
    capacity_factor: float = 1.25
    first_layer_dense: bool = False
    d_ff_dense: int = 0          # d_ff of the dense first layer


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0             # 0 => ceil(d_model / 16)


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma: repeating (rec, rec, attn) pattern."""
    pattern_period: int = 3      # every third layer is local attention
    lru_width: int = 0           # 0 => d_model
    conv_width: int = 4
    window: int = 2048           # local-attention window
    lru_c: float = 8.0           # RG-LRU a = sigmoid(L)^(c*r)


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    """Whisper: encoder-decoder with stubbed conv/audio frontend."""
    n_encoder_layers: int = 12
    encoder_frames: int = 1500   # frontend stub output length
    max_target_positions: int = 448


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    """Pixtral: ViT frontend stub; patch embeddings prepended to tokens."""
    n_patches: int = 256         # stub patches per example
    patch_embed_dim: int = 0     # 0 => d_model (already projected)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 => d_model // n_heads
    mlp_type: str = "swiglu"     # swiglu | geglu | gelu_mlp
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    embed_scale: bool = False    # gemma: scale embeddings by sqrt(d_model)
    attn_window: int = 0         # 0 => full attention
    # head padding (beyond-paper optimization, EXPERIMENTS.md section Perf):
    # when n_heads doesn't divide the model axis (e.g. qwen1.5's 40 on a
    # 16-wide axis) attention replicates across it (measured 16x flop +
    # HBM waste). Padding q/kv heads to a divisible count with ZERO-
    # initialized weights is output-exact at init and shards cleanly.
    pad_heads: int = 0           # 0 => no padding
    pad_kv_heads: int = 0
    # fused QKV projection (beyond-paper optimization): one einsum for
    # q/k/v means ONE backward all-reduce of dL/dx instead of three
    # (measured 30% of grok train_4k's collective bytes). Numerically
    # identical; params store a single wqkv.
    fused_qkv: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vlm: Optional[VLMConfig] = None
    dtype: str = "bfloat16"
    remat: bool = True           # checkpoint each scanned layer in train

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def eff_heads(self) -> int:
        return self.pad_heads or self.n_heads

    @property
    def eff_kv_heads(self) -> int:
        return self.pad_kv_heads or self.n_kv_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 128 so the logits dim always
        shards over the model axis (whisper's 51865 is odd — unpadded it
        replicates (B, S, V) f32 logits and all-reduces them; measured
        ~98 TB of collective traffic on train_4k). Pad logits are masked
        to -inf in apply_unembed."""
        return -(-self.vocab_size // 128) * 128

    @property
    def jnp_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode with O(1)-or-O(window) state? (long_500k)."""
        return self.family in ("ssm", "hybrid")

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned input-shape cell."""
    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPE_CELLS = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


def get_shape_cell(name: str) -> ShapeCell:
    for c in SHAPE_CELLS:
        if c.name == name:
            return c
    raise KeyError(name)


def cell_applicable(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """Whether a shape cell applies to an arch (DESIGN.md shape-cell notes)."""
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 512k dense-KV decode has no "
                       "sub-quadratic mechanism (skip per assignment)")
    if cfg.family == "encdec" and cell.name == "long_500k":
        return False, "whisper decoder max positions 448 << 524288"
    return True, ""
