"""Serving path: per-family decode caches, prefill, and one-token decode.

`init_cache_abstract` builds ShapeDtypeStruct caches so the dry-run can
lower `serve_step` against a seq_len-sized cache without allocating it.
Cache memory classes (DESIGN.md shape-cell notes):
  dense/vlm/moe : O(S) KV cache            (long_500k skipped)
  encdec        : O(S) self + O(1500) cross
  ssm           : O(1) state               (long_500k runs)
  hybrid        : O(1) LRU + O(window) KV  (long_500k runs)
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru, ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.transformer import Model

Array = jax.Array


# --------------------------------------------------------------------------
# cache construction


def init_cache(model: Model, batch: int, max_len: int, concrete=True):
    cfg = model.cfg
    zeros = jnp.zeros if concrete else jax.ShapeDtypeStruct

    def mk(shape, dtype):
        return (jnp.zeros(shape, dtype) if concrete
                else jax.ShapeDtypeStruct(shape, dtype))

    def kv(n_layers, length):
        Kv, Dh = cfg.eff_kv_heads, cfg.resolved_head_dim
        shape = (n_layers, batch, length, Kv, Dh)
        return {"k": mk(shape, cfg.jnp_dtype), "v": mk(shape, cfg.jnp_dtype)}

    cache: Dict[str, Any] = {"length": mk((), jnp.int32)}
    if cfg.family in ("dense", "vlm"):
        cache["kv"] = kv(cfg.n_layers, max_len)
    elif cfg.family == "moe":
        n_moe = cfg.n_layers - (1 if cfg.moe.first_layer_dense else 0)
        cache["kv"] = kv(n_moe, max_len)
        if cfg.moe.first_layer_dense:
            cache["kv0"] = kv(1, max_len)
    elif cfg.family == "ssm":
        Di = cfg.ssm.expand * cfg.d_model
        N, Kc = cfg.ssm.d_state, cfg.ssm.d_conv
        cache["h"] = mk((cfg.n_layers, batch, Di, N), jnp.float32)
        cache["conv"] = mk((cfg.n_layers, batch, Kc - 1, Di), cfg.jnp_dtype)
    elif cfg.family == "hybrid":
        W = cfg.hybrid.lru_width or cfg.d_model
        Kc = cfg.hybrid.conv_width
        nt = cfg.n_layers // 3
        rem = cfg.n_layers - 3 * nt
        wlen = min(max_len, cfg.hybrid.window)
        for i in (1, 2):
            cache[f"lru{i}_h"] = mk((nt, batch, W), jnp.float32)
            cache[f"lru{i}_conv"] = mk((nt, batch, Kc - 1, W), cfg.jnp_dtype)
        cache["kv"] = kv(nt, wlen)
        for i in range(rem):
            cache[f"tail{i}_h"] = mk((batch, W), jnp.float32)
            cache[f"tail{i}_conv"] = mk((batch, Kc - 1, W), cfg.jnp_dtype)
    elif cfg.family == "encdec":
        cache["kv"] = kv(cfg.n_layers, max_len)                  # self
        fr = cfg.encdec.encoder_frames
        cache["cross"] = kv(cfg.n_layers, fr)                    # cross k/v
    else:
        raise ValueError(cfg.family)
    return cache


# --------------------------------------------------------------------------
# one-token decode


def _layer_kv(cache_kv, i=None):
    """Make a per-layer attn.KVCache view (used inside scan, i is None)."""
    return attn.KVCache(cache_kv["k"], cache_kv["v"], cache_kv["length"])


def decode_step(model: Model, params, cache, tokens: Array) -> tuple:
    """tokens: (B, 1) int32 -> (logits (B, 1, V), new cache)."""
    cfg = model.cfg
    x = L.apply_embed(cfg, params["embed"], tokens)
    x = model._constrain(x, "batch", None, "embed_act")
    length = cache["length"]
    # flash-decoding guard for seq-sharded caches (see attn.decode_step):
    # only needed when kv heads cannot shard over the model axis.
    m_sz = model.mesh.shape.get("model", 1)
    kv_shardable = m_sz <= 1 or cfg.eff_kv_heads % m_sz == 0
    qrep = (None if kv_shardable else
            (lambda t: model._constrain(t, "batch", None, None, None)))

    if cfg.family in ("dense", "vlm", "moe"):
        window = cfg.attn_window

        def body(h, xs):
            p, k_l, v_l = xs
            kvc = attn.KVCache(k_l, v_l, length)
            a, kvc = attn.decode_step(
                cfg, p["attn"], L.apply_norm(cfg, p["norm1"], h), kvc,
                window=window, constrain_fn=qrep)
            h = h + a
            hn = L.apply_norm(cfg, p["norm2"], h)
            if cfg.family == "moe":
                f = moe_mod.apply_moe_dense(cfg, p["moe"], hn)
            else:
                f = L.apply_mlp(cfg, p["mlp"], hn)
            return h + f, (kvc.k, kvc.v)

        if cfg.family == "moe" and cfg.moe.first_layer_dense:
            kv0 = attn.KVCache(cache["kv0"]["k"][0], cache["kv0"]["v"][0],
                               length)
            a, kv0 = attn.decode_step(
                cfg, params["layer0"]["attn"],
                L.apply_norm(cfg, params["layer0"]["norm1"], x), kv0,
                constrain_fn=qrep)
            x = x + a
            hn = L.apply_norm(cfg, params["layer0"]["norm2"], x)
            x = x + L.apply_mlp(cfg, params["layer0"]["mlp"], hn)
            cache = dict(cache)
            cache["kv0"] = {"k": kv0.k[None], "v": kv0.v[None]}

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["layers"], cache["kv"]["k"], cache["kv"]["v"]))
        new_cache = dict(cache)
        new_cache["kv"] = {"k": ks, "v": vs}

    elif cfg.family == "ssm":
        def body(h, xs):
            p, h_l, conv_l = xs
            st = ssm_mod.SSMState(h_l, conv_l, length)
            out, st = ssm_mod.ssm_decode_step(
                cfg, p["ssm"], L.apply_norm(cfg, p["norm"], h), st)
            return h + out, (st.h, st.conv)

        x, (hs, convs) = jax.lax.scan(
            body, x, (params["layers"], cache["h"], cache["conv"]))
        new_cache = dict(cache)
        new_cache["h"], new_cache["conv"] = hs, convs

    elif cfg.family == "hybrid":
        window = cfg.hybrid.window

        def rec_step(p, h, h_l, conv_l):
            st = rglru.LRUState(h_l, conv_l, length)
            a, st = rglru.rglru_decode_step(
                cfg, p["rec"], L.apply_norm(cfg, p["norm1"], h), st)
            h = h + a
            f = L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["norm2"], h))
            return h + f, st

        def body(h, xs):
            p, s1h, s1c, s2h, s2c, k_l, v_l = xs
            h, st1 = rec_step(p["rec1"], h, s1h, s1c)
            h, st2 = rec_step(p["rec2"], h, s2h, s2c)
            kvc = attn.KVCache(k_l, v_l, length)
            a, kvc = attn.decode_step(
                cfg, p["attn"]["attn"],
                L.apply_norm(cfg, p["attn"]["norm1"], h), kvc,
                window=window, constrain_fn=qrep)
            h = h + a
            f = L.apply_mlp(cfg, p["attn"]["mlp"],
                            L.apply_norm(cfg, p["attn"]["norm2"], h))
            return h + f, (st1.h, st1.conv, st2.h, st2.conv, kvc.k, kvc.v)

        x, outs = jax.lax.scan(
            body, x, (params["triples"],
                      cache["lru1_h"], cache["lru1_conv"],
                      cache["lru2_h"], cache["lru2_conv"],
                      cache["kv"]["k"], cache["kv"]["v"]))
        new_cache = dict(cache)
        (new_cache["lru1_h"], new_cache["lru1_conv"], new_cache["lru2_h"],
         new_cache["lru2_conv"], ks, vs) = outs
        new_cache["kv"] = {"k": ks, "v": vs}
        i = 0
        while f"tail_rec{i}" in params:
            st = rglru.LRUState(cache[f"tail{i}_h"], cache[f"tail{i}_conv"],
                                length)
            p = params[f"tail_rec{i}"]
            a, st = rglru.rglru_decode_step(
                cfg, p["rec"], L.apply_norm(cfg, p["norm1"], x), st)
            x = x + a
            x = x + L.apply_mlp(cfg, p["mlp"],
                                L.apply_norm(cfg, p["norm2"], x))
            new_cache[f"tail{i}_h"], new_cache[f"tail{i}_conv"] = st.h, st.conv
            i += 1

    elif cfg.family == "encdec":
        # position embedding for the current step (sinusoidal, computed
        # directly from `length` to stay shape-generic):
        half = cfg.d_model // 2
        freqs = jnp.exp(-jnp.arange(half) * (jnp.log(10000.0) / (half - 1)))
        ang = length.astype(jnp.float32) * freqs
        pos_e = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None]
        x = x + pos_e.astype(x.dtype)

        def body(h, xs):
            p, k_l, v_l, ck_l, cv_l = xs
            kvc = attn.KVCache(k_l, v_l, length)
            a, kvc = attn.decode_step(
                cfg, p["self_attn"], L.apply_norm(cfg, p["norm1"], h), kvc,
                constrain_fn=qrep)
            h = h + a
            # cross attention against the precomputed encoder kv
            hq = L.apply_norm(cfg, p["norm_x"], h)
            q = jnp.einsum("bsd,dhk->bshk", hq, p["cross_attn"]["wq"])
            if "bq" in p["cross_attn"]:
                q = q + p["cross_attn"]["bq"]
            bias = jnp.zeros((1, 1, ck_l.shape[1]), jnp.float32)
            o = attn._sdpa(cfg, q, ck_l, cv_l, bias)
            h = h + jnp.einsum("bshk,hkd->bsd", o, p["cross_attn"]["wo"])
            f = L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["norm2"], h))
            return h + f, (kvc.k, kvc.v)

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["dec_layers"], cache["kv"]["k"],
                      cache["kv"]["v"], cache["cross"]["k"],
                      cache["cross"]["v"]))
        new_cache = dict(cache)
        new_cache["kv"] = {"k": ks, "v": vs}
    else:
        raise ValueError(cfg.family)

    x = L.apply_norm(cfg, params["final_norm"], x)
    logits = L.apply_unembed(cfg, params["embed"], x)
    new_cache["length"] = length + 1
    return model._constrain(logits, "batch", None, "vocab"), new_cache


# --------------------------------------------------------------------------
# prefill (build caches by running the full sequence)


def prefill(model: Model, params, batch: Dict[str, Array],
            max_len: int) -> tuple:
    """Run the prompt and return (last-position logits, decode cache).

    Implemented for the interactive serving example; the heavy-lowering
    path for benchmarks is `Model.logits` (prefill cells) and
    `decode_step` (decode cells).
    """
    cfg = model.cfg
    tokens = batch["tokens"]
    B, S = tokens.shape
    cache = init_cache(model, B, max_len)
    logits = model.logits(params, batch, train=False)

    # rebuild caches by replaying projections layer-by-layer (keeps decode
    # correctness exactly aligned with training numerics). Dense/moe/encdec
    # families store rotated keys.
    x = L.apply_embed(cfg, params["embed"], tokens)
    if cfg.family == "vlm":
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    S = x.shape[1]                       # vlm: patches + text positions
    positions = jnp.arange(S)

    if cfg.family in ("dense", "vlm", "moe", "encdec"):
        # re-run hidden states through the stack, capturing k/v per layer
        pkey = {"dense": "layers", "vlm": "layers", "moe": "layers",
                "encdec": "dec_layers"}[cfg.family]
        norm_key = "norm1" if cfg.family != "encdec" else "norm1"
        attn_key = "attn" if cfg.family != "encdec" else "self_attn"
        if cfg.family == "encdec":
            enc_out = model.encode(params, batch["frames"])
            pos_t = L.sinusoidal_positions(S, cfg.d_model)
            x = x + pos_t[None].astype(x.dtype)

        def capture(h, p):
            hn = L.apply_norm(cfg, p[norm_key], h)
            k, v = attn._project_kv(cfg, p[attn_key], hn)
            if cfg.rope_theta > 0:
                from repro.models.layers import rope
                k = rope(k, positions, cfg.rope_theta)
            # advance hidden state with the full layer
            if cfg.family == "moe":
                h = _apply_full_layer_moe(model, p, h, positions)
            elif cfg.family == "encdec":
                h = _apply_full_layer_encdec(model, p, h, positions, enc_out)
            else:
                from repro.models.transformer import _apply_dense_layer
                h = _apply_dense_layer(cfg, p, h, positions, model.mesh,
                                       window=cfg.attn_window)
            return h, (k, v)

        h = x
        if cfg.family == "moe" and cfg.moe.first_layer_dense:
            p0 = params["layer0"]
            hn = L.apply_norm(cfg, p0["norm1"], h)
            k0, v0 = attn._project_kv(cfg, p0["attn"], hn)
            from repro.models.layers import rope
            if cfg.rope_theta > 0:
                k0 = rope(k0, positions, cfg.rope_theta)
            from repro.models.transformer import _apply_dense_layer
            h = _apply_dense_layer(cfg, p0, h, positions, model.mesh)
            cache["kv0"]["k"] = _fit(k0, max_len)[None]
            cache["kv0"]["v"] = _fit(v0, max_len)[None]
        _, (ks, vs) = jax.lax.scan(capture, h, params[pkey])
        cache["kv"]["k"] = jax.vmap(lambda a: _fit(a, max_len))(ks)
        cache["kv"]["v"] = jax.vmap(lambda a: _fit(a, max_len))(vs)
        if cfg.family == "encdec":
            def cross_kv(p):
                return attn._project_kv(cfg, p["cross_attn"], enc_out)
            cks, cvs = jax.vmap(cross_kv)(params["dec_layers"])
            cache["cross"]["k"], cache["cross"]["v"] = cks, cvs
    elif cfg.family in ("ssm", "hybrid"):
        # recurrent families: replay with state captured per layer
        cache = _prefill_recurrent(model, params, x, positions, cache)
    cache["length"] = jnp.asarray(S, jnp.int32)
    return logits[:, -1:], cache


def _fit(kv: Array, max_len: int) -> Array:
    """(B, S, Kv, Dh) -> (B, max_len, Kv, Dh) (pad or ring-window)."""
    B, S = kv.shape[:2]
    if S == max_len:
        return kv
    if S < max_len:
        pad = [(0, 0), (0, max_len - S), (0, 0), (0, 0)]
        return jnp.pad(kv, pad)
    kv = kv[:, -max_len:]
    return jnp.roll(kv, S % max_len, axis=1)


def _apply_full_layer_moe(model, p, h, positions):
    from repro.models.transformer import _apply_moe_layer
    return _apply_moe_layer(model.cfg, p, h, positions, model.mesh,
                            model.rules)


def _apply_full_layer_encdec(model, p, h, positions, enc_out):
    cfg = model.cfg
    a = attn.attend_full(cfg, p["self_attn"],
                         L.apply_norm(cfg, p["norm1"], h), positions,
                         causal=True)
    h = h + a
    a = attn.attend_full(cfg, p["cross_attn"],
                         L.apply_norm(cfg, p["norm_x"], h), positions,
                         causal=False, kv_x=enc_out,
                         kv_positions=jnp.arange(enc_out.shape[1]))
    h = h + a
    return h + L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["norm2"], h))


def _prefill_recurrent(model, params, x, positions, cache):
    cfg = model.cfg
    from repro.models.transformer import _apply_rec_layer, _apply_ssm_layer
    if cfg.family == "ssm":
        def body(h, xs):
            p = xs
            out, st = _apply_ssm_layer(cfg, p, h)
            return out, (st.h, st.conv)
        _, (hs, convs) = jax.lax.scan(body, x, params["layers"])
        cache["h"], cache["conv"] = hs, convs
        return cache
    # hybrid
    window = cfg.hybrid.window
    wlen = cache["kv"]["k"].shape[2]

    def body(h, p):
        h, st1 = _apply_rec_layer(cfg, p["rec1"], h)
        h, st2 = _apply_rec_layer(cfg, p["rec2"], h)
        hn = L.apply_norm(cfg, p["attn"]["norm1"], h)
        k, v = attn._project_kv(cfg, p["attn"]["attn"], hn)
        if cfg.rope_theta > 0:
            from repro.models.layers import rope
            k = rope(k, positions, cfg.rope_theta)
        from repro.models.transformer import _apply_dense_layer
        h = _apply_dense_layer(cfg, p["attn"], h, positions, model.mesh,
                               window=window)
        return h, (st1.h, st1.conv, st2.h, st2.conv,
                   _fit(k, wlen), _fit(v, wlen))

    h, outs = jax.lax.scan(body, x, params["triples"])
    (cache["lru1_h"], cache["lru1_conv"], cache["lru2_h"],
     cache["lru2_conv"], cache["kv"]["k"], cache["kv"]["v"]) = outs
    i = 0
    while f"tail_rec{i}" in params:
        h, st = _apply_rec_layer(cfg, params[f"tail_rec{i}"], h)
        cache[f"tail{i}_h"], cache[f"tail{i}_conv"] = st.h, st.conv
        i += 1
    return cache
