"""Shared building blocks: norms, MLPs, RoPE, embeddings.

All modules are (decls, apply) pairs: `*_decls(cfg)` returns a ParamDecl
tree; `apply_*(params, x, ...)` is the pure function. Compute runs in the
param dtype with float32 accumulation where it matters (norm statistics,
softmax, losses).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import sharding as sh
from repro.models.config import ModelConfig

Array = jax.Array


# --- norms -------------------------------------------------------------------

def rmsnorm_decls(d: int, dtype):
    return {"scale": sh.ones((d,), ("embed",), dtype)}


def apply_rmsnorm(p, x: Array, eps: float, plus_one: bool = False) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    scale = p["scale"].astype(jnp.float32)
    if plus_one:  # gemma convention: weight is a residual around 1
        scale = scale + 1.0
    return (y * scale).astype(x.dtype)


def layernorm_decls(d: int, dtype):
    return {"scale": sh.ones((d,), ("embed",), dtype),
            "bias": sh.zeros((d,), ("embed",), dtype)}


def apply_layernorm(p, x: Array, eps: float) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) +
            p["bias"].astype(jnp.float32)).astype(x.dtype)


def norm_decls(cfg: ModelConfig, d: int = 0):
    d = d or cfg.d_model
    if cfg.family == "encdec":   # whisper uses layernorm
        return layernorm_decls(d, cfg.jnp_dtype)
    return rmsnorm_decls(d, cfg.jnp_dtype)


def apply_norm(cfg: ModelConfig, p, x: Array) -> Array:
    if cfg.family == "encdec":
        return apply_layernorm(p, x, cfg.norm_eps)
    return apply_rmsnorm(p, x, cfg.norm_eps,
                         plus_one=cfg.name.startswith(("gemma",
                                                       "recurrentgemma")))


# --- MLPs --------------------------------------------------------------------

def mlp_decls(cfg: ModelConfig, d_ff: int = 0, bias: bool = False):
    d, dt = cfg.d_model, cfg.jnp_dtype
    f = d_ff or cfg.d_ff
    if cfg.mlp_type in ("swiglu", "geglu"):
        decls = {
            "w_gate": sh.dense((d, f), ("embed", "ff"), dt),
            "w_up": sh.dense((d, f), ("embed", "ff"), dt),
            "w_down": sh.dense((f, d), ("ff", "embed"), dt),
        }
    else:  # gelu_mlp (whisper / grok-style 2-matrix)
        decls = {
            "w_up": sh.dense((d, f), ("embed", "ff"), dt),
            "w_down": sh.dense((f, d), ("ff", "embed"), dt),
        }
        if bias:
            decls["b_up"] = sh.zeros((f,), ("ff",), dt)
            decls["b_down"] = sh.zeros((d,), ("embed",), dt)
    return decls


def apply_mlp(cfg: ModelConfig, p, x: Array) -> Array:
    if cfg.mlp_type == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if cfg.mlp_type == "geglu":
        return (jax.nn.gelu(x @ p["w_gate"], approximate=True) *
                (x @ p["w_up"])) @ p["w_down"]
    h = x @ p["w_up"]
    if "b_up" in p:
        h = h + p["b_up"]
    h = jax.nn.gelu(h, approximate=True)
    out = h @ p["w_down"]
    if "b_down" in p:
        out = out + p["b_down"]
    return out


# --- embeddings / unembedding -------------------------------------------------

def embed_decls(cfg: ModelConfig):
    dt = cfg.jnp_dtype
    Vp = cfg.padded_vocab
    decls = {"embedding": sh.embedding((Vp, cfg.d_model),
                                       ("vocab", "embed"), dt)}
    if not cfg.tie_embeddings:
        decls["unembed"] = sh.dense((cfg.d_model, Vp), ("embed", "vocab"),
                                    dt)
    return decls


def apply_embed(cfg: ModelConfig, p, tokens: Array) -> Array:
    x = jnp.take(p["embedding"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def apply_unembed(cfg: ModelConfig, p, x: Array) -> Array:
    if cfg.tie_embeddings:
        logits = x @ p["embedding"].T
    else:
        logits = x @ p["unembed"]
    if cfg.padded_vocab != cfg.vocab_size:  # mask the pad logits
        Vp = cfg.padded_vocab
        pad_bias = jnp.where(jnp.arange(Vp) < cfg.vocab_size, 0.0, -1e9)
        logits = logits + pad_bias.astype(logits.dtype)
    return logits


# --- rotary position embeddings -----------------------------------------------

def rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin,
                           xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos: int, d: int) -> Array:
    """Whisper-encoder style fixed sinusoids, (n_pos, d) float32.

    Built in numpy at trace time (shapes are static) and embedded as a
    constant. It must NOT be traced jnp math: on jax 0.4.x CPU, GSPMD
    mispartitions the concatenate(sin(iota.f), cos(iota.f)) pattern when
    the consumer is sharded along the feature axis — each shard evaluates
    the wrong slice of the table (observed as a 0.14 loss delta for
    whisper-small on a (data=2, model=4) mesh; tests/test_sharded_pcdn.py
    guards the fixed behaviour).
    """
    import numpy as np
    half = d // 2
    freqs = np.exp(-np.arange(half) * (np.log(10000.0) / (half - 1)))
    ang = np.arange(n_pos)[:, None] * freqs[None, :]
    table = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(table, jnp.float32)


# --- losses --------------------------------------------------------------------

def softmax_xent(logits: Array, labels: Array, mask: Array | None = None):
    """Mean next-token cross-entropy in float32. logits (..., V).

    The gold-logit gather is written as a masked reduction over the vocab
    axis (NOT take_along_axis): with vocab sharded over "model" this
    partitions to a local select + tiny all-reduce, whereas a gather would
    force GSPMD to all-gather the full f32 logits (tens of GB at the
    assigned shapes)."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    V = logits.shape[-1]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, (V,), 0)
    onehot = (labels[..., None] == vocab_iota)
    gold = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    nll = lse - gold
    if mask is not None:
        m = mask.astype(jnp.float32)
        return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    return jnp.mean(nll)
