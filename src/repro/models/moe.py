"""Mixture-of-Experts layer with sort-based capacity dispatch.

Covers both assigned MoE archs on the same code path:
  * deepseek-moe-16b : 64 routed (top-6, fine-grained) + 2 shared experts,
                       E (64) >= model-axis (16)  -> expert-parallel slabs
  * grok-1-314b      : 8 routed (top-2), E (8) < model-axis (16)
                       -> experts x ff 2-D split (each expert's FFN is
                          sharded (model/E)-ways along d_ff)

Dispatch (DESIGN.md section 4): activations are replicated across the
model axis (batch is data-sharded), so routing + sort are computed
redundantly per model shard and each shard gathers ONLY the tokens of its
local expert slice into an (E_local, C, D) buffer — no all-to-all is
needed; the single combine psum over "model" (the same collective a
Megatron MLP needs anyway) merges expert outputs AND intra-expert ff
partial sums in one reduction.

Inside jit this runs as a nested shard_map over the full mesh.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.models import sharding as sh
from repro.models.config import ModelConfig
from repro.utils import compat

Array = jax.Array


def moe_decls(cfg: ModelConfig):
    m = cfg.moe
    d, dt = cfg.d_model, cfg.jnp_dtype
    E, F = m.n_experts, m.d_ff_expert
    decls = {
        "router": sh.dense((d, E), ("embed", None), jnp.float32),
        "w_gate": sh.dense((E, d, F), ("experts", "embed", "expert_ff"), dt),
        "w_up": sh.dense((E, d, F), ("experts", "embed", "expert_ff"), dt),
        "w_down": sh.dense((E, F, d), ("experts", "expert_ff", "embed"), dt,
                           fan_in=F),
    }
    if m.n_shared:
        Fs = m.d_ff_shared or m.n_shared * F
        decls["shared"] = {
            "w_gate": sh.dense((d, Fs), ("embed", "ff"), dt),
            "w_up": sh.dense((d, Fs), ("embed", "ff"), dt),
            "w_down": sh.dense((Fs, d), ("ff", "embed"), dt),
        }
    return decls


def apply_moe_dense(cfg: ModelConfig, params, x: Array) -> Array:
    """Gather-free MoE for tiny token counts (decode): computes ALL experts
    on all tokens and masks by the top-k gates.

    Rationale: a serving batch touches every expert anyway, so the weight
    READ traffic is identical to sparse dispatch, while the shard_map
    dispatch path would all-gather the FSDP-sharded expert weights every
    step (measured 77 GB/step on grok decode_32k). Here the einsums consume
    the sharded weights in place — GSPMD reduces small activation partials
    instead of moving weights. Extra flops (E/top_k) are irrelevant at
    decode: the step is bandwidth-bound.
    """
    m = cfg.moe
    E, K = m.n_experts, m.top_k
    B, S, D = x.shape
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"])
    gates_all = jax.nn.softmax(logits, axis=-1)
    top_g, _ = jax.lax.top_k(gates_all, K)
    thresh = top_g[..., -1:]
    weights = jnp.where(gates_all >= thresh, gates_all, 0.0)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)

    hg = jnp.einsum("bsd,edf->besf", x, params["w_gate"],
                    preferred_element_type=jnp.float32)
    hu = jnp.einsum("bsd,edf->besf", x, params["w_up"],
                    preferred_element_type=jnp.float32)
    h = (jax.nn.silu(hg) * hu).astype(x.dtype)
    out_e = jnp.einsum("besf,efd->besd", h, params["w_down"],
                       preferred_element_type=jnp.float32)
    out = jnp.einsum("besd,bse->bsd", out_e, weights).astype(x.dtype)
    if m.n_shared:
        sp = params["shared"]
        hs = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        out = out + hs @ sp["w_down"]
    return out


def apply_moe(cfg: ModelConfig, params, x: Array, mesh: Mesh,
              rules: sh.ShardingRules):
    """x: (B, S, D) -> (B, S, D). Routed experts + optional shared experts."""
    m = cfg.moe
    Bsz, S, D = x.shape
    E, K, F = m.n_experts, m.top_k, m.d_ff_expert

    dspec = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dspec = dspec if len(dspec) > 1 else (dspec[0] if dspec else None)
    model_ax = "model" if "model" in mesh.shape else None

    decls = moe_decls(cfg)
    w_specs = {k: sh.resolve_spec(params[k].shape, decls[k].logical_axes,
                                  rules, mesh)
               for k in ("router", "w_gate", "w_up", "w_down")}

    x_spec = P(dspec, None, None)

    local = functools.partial(
        _moe_local, cfg=cfg, mesh=mesh, w_specs=w_specs, model_ax=model_ax)

    mapped = compat.shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, w_specs["router"], w_specs["w_gate"],
                  w_specs["w_up"], w_specs["w_down"]),
        out_specs=x_spec,
    )
    out = mapped(x, params["router"], params["w_gate"], params["w_up"],
                 params["w_down"])

    if m.n_shared:
        sp = params["shared"]
        h = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
        out = out + h @ sp["w_down"]
    return out


def _moe_local(x_l, wr, wg, wu, wd, *, cfg: ModelConfig, mesh: Mesh,
               w_specs, model_ax):
    """Per-shard body. x_l: (B_l, S, D) local tokens (replicated over model);
    w*: local expert-weight blocks per w_specs."""
    m = cfg.moe
    E, K = m.n_experts, m.top_k
    B_l, S, D = x_l.shape
    T = B_l * S
    xt = x_l.reshape(T, D)

    n_model = mesh.shape.get("model", 1) if model_ax else 1
    m_idx = jax.lax.axis_index(model_ax) if model_ax else 0

    # FSDP all-gather of any data-sharded weight dim
    def fsdp_gather(w, spec, dim):
        ax = spec[dim] if len(spec) > dim else None
        if ax is None:
            return w
        axes = ax if isinstance(ax, tuple) else (ax,)
        for a in axes:
            if a != "model":
                w = jax.lax.all_gather(w, a, axis=dim, tiled=True)
        return w

    wr = fsdp_gather(wr, w_specs["router"], 0)

    # --- routing (identical on every model shard) -------------------------
    logits = (xt.astype(jnp.float32) @ wr.astype(jnp.float32))  # (T, E)
    gates_all = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(gates_all, K)                    # (T, K)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)

    cap = int(m.capacity_factor * T * K / E)
    cap = max(8, -(-cap // 8) * 8)

    flat_e = ids.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), K)
    flat_g = gates.reshape(-1).astype(x_l.dtype)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    starts = jnp.searchsorted(se, jnp.arange(E))
    pos = jnp.arange(T * K) - starts[se]
    keep = pos < cap

    # --- local expert slice ------------------------------------------------
    ge_spec = w_specs["w_gate"]
    e_sharded = len(ge_spec) > 0 and ge_spec[0] == "model"
    f_sharded = len(ge_spec) > 2 and ge_spec[2] == "model"
    if e_sharded:
        # case A (E % n_model == 0, e.g. deepseek 64 on 16): expert-
        # parallel — this shard holds E_l whole experts.
        E_l = E // n_model
        e_lo = m_idx * E_l
        redundancy = 1
        wg_l, wu_l, wd_l = wg, wu, wd   # already (E_l, ., .)
    elif f_sharded and n_model > 1:
        # case B (E < n_model, e.g. grok 8 on 16): every shard keeps ALL
        # experts but only a d_ff slice; silu(gate)*up is elementwise in
        # d_ff and w_down contracts over it, so each shard's output is a
        # partial sum that the combine psum below completes. No slicing,
        # no redundancy — total flops match the E-parallel case.
        E_l, e_lo = E, 0
        redundancy = 1
        wg_l, wu_l, wd_l = wg, wu, wd   # (E, ., F_l) blocks
    else:                               # fallback: replicated experts
        E_l, e_lo = E, 0
        redundancy = n_model
        wg_l, wu_l, wd_l = wg, wu, wd

    wg_l = fsdp_gather(wg_l, w_specs["w_gate"], 1)
    wu_l = fsdp_gather(wu_l, w_specs["w_up"], 1)
    wd_l = fsdp_gather(wd_l, w_specs["w_down"], 2)

    # --- gather local tokens into (E_l, cap, D) ----------------------------
    loc = se - e_lo
    in_local = (loc >= 0) & (loc < E_l) & keep
    idx_e = jnp.where(in_local, loc, E_l)       # OOB row -> dropped
    idx_c = jnp.where(in_local, pos, cap)
    buf = jnp.zeros((E_l, cap, D), x_l.dtype)
    buf = buf.at[idx_e, idx_c].set(xt[st], mode="drop")

    # --- expert FFN (gated) -------------------------------------------------
    h_g = jnp.einsum("ecd,edf->ecf", buf, wg_l)
    h_u = jnp.einsum("ecd,edf->ecf", buf, wu_l)
    h = jax.nn.silu(h_g) * h_u
    out_e = jnp.einsum("ecf,efd->ecd", h, wd_l)   # partial over f if split

    # --- combine: scatter back + ONE psum over model ------------------------
    vals = out_e[idx_e.clip(0, E_l - 1), idx_c.clip(0, cap - 1)]
    vals = vals * (sg * in_local.astype(sg.dtype))[:, None]
    out = jnp.zeros((T, D), x_l.dtype).at[st].add(vals)
    if model_ax:
        out = jax.lax.psum(out, model_ax)
    if redundancy > 1:
        out = out / redundancy
    return out.reshape(B_l, S, D)
