"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Recurrent block: x -> two linear branches (lru_width); branch 1 gets a
causal depthwise conv then the Real-Gated LRU

    r_t = sigmoid(W_a x_t + b_a)        (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)        (input gate)
    a_t = a^(c * r_t) ,  a = sigmoid(Lambda)   (per-channel, c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

branch 2 gets GeLU; outputs multiply then project back. Channelwise
independent -> lru_width shards over the model axis; decode is O(1) state,
which is why recurrentgemma runs the long_500k cell (its attention layers
are local/windowed — O(window) cache).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import sharding as sh
from repro.models.config import ModelConfig

Array = jax.Array


def _width(cfg: ModelConfig) -> int:
    return cfg.hybrid.lru_width or cfg.d_model


def rglru_decls(cfg: ModelConfig):
    d, dt = cfg.d_model, cfg.jnp_dtype
    W = _width(cfg)
    Kc = cfg.hybrid.conv_width
    return {
        "in_x": sh.dense((d, W), ("embed", "lru"), dt),
        "in_gate": sh.dense((d, W), ("embed", "lru"), dt),
        "conv_w": sh.dense((Kc, W), ("conv", "lru"), dt, fan_in=Kc),
        "conv_b": sh.zeros((W,), ("lru",), dt),
        "w_a": sh.dense((W, W), ("lru", "lru"), dt),
        "b_a": sh.zeros((W,), ("lru",), jnp.float32),
        "w_i": sh.dense((W, W), ("lru", "lru"), dt),
        "b_i": sh.zeros((W,), ("lru",), jnp.float32),
        # Lambda init so a = sigmoid(L) in ~(0.9, 0.999)
        "Lambda": sh.const(3.0, (W,), ("lru",), jnp.float32),
        "out": sh.dense((W, d), ("lru", "embed"), dt),
    }


class LRUState(NamedTuple):
    h: Array       # (B, W) float32
    conv: Array    # (B, Kc-1, W)
    length: Array  # () int32


def init_lru_state(cfg: ModelConfig, batch: int, n_layers: int = 0):
    W = _width(cfg)
    Kc = cfg.hybrid.conv_width
    sh_h, sh_c = (batch, W), (batch, Kc - 1, W)
    if n_layers:
        sh_h, sh_c = (n_layers,) + sh_h, (n_layers,) + sh_c
    return LRUState(jnp.zeros(sh_h, jnp.float32),
                    jnp.zeros(sh_c, cfg.jnp_dtype),
                    jnp.zeros((), jnp.int32))


def _gates(cfg, p, xc: Array):
    """a_t and gated input for the LRU. xc: (..., W) post-conv."""
    c = cfg.hybrid.lru_c
    xf = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["w_a"].astype(jnp.float32) + p["b_a"])
    i = jax.nn.sigmoid(xf @ p["w_i"].astype(jnp.float32) + p["b_i"])
    log_a = c * r * jax.nn.log_sigmoid(p["Lambda"])[None]
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * \
        (i * xf)
    return a, gated


def apply_rglru_block(cfg: ModelConfig, p, x: Array,
                      state: LRUState | None = None):
    """Train/prefill. x: (B, S, D) -> (out, new_state)."""
    W = _width(cfg)
    Kc = cfg.hybrid.conv_width
    B, S, _ = x.shape
    xb = x @ p["in_x"]
    gate_branch = jax.nn.gelu(x @ p["in_gate"], approximate=True)
    prev = (state.conv if state is not None
            else jnp.zeros((B, Kc - 1, W), x.dtype))
    xpad = jnp.concatenate([prev, xb], axis=1)
    ker = p["conv_w"]
    xc = sum(xpad[:, i:i + S] * ker[i][None, None]
             for i in range(Kc)) + p["conv_b"].astype(x.dtype)

    a, gated = _gates(cfg, p, xc)                 # (B,S,W) float32

    def comb(u, v):
        (a1, b1), (a2, b2) = u, v
        return a2 * a1, a2 * b1 + b2

    if state is not None:
        gated = gated.at[:, 0].add(a[:, 0] * state.h)
    _, hs = jax.lax.associative_scan(comb, (a, gated), axis=1)
    y = (hs.astype(x.dtype) * gate_branch) @ p["out"]
    new_state = LRUState(hs[:, -1], xpad[:, S:],
                         (state.length if state is not None else 0) + S)
    return y, new_state


def rglru_decode_step(cfg: ModelConfig, p, x: Array, state: LRUState):
    """One token. x: (B, 1, D)."""
    B = x.shape[0]
    xb = x[:, 0] @ p["in_x"]                      # (B, W)
    gate_branch = jax.nn.gelu(x[:, 0] @ p["in_gate"], approximate=True)
    window = jnp.concatenate([state.conv, xb[:, None]], axis=1)
    xc = jnp.einsum("bkw,kw->bw", window, p["conv_w"]) + \
        p["conv_b"].astype(x.dtype)
    a, gated = _gates(cfg, p, xc)                 # (B, W)
    h = a * state.h + gated
    y = (h.astype(x.dtype) * gate_branch) @ p["out"]
    return y[:, None], LRUState(h, window[:, 1:], state.length + 1)
