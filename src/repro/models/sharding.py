"""Parameter declaration + logical-axis sharding resolution.

Every parameter is declared once with a shape, dtype, init and a tuple of
*logical* axis names. `ShardingRules` maps logical names to mesh axes;
`resolve_spec` drops any mapping that does not divide the concrete dim
(e.g. kv_heads=1 cannot shard 16-ways -> replicated), so one rule set
serves every architecture and mesh.

Three materializations of a declaration tree:
  * `init_params(key, tree)`        — concrete arrays (smoke tests, examples)
  * `abstract_params(tree)`         — jax.ShapeDtypeStruct (dry-run: no alloc)
  * `spec_tree(tree, rules, mesh)`  — PartitionSpec pytree for pjit shardings
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

Array = jax.Array

# Default logical-axis -> mesh-axis rules (DESIGN.md section 4).
# "fsdp"-style: the non-tensor-parallel weight dim shards over data.
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "embed": ("data",),        # FSDP dim for 2-D weight sharding
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "ff": ("model",),
    "experts": ("model",),
    # expert_ff engages only when "experts" could not take the model axis
    # (E < mesh model size, e.g. grok's 8 experts on a 16-wide axis): the
    # per-expert FFN then splits along d_ff instead (2-D expert split).
    "expert_ff": ("model",),
    "lru": ("model",),         # RG-LRU / mamba inner channels
    "ssm_inner": ("model",),
    "ssm_state": (),
    "conv": (),
    "seq": (),
    "layers": (),              # scan dim, never sharded
}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: dict

    def mesh_axes_for(self, logical: str) -> Tuple[str, ...]:
        return tuple(self.rules.get(logical, ()))


def default_rules(**overrides) -> ShardingRules:
    r = dict(DEFAULT_RULES)
    r.update(overrides)
    return ShardingRules(r)


@dataclasses.dataclass(frozen=True)
class ParamDecl:
    shape: Tuple[int, ...]
    dtype: jnp.dtype
    logical_axes: Tuple[Optional[str], ...]
    init: Callable[[Array, Tuple[int, ...], jnp.dtype], Array]

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), \
            (self.shape, self.logical_axes)


def _normal_init(stddev: float):
    def f(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * stddev
                ).astype(dtype)
    return f


def _zeros_init(key, shape, dtype):
    return jnp.zeros(shape, dtype)


def _ones_init(key, shape, dtype):
    return jnp.ones(shape, dtype)


def dense(shape, logical_axes, dtype=jnp.bfloat16, fan_in: int = None):
    fi = fan_in if fan_in is not None else shape[0]
    return ParamDecl(tuple(shape), dtype, tuple(logical_axes),
                     _normal_init(1.0 / np.sqrt(fi)))


def embedding(shape, logical_axes, dtype=jnp.bfloat16):
    return ParamDecl(tuple(shape), dtype, tuple(logical_axes),
                     _normal_init(0.02))


def zeros(shape, logical_axes, dtype=jnp.bfloat16):
    return ParamDecl(tuple(shape), dtype, tuple(logical_axes), _zeros_init)


def ones(shape, logical_axes, dtype=jnp.bfloat16):
    return ParamDecl(tuple(shape), dtype, tuple(logical_axes), _ones_init)


def const(value: float, shape, logical_axes, dtype=jnp.bfloat16):
    def f(key, shp, dt):
        return jnp.full(shp, value, dt)
    return ParamDecl(tuple(shape), dtype, tuple(logical_axes), f)


def stacked(n_layers: int, decl_tree):
    """Stack a per-layer declaration tree along a leading 'layers' dim
    (for scan-over-layers)."""
    def stack_one(d: ParamDecl) -> ParamDecl:
        return ParamDecl((n_layers,) + d.shape, d.dtype,
                         ("layers",) + d.logical_axes, d.init)
    return jax.tree.map(stack_one, decl_tree,
                        is_leaf=lambda x: isinstance(x, ParamDecl))


def is_decl(x) -> bool:
    return isinstance(x, ParamDecl)


def init_params(key: Array, tree):
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_decl)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, d in zip(keys, leaves):
        if d.logical_axes and d.logical_axes[0] == "layers":
            per_layer = jax.vmap(
                lambda kk: d.init(kk, d.shape[1:], d.dtype))(
                    jax.random.split(k, d.shape[0]))
            out.append(per_layer)
        else:
            out.append(d.init(k, d.shape, d.dtype))
    return jax.tree.unflatten(treedef, out)


def abstract_params(tree):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), tree,
        is_leaf=is_decl)


def resolve_spec(shape: Sequence[int], logical_axes, rules: ShardingRules,
                 mesh: Mesh) -> P:
    """Logical axes -> PartitionSpec, dropping non-dividing mesh axes and
    never using the same mesh axis twice in one spec."""
    used = set()
    parts = []
    for dim, name in zip(shape, logical_axes):
        if name is None:
            parts.append(None)
            continue
        chosen = []
        prod = 1
        for ax in rules.mesh_axes_for(name):
            if ax in used or ax not in mesh.shape:
                continue
            sz = mesh.shape[ax]
            if dim % (prod * sz) == 0:
                chosen.append(ax)
                used.add(ax)
                prod *= sz
        if not chosen:
            parts.append(None)
        elif len(chosen) == 1:
            parts.append(chosen[0])
        else:
            parts.append(tuple(chosen))
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def spec_tree(tree, rules: ShardingRules, mesh: Mesh):
    return jax.tree.map(
        lambda d: resolve_spec(d.shape, d.logical_axes, rules, mesh),
        tree, is_leaf=is_decl)


def shard_params(params, specs, mesh: Mesh):
    from jax.sharding import NamedSharding
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)),
        params, specs)


def constrain(x: Array, mesh: Mesh, *logical_axes) -> Array:
    """with_sharding_constraint through the logical-axis rules."""
    rules = default_rules()
    spec = resolve_spec(x.shape, logical_axes, rules, mesh)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))
