"""Mamba-1 selective SSM block (falcon-mamba-7b).

Per layer: in_proj -> (x, z) branches; causal depthwise conv(4) + silu on
the x branch; input-dependent (Delta, B, C); diagonal selective scan

    h_t = exp(Delta_t A) h_{t-1} + Delta_t B_t x_t ,   y_t = C_t . h_t + D x_t

run as an associative scan over the sequence (log-depth, channelwise
independent -> d_inner shards cleanly over the model axis). Decode keeps an
O(1) recurrent state (h, conv tail) — this is why falcon-mamba runs the
long_500k cell.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import sharding as sh
from repro.models.config import ModelConfig

Array = jax.Array


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    dt_rank = s.dt_rank or -(-cfg.d_model // 16)
    return d_inner, dt_rank, s.d_state, s.d_conv


def ssm_decls(cfg: ModelConfig):
    d, dt = cfg.d_model, cfg.jnp_dtype
    Di, R, N, Kc = _dims(cfg)
    return {
        "in_proj": sh.dense((d, 2 * Di), ("embed", "ssm_inner"), dt),
        "conv_w": sh.dense((Kc, Di), ("conv", "ssm_inner"), dt, fan_in=Kc),
        "conv_b": sh.zeros((Di,), ("ssm_inner",), dt),
        "x_proj": sh.dense((Di, R + 2 * N), ("ssm_inner", None), dt),
        "dt_proj": sh.dense((R, Di), (None, "ssm_inner"), dt, fan_in=R),
        "dt_bias": sh.zeros((Di,), ("ssm_inner",), dt),
        # A_log init ~ log(1..N) per mamba; keep simple uniform-ish
        "A_log": sh.const(0.5, (Di, N), ("ssm_inner", "ssm_state"),
                          jnp.float32),
        "D": sh.ones((Di,), ("ssm_inner",), jnp.float32),
        "out_proj": sh.dense((Di, d), ("ssm_inner", "embed"), dt),
    }


class SSMState(NamedTuple):
    h: Array         # (B, Di, N) float32 recurrent state
    conv: Array      # (B, Kc-1, Di) conv tail
    length: Array    # () int32


def init_ssm_state(cfg: ModelConfig, batch: int, n_layers: int = 0):
    Di, R, N, Kc = _dims(cfg)
    shape_h = (batch, Di, N)
    shape_c = (batch, Kc - 1, Di)
    if n_layers:
        shape_h = (n_layers,) + shape_h
        shape_c = (n_layers,) + shape_c
    return SSMState(jnp.zeros(shape_h, jnp.float32),
                    jnp.zeros(shape_c, cfg.jnp_dtype),
                    jnp.zeros((), jnp.int32))


def _chunk_size(S: int, target: int = 256) -> int:
    """Largest divisor of S not exceeding target (bounds scan memory)."""
    best = 1
    for c in range(1, min(S, target) + 1):
        if S % c == 0:
            best = c
    return best


def _ssm_core(cfg, p, xb: Array, h0: Array | None):
    """xb: (B, S, Di) post-conv activations -> (y (B,S,Di), h_last).

    Chunked scan: the (B, ck, Di, N) discretized-state tensor only ever
    exists for one chunk (lax.scan over chunks carries h), so peak memory
    is O(B * ck * Di * N) instead of O(B * S * Di * N).
    """
    Di, R, N, _ = _dims(cfg)
    B, S, _ = xb.shape
    xf = xb.astype(jnp.float32)
    dbc = xb @ p["x_proj"]                                   # (B,S,R+2N)
    dt_in, Bm, Cm = jnp.split(dbc.astype(jnp.float32), [R, R + N], axis=-1)
    delta = jax.nn.softplus(dt_in @ p["dt_proj"].astype(jnp.float32)
                            + p["dt_bias"].astype(jnp.float32))  # (B,S,Di)
    A = -jnp.exp(p["A_log"])                                 # (Di,N) negative

    ck = _chunk_size(S)
    nc = S // ck

    def to_chunks(t):  # (B, S, ...) -> (nc, B, ck, ...)
        return jnp.moveaxis(t.reshape(B, nc, ck, *t.shape[2:]), 1, 0)

    def comb(a, b):
        (A1, b1), (A2, b2) = a, b
        return A2 * A1, A2 * b1 + b2

    def step(h, inp):
        d_c, B_c, C_c, x_c = inp                 # (B,ck,Di) / (B,ck,N) x2
        Abar = jnp.exp(d_c[..., None] * A[None, None])       # (B,ck,Di,N)
        Bx = (d_c * x_c)[..., None] * B_c[:, :, None, :]
        Bx = Bx.at[:, 0].add(Abar[:, 0] * h)
        _, hs = jax.lax.associative_scan(comb, (Abar, Bx), axis=1)
        y_c = jnp.einsum("bsdn,bsn->bsd", hs, C_c)           # (B,ck,Di)
        return hs[:, -1], y_c

    h_init = (h0 if h0 is not None
              else jnp.zeros((B, Di, N), jnp.float32))
    h_last, ys = jax.lax.scan(
        step, h_init, (to_chunks(delta), to_chunks(Bm), to_chunks(Cm),
                       to_chunks(xf)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, Di)
    y = y + xf * p["D"][None, None]
    return y.astype(xb.dtype), h_last


def apply_ssm_block(cfg: ModelConfig, p, x: Array,
                    state: SSMState | None = None):
    """Full mamba block, train/prefill. x: (B, S, D)."""
    Di, R, N, Kc = _dims(cfg)
    B, S, _ = x.shape
    xz = x @ p["in_proj"]                                    # (B,S,2Di)
    xb, zb = jnp.split(xz, 2, axis=-1)
    # causal depthwise conv along S
    prev = (state.conv if state is not None
            else jnp.zeros((B, Kc - 1, Di), x.dtype))
    xpad = jnp.concatenate([prev, xb], axis=1)               # (B,S+Kc-1,Di)
    ker = p["conv_w"]                                        # (Kc, Di)
    xc = sum(xpad[:, i:i + S] * ker[i][None, None]
             for i in range(Kc)) + p["conv_b"]
    xc = jax.nn.silu(xc)
    h0 = state.h if state is not None else None
    y, h_last = _ssm_core(cfg, p, xc, h0)
    out = (y * jax.nn.silu(zb)) @ p["out_proj"]
    new_state = SSMState(h_last, xpad[:, S:S + Kc - 1 if Kc > 1 else 0],
                         (state.length if state is not None else 0) + S)
    return out, new_state


def ssm_decode_step(cfg: ModelConfig, p, x: Array, state: SSMState):
    """One-token decode with O(1) state. x: (B, 1, D)."""
    Di, R, N, Kc = _dims(cfg)
    B = x.shape[0]
    xz = x[:, 0] @ p["in_proj"]                              # (B, 2Di)
    xb, zb = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([state.conv, xb[:, None]], axis=1)  # (B,Kc,Di)
    xc = jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)
    xf = xc.astype(jnp.float32)
    dbc = xc @ p["x_proj"]
    dt_in, Bm, Cm = jnp.split(dbc.astype(jnp.float32), [R, R + N], axis=-1)
    delta = jax.nn.softplus(dt_in @ p["dt_proj"].astype(jnp.float32)
                            + p["dt_bias"].astype(jnp.float32))  # (B,Di)
    A = -jnp.exp(p["A_log"])
    Abar = jnp.exp(delta[..., None] * A[None])               # (B,Di,N)
    h = Abar * state.h + (delta * xf)[..., None] * Bm[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, Cm) + xf * p["D"][None]
    out = (y.astype(x.dtype) * jax.nn.silu(zb)) @ p["out_proj"]
    return out[:, None], SSMState(h, window[:, 1:], state.length + 1)
