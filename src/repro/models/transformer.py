"""Model assembly: every assigned family behind one functional API.

  Model(cfg, mesh).decls() / init_params(key) / abstract_params()
      .param_specs()                      — PartitionSpec tree for pjit
      .loss_fn(params, batch)             — train loss (scan-over-layers,
                                            optional remat)
      .prefill(params, batch)             — build decode caches
      .decode_step(params, cache, tok)    — one token for the whole batch

Families: dense (yi/qwen/gemma), vlm (pixtral: stubbed patch embeddings
prepended), moe (deepseek/grok: nested shard_map expert layer), ssm
(falcon-mamba), hybrid (recurrentgemma: (rec, rec, attn) pattern), encdec
(whisper: stubbed audio frames -> encoder, causal decoder w/ cross-attn).

Scan-over-layers keeps HLO size O(1) in depth — required for 64-layer
models to compile quickly on the 512-device dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import attention as attn
from repro.models import layers as L
from repro.models import moe as moe_mod
from repro.models import rglru, sharding as sh, ssm as ssm_mod
from repro.models.config import ModelConfig

Array = jax.Array


# --------------------------------------------------------------------------
# declarations


def _dense_layer_decls(cfg: ModelConfig, d_ff: int = 0):
    return {
        "norm1": L.norm_decls(cfg),
        "attn": attn.attn_decls(cfg),
        "norm2": L.norm_decls(cfg),
        "mlp": L.mlp_decls(cfg, d_ff=d_ff,
                           bias=(cfg.family == "encdec")),
    }


def _moe_layer_decls(cfg: ModelConfig):
    return {
        "norm1": L.norm_decls(cfg),
        "attn": attn.attn_decls(cfg),
        "norm2": L.norm_decls(cfg),
        "moe": moe_mod.moe_decls(cfg),
    }


def _ssm_layer_decls(cfg: ModelConfig):
    return {"norm": L.norm_decls(cfg), "ssm": ssm_mod.ssm_decls(cfg)}


def _rec_layer_decls(cfg: ModelConfig):
    return {
        "norm1": L.norm_decls(cfg),
        "rec": rglru.rglru_decls(cfg),
        "norm2": L.norm_decls(cfg),
        "mlp": L.mlp_decls(cfg),
    }


def _hybrid_triple_decls(cfg: ModelConfig):
    return {
        "rec1": _rec_layer_decls(cfg),
        "rec2": _rec_layer_decls(cfg),
        "attn": _dense_layer_decls(cfg),
    }


def _encdec_decls(cfg: ModelConfig):
    ed = cfg.encdec
    dec_layer = {
        "norm1": L.norm_decls(cfg),
        "self_attn": attn.attn_decls(cfg),
        "norm_x": L.norm_decls(cfg),
        "cross_attn": attn.attn_decls(cfg),
        "norm2": L.norm_decls(cfg),
        "mlp": L.mlp_decls(cfg, bias=True),
    }
    enc_layer = _dense_layer_decls(cfg)
    return {
        "embed": L.embed_decls(cfg),
        "enc_layers": sh.stacked(ed.n_encoder_layers, enc_layer),
        "enc_norm": L.norm_decls(cfg),
        "dec_layers": sh.stacked(cfg.n_layers, dec_layer),
        "final_norm": L.norm_decls(cfg),
    }


def lm_decls(cfg: ModelConfig):
    if cfg.family == "encdec":
        return _encdec_decls(cfg)
    decls: Dict[str, Any] = {"embed": L.embed_decls(cfg)}
    if cfg.family in ("dense", "vlm"):
        decls["layers"] = sh.stacked(cfg.n_layers, _dense_layer_decls(cfg))
    elif cfg.family == "moe":
        n_moe = cfg.n_layers - (1 if cfg.moe.first_layer_dense else 0)
        if cfg.moe.first_layer_dense:
            decls["layer0"] = _dense_layer_decls(
                cfg, d_ff=cfg.moe.d_ff_dense)
        decls["layers"] = sh.stacked(n_moe, _moe_layer_decls(cfg))
    elif cfg.family == "ssm":
        decls["layers"] = sh.stacked(cfg.n_layers, _ssm_layer_decls(cfg))
    elif cfg.family == "hybrid":
        n_triples = cfg.n_layers // 3
        rem = cfg.n_layers - 3 * n_triples
        decls["triples"] = sh.stacked(n_triples, _hybrid_triple_decls(cfg))
        for i in range(rem):
            decls[f"tail_rec{i}"] = _rec_layer_decls(cfg)
    else:
        raise ValueError(cfg.family)
    decls["final_norm"] = L.norm_decls(cfg)
    return decls


# --------------------------------------------------------------------------
# layer applications


def _apply_dense_layer(cfg, p, x, positions, mesh, causal=True, window=0):
    h = attn.attend_full(cfg, p["attn"], L.apply_norm(cfg, p["norm1"], x),
                         positions, causal=causal, window=window)
    x = x + h
    h = L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["norm2"], x))
    return x + h


def _apply_moe_layer(cfg, p, x, positions, mesh, rules):
    h = attn.attend_full(cfg, p["attn"], L.apply_norm(cfg, p["norm1"], x),
                         positions, causal=True)
    x = x + h
    h = moe_mod.apply_moe(cfg, p["moe"], L.apply_norm(cfg, p["norm2"], x),
                          mesh, rules)
    return x + h


def _apply_ssm_layer(cfg, p, x, state=None):
    h, new_state = ssm_mod.apply_ssm_block(
        cfg, p["ssm"], L.apply_norm(cfg, p["norm"], x), state)
    return x + h, new_state


def _apply_rec_layer(cfg, p, x, state=None):
    h, new_state = rglru.apply_rglru_block(
        cfg, p["rec"], L.apply_norm(cfg, p["norm1"], x), state)
    x = x + h
    h = L.apply_mlp(cfg, p["mlp"], L.apply_norm(cfg, p["norm2"], x))
    return x + h, new_state


# --------------------------------------------------------------------------


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    mesh: Mesh
    rules: sh.ShardingRules = dataclasses.field(
        default_factory=sh.default_rules)

    # -- params ----------------------------------------------------------
    def decls(self):
        return lm_decls(self.cfg)

    def init_params(self, key: Array):
        return sh.init_params(key, self.decls())

    def abstract_params(self):
        return sh.abstract_params(self.decls())

    def param_specs(self):
        return sh.spec_tree(self.decls(), self.rules, self.mesh)

    def shard_params(self, params):
        return sh.shard_params(params, self.param_specs(), self.mesh)

    def _constrain(self, x, *axes):
        spec = sh.resolve_spec(x.shape, axes, self.rules, self.mesh)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    # -- forward ------------------------------------------------------------
    def _maybe_remat(self, fn, train: bool):
        # save-inputs-only: each scanned layer keeps just its (B, S, D)
        # input; everything else (incl. the f32 norm/attention internals)
        # is recomputed in the backward pass. The dots-saveable policy was
        # measured to stack multi-GB f32 per-layer residuals (see
        # EXPERIMENTS.md section Perf).
        if train and self.cfg.remat:
            return jax.checkpoint(fn)
        return fn

    def backbone(self, params, x: Array, positions: Array,
                 train: bool = False) -> Array:
        """x: (B, S, D) embedded inputs -> final hidden states."""
        cfg, mesh = self.cfg, self.mesh
        x = self._constrain(x, "batch", None, "embed_act")

        if cfg.family in ("dense", "vlm"):
            def body(h, p):
                return (_apply_dense_layer(cfg, p, h, positions, mesh,
                                           window=cfg.attn_window), None)
            x, _ = jax.lax.scan(self._maybe_remat(body, train), x,
                                params["layers"])
        elif cfg.family == "moe":
            if cfg.moe.first_layer_dense:
                x = _apply_dense_layer(cfg, params["layer0"], x, positions,
                                       mesh)

            def body(h, p):
                return (_apply_moe_layer(cfg, p, h, positions, mesh,
                                         self.rules), None)
            x, _ = jax.lax.scan(self._maybe_remat(body, train), x,
                                params["layers"])
        elif cfg.family == "ssm":
            def body(h, p):
                out, _ = _apply_ssm_layer(cfg, p, h)
                return out, None
            x, _ = jax.lax.scan(self._maybe_remat(body, train), x,
                                params["layers"])
        elif cfg.family == "hybrid":
            window = cfg.hybrid.window

            def body(h, p):
                h, _ = _apply_rec_layer(cfg, p["rec1"], h)
                h, _ = _apply_rec_layer(cfg, p["rec2"], h)
                h = _apply_dense_layer(cfg, p["attn"], h, positions, mesh,
                                       window=window)
                return h, None
            x, _ = jax.lax.scan(self._maybe_remat(body, train), x,
                                params["triples"])
            i = 0
            while f"tail_rec{i}" in params:
                x, _ = _apply_rec_layer(cfg, params[f"tail_rec{i}"], x)
                i += 1
        else:
            raise ValueError(cfg.family)
        return L.apply_norm(cfg, params["final_norm"], x)

    def encode(self, params, frames: Array) -> Array:
        """Whisper encoder over stubbed frame embeddings (B, T_f, D)."""
        cfg = self.cfg
        pos = L.sinusoidal_positions(frames.shape[1], cfg.d_model)
        x = frames + pos[None].astype(frames.dtype)
        positions = jnp.arange(frames.shape[1])

        def body(h, p):
            return (_apply_dense_layer(cfg, p, h, positions, self.mesh,
                                       causal=False), None)
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return L.apply_norm(cfg, params["enc_norm"], x)

    def _decode_stack(self, params, x, positions, enc_out, train):
        """Whisper decoder stack (self-causal + cross)."""
        cfg = self.cfg
        enc_pos = jnp.arange(enc_out.shape[1])

        def body(h, p):
            a = attn.attend_full(cfg, p["self_attn"],
                                 L.apply_norm(cfg, p["norm1"], h),
                                 positions, causal=True)
            h = h + a
            a = attn.attend_full(cfg, p["cross_attn"],
                                 L.apply_norm(cfg, p["norm_x"], h),
                                 positions, causal=False,
                                 kv_x=enc_out, kv_positions=enc_pos)
            h = h + a
            a = L.apply_mlp(cfg, p["mlp"],
                            L.apply_norm(cfg, p["norm2"], h))
            return h + a, None

        x, _ = jax.lax.scan(self._maybe_remat(body, train), x,
                            params["dec_layers"])
        return L.apply_norm(cfg, params["final_norm"], x)

    def logits(self, params, batch: Dict[str, Array],
               train: bool = False) -> Array:
        cfg = self.cfg
        tokens = batch["tokens"]
        x = L.apply_embed(cfg, params["embed"], tokens)
        if cfg.family == "encdec":
            enc_out = self.encode(params, batch["frames"])
            pos = L.sinusoidal_positions(tokens.shape[1], cfg.d_model)
            x = x + pos[None].astype(x.dtype)
            positions = jnp.arange(tokens.shape[1])
            h = self._decode_stack(params, x, positions, enc_out, train)
        else:
            if cfg.family == "vlm":
                x = jnp.concatenate(
                    [batch["patches"].astype(x.dtype), x], axis=1)
            positions = jnp.arange(x.shape[1])
            h = self.backbone(params, x, positions, train=train)
            # vlm: logits cover the full (patches + text) sequence; the
            # loss masks out patch positions (see train_batch_specs).
        out = L.apply_unembed(cfg, params["embed"], h)
        return self._constrain(out, "batch", None, "vocab")

    def loss_fn(self, params, batch: Dict[str, Array]) -> Array:
        logits = self.logits(params, batch, train=True)
        return L.softmax_xent(logits, batch["labels"],
                              batch.get("loss_mask"))
