"""Unified telemetry subsystem (DESIGN.md section 13).

Two data planes, one enable story:

  * `repro.obs.registry` — process-wide metrics (counters, gauges,
    fixed-bucket histograms) for the host-side control plane. Strictly
    one boolean check when disabled.
  * `repro.obs.trace`    — Chrome-trace / Perfetto trace-event writer
    with span helpers ("X" complete events on named tracks) and a
    schema validator.

Device-side solver signals (per-bundle accepted alpha, backtrack depth
q^t, active-set size) do NOT go through host callbacks: the engine
iteration surfaces them as extra device outputs behind the `record_aux`
config flag and the host loop folds them into `SolveHistory` (and, when
the registry is enabled, into histograms) at the per-iteration sync it
already performs. With `record_aux=False` the compiled step is
byte-identical to the uninstrumented solver.

Convenience facade: `obs.enable(metrics=..., trace=...)` switches both
planes; the module-level helpers (`inc`, `observe`, `span`, ...) proxy
to the respective plane's zero-cost gate.
"""
from __future__ import annotations

from repro.obs import registry, trace
from repro.obs.registry import (ALPHA_BOUNDS, LATENCY_BOUNDS_S, Q_BOUNDS,
                                Histogram, Registry, get_registry, inc,
                                observe, observe_many, set_gauge,
                                write_metrics)
from repro.obs.trace import (TraceWriter, complete, counter, instant, span,
                             validate_trace, validate_trace_file)

__all__ = [
    "registry", "trace", "Registry", "Histogram", "TraceWriter",
    "LATENCY_BOUNDS_S", "Q_BOUNDS", "ALPHA_BOUNDS",
    "inc", "observe", "observe_many", "set_gauge", "write_metrics",
    "span", "complete", "instant", "counter",
    "validate_trace", "validate_trace_file",
    "enable", "disable", "metrics_enabled", "trace_enabled",
]


def enable(metrics: bool = True, trace_: bool = False,
           process_name: str = "repro") -> None:
    """Switch the telemetry planes on. REPRO_METRICS=off still wins for
    the metrics plane (registry.env_force_off)."""
    if metrics:
        registry.enable()
    if trace_:
        trace.enable(process_name)


def disable() -> None:
    registry.disable()
    trace.disable()


def metrics_enabled() -> bool:
    return registry.enabled()


def trace_enabled() -> bool:
    return trace.enabled()
