"""Process-wide metrics registry (DESIGN.md section 13.1).

Counters, gauges and FIXED-BUCKET histograms for the host-side control
plane: the engine outer loop, the serving batcher, the autotune cache.
Nothing here ever runs inside a jit trace — device-side signals (per-
bundle alpha / q^t) ride the solver's aux outputs (section 13.2) and are
folded into the registry at the existing per-iteration host sync.

Cost contract (pinned by tests/test_obs.py):

  * disabled (the default): every module-level helper is a single
    boolean check and an immediate return — no allocation, no dict
    lookup, no time syscall. The compiled solver step is untouched
    (aux outputs are a separate config flag, `record_aux`).
  * enabled: a counter inc is one dict lookup + float add; a histogram
    observe is a bisect into a static bound list. No locks — jax
    dispatch is single-threaded host-side, and the serving batcher is
    synchronous; the registry documents (not guards) that contract.

Enablement: `obs.enable()` / `obs.disable()` (the `--metrics-out` CLI
flag calls enable). The env knob REPRO_METRICS=off force-disables even
when code calls enable() — the documented kill switch for production
runs that must not pay even the cheap path (README "Observability").

Histograms are fixed-bucket so a snapshot is O(#buckets) JSON, never a
raw sample log; `Histogram.quantile` interpolates p50/p99 from the
bucket counts (exact min/max/sum/count are tracked alongside, so mean
and range are exact even where quantiles are estimates).
"""
from __future__ import annotations

import bisect
import json
import math
import os
import time
from typing import Dict, Optional, Sequence

# default latency bounds: 1us .. ~100s, quarter-decade log spacing
LATENCY_BOUNDS_S = tuple(
    10.0 ** (e / 4.0) for e in range(-24, 9))
# Armijo backtrack depth q^t: small integers (paper Table 4: mean ~ 1)
Q_BOUNDS = tuple(float(v) for v in (1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 40))
# accepted step size alpha = beta^q in (0, 1]
ALPHA_BOUNDS = tuple(0.5 ** e for e in range(12, -1, -1))


class Histogram:
    """Fixed-bucket histogram: counts[i] = #observations <= bounds[i],
    counts[-1] = overflow. Exact sum/count/min/max on the side."""

    __slots__ = ("bounds", "counts", "count", "total", "vmin", "vmax")

    def __init__(self, bounds: Sequence[float]):
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError(f"bounds must be strictly increasing: {bounds}")
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf

    def observe(self, value: float) -> None:
        v = float(value)
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v

    def observe_many(self, values) -> None:
        for v in values:
            self.observe(v)

    @property
    def mean(self) -> Optional[float]:
        return (self.total / self.count) if self.count else None

    def quantile(self, q: float) -> Optional[float]:
        """Interpolated quantile estimate from the bucket counts; exact
        at the tracked min/max endpoints."""
        if not self.count:
            return None
        if q <= 0:
            return self.vmin
        if q >= 1:
            return self.vmax
        rank = q * self.count
        seen = 0.0
        for i, c in enumerate(self.counts):
            if seen + c >= rank:
                lo = self.bounds[i - 1] if i > 0 else self.vmin
                hi = self.bounds[i] if i < len(self.bounds) else self.vmax
                lo = max(lo, self.vmin)
                hi = min(hi, self.vmax)
                frac = (rank - seen) / c if c else 0.0
                return lo + frac * (hi - lo)
            seen += c
        return self.vmax

    def as_dict(self) -> dict:
        return {
            "count": self.count,
            "sum": self.total,
            "min": None if self.count == 0 else self.vmin,
            "max": None if self.count == 0 else self.vmax,
            "mean": self.mean,
            "p50": self.quantile(0.5),
            "p99": self.quantile(0.99),
            "bounds": list(self.bounds),
            "counts": list(self.counts),
        }


class Registry:
    """A bag of named counters / gauges / histograms."""

    def __init__(self):
        self.counters: Dict[str, float] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, Histogram] = {}

    def inc(self, name: str, value: float = 1.0) -> None:
        self.counters[name] = self.counters.get(name, 0.0) + value

    def set_gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    def histogram(self, name: str,
                  bounds: Sequence[float] = LATENCY_BOUNDS_S) -> Histogram:
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = Histogram(bounds)
        return h

    def observe(self, name: str, value: float,
                bounds: Sequence[float] = LATENCY_BOUNDS_S) -> None:
        self.histogram(name, bounds).observe(value)

    def observe_many(self, name: str, values,
                     bounds: Sequence[float] = LATENCY_BOUNDS_S) -> None:
        self.histogram(name, bounds).observe_many(values)

    @property
    def empty(self) -> bool:
        return not (self.counters or self.gauges or self.histograms)

    def snapshot(self) -> dict:
        """JSON-ready view of everything recorded so far."""
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "histograms": {k: h.as_dict()
                           for k, h in sorted(self.histograms.items())},
        }

    def reset(self) -> None:
        self.counters.clear()
        self.gauges.clear()
        self.histograms.clear()


# ---------------------------------------------------------------------------
# module-level default registry + the zero-cost gate

_registry = Registry()
_enabled = False


def env_force_off() -> bool:
    """REPRO_METRICS=off/0/false force-disables the registry even when
    code calls enable() — the production kill switch."""
    return os.environ.get("REPRO_METRICS", "").strip().lower() in (
        "0", "off", "false", "no")


def enable() -> bool:
    """Turn the default registry on (no-op under REPRO_METRICS=off).
    Returns the resulting enabled state."""
    global _enabled
    _enabled = not env_force_off()
    return _enabled


def disable() -> None:
    global _enabled
    _enabled = False


def enabled() -> bool:
    return _enabled


def get_registry() -> Registry:
    return _registry


def reset() -> None:
    _registry.reset()


# The hot-path helpers: ONE boolean check when disabled. Instrumented
# code calls these, never the Registry methods directly.

def inc(name: str, value: float = 1.0) -> None:
    if _enabled:
        _registry.inc(name, value)


def set_gauge(name: str, value: float) -> None:
    if _enabled:
        _registry.set_gauge(name, value)


def observe(name: str, value: float,
            bounds: Sequence[float] = LATENCY_BOUNDS_S) -> None:
    if _enabled:
        _registry.observe(name, value, bounds)


def observe_many(name: str, values,
                 bounds: Sequence[float] = LATENCY_BOUNDS_S) -> None:
    if _enabled:
        _registry.observe_many(name, values, bounds)


def write_metrics(path: str, meta: Optional[dict] = None) -> dict:
    """Append one JSONL run record: {ts, meta..., metrics: snapshot}.

    JSONL so repeated runs of a CLI accumulate a comparable log — each
    line is one run, self-contained.
    """
    record = {"ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
              **(meta or {}),
              "metrics": _registry.snapshot()}
    with open(path, "a") as fh:
        fh.write(json.dumps(record, default=float) + "\n")
    return record
