"""Chrome-trace / Perfetto trace-event writer (DESIGN.md section 13.3).

Emits the JSON Object Format of the Trace Event specification —
``{"traceEvents": [...]}`` with complete ("X"), instant ("i"), counter
("C") and metadata ("M") events — which both ``chrome://tracing`` and
https://ui.perfetto.dev load directly.

Schema subset we emit (and `validate_trace` enforces):

  * every event: ``name`` (str), ``ph`` (one of X/i/C/M), ``ts``
    (microseconds, float, >= 0), ``pid``/``tid`` (ints);
  * "X" events additionally carry ``dur`` (microseconds, >= 0);
  * on one (pid, tid) track, "X" spans are properly nested — a span
    either encloses another or is disjoint from it; partial overlap is
    a writer bug (it renders as garbage in Perfetto) and validation
    fails on it.

Tracks are named ("engine", "serve", "kernels", "path"): each maps to a
stable tid plus a thread_name metadata event, so Perfetto shows labeled
rows. Span timing uses `time.perf_counter_ns` rebased to the writer's
construction, so ts stays small and float-exact.

Cost contract: module-level `span(...)` returns a shared no-op context
manager when tracing is disabled — one predicate call, no allocation.
Spans measure HOST time; around async jax dispatch a span measures the
dispatch unless the caller blocks (the engine loop and the batcher both
already block at their harvest points, so their spans are true
durations).
"""
from __future__ import annotations

import json
import os
import time
from typing import Optional

_PID = os.getpid()


class _NullSpan:
    """Shared disabled-path context manager: enter/exit do nothing."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("writer", "name", "tid", "args", "t0")

    def __init__(self, writer: "TraceWriter", name: str, tid: int, args):
        self.writer = writer
        self.name = name
        self.tid = tid
        self.args = args
        self.t0 = 0

    def __enter__(self):
        self.t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        self.writer._complete_ns(self.name, self.tid, self.t0,
                                 time.perf_counter_ns(), self.args)
        return False


class TraceWriter:
    """Collects trace events in memory; `save` writes the JSON file."""

    def __init__(self, process_name: str = "repro"):
        self.events: list = []
        self._t0_ns = time.perf_counter_ns()
        self._tids: dict = {}
        self.events.append({
            "name": "process_name", "ph": "M", "ts": 0.0, "pid": _PID,
            "tid": 0, "args": {"name": process_name}})

    # -- track bookkeeping ---------------------------------------------------
    def track(self, name: str) -> int:
        tid = self._tids.get(name)
        if tid is None:
            tid = self._tids[name] = len(self._tids) + 1
            self.events.append({
                "name": "thread_name", "ph": "M", "ts": 0.0, "pid": _PID,
                "tid": tid, "args": {"name": name}})
        return tid

    def _us(self, t_ns: int) -> float:
        return (t_ns - self._t0_ns) / 1e3

    # -- events --------------------------------------------------------------
    def span(self, name: str, track: str = "main",
             args: Optional[dict] = None) -> _Span:
        return _Span(self, name, self.track(track), args)

    def _complete_ns(self, name: str, tid: int, t0_ns: int, t1_ns: int,
                     args: Optional[dict]) -> None:
        ev = {"name": name, "ph": "X", "ts": self._us(t0_ns),
              "dur": max((t1_ns - t0_ns) / 1e3, 0.0), "pid": _PID,
              "tid": tid}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def complete(self, name: str, track: str, t0_ns: int, t1_ns: int,
                 args: Optional[dict] = None) -> None:
        """Record a finished span from explicit perf_counter_ns stamps —
        for callers that already timestamp (the engine loop), so the
        span matches their recorded wall clock exactly."""
        self._complete_ns(name, self.track(track), t0_ns, t1_ns, args)

    def instant(self, name: str, track: str = "main",
                args: Optional[dict] = None) -> None:
        ev = {"name": name, "ph": "i", "ts": self._us(time.perf_counter_ns()),
              "pid": _PID, "tid": self.track(track), "s": "t"}
        if args:
            ev["args"] = args
        self.events.append(ev)

    def counter(self, name: str, value: float, track: str = "main") -> None:
        self.events.append({
            "name": name, "ph": "C",
            "ts": self._us(time.perf_counter_ns()), "pid": _PID,
            "tid": self.track(track), "args": {"value": float(value)}})

    # -- output --------------------------------------------------------------
    def to_dict(self) -> dict:
        return {"traceEvents": list(self.events), "displayTimeUnit": "ms"}

    def save(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_dict(), fh, default=float)


# ---------------------------------------------------------------------------
# module-level default tracer + the zero-cost gate

_tracer: Optional[TraceWriter] = None


def enable(process_name: str = "repro") -> TraceWriter:
    """Install (and return) a fresh default tracer."""
    global _tracer
    _tracer = TraceWriter(process_name)
    return _tracer


def disable() -> None:
    global _tracer
    _tracer = None


def enabled() -> bool:
    return _tracer is not None


def get_tracer() -> Optional[TraceWriter]:
    return _tracer


def span(name: str, track: str = "main", args: Optional[dict] = None):
    t = _tracer
    if t is None:
        return _NULL_SPAN
    return t.span(name, track, args)


def complete(name: str, track: str, t0_ns: int, t1_ns: int,
             args: Optional[dict] = None) -> None:
    t = _tracer
    if t is not None:
        t.complete(name, track, t0_ns, t1_ns, args)


def instant(name: str, track: str = "main",
            args: Optional[dict] = None) -> None:
    t = _tracer
    if t is not None:
        t.instant(name, track, args)


def counter(name: str, value: float, track: str = "main") -> None:
    t = _tracer
    if t is not None:
        t.counter(name, value, track)


def save(path: str) -> bool:
    """Save and clear the default tracer. Returns False if none active."""
    global _tracer
    if _tracer is None:
        return False
    _tracer.save(path)
    _tracer = None
    return True


# ---------------------------------------------------------------------------
# schema validation (the CI gate; also used by tests and bench_obs)

_PHASES = {"X", "i", "C", "M"}


def validate_trace(obj) -> int:
    """Assert `obj` is valid trace-event JSON per the module contract.

    Returns the number of events checked; raises ValueError with a
    pointed message on the first violation. Checks: top-level shape,
    required fields and types per event, non-negative ts/dur, and
    proper nesting (no partial overlap) of "X" spans per (pid, tid)
    track.
    """
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("not a trace-event JSON object "
                         "(missing 'traceEvents')")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    spans: dict = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i} is not an object")
        for field in ("name", "ph", "ts", "pid", "tid"):
            if field not in ev:
                raise ValueError(f"event {i} ({ev.get('name')!r}) missing "
                                 f"required field {field!r}")
        ph = ev["ph"]
        if ph not in _PHASES:
            raise ValueError(f"event {i} has unknown phase {ph!r}")
        if not isinstance(ev["ts"], (int, float)) or ev["ts"] < 0:
            raise ValueError(f"event {i} has invalid ts {ev['ts']!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"span event {i} ({ev['name']!r}) has "
                                 f"invalid dur {dur!r}")
            spans.setdefault((ev["pid"], ev["tid"]), []).append(
                (float(ev["ts"]), float(ev["ts"]) + float(dur), ev["name"]))
    # proper nesting per track: sweep spans by (start, -end); each span
    # must fit inside the innermost open ancestor.
    for track, ss in spans.items():
        ss.sort(key=lambda t: (t[0], -t[1]))
        stack: list = []
        for t0, t1, name in ss:
            while stack and stack[-1][1] <= t0:
                stack.pop()
            if stack and t1 > stack[-1][1]:
                raise ValueError(
                    f"track {track}: span {name!r} [{t0}, {t1}] partially "
                    f"overlaps {stack[-1][2]!r} [{stack[-1][0]}, "
                    f"{stack[-1][1]}] — same-track spans must nest or be "
                    f"disjoint")
            stack.append((t0, t1, name))
    return len(events)


def validate_trace_file(path: str) -> int:
    with open(path) as fh:
        return validate_trace(json.load(fh))
