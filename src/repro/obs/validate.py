"""Telemetry-artifact schema validator CLI (the CI gate):

    python -m repro.obs.validate /tmp/trace.json /tmp/run.jsonl [...]

Validates both telemetry planes by file extension:

* ``*.jsonl`` — metrics run-record logs (``--metrics-out``): every line
  must be one self-contained ``{ts, meta..., metrics: {counters,
  gauges, histograms}}`` record per the `repro.obs.registry` contract —
  numeric counter/gauge values, histogram dicts with consistent
  bounds/counts (len(counts) == len(bounds)+1, sum(counts) == count).
* anything else — Chrome-trace JSON per the contract of
  `repro.obs.trace`: required ph/ts/dur fields, known phases, and
  properly nested (never partially overlapping) "X" spans on every
  (pid, tid) track.

Exit code 0 iff every file validates.
"""
from __future__ import annotations

import json
import sys

from repro.obs.trace import validate_trace_file

# every histogram dict the registry snapshot writes carries exactly
# these keys (registry.Histogram.as_dict)
_HIST_KEYS = {"count", "sum", "min", "max", "mean", "p50", "p99",
              "bounds", "counts"}


def _check_numeric_map(name: str, obj) -> None:
    if not isinstance(obj, dict):
        raise ValueError(f"'{name}' must be an object")
    for k, v in obj.items():
        if not isinstance(k, str):
            raise ValueError(f"'{name}' key {k!r} is not a string")
        if not isinstance(v, (int, float)) or isinstance(v, bool):
            raise ValueError(f"{name}[{k!r}] must be numeric, got {v!r}")


def _check_histogram(name: str, h) -> None:
    if not isinstance(h, dict):
        raise ValueError(f"histogram {name!r} must be an object")
    missing = _HIST_KEYS - set(h)
    if missing:
        raise ValueError(f"histogram {name!r} missing keys "
                         f"{sorted(missing)}")
    count, bounds, counts = h["count"], h["bounds"], h["counts"]
    if not isinstance(count, int) or count < 0:
        raise ValueError(f"histogram {name!r}: 'count' must be a "
                         f"non-negative int, got {count!r}")
    if not isinstance(bounds, list) or not isinstance(counts, list):
        raise ValueError(f"histogram {name!r}: 'bounds'/'counts' must "
                         f"be lists")
    if len(counts) != len(bounds) + 1:
        raise ValueError(
            f"histogram {name!r}: len(counts)={len(counts)} != "
            f"len(bounds)+1={len(bounds) + 1}")
    if any(not isinstance(b, (int, float)) or isinstance(b, bool)
           for b in bounds):
        raise ValueError(f"histogram {name!r}: non-numeric bound")
    if any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
        raise ValueError(f"histogram {name!r}: bounds must be strictly "
                         f"increasing")
    if any(not isinstance(c, int) or c < 0 for c in counts):
        raise ValueError(f"histogram {name!r}: counts must be "
                         f"non-negative ints")
    if sum(counts) != count:
        raise ValueError(f"histogram {name!r}: sum(counts)="
                         f"{sum(counts)} != count={count}")
    if count > 0 and (h["min"] is None or h["max"] is None):
        raise ValueError(f"histogram {name!r}: min/max must be set when "
                         f"count > 0")


def validate_metrics_record(record) -> None:
    """One run record per the `registry.write_metrics` contract."""
    if not isinstance(record, dict):
        raise ValueError("record must be a JSON object")
    ts = record.get("ts")
    if not isinstance(ts, str) or not ts:
        raise ValueError("record missing string 'ts'")
    metrics = record.get("metrics")
    if not isinstance(metrics, dict):
        raise ValueError("record missing object 'metrics'")
    unknown = set(metrics) - {"counters", "gauges", "histograms"}
    if unknown:
        raise ValueError(f"'metrics' has unknown sections "
                         f"{sorted(unknown)}")
    _check_numeric_map("metrics.counters", metrics.get("counters", {}))
    _check_numeric_map("metrics.gauges", metrics.get("gauges", {}))
    hists = metrics.get("histograms", {})
    if not isinstance(hists, dict):
        raise ValueError("'metrics.histograms' must be an object")
    for name, h in hists.items():
        _check_histogram(name, h)


def validate_metrics_file(path: str) -> int:
    """Validate a --metrics-out JSONL log; returns the record count."""
    n = 0
    with open(path) as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            try:
                record = json.loads(line)
            except json.JSONDecodeError as exc:
                raise ValueError(f"line {lineno}: not JSON — {exc}") \
                    from None
            try:
                validate_metrics_record(record)
            except ValueError as exc:
                raise ValueError(f"line {lineno}: {exc}") from None
            n += 1
    if n == 0:
        raise ValueError("no records (empty log)")
    return n


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.obs.validate TRACE.json|RUN.jsonl "
              "[...]", file=sys.stderr)
        return 2
    status = 0
    for path in argv:
        kind = "metrics" if path.endswith(".jsonl") else "trace"
        try:
            if kind == "metrics":
                n = validate_metrics_file(path)
                unit = "records"
            else:
                n = validate_trace_file(path)
                unit = "events"
        except (OSError, ValueError) as exc:
            print(f"[obs.validate] {path}: INVALID — {exc}", file=sys.stderr)
            status = 1
        else:
            print(f"[obs.validate] {path}: OK ({n} {unit})")
    return status


if __name__ == "__main__":
    raise SystemExit(main())
