"""Trace-file schema validator CLI (the CI gate):

    python -m repro.obs.validate /tmp/trace.json [...]

Loads each file and asserts it is valid trace-event JSON per the
contract of `repro.obs.trace` — required ph/ts/dur fields, known
phases, and properly nested (never partially overlapping) "X" spans on
every (pid, tid) track. Exit code 0 iff every file validates.
"""
from __future__ import annotations

import sys

from repro.obs.trace import validate_trace_file


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if not argv:
        print("usage: python -m repro.obs.validate TRACE.json [...]",
              file=sys.stderr)
        return 2
    status = 0
    for path in argv:
        try:
            n = validate_trace_file(path)
        except (OSError, ValueError) as exc:
            print(f"[obs.validate] {path}: INVALID — {exc}", file=sys.stderr)
            status = 1
        else:
            print(f"[obs.validate] {path}: OK ({n} events)")
    return status


if __name__ == "__main__":
    raise SystemExit(main())
