"""Optimizer substrate (built from scratch: no optax in this environment)."""
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedules import cosine_schedule, linear_warmup_cosine
from repro.optim.compression import topk_compress_update

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule",
           "linear_warmup_cosine", "topk_compress_update"]
