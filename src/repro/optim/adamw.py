"""AdamW with decoupled weight decay and global-norm clipping.

Optimizer state is kept in float32 regardless of the (bf16) param dtype;
master-weight copies are optional (`keep_master=True` stores f32 params in
the state for bit-accurate long runs, at +4 bytes/param).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    keep_master: bool = False


class AdamWState(NamedTuple):
    step: Array
    mu: Any
    nu: Any
    master: Any  # f32 params or None


def adamw_init(params, cfg: AdamWConfig) -> AdamWState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    mu = jax.tree.map(f32, params)
    nu = jax.tree.map(f32, params)
    master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
              if cfg.keep_master else None)
    return AdamWState(jnp.zeros((), jnp.int32), mu, nu, master)


def global_norm(tree) -> Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(params, grads, state: AdamWState, cfg: AdamWConfig,
                 lr: Optional[Array] = None):
    """-> (new_params, new_state, metrics)."""
    lr = cfg.lr if lr is None else lr
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip > 0 else 1.0

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, master):
        gf = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * jnp.square(gf)
        mhat = m / c1
        vhat = v / c2
        base = (master if master is not None else p).astype(jnp.float32)
        new = base - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps)
                           + cfg.weight_decay * base)
        return new.astype(p.dtype), m, v, new

    if state.master is not None:
        out = jax.tree.map(upd, params, grads, state.mu, state.nu,
                           state.master)
    else:
        out = jax.tree.map(lambda p, g, m, v: upd(p, g, m, v, None),
                           params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    mu = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda t: t[2], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    master = (jax.tree.map(lambda t: t[3], out,
                           is_leaf=lambda x: isinstance(x, tuple))
              if cfg.keep_master else None)
    return new_params, AdamWState(step, mu, nu, master), {
        "grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}


def adamw_abstract_state(abstract_params, cfg: AdamWConfig) -> AdamWState:
    """ShapeDtypeStruct state for dry-run lowering (no allocation)."""
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    mu = jax.tree.map(f32, abstract_params)
    nu = jax.tree.map(f32, abstract_params)
    master = (jax.tree.map(f32, abstract_params) if cfg.keep_master
              else None)
    return AdamWState(jax.ShapeDtypeStruct((), jnp.int32), mu, nu, master)
