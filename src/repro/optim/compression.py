"""Top-k error-feedback gradient compression (DESIGN.md section 4).

For bandwidth-bound data-parallel all-reduces: each step transmits only the
top-k fraction of gradient entries per leaf; the residual is accumulated
locally (error feedback, Karimireddy et al. 2019) so the compression error
is corrected over time rather than lost. PCDN's own collectives are already
O(P + Q) floats so this applies to the LM trainer path.

The compressed all-reduce is expressed as psum-of-sparse-densified inside
shard_map; on a real fleet the wire format is (values, indices) — we carry
the dense masked tensor through XLA (the collective-bytes accounting in
the roofline counts the ideal 2k floats; see benchmarks/roofline.py).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


def topk_mask(x: Array, frac: float) -> Array:
    """Boolean mask of the top-|frac| fraction of |x| entries."""
    flat = jnp.abs(x.reshape(-1))
    k = max(1, int(frac * flat.shape[0]))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh)


def topk_compress_update(grads: Any, residual: Any,
                         frac: float = 0.01) -> Tuple[Any, Any]:
    """-> (compressed_grads, new_residual). compressed + residual == grads
    + old residual (mass conservation, property-tested)."""
    def one(g, r):
        total = g.astype(jnp.float32) + r
        mask = topk_mask(total, frac)
        sent = jnp.where(mask, total, 0.0)
        return sent.astype(g.dtype), total - sent

    flat_g, td = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    comp = jax.tree.unflatten(td, [o[0] for o in out])
    res = jax.tree.unflatten(td, [o[1] for o in out])
    return comp, res


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
