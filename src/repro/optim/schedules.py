"""Learning-rate schedules (pure functions of the int32 step)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(base_lr: float, total_steps: int, min_frac: float = 0.1):
    def f(step):
        t = jnp.clip(step.astype(jnp.float32) / max(total_steps, 1), 0, 1)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return base_lr * (min_frac + (1 - min_frac) * cos)
    return f


def linear_warmup_cosine(base_lr: float, warmup_steps: int,
                         total_steps: int, min_frac: float = 0.1):
    cos = cosine_schedule(base_lr, max(total_steps - warmup_steps, 1),
                          min_frac)

    def f(step):
        s = step.astype(jnp.float32)
        warm = base_lr * s / max(warmup_steps, 1)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))
    return f
