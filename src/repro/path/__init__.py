"""Regularization-path engine (DESIGN.md section 8): warm-started
λ-sweeps over a geometric c-grid, active-set shrinking, and vmapped
multi-problem batch solving over a shared design matrix."""
from repro.path.batch import BatchSolveResult, make_batch_outer, solve_batch
from repro.path.driver import (PathConfig, PathPoint, PathResult,
                               path_summary, pick_best, run_path)
from repro.path.grid import c_grid, problem_grid

__all__ = [
    "PathConfig", "PathPoint", "PathResult", "run_path", "path_summary",
    "pick_best", "c_grid", "problem_grid",
    "BatchSolveResult", "make_batch_outer", "solve_batch",
]
