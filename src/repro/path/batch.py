"""Vmapped multi-problem batch solving (DESIGN.md section 8.3).

Solves B l1 problems that share one DesignMatrix — different c values,
labels and/or partition seeds — in a SINGLE XLA program: the per-problem
outer iteration is `jax.vmap`-ed over the (w, z, key, c[, y]) carries
while the design arrays are closed over (broadcast, resident once). This
is the throughput-oriented serving mode: one dispatch advances every
request in the batch by one outer iteration.

Contract (the "vmap batching contract" of DESIGN.md section 8.3):
  * the design matrix is shared and read-only; per-problem state is
    exactly the vmapped carry, so peak memory is B * (n + s) + one design;
  * every problem runs the same bundle schedule SHAPE (same P, same b)
    but its own random partition (per-problem PRNG key chain, identical
    to what a solo `pcdn.solve` with that seed would draw);
  * convergence is per-problem: a problem whose full-set KKT drops below
    tol is frozen (its carry is re-selected, not updated), so its result
    is bit-identical to stopping — stragglers keep iterating in lockstep.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bundles as B
from repro.core.pcdn import PCDNConfig, make_bundle_step
from repro.core.problem import L1Problem
from repro.engine import loop as engine_loop

Array = jax.Array


class BatchSolveResult(NamedTuple):
    w: Array            # (B, n)
    objective: Array    # (B,)
    kkt: Array          # (B,)
    nnz: Array          # (B,)
    n_outer: Array      # (B,) outer iterations until each problem froze
    converged: Array    # (B,) bool
    z: Array            # (B, s) final margins X w — free from the carry;
                        # OVR training reads its train accuracy off these
                        # without another B-way matvec (serve/ovr.py)


def make_batch_outer(problem: L1Problem, cfg: PCDNConfig,
                     batched_labels: bool):
    """One jitted, vmapped outer iteration over B problem carries.

    Returns outer(w (B,n), z (B,s), key (B,2), c (B,)[, y (B,s)])
    -> (w, z, key, f, kkt, nnz), all B-leading.
    """
    n = problem.n_features

    def one(w, z, key, c, y):
        prob = problem.with_c(c)
        if y is not None:
            prob = prob.with_labels(y)
        step = make_bundle_step(prob, cfg)
        key, sub = jax.random.split(key)
        idxs = B.partition(sub, n, cfg.P)
        (w, z), (steps, _alphas) = jax.lax.scan(step, (w, z), idxs)
        f = prob.objective_from_margins(z, w)
        kkt = prob.kkt_violation(w, z)
        nnz = jnp.sum(w != 0)
        return w, z, key, f, kkt, nnz

    if batched_labels:
        mapped = jax.vmap(one, in_axes=(0, 0, 0, 0, 0))
    else:
        mapped = jax.vmap(lambda w, z, key, c: one(w, z, key, c, None),
                          in_axes=(0, 0, 0, 0))
    return jax.jit(mapped)


def solve_batch(problem: L1Problem, cfg: PCDNConfig,
                cs: Sequence[float],
                ys: Optional[np.ndarray] = None,
                seeds: Optional[Sequence[int]] = None,
                w0: Optional[np.ndarray] = None,
                outer=None) -> BatchSolveResult:
    """Solve B problems sharing `problem.design` in one vmapped program.

    cs: (B,) per-problem regularization values. ys: optional (B, s)
    per-problem labels (default: share problem.y). seeds: optional (B,)
    partition seeds (default: cfg.seed for every problem — same schedule,
    different c). w0: optional (B, n) warm starts.

    Matches a Python loop of `pcdn.solve` per problem up to f32 reduction
    -order noise from batched matvecs (tests/test_path.py pins this).
    """
    if cfg.shrink:
        raise ValueError(
            "solve_batch does not implement active-set shrinking (every "
            "problem would need its own active mask + dynamic trip count, "
            "breaking the lockstep vmap); pass PCDNConfig(shrink=False) "
            "and use run_path for shrinking sweeps")
    cs = np.asarray(cs, np.float64)
    batch = cs.shape[0]
    n, s = problem.n_features, problem.n_samples
    dtype = problem.dtype
    if ys is not None:
        ys = jnp.asarray(np.asarray(ys), dtype)
        if ys.shape != (batch, s):
            raise ValueError(f"ys must be ({batch}, {s}), got {ys.shape}")
    if seeds is None:
        seeds = [cfg.seed] * batch
    if len(seeds) != batch:
        raise ValueError(f"need {batch} seeds, got {len(seeds)}")

    if w0 is None:
        w = jnp.zeros((batch, n), dtype)
        z = jnp.zeros((batch, s), dtype)
    else:
        w = jnp.asarray(np.asarray(w0), dtype)
        if w.shape != (batch, n):
            raise ValueError(f"w0 must be ({batch}, {n}), got {w.shape}")
        z = jax.vmap(problem.design.matvec)(w)
    keys = jnp.stack([jax.random.PRNGKey(int(sd)) for sd in seeds])
    c_arr = jnp.asarray(cs, dtype)

    if outer is None:
        outer = make_batch_outer(problem, cfg, batched_labels=ys is not None)
    args = (ys,) if ys is not None else ()

    # the freeze-on-convergence host loop is the engine's (DESIGN.md §9)
    (w, z, keys), f, kkt, nnz, n_outer, done = engine_loop.run_lockstep_loop(
        outer, (w, z, keys), (c_arr,) + args,
        max_outer=cfg.max_outer, tol_kkt=cfg.tol_kkt, dtype=dtype)

    return BatchSolveResult(w=w, objective=f, kkt=kkt, nnz=nnz,
                            n_outer=n_outer, converged=done, z=z)
