"""Warm-started regularization-path driver (DESIGN.md sections 8 / 9).

Solves an l1 problem along a geometric c-grid built from the analytic
c_max, chaining the engine carry (w, z, active-set) from each point into
the next. The sweep runs on ANY execution backend (`repro.engine`):
locally one `pcdn.make_path_outer` program is compiled for the whole
sweep — c is a traced argument — so a 20-point path pays one XLA
compile, not twenty; on a `ShardedBackend` the same driver runs the
warm-started sweep (including active-set shrinking) across a
multi-device mesh with one compiled shard_map program.

Per point the driver records objective / nnz / full-set KKT / iteration
and wall-time cost plus (optionally) held-out validation accuracy, and
picks the best c by validation accuracy when a validation split is given.
"""
from __future__ import annotations

import dataclasses
import time
from typing import NamedTuple, Optional, Sequence

import numpy as np

from repro import obs
from repro.core.pcdn import PCDNConfig
from repro.core.problem import L1Problem, validation_accuracy
from repro.engine import loop as engine_loop
from repro.engine.local import LocalBackend
from repro.path import grid as grid_mod


@dataclasses.dataclass(frozen=True)
class PathConfig:
    """A λ-sweep: grid geometry + the per-point PCDN solver settings.

    `solver` supplies the stop parameters (max_outer / tol_kkt /
    recheck_every / tol_rel_obj) for every backend; its execution fields
    (P, ls_kind, use_kernels, shrink) govern the default local backend —
    a `ShardedBackend` brings its own `ShardedPCDNConfig` for those.
    """

    solver: PCDNConfig = PCDNConfig(P=256)
    n_points: int = 20
    span: float = 100.0                 # c_final = span * c_max when unset
    c_final: Optional[float] = None
    warm_start: bool = True             # chain (w, z, active) across points


class PathPoint(NamedTuple):
    c: float
    objective: float
    nnz: int
    kkt: float
    n_outer: int
    seconds: Optional[float]            # wall time on this point (None in
                                        # batch mode — lockstep solves
                                        # have no per-point timing)
    converged: bool
    val_accuracy: Optional[float]       # None without a validation split


class PathResult(NamedTuple):
    c_max: float
    cs: np.ndarray                      # (n_points,) ascending grid
    points: list                        # [PathPoint]
    weights: np.ndarray                 # (n_points, n) solutions per point
    best_index: Optional[int]           # argmax val accuracy (ties -> sparser)
    total_seconds: float
    # final grid point's full SolveHistory (the tightest c — where
    # parallelism stress peaks) for the `--diag-out` health report;
    # None in batch mode, which has no per-iteration history.
    last_history: Optional[object] = None
    last_postmortem: Optional[dict] = None

    @property
    def best(self) -> Optional[PathPoint]:
        return None if self.best_index is None else self.points[self.best_index]


def pick_best(points: Sequence[PathPoint]) -> Optional[int]:
    """Highest validation accuracy; ties go to the sparser (smaller-c)
    model, the usual one-standard-error-rule direction. Shared by the
    sweep driver and the batch-mode CLI so both modes pick identically."""
    scored = [(p.val_accuracy, -p.nnz, -i) for i, p in enumerate(points)
              if p.val_accuracy is not None]
    if not scored:
        return None
    return -max(scored)[2]


def run_path(problem: Optional[L1Problem], cfg: PathConfig,
             val_design=None, val_y=None,
             verbose: bool = False, outer=None,
             backend=None, callback=None,
             ckpt=None, resume: bool = False,
             fault_plan=None) -> PathResult:
    """Sweep the c-grid; `problem.c` is a template value and is ignored.

    backend: any engine execution backend; defaults to a `LocalBackend`
    over `problem` (which may then not be None). With a backend given,
    `problem` is unused — data, placement and the compiled iteration all
    live in the backend, which is how one sweep runs on a sharded mesh.
    val_design / val_y: optional held-out split (anything `as_design`
    accepts) scored after each point; enables the best-c pick.
    outer: optional prebuilt `pcdn.make_path_outer(problem, cfg.solver)`
    for the default local backend — benchmarks pass an already-compiled
    one so warm-vs-cold timings compare solver work, not XLA compile
    time.
    callback: forwarded to every point's engine loop (the `--progress`
    live status — signature (k, w, f, kkt, mean_q)).
    ckpt: optional `fault.SolveCheckpointer` — the finished carry, the
    per-point records and the weight rows are checkpointed after EVERY
    grid point (the point boundary is the natural resume unit; see the
    checkpointer docstring). resume=True restarts from the newest
    committed point checkpoint — the restored carry is the same host
    image the uninterrupted run had, so the resumed sweep's artifacts
    match bit-for-bit. The stored c-grid is validated against the live
    one. fault_plan: optional `fault.FaultPlan`; its iteration hooks
    count cumulative outer iterations across the sweep and
    `crash_at_point` fires right AFTER a point's checkpoint commits.
    """
    if (val_design is None) != (val_y is None):
        raise ValueError("pass both val_design and val_y or neither")
    if backend is None:
        if problem is None:
            raise ValueError("run_path needs a problem or a backend")
        backend = LocalBackend(problem, cfg.solver, outer=outer)
    solver = cfg.solver
    engine_loop.check_shrink_stop_consistency(backend, solver.tol_kkt)
    c_max = backend.c_max()
    cs = grid_mod.c_grid(c_max, c_final=cfg.c_final, n_points=cfg.n_points,
                         span=cfg.span)

    n = backend.n_features
    state = backend.init_state()

    points: list[PathPoint] = []
    res = None
    weights = np.zeros((len(cs), n), np.dtype(backend.dtype))
    i_start = 0
    if resume and ckpt is not None:
        got = ckpt.restore_path(backend, cs=cs, c_max=c_max)
        if got is not None:
            state, meta, saved_w = got
            i_start = int(meta["point_index"]) + 1
            points = [PathPoint(**p) for p in meta["points"]]
            weights[:i_start] = saved_w[:i_start]
            if verbose:
                print(f"[fault] resuming path sweep at point "
                      f"{i_start}/{len(cs)}", flush=True)
    outer_fn = backend.outer
    if fault_plan is not None:
        from repro.fault import inject as fault_inject
        outer_fn = fault_inject.wrap_outer(backend.outer, fault_plan)
    t_total0 = time.perf_counter()
    for i in range(i_start, len(cs)):
        c = cs[i]
        t0_ns = time.perf_counter_ns()
        t0 = time.perf_counter()
        if not cfg.warm_start:
            state = backend.init_state()
        else:
            # refresh margins from w once per point: O(one matvec), stops
            # f32 z-drift from accumulating across the whole sweep
            state = state._replace(z=backend.margins(state.w))
        state, res = engine_loop.run_outer_loop(
            outer_fn, state, float(c),
            max_outer=solver.max_outer, tol_kkt=solver.tol_kkt,
            recheck_every=solver.recheck_every,
            tol_rel_obj=solver.tol_rel_obj, callback=callback)
        seconds = time.perf_counter() - t0
        obs.complete("path.point", "path", t0_ns, time.perf_counter_ns(),
                     args={"i": i, "c": float(c), "n_outer": res.n_outer,
                           "converged": res.converged})
        obs.inc("path.points")
        w_host = backend.host_weights(state.w)
        val_acc = (validation_accuracy(val_design, val_y, w_host)
                   if val_design is not None else None)
        weights[i] = w_host
        points.append(PathPoint(
            c=float(c), objective=res.objective,
            nnz=int(np.count_nonzero(weights[i])),
            kkt=float(res.history.kkt[-1]) if res.history.kkt.size else 0.0,
            n_outer=res.n_outer, seconds=seconds,
            converged=res.converged, val_accuracy=val_acc))
        if verbose:
            p = points[-1]
            extra = f" val_acc={p.val_accuracy:.4f}" if p.val_accuracy is not None else ""
            print(f"[path] c={p.c:.5g} F={p.objective:.5f} nnz={p.nnz} "
                  f"kkt={p.kkt:.2e} iters={p.n_outer} "
                  f"t={p.seconds:.2f}s{extra}", flush=True)
        if ckpt is not None:
            ckpt.save_path(backend, state, point_index=i, cs=cs,
                           c_max=c_max, points=points, weights=weights)
        if fault_plan is not None:
            fault_plan.fire_point(i)

    return PathResult(c_max=c_max, cs=cs, points=points, weights=weights,
                      best_index=pick_best(points),
                      total_seconds=time.perf_counter() - t_total0,
                      last_history=res.history if res else None,
                      last_postmortem=res.postmortem if res else None)


def path_summary(result: PathResult) -> dict:
    """JSON-ready summary (weights omitted — they go to .npy if wanted)."""
    return {
        "c_max": result.c_max,
        "total_seconds": result.total_seconds,
        "best_index": result.best_index,
        "best_c": None if result.best is None else result.best.c,
        "points": [p._asdict() for p in result.points],
    }
