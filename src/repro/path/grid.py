"""Geometric c-grids anchored at the analytic c_max (DESIGN.md section 8.1).

The paper's objective F_c(w) = c * L(w) + ||w||_1 puts the regularization
strength at lambda ~ 1/c: SMALL c means strong regularization. The
largest c whose solution is exactly w = 0 is

    c_max = 1 / || X^T phi'(0, y) ||_inf        (L1Problem.c_max)

— the analogue of the classical lasso lambda_max. A regularization path
therefore sweeps c geometrically UP from c_max toward weaker
regularization (lambda descends, features activate one by one), which is
the order that makes warm starting effective: each point's solution is a
small perturbation of the previous one.
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.problem import L1Problem


def c_grid(c_max: float, c_final: Optional[float] = None,
           n_points: int = 20, span: float = 100.0) -> np.ndarray:
    """Geometric grid of n_points values from c_max to c_final, ascending.

    c_final defaults to span * c_max (span=100 covers two decades of
    lambda, the usual glmnet-style default). The first point sits exactly
    at c_max, where the all-zero model is optimal and the solver converges
    in one KKT check — the free anchor every warm chain starts from.
    """
    if c_max <= 0:
        raise ValueError(f"c_max must be positive, got {c_max}")
    if c_final is None:
        c_final = span * c_max
    if c_final <= c_max:
        raise ValueError(
            f"c_final={c_final} must exceed c_max={c_max}: values at or "
            f"below c_max all have the trivial solution w = 0")
    if n_points < 2:
        raise ValueError(f"need at least 2 grid points, got {n_points}")
    return np.geomspace(c_max, c_final, n_points)


def problem_grid(problem: L1Problem, c_final: Optional[float] = None,
                 n_points: int = 20, span: float = 100.0) -> np.ndarray:
    """c_grid anchored at `problem.c_max()` (problem.c itself is ignored)."""
    return c_grid(problem.c_max(), c_final=c_final, n_points=n_points,
                  span=span)
