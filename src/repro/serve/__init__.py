"""Sparse-model serving subsystem (DESIGN.md sections 10 and 14).

Training-to-traffic path for the solvers' l1 solutions:

  * `serve.artifact`  — versioned on-disk model format (active indices +
    values, loss/c, label vocabulary, solver provenance); a path sweep or
    an OVR head saves as one multi-model family, with `pick_best_c`
    selecting a path family's best grid point for serving.
  * `serve.ovr`       — one-vs-rest multiclass training: K binary
    subproblems fitted in ONE vmapped `path.batch.solve_batch` program
    over a shared DesignMatrix.
  * `serve.predict`   — batched-margin prediction engine over the stacked
    active-coordinate `ModelBank`, with Pallas sparse-gather kernels for
    dense and padded-CSC request layouts, and measured-crossover routing
    between the union-gather and densified-matmul scorers.
  * `serve.policy`    — shared bucket geometry (shape quantization) and
    the per-bucket EWMA latency model behind deadline math.
  * `serve.batcher`   — synchronous microbatching front-end: one
    bucket-padded batch per caller round-trip.
  * `serve.loop`      — continuous-batching serving loop: async request
    queue, deadline-aware flushing, multi-model routing, zero-downtime
    hot-swap via capacity-padded banks and donated installs.
"""
from repro.serve.artifact import (ModelArtifact, ModelFamily, SCHEMA,
                                  artifact_from_solution, load_model,
                                  path_family, pick_best_c, save_model,
                                  solver_provenance)
from repro.serve.batcher import BucketStats, MicroBatcher
from repro.serve.loop import (ServeFuture, ServeLoop, ServeOverload,
                              ServeResult, SwapCapacityError, drive_poisson)
from repro.serve.ovr import (OVRResult, encode_labels, fit_ovr, ovr_family,
                             ovr_label_matrix, ovr_margins)
from repro.serve.policy import BucketPolicy, LatencyModel, default_buckets
from repro.serve.predict import (ModelBank, decide, margins_dense,
                                 margins_padded_csc, pick_route, predict,
                                 route_crossover, scorer_cache_sizes,
                                 set_route_crossover)

__all__ = [
    "SCHEMA", "ModelArtifact", "ModelFamily", "artifact_from_solution",
    "save_model", "load_model", "path_family", "pick_best_c",
    "solver_provenance",
    "OVRResult", "encode_labels", "fit_ovr", "ovr_family",
    "ovr_label_matrix", "ovr_margins",
    "ModelBank", "margins_dense", "margins_padded_csc", "predict", "decide",
    "pick_route", "route_crossover", "set_route_crossover",
    "scorer_cache_sizes",
    "MicroBatcher", "BucketStats", "default_buckets",
    "BucketPolicy", "LatencyModel",
    "ServeLoop", "ServeFuture", "ServeResult", "ServeOverload",
    "SwapCapacityError", "drive_poisson",
]
