"""Sparse-model serving subsystem (DESIGN.md section 10).

Training-to-traffic path for the solvers' l1 solutions:

  * `serve.artifact`  — versioned on-disk model format (active indices +
    values, loss/c, label vocabulary, solver provenance); a path sweep or
    an OVR head saves as one multi-model family.
  * `serve.ovr`       — one-vs-rest multiclass training: K binary
    subproblems fitted in ONE vmapped `path.batch.solve_batch` program
    over a shared DesignMatrix.
  * `serve.predict`   — batched-margin prediction engine over the stacked
    active-coordinate `ModelBank`, with Pallas sparse-gather kernels for
    dense and padded-CSC request layouts.
  * `serve.batcher`   — microbatching front-end: bucket-padded request
    batches so steady-state traffic never recompiles, with per-bucket
    latency/throughput accounting.
"""
from repro.serve.artifact import (ModelArtifact, ModelFamily, SCHEMA,
                                  artifact_from_solution, load_model,
                                  path_family, save_model,
                                  solver_provenance)
from repro.serve.batcher import BucketStats, MicroBatcher, default_buckets
from repro.serve.ovr import (OVRResult, encode_labels, fit_ovr, ovr_family,
                             ovr_label_matrix, ovr_margins)
from repro.serve.predict import (ModelBank, decide, margins_dense,
                                 margins_padded_csc, predict)

__all__ = [
    "SCHEMA", "ModelArtifact", "ModelFamily", "artifact_from_solution",
    "save_model", "load_model", "path_family", "solver_provenance",
    "OVRResult", "encode_labels", "fit_ovr", "ovr_family",
    "ovr_label_matrix", "ovr_margins",
    "ModelBank", "margins_dense", "margins_padded_csc", "predict", "decide",
    "MicroBatcher", "BucketStats", "default_buckets",
]
