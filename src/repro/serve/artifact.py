"""Versioned on-disk model format (DESIGN.md section 10.1).

An l1 solution is sparse by construction, so a model ships as its active
set — (indices, values) of the nonzero weights — plus everything needed
to score a request and to audit where the model came from: loss name,
regularization c, optional bias, the label each model separates (OVR) or
its grid position (path family), and solver provenance.

One JSON file holds either a single binary model or a *family* of models
sharing (n_features, loss): a one-vs-rest head (kind="ovr", one model per
class) or a regularization-path sweep (kind="path", one model per grid
point — a sweep becomes a servable model family for free).

The format deliberately extends the `--out` report of `repro.launch.solve`
rather than replacing it: a report written with the artifact fields is
simultaneously a loadable model, a warm-start input (it keeps the
`w_indices`/`w_values`/`n_features` record `launch.common.load_warm_start`
reads), and a history log. `load_model` refuses files without the schema
tag loudly so stale pre-artifact reports fail with a clear message.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Optional, Sequence, Tuple

import numpy as np

from repro.fault.atomic import atomic_write_json

SCHEMA = "repro.serve/model@1"


@dataclasses.dataclass(frozen=True)
class ModelArtifact:
    """One sparse linear classifier: score(x) = w . x + bias.

    Weights are stored as the active set only; `w_indices` is sorted
    strictly ascending, `w_values` is aligned with it. `label` is the
    class this model separates in an OVR head (None for binary / path
    members); `meta` carries per-model fit diagnostics (objective, kkt,
    n_outer, converged) — free-form, never needed for scoring.
    """

    n_features: int
    w_indices: np.ndarray          # (nnz,) int64, sorted ascending
    w_values: np.ndarray           # (nnz,) float64
    loss_name: str
    c: float
    bias: float = 0.0
    label: Optional[float] = None
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        idx = np.asarray(self.w_indices, np.int64).reshape(-1)
        val = np.asarray(self.w_values, np.float64).reshape(-1)
        if idx.shape != val.shape:
            raise ValueError(f"w_indices {idx.shape} vs w_values "
                             f"{val.shape} length mismatch")
        if idx.size:
            if int(idx.min()) < 0 or int(idx.max()) >= self.n_features:
                raise ValueError(
                    f"w_indices outside [0, {self.n_features})")
            if np.any(np.diff(idx) <= 0):
                raise ValueError("w_indices must be sorted strictly "
                                 "ascending (duplicate or unsorted index)")
        object.__setattr__(self, "w_indices", idx)
        object.__setattr__(self, "w_values", val)

    @property
    def nnz(self) -> int:
        return int(self.w_indices.shape[0])

    def sparsity(self) -> float:
        return 1.0 - self.nnz / float(max(self.n_features, 1))

    def dense_weights(self, dtype=np.float32) -> np.ndarray:
        w = np.zeros((self.n_features,), dtype)
        w[self.w_indices] = self.w_values.astype(dtype)
        return w

    def _to_json(self) -> dict:
        d = {"c": float(self.c), "bias": float(self.bias),
             "w_indices": self.w_indices.tolist(),
             "w_values": self.w_values.tolist()}
        if self.label is not None:
            d["label"] = self.label
        if self.meta:
            d["meta"] = self.meta
        return d


def artifact_from_solution(w, loss_name: str, c: float, bias: float = 0.0,
                           label=None, meta: Optional[dict] = None,
                           ) -> ModelArtifact:
    """Build an artifact from a dense solution vector (host or device)."""
    w = np.asarray(w, np.float64).reshape(-1)
    idx = np.flatnonzero(w)
    return ModelArtifact(n_features=int(w.shape[0]), w_indices=idx,
                         w_values=w[idx], loss_name=loss_name, c=float(c),
                         bias=float(bias), label=label, meta=meta or {})


@dataclasses.dataclass(frozen=True)
class ModelFamily:
    """Models sharing (n_features, loss): binary (1), ovr (K), path (K).

    For kind="ovr" every member carries its `label` and `classes` lists
    them in model order (argmax over member margins indexes into it);
    for kind="path" members are ordered by their grid c (ascending, the
    sweep order). kind="binary" has exactly one member.
    """

    kind: str                      # "binary" | "ovr" | "path"
    models: Tuple[ModelArtifact, ...]
    provenance: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in ("binary", "ovr", "path"):
            raise ValueError(f"unknown family kind {self.kind!r}")
        if not self.models:
            raise ValueError("empty model family")
        if self.kind == "binary" and len(self.models) != 1:
            raise ValueError("kind='binary' must hold exactly one model")
        m0 = self.models[0]
        for m in self.models:
            if (m.n_features, m.loss_name) != (m0.n_features, m0.loss_name):
                raise ValueError(
                    "family members must share (n_features, loss); got "
                    f"({m.n_features}, {m.loss_name!r}) vs "
                    f"({m0.n_features}, {m0.loss_name!r})")
        if self.kind == "ovr":
            labels = [m.label for m in self.models]
            if any(lb is None for lb in labels):
                raise ValueError("every ovr member needs its class label")
            try:
                ordered = all(a < b for a, b in zip(labels, labels[1:]))
            except TypeError:
                raise ValueError(
                    f"ovr class labels must be mutually orderable, got "
                    f"{labels!r}")
            if not ordered:
                # serving maps file-side class codes to model order by
                # SORTED vocabulary position (launch.predict), so model
                # order must be the sorted label order, no duplicates
                raise ValueError(
                    f"ovr members must be in strictly ascending label "
                    f"order, got {labels!r} (fit_ovr canonicalizes this)")
        object.__setattr__(self, "models", tuple(self.models))

    def __len__(self) -> int:
        return len(self.models)

    def __iter__(self):
        return iter(self.models)

    @property
    def model(self) -> ModelArtifact:
        """The single member of a binary family (errors otherwise)."""
        if len(self.models) != 1:
            raise ValueError(f"family has {len(self.models)} models; "
                             f"pick one explicitly")
        return self.models[0]

    @property
    def n_features(self) -> int:
        return self.models[0].n_features

    @property
    def loss_name(self) -> str:
        return self.models[0].loss_name

    @property
    def classes(self) -> Optional[np.ndarray]:
        """Label vocabulary in model order (ovr families only)."""
        if self.kind != "ovr":
            return None
        return np.asarray([m.label for m in self.models])

    @property
    def cs(self) -> np.ndarray:
        return np.asarray([m.c for m in self.models], np.float64)

    def dense_weights(self, dtype=np.float32) -> np.ndarray:
        """(K, n) densified stack — debug / reference scoring only."""
        return np.stack([m.dense_weights(dtype) for m in self.models])


def solver_provenance(solver: str = "pcdn", dataset: Optional[str] = None,
                      **cfg_fields) -> dict:
    """Standard provenance block: who fitted this and with what knobs."""
    prov = {"solver": solver, "created_unix": time.time(),
            "repro": "arxiv:1306.4080 PCDN"}
    if dataset is not None:
        prov["dataset"] = str(dataset)
    prov.update({k: v for k, v in cfg_fields.items() if v is not None})
    return prov


def save_model(path: str, family, extra: Optional[dict] = None) -> dict:
    """Write a ModelFamily (or a lone ModelArtifact) as one JSON file.

    `extra` merges additional top-level keys into the payload — this is
    how `launch.solve --out` keeps its history / timing fields next to
    the artifact ones. Reserved artifact keys cannot be overridden.
    Returns the payload written.

    The write is atomic (tmp file + fsync + rename — `fault.atomic`):
    a hot-swap watcher polling this path can never observe a torn,
    half-written artifact, and a crash mid-save leaves any previous
    artifact intact.
    """
    if isinstance(family, ModelArtifact):
        family = ModelFamily(kind="binary", models=(family,))
    payload = {}
    if extra:
        payload.update(extra)
    reserved = {"schema", "kind", "loss", "n_features", "models"}
    clash = reserved & set(extra or ())
    if clash:
        raise ValueError(f"extra keys {sorted(clash)} collide with the "
                         f"artifact schema")
    payload.update({
        "schema": SCHEMA,
        "kind": family.kind,
        "loss": family.loss_name,
        "n_features": family.n_features,
        "provenance": {**family.provenance, **payload.get("provenance", {})},
        "models": [m._to_json() for m in family.models],
    })
    if family.kind == "ovr":
        payload["classes"] = [m.label for m in family.models]
    atomic_write_json(path, payload, indent=1, default=float)
    return payload


def load_model(path: str) -> ModelFamily:
    """Load a model family; validates the schema tag and weight records."""
    with open(path) as fh:
        obj = json.load(fh)
    return family_from_payload(obj, source=path)


def family_from_payload(obj: dict, source: str = "<payload>") -> ModelFamily:
    schema = obj.get("schema")
    if schema != SCHEMA:
        hint = ""
        if schema is None and "w_indices" in obj:
            hint = (" (looks like a pre-artifact --out report: it still "
                    "works as --warm-start input, but re-run the solve "
                    "with the current launch.solve to get a servable "
                    "model)")
        raise ValueError(f"{source}: not a {SCHEMA} artifact "
                         f"(schema={schema!r}){hint}")
    n = int(obj["n_features"])
    loss = obj["loss"]
    models = []
    for m in obj["models"]:
        models.append(ModelArtifact(
            n_features=n,
            w_indices=np.asarray(m["w_indices"], np.int64),
            w_values=np.asarray(m["w_values"], np.float64),
            loss_name=loss, c=float(m["c"]),
            bias=float(m.get("bias", 0.0)),
            label=m.get("label"), meta=m.get("meta", {})))
    return ModelFamily(kind=obj["kind"], models=tuple(models),
                       provenance=obj.get("provenance", {}))


def pick_best_c(family: ModelFamily, metric: str = "val_accuracy",
                ) -> Tuple[int, ModelArtifact]:
    """Best grid point of a kind="path" family -> (index, artifact).

    Mirrors `path.driver.pick_best` on the SERVED artifact (so hot-swap
    and `launch.predict --best-c` select exactly what the path CLI would
    have): maximize `metric` from each member's fit meta, break ties by
    fewer nonzeros, then by the EARLIER grid point (smaller c — the
    stronger regularizer). metric="nnz" inverts to "sparsest member"
    (min nnz, ties -> earlier). Raises if no member records the metric —
    a family without validation scores has nothing to select on.
    """
    if family.kind != "path":
        raise ValueError(f"pick_best_c selects over a path family, got "
                         f"kind={family.kind!r}")
    if metric == "nnz":
        scored = [(-(m.nnz), -i) for i, m in enumerate(family.models)]
    else:
        scored = []
        for i, m in enumerate(family.models):
            v = m.meta.get(metric)
            if v is None:
                continue
            scored.append((float(v), -m.nnz, -i))
        if not scored:
            raise ValueError(
                f"no member of the family records meta[{metric!r}] — "
                f"fit the path with a validation split (launch.path "
                f"--val-frac) to enable best-c selection")
    best = max(scored)
    i = -best[-1]
    return i, family.models[i]


def path_family(weights: np.ndarray, cs: Sequence[float], loss_name: str,
                metas: Optional[Sequence[dict]] = None,
                provenance: Optional[dict] = None) -> ModelFamily:
    """Family from a path sweep's (K, n) weight stack + its c-grid."""
    weights = np.asarray(weights)
    if weights.shape[0] != len(cs):
        raise ValueError(f"{weights.shape[0]} weight rows vs {len(cs)} cs")
    models = tuple(
        artifact_from_solution(weights[i], loss_name, float(cs[i]),
                               meta=(metas[i] if metas else None))
        for i in range(len(cs)))
    return ModelFamily(kind="path", models=models,
                       provenance=provenance or {})
