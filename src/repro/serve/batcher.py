"""Microbatching front-end: bucket-padded request batches (DESIGN.md 10.4).

XLA compiles one program per input SHAPE, so serving raw variable-sized
request batches would recompile constantly. The batcher quantizes every
batch to a small fixed set of bucket sizes: a pending chunk of r requests
is padded with empty rows up to the smallest bucket >= r, so after one
warmup call per bucket, steady-state traffic NEVER recompiles — the
recompile policy of DESIGN.md section 10.4. The bucket geometry and
chunk packing live in `serve.policy.BucketPolicy`, shared with the
continuous-batching `serve.loop.ServeLoop` (DESIGN.md section 14) so
both fronts pad identically; this class remains the synchronous
one-batch-at-a-time front-end (and the per-request baseline arm of
benchmarks/bench_serve2.py).

`route` picks the dense-layout scorer ("sparse" union-gather, "dense"
densified matmul, or "auto" from the measured crossover table of
BENCH_serve.json — see serve.predict.pick_route).

Two request layouts:

  * "dense":      requests are (B, n) float rows; padding appends zero
                  rows (their margins are computed and discarded).
  * "padded_csc": requests arrive as a CSRMatrix (row-major sparse); each
                  bucket chunk is packed into the feature-major padded-CSC
                  layout with a FIXED column width `k_max` — shape
                  stability demands a fixed width, so `k_max` is a
                  construction-time cap. A chunk whose column nnz
                  overflows it raises loudly (truncation would silently
                  change margins); derive the cap from the full request
                  set (`CSRMatrix.max_col_nnz`) when you have it.

Per bucket the batcher accounts calls, rows, padding overhead, warmup
(first-call, compile-inclusive) latency and steady-state latency, so
`stats()` exposes exactly the throughput/recompile story
benchmarks/bench_serve.py reports.

Observability (DESIGN.md section 13): each bucket additionally keeps a
fixed-bucket latency histogram of its steady-state calls, so `stats()`
reports p50/p99 per bucket — always on, since a histogram observe is
one bisect. When the global metrics registry is enabled the same
events are mirrored there (serve.rows / serve.pad_rows /
serve.compiles counters, serve.latency_s histograms) and every engine
invocation emits a span on the "serve" trace track.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import numpy as np

from repro import obs
from repro.serve.policy import BucketPolicy, default_buckets  # noqa: F401
from repro.serve.predict import (ModelBank, margins_dense,
                                 margins_padded_csc)


@dataclasses.dataclass
class BucketStats:
    bucket: int
    calls: int = 0                 # total engine invocations at this shape
    rows: int = 0                  # real (unpadded) requests served
    pad_rows: int = 0              # padding rows computed and discarded
    warmup_rows: int = 0           # real rows of the first (compile) call
    warmup_seconds: float = 0.0    # first call (includes XLA compile)
    busy_seconds: float = 0.0      # steady-state time after warmup
    # steady-state per-call latency distribution (warmup excluded — the
    # compile call would dominate every quantile)
    latency: obs.Histogram = dataclasses.field(
        default_factory=lambda: obs.Histogram(obs.LATENCY_BOUNDS_S))

    @property
    def warm_calls(self) -> int:
        return max(self.calls - 1, 0)

    @property
    def rows_per_s(self) -> Optional[float]:
        """Steady-state REQUEST throughput: real rows only — padding is
        engine work, not served traffic (pad_rows reports it separately).
        None until a bucket has warm calls."""
        if self.warm_calls == 0 or self.busy_seconds <= 0:
            return None
        return (self.rows - self.warmup_rows) / self.busy_seconds

    def as_dict(self) -> dict:
        return {"bucket": self.bucket, "calls": self.calls,
                "rows": self.rows, "pad_rows": self.pad_rows,
                "warmup_rows": self.warmup_rows,
                "warmup_seconds": self.warmup_seconds,
                "busy_seconds": self.busy_seconds,
                "rows_per_s": self.rows_per_s,
                "latency_p50_s": self.latency.quantile(0.5),
                "latency_p99_s": self.latency.quantile(0.99)}


class MicroBatcher:
    """Pads request batches to bucket shapes and scores them on a bank."""

    def __init__(self, bank: ModelBank, buckets: Sequence[int] = None,
                 layout: str = "dense", use_kernels: bool = False,
                 k_max: Optional[int] = None, max_batch: int = 64,
                 route: str = "sparse"):
        self.policy = BucketPolicy(
            buckets=tuple(buckets or default_buckets(max_batch)),
            layout=layout, k_max=k_max)
        self.bank = bank
        self.use_kernels = use_kernels
        self.route = route
        self._stats = {b: BucketStats(bucket=b) for b in self.buckets}

    # -- bucket geometry (delegated to the shared BucketPolicy) --------------
    @property
    def layout(self) -> str:
        return self.policy.layout

    @property
    def k_max(self) -> Optional[int]:
        return self.policy.k_max

    @property
    def buckets(self) -> tuple:
        return self.policy.buckets

    @property
    def max_bucket(self) -> int:
        return self.policy.max_bucket

    def bucket_for(self, r: int) -> int:
        """Smallest bucket >= r (r must not exceed the largest bucket)."""
        return self.policy.bucket_for(r)

    # -- request plumbing ----------------------------------------------------
    def predict(self, requests) -> np.ndarray:
        """Score any number of requests -> (B, K) margins.

        dense layout: (B, n) array rows. padded_csc layout: a CSRMatrix
        (row-major sparse requests). Oversized inputs are split into
        max-bucket chunks; the ragged tail is padded up to its bucket.
        """
        n_req = (requests.shape[0] if hasattr(requests, "shape")
                 else len(requests))
        out = []
        start = 0
        while start < n_req:
            stop = min(start + self.max_bucket, n_req)
            out.append(self._run_chunk(requests, start, stop))
            start = stop
        return np.concatenate(out, axis=0) if out else \
            np.zeros((0, self.bank.n_models), np.float32)

    def _run_chunk(self, requests, start: int, stop: int) -> np.ndarray:
        r = stop - start
        bucket = self.bucket_for(r)
        if self.layout == "dense":
            X = np.asarray(requests[start:stop], np.float32)
            if X.shape[1] != self.bank.n_features:
                raise ValueError(f"requests have {X.shape[1]} features, "
                                 f"bank has {self.bank.n_features}")
            X = self.policy.pad_dense(X, bucket)
            run = lambda: margins_dense(self.bank, X,
                                        use_kernels=self.use_kernels,
                                        route=self.route)
        else:
            packed = self.policy.pack_csc(requests, start, stop, bucket,
                                          self.bank.n_features)
            run = lambda: margins_padded_csc(self.bank, packed,
                                             use_kernels=self.use_kernels)
        st = self._stats[bucket]
        t0_ns = time.perf_counter_ns()
        t0 = time.perf_counter()
        z = run()
        z = np.asarray(z)              # blocks until the device is done
        dt = time.perf_counter() - t0
        warm = st.calls > 0
        if warm:
            st.busy_seconds += dt
            st.latency.observe(dt)
        else:
            st.warmup_seconds += dt
            st.warmup_rows = r
        st.calls += 1
        st.rows += r
        st.pad_rows += bucket - r
        if obs.metrics_enabled():
            obs.inc("serve.calls")
            obs.inc("serve.rows", r)
            obs.inc("serve.pad_rows", bucket - r)
            if warm:
                obs.observe(f"serve.latency_s.bucket_{bucket}", dt)
                obs.observe("serve.latency_s", dt)
            else:
                obs.inc("serve.compiles")
                obs.observe("serve.warmup_s", dt)
        obs.complete("serve.chunk", "serve", t0_ns, time.perf_counter_ns(),
                     args={"bucket": bucket, "rows": r,
                           "pad_rows": bucket - r, "warmup": not warm})
        return z[:r]

    # -- accounting ----------------------------------------------------------
    def stats(self) -> dict:
        per_bucket = [self._stats[b].as_dict() for b in self.buckets
                      if self._stats[b].calls]
        rows = sum(s["rows"] for s in per_bucket)
        busy = sum(s["busy_seconds"] for s in per_bucket)
        # real served requests only — padding is engine overhead, not
        # traffic (each bucket's pad_rows reports it)
        warm_rows = sum(s["rows"] - s["warmup_rows"] for s in per_bucket)
        # batcher-wide steady-state latency: merge the per-bucket
        # histograms (same fixed bounds, so counts add exactly)
        agg = obs.Histogram(obs.LATENCY_BOUNDS_S)
        for b in self.buckets:
            h = self._stats[b].latency
            if h.count:
                agg.counts = [a + c for a, c in zip(agg.counts, h.counts)]
                agg.count += h.count
                agg.total += h.total
                agg.vmin = min(agg.vmin, h.vmin)
                agg.vmax = max(agg.vmax, h.vmax)
        return {
            "layout": self.layout,
            "use_kernels": self.use_kernels,
            "route": self.route,
            "buckets": per_bucket,
            "total_rows": rows,
            "compiles": len(per_bucket),   # one warmup per bucket shape
            "steady_rows_per_s": (warm_rows / busy) if busy > 0 else None,
            "latency_p50_s": agg.quantile(0.5),
            "latency_p99_s": agg.quantile(0.99),
        }
