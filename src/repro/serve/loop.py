"""Continuous-batching serving loop (DESIGN.md section 14).

`ServeLoop` turns the synchronous microbatcher into a server: requests
are admitted into a per-model queue and a single scheduler thread pops
bucket-shaped chunks continuously — Orca-style iteration-level
scheduling over the ModelBank scorers instead of one padded batch per
caller round-trip.

Flush policy (DESIGN.md 14.3): a model's queue is flushed when

  * it holds a full max-size bucket ("full"), or
  * waiting any longer would blow the OLDEST request's latency budget
    ("deadline"): with est(b) the per-bucket EWMA compute estimate
    (`serve.policy.LatencyModel`), the latest safe flush instant is

        flush_at = oldest.deadline - (est(bucket) * safety_factor
                                      + safety_s)

    so a lull never strands a request, and under load buckets fill
    before their deadline and amortize padding.

Multi-model routing: the loop serves a BANK of named models, each in
its own `_ModelSlot` (own queue, own capacity-padded ModelBank, own
latency model); `submit(x, model=...)` routes by name. Slots are
heterogeneous — different n_features, kinds, K — because each slot's
scorer programs are keyed on its own shapes.

Zero-downtime hot-swap (DESIGN.md 14.5): every slot's bank is built at
FIXED capacity widths (`a_cap`/`u_cap`, see serve.predict.ModelBank),
so an incoming model — e.g. the best-c member of a freshly solved path
artifact (`serve.artifact.pick_best_c`) — is padded to the SAME shapes
and installed through the jitted `_install` program, whose old-bank
arguments are DONATED: XLA may write the new weights into the slot's
existing device allocation, so steady state never reallocates and every
scorer call after the swap is a jit cache hit (zero recompiles; the
regression tests pin `scorer_cache_sizes()` flat across swaps).
Installs are applied BY THE SCHEDULER THREAD between flushes: a batch
snapshots (bank, version) when popped and all compute happens on that
same thread, so in-flight batches finish on the old weights and no
response can see a torn read by construction. Corollary: after a swap
the previous bank's buffers are donated away — hold the margins you
need, not the old `ModelBank`.

Warm start: construction precompiles every bucket shape for every slot
(and the install program) before the first request is admitted, so
steady-state traffic NEVER compiles. Dense-layout routes are resolved
per (slot, bucket) at warmup — `route="auto"` consults the measured
crossover table (serve.predict.pick_route) — and stay pinned across
swaps, so a swap cannot flip a route onto a cold program.

Observability (DESIGN.md 13): `serve.queue_depth` gauge, a
`serve.e2e_latency_s` admission-to-response histogram DISTINCT from the
per-bucket compute histograms, flush/install counters and "serve" track
spans (scheduler thread only, so span nesting stays valid).
"""
from __future__ import annotations

import dataclasses
import functools
import threading
import time
from collections import deque
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.serve.artifact import ModelFamily, pick_best_c
from repro.serve.policy import BucketPolicy, LatencyModel, default_buckets
from repro.serve.predict import (ModelBank, margins_dense, pick_route,
                                 scorer_cache_sizes)


class ServeOverload(RuntimeError):
    """Admission control refused the request: the queue is full."""


class SlotQuarantined(RuntimeError):
    """The model slot was quarantined after repeated batch failures;
    submits are refused until a hot-swap installs a fresh model."""


class SwapCapacityError(ValueError):
    """The incoming model does not fit the slot's fixed capacity shapes."""


def _overwrite(dst, src):
    # elementwise blend rather than a bare pass-through of `src`, so each
    # output is a fresh computation XLA may place in dst's donated
    # allocation (a pass-through would alias src and leave dst unused)
    return jnp.where(jnp.ones(dst.shape, jnp.bool_), src, dst)


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4))
def _install(dst_idx, dst_val, dst_uidx, dst_uval, dst_bias,
             src_idx, src_val, src_uidx, src_uval, src_bias):
    """Overwrite a slot's live bank arrays with an incoming model's.

    The dst arrays (the slot's current bank) are donated: the swap may
    reuse the slot's existing device allocation instead of growing the
    footprint. Capacity padding guarantees src and dst shapes match, so
    this program compiles ONCE per slot geometry (warmed at startup).
    """
    return (_overwrite(dst_idx, src_idx), _overwrite(dst_val, src_val),
            _overwrite(dst_uidx, src_uidx), _overwrite(dst_uval, src_uval),
            _overwrite(dst_bias, src_bias))


@dataclasses.dataclass(frozen=True)
class ServeResult:
    """One scored request: its margins row plus full provenance."""

    id: int
    model: str
    margins: np.ndarray            # (K,) this slot's per-model margins
    version: int                   # bank version live at the batch's flush
    bucket: int
    flush_reason: str              # "full" | "deadline" | "drain"
    t_submit: float                # perf_counter seconds
    t_done: float

    @property
    def latency_s(self) -> float:
        """Admission-to-response latency (queue wait + compute)."""
        return self.t_done - self.t_submit


class ServeFuture:
    """Handle returned by submit(); result() blocks for the ServeResult."""

    def __init__(self):
        self._event = threading.Event()
        self._result: Optional[ServeResult] = None
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None) -> ServeResult:
        if not self._event.wait(timeout):
            raise TimeoutError(f"no response within {timeout}s")
        if self._error is not None:
            raise self._error
        return self._result

    def _set(self, result: ServeResult) -> None:
        self._result = result
        self._event.set()

    def _set_error(self, exc: BaseException) -> None:
        self._error = exc
        self._event.set()


@dataclasses.dataclass
class _Pending:
    id: int
    x: np.ndarray
    t_submit: float
    deadline: float
    future: ServeFuture


@dataclasses.dataclass
class _SwapTicket:
    """swap() receipt: wait on `installed`, then read `version`."""

    model: str
    installed: threading.Event
    version: Optional[int] = None


class _ModelSlot:
    """One served model: queue + capacity bank + pinned routes + stats."""

    def __init__(self, name: str, bank: ModelBank):
        self.name = name
        self.bank = bank
        self.version = 1
        self.installs = 0
        self.latency = LatencyModel()
        self.routes: Dict[int, str] = {}       # bucket -> "sparse"|"dense"
        self.pending: deque = deque()
        self.rows = 0
        self.pad_rows = 0
        self.flushes = {"full": 0, "deadline": 0, "drain": 0}
        self.slo_violations = 0
        # batch-failure resilience (DESIGN.md section 16.6)
        self.retries = 0               # in-place batch retries that ran
        self.failed_batches = 0        # batches failed after the retry
        self.consecutive_failures = 0  # reset on success and on install
        self.quarantined = False
        self.e2e = obs.Histogram(obs.LATENCY_BOUNDS_S)
        self.compute: Dict[int, obs.Histogram] = {}

    def stats(self) -> dict:
        return {
            "version": self.version, "installs": self.installs,
            "rows": self.rows, "pad_rows": self.pad_rows,
            "queue_depth": len(self.pending),
            "flushes": dict(self.flushes),
            "slo_violations": self.slo_violations,
            "retries": self.retries,
            "failed_batches": self.failed_batches,
            "consecutive_failures": self.consecutive_failures,
            "quarantined": self.quarantined,
            "routes": {str(b): r for b, r in sorted(self.routes.items())},
            "e2e_p50_s": self.e2e.quantile(0.5),
            "e2e_p99_s": self.e2e.quantile(0.99),
            "compute_latency_s": {
                str(b): {"p50": h.quantile(0.5), "p99": h.quantile(0.99),
                         "calls": h.count}
                for b, h in sorted(self.compute.items())},
            "latency_model_s": self.latency.as_dict(),
        }


def _bank_capacity(family: ModelFamily, factor: float) -> tuple:
    """(a_cap, u_cap) for a family with `factor` growth headroom."""
    a_need = max(1, max(m.nnz for m in family.models))
    union = np.unique(np.concatenate(
        [m.w_indices for m in family.models] or [np.zeros(0, np.int64)]))
    u_need = max(1, int(union.shape[0]))
    return (int(np.ceil(factor * a_need)), int(np.ceil(factor * u_need)))


class ServeLoop:
    """Deadline-aware continuous-batching server over named ModelBanks.

    `models`: a ModelBank / ModelFamily (served as "default") or a dict
    name -> bank-or-family. Families are built into capacity-padded
    banks with `capacity_factor` headroom so later hot-swaps fit;
    prebuilt banks are served at their existing shapes (swaps must fit
    them exactly). Construction warms every (slot, bucket) scorer
    program and the install program, then starts the scheduler thread —
    the loop is serving when __init__ returns. Use as a context manager
    or call stop() (which drains the queue) when done.
    """

    def __init__(self, models, *, buckets=None, max_batch: int = 64,
                 default_budget_s: float = 0.05,
                 safety_factor: float = 1.2, safety_s: float = 1e-3,
                 max_queue: Optional[int] = None, route: str = "sparse",
                 use_kernels: bool = False, capacity_factor: float = 2.0,
                 dtype=np.float32, batch_retries: int = 1,
                 quarantine_after: Optional[int] = 3):
        """batch_retries: bounded in-place retries of a failed batch
        compute before its futures are failed (a transient device error
        should not surface to callers). quarantine_after: after this
        many CONSECUTIVE failed batches the slot is quarantined —
        further submits raise `SlotQuarantined` instead of feeding a
        model that cannot score; a hot-swap install clears it. None
        disables quarantine."""
        if route not in ("sparse", "dense", "auto"):
            raise ValueError(f"unknown route {route!r}")
        if batch_retries < 0:
            raise ValueError(f"batch_retries must be >= 0, "
                             f"got {batch_retries}")
        if quarantine_after is not None and quarantine_after < 1:
            raise ValueError(f"quarantine_after must be >= 1 or None, "
                             f"got {quarantine_after}")
        self.batch_retries = int(batch_retries)
        self.quarantine_after = (None if quarantine_after is None
                                 else int(quarantine_after))
        self.policy = BucketPolicy(
            buckets=tuple(buckets or default_buckets(max_batch)),
            layout="dense")
        self.default_budget_s = float(default_budget_s)
        self.safety_factor = float(safety_factor)
        self.safety_s = float(safety_s)
        self.max_queue = None if max_queue is None else int(max_queue)
        self.route = route
        self.use_kernels = bool(use_kernels)

        if not isinstance(models, dict):
            models = {"default": models}
        if not models:
            raise ValueError("ServeLoop needs at least one model")
        self._slots: Dict[str, _ModelSlot] = {}
        for name, m in models.items():
            if isinstance(m, ModelFamily):
                a_cap, u_cap = _bank_capacity(m, capacity_factor)
                bank = ModelBank.from_family(m, dtype=dtype, a_cap=a_cap,
                                             u_cap=u_cap)
            elif isinstance(m, ModelBank):
                bank = m
            else:
                raise TypeError(f"model {name!r}: expected ModelBank or "
                                f"ModelFamily, got {type(m).__name__}")
            self._slots[name] = _ModelSlot(name, bank)

        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._installs: deque = deque()
        self._stop = False
        self._depth = 0
        self._requests = 0
        self._rejects = 0
        self._responses = 0
        self._errors = 0
        self._next_id = 0
        self._warm_compiles = 0

        self._warmup()
        self._thread = threading.Thread(target=self._scheduler,
                                        name="repro-serve-loop", daemon=True)
        self._thread.start()

    # -- lifecycle -----------------------------------------------------------
    def __enter__(self) -> "ServeLoop":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    def stop(self) -> None:
        """Drain the queue (pending requests flush as "drain") and join
        the scheduler thread. Idempotent; submits after stop raise."""
        with self._work:
            self._stop = True
            self._work.notify_all()
        if self._thread.is_alive():
            self._thread.join()

    # -- warm start ----------------------------------------------------------
    def _warmup(self) -> None:
        """Precompile every (slot, bucket) scorer + the install program
        so steady-state traffic (including across hot-swaps) never
        compiles; seed each slot's latency model with a measured
        post-compile call per bucket."""
        before = sum(scorer_cache_sizes().values())
        t0_ns = time.perf_counter_ns()
        for slot in self._slots.values():
            b0 = slot.bank
            # round-trip the initial bank through the install program
            # (via copies — an array cannot be donated AND read as src):
            # warms the swap path and lands the bank on installed buffers
            dst = tuple(jnp.array(a) for a in
                        (b0.idx, b0.val, b0.union_idx, b0.union_val,
                         b0.bias))
            arrs = _install(*dst, b0.idx, b0.val, b0.union_idx,
                            b0.union_val, b0.bias)
            slot.bank = self._rebind(slot.bank, arrs)
            for bucket in self.policy.buckets:
                r = self.route
                if r == "auto":
                    r = pick_route(slot.bank.sparsity(), bucket)
                slot.routes[bucket] = r
                X = np.zeros((bucket, slot.bank.n_features), np.float32)
                np.asarray(margins_dense(slot.bank, X,
                                         use_kernels=self.use_kernels,
                                         route=r))          # compile call
                t0 = time.perf_counter()
                np.asarray(margins_dense(slot.bank, X,
                                         use_kernels=self.use_kernels,
                                         route=r))          # steady call
                slot.latency.observe(bucket, time.perf_counter() - t0)
        self._warm_compiles = sum(scorer_cache_sizes().values()) - before
        if obs.metrics_enabled():
            obs.inc("serve.compiles", self._warm_compiles)
        obs.complete("serve.warmup", "serve", t0_ns, time.perf_counter_ns(),
                     args={"compiles": self._warm_compiles,
                           "models": len(self._slots),
                           "buckets": list(self.policy.buckets)})

    @staticmethod
    def _rebind(template: ModelBank, arrs) -> ModelBank:
        bank = ModelBank(idx=arrs[0], val=arrs[1], union_idx=arrs[2],
                         union_val=arrs[3], bias=arrs[4],
                         n_features=template.n_features, kind=template.kind,
                         loss_name=template.loss_name,
                         classes=template.classes)
        W = getattr(template, "_dense_w_cache", None)
        if W is not None:
            object.__setattr__(bank, "_dense_w_cache", W)
        return bank

    # -- request plane -------------------------------------------------------
    def _resolve(self, model: Optional[str]) -> str:
        if model is None:
            if len(self._slots) == 1:
                return next(iter(self._slots))
            raise ValueError(f"loop serves {sorted(self._slots)}; "
                             f"pick one with model=...")
        if model not in self._slots:
            raise KeyError(f"unknown model {model!r} "
                           f"(serving {sorted(self._slots)})")
        return model

    def submit(self, x, model: Optional[str] = None,
               budget_s: Optional[float] = None) -> ServeFuture:
        """Admit one request row; returns a future for its ServeResult.

        Raises ServeOverload when `max_queue` requests are already
        pending (open-loop admission control — the caller sheds load).
        """
        name = self._resolve(model)
        slot = self._slots[name]
        x = np.asarray(x, np.float32).reshape(-1)
        if x.shape[0] != slot.bank.n_features:
            raise ValueError(f"request has {x.shape[0]} features, model "
                             f"{name!r} has {slot.bank.n_features}")
        budget = self.default_budget_s if budget_s is None else float(budget_s)
        fut = ServeFuture()
        now = time.perf_counter()
        with self._work:
            if self._stop:
                raise RuntimeError("ServeLoop is stopped")
            if slot.quarantined:
                raise SlotQuarantined(
                    f"model {name!r} is quarantined after "
                    f"{slot.consecutive_failures} consecutive batch "
                    f"failures; hot-swap a fresh model (swap()) to "
                    f"restore it")
            if self.max_queue is not None and self._depth >= self.max_queue:
                self._rejects += 1
                if obs.metrics_enabled():
                    obs.inc("serve.loop.rejects")
                raise ServeOverload(
                    f"queue full ({self._depth}/{self.max_queue})")
            self._next_id += 1
            slot.pending.append(_Pending(self._next_id, x, now,
                                         now + budget, fut))
            self._depth += 1
            self._requests += 1
            if obs.metrics_enabled():
                obs.inc("serve.loop.requests")
                obs.set_gauge("serve.queue_depth", self._depth)
            self._work.notify()
        return fut

    def submit_many(self, X, model: Optional[str] = None,
                    budget_s: Optional[float] = None) -> list:
        return [self.submit(x, model=model, budget_s=budget_s) for x in X]

    # -- model plane ---------------------------------------------------------
    def models(self) -> tuple:
        return tuple(sorted(self._slots))

    def bank(self, model: Optional[str] = None) -> ModelBank:
        return self._slots[self._resolve(model)].bank

    def version(self, model: Optional[str] = None) -> int:
        with self._lock:
            return self._slots[self._resolve(model)].version

    def swap(self, model_or_name=None, model=None,
             metric: str = "val_accuracy") -> _SwapTicket:
        """Queue a zero-downtime model install; returns a _SwapTicket.

        `model` is a ModelFamily (a kind="path" family is reduced to its
        best-c member via pick_best_c(metric=...) first — swap straight
        from a fresh path solve) or a prebuilt ModelBank at the slot's
        exact shapes. The install is applied by the scheduler thread
        between flushes: batches popped before it score on the old
        weights, batches popped after score on the new ones, and
        `ServeResult.version` records which. Wait on ticket.installed
        to synchronize. Raises SwapCapacityError when the incoming
        model does not fit the slot's capacity shapes.
        """
        if model is None:           # single-model convenience: swap(family)
            model, model_or_name = model_or_name, None
        name = self._resolve(model_or_name)
        slot = self._slots[name]
        if isinstance(model, ModelFamily):
            if model.kind == "path":
                _, best = pick_best_c(model, metric=metric)
                model = ModelFamily(kind="binary", models=(best,),
                                    provenance=model.provenance)
            try:
                new_bank = ModelBank.from_family(
                    model, dtype=np.asarray(slot.bank.val).dtype,
                    a_cap=slot.bank.a_max,
                    u_cap=int(slot.bank.union_idx.shape[0]))
            except ValueError as e:
                raise SwapCapacityError(str(e)) from None
        elif isinstance(model, ModelBank):
            new_bank = model
        else:
            raise TypeError(f"swap expects ModelFamily or ModelBank, got "
                            f"{type(model).__name__}")
        old = slot.bank
        same = (new_bank.n_models == old.n_models
                and new_bank.n_features == old.n_features
                and new_bank.idx.shape == old.idx.shape
                and new_bank.union_idx.shape == old.union_idx.shape
                and new_bank.val.dtype == old.val.dtype)
        if not same:
            raise SwapCapacityError(
                f"incoming bank shapes (K={new_bank.n_models}, "
                f"n={new_bank.n_features}, idx={tuple(new_bank.idx.shape)}, "
                f"union={tuple(new_bank.union_idx.shape)}, "
                f"{new_bank.val.dtype}) do not match slot {name!r} "
                f"(K={old.n_models}, n={old.n_features}, "
                f"idx={tuple(old.idx.shape)}, "
                f"union={tuple(old.union_idx.shape)}, {old.val.dtype})")
        if "dense" in slot.routes.values():
            new_bank.dense_matrix()     # prebuild off the scheduler thread
        ticket = _SwapTicket(model=name, installed=threading.Event())
        with self._work:
            if self._stop:
                raise RuntimeError("ServeLoop is stopped")
            self._installs.append((name, new_bank, ticket))
            self._work.notify()
        return ticket

    # -- scheduler thread ----------------------------------------------------
    def _scheduler(self) -> None:
        while True:
            chunk = None
            with self._work:
                while True:
                    self._apply_installs_locked()
                    now = time.perf_counter()
                    choice, wait_s = self._next_action_locked(now)
                    if choice is not None:
                        chunk = self._pop_locked(*choice)
                        break
                    if self._stop:
                        self._apply_installs_locked()
                        return
                    self._work.wait(wait_s)
            self._score(*chunk)

    def _next_action_locked(self, now: float):
        """(slot, take, reason) ready to flush, or (None, wait_seconds)."""
        ready = None
        ready_at = None
        soonest = None
        maxb = self.policy.max_bucket
        for slot in self._slots.values():
            r = len(slot.pending)
            if r == 0:
                continue
            if self._stop:
                return (slot, min(r, maxb), "drain"), None
            if r >= maxb:
                at, take, reason = now, maxb, "full"
            else:
                bucket = self.policy.bucket_for(r)
                est = slot.latency.estimate(bucket) * self.safety_factor \
                    + self.safety_s
                at, take, reason = slot.pending[0].deadline - est, r, \
                    "deadline"
            if at <= now:
                if ready is None or at < ready_at:
                    ready, ready_at = (slot, take, reason), at
            elif soonest is None or at < soonest:
                soonest = at
        if ready is not None:
            return ready, None
        return None, (None if soonest is None else max(soonest - now, 0.0))

    def _pop_locked(self, slot: _ModelSlot, take: int, reason: str):
        reqs = [slot.pending.popleft() for _ in range(take)]
        self._depth -= take
        if obs.metrics_enabled():
            obs.set_gauge("serve.queue_depth", self._depth)
        # the (bank, version) snapshot: installs also run on the
        # scheduler thread, so this batch's compute happens-before any
        # later install — old weights, never torn ones
        return slot, reqs, reason, slot.bank, slot.version

    def _score(self, slot: _ModelSlot, reqs, reason: str, bank: ModelBank,
               version: int) -> None:
        bucket = self.policy.bucket_for(len(reqs))
        t0_ns = time.perf_counter_ns()
        t0 = time.perf_counter()
        z = err = None
        for attempt in range(1 + self.batch_retries):
            try:
                X = self.policy.pad_dense(np.stack([p.x for p in reqs]),
                                          bucket)
                z = np.asarray(margins_dense(bank, X,
                                             use_kernels=self.use_kernels,
                                             route=slot.routes[bucket]))
                err = None
                break
            except Exception as e:      # bounded in-place retry first
                err = e
                if attempt < self.batch_retries:
                    with self._lock:
                        slot.retries += 1
                    if obs.metrics_enabled():
                        obs.inc("serve.batch_retries")
        if err is not None:                     # serve on: fail the batch
            with self._lock:
                self._errors += len(reqs)
                slot.failed_batches += 1
                slot.consecutive_failures += 1
                if (self.quarantine_after is not None
                        and slot.consecutive_failures
                        >= self.quarantine_after
                        and not slot.quarantined):
                    slot.quarantined = True
                    if obs.metrics_enabled():
                        obs.inc("serve.loop.quarantines")
                    obs.instant("serve.quarantine", "serve",
                                args={"model": slot.name,
                                      "failures":
                                      slot.consecutive_failures})
            if obs.metrics_enabled():
                obs.inc("serve.loop.errors", len(reqs))
                obs.inc("serve.batch_failures")
            for p in reqs:
                p.future._set_error(err)
            return
        with self._lock:
            slot.consecutive_failures = 0
        t_done = time.perf_counter()
        dt = t_done - t0
        with self._lock:
            slot.latency.observe(bucket, dt)
            slot.rows += len(reqs)
            slot.pad_rows += bucket - len(reqs)
            slot.flushes[reason] += 1
            hist = slot.compute.get(bucket)
            if hist is None:
                hist = slot.compute[bucket] = obs.Histogram(
                    obs.LATENCY_BOUNDS_S)
            hist.observe(dt)
            self._responses += len(reqs)
            late = sum(1 for p in reqs if t_done > p.deadline)
            slot.slo_violations += late
            for p in reqs:
                slot.e2e.observe(t_done - p.t_submit)
        if obs.metrics_enabled():
            obs.inc("serve.loop.responses", len(reqs))
            obs.inc("serve.loop.rows", len(reqs))
            obs.inc("serve.loop.pad_rows", bucket - len(reqs))
            obs.inc(f"serve.loop.flush.{reason}")
            if late:
                obs.inc("serve.loop.slo_violations", late)
            obs.observe(f"serve.latency_s.bucket_{bucket}", dt)
            for p in reqs:
                obs.observe("serve.e2e_latency_s", t_done - p.t_submit)
        obs.complete("serve.flush", "serve", t0_ns, time.perf_counter_ns(),
                     args={"model": slot.name, "bucket": bucket,
                           "rows": len(reqs), "pad_rows": bucket - len(reqs),
                           "reason": reason, "version": version})
        for i, p in enumerate(reqs):
            p.future._set(ServeResult(
                id=p.id, model=slot.name, margins=z[i], version=version,
                bucket=bucket, flush_reason=reason, t_submit=p.t_submit,
                t_done=t_done))

    def _apply_installs_locked(self) -> None:
        while self._installs:
            name, new_bank, ticket = self._installs.popleft()
            slot = self._slots[name]
            t0_ns = time.perf_counter_ns()
            old = slot.bank
            arrs = _install(old.idx, old.val, old.union_idx, old.union_val,
                            old.bias, new_bank.idx, new_bank.val,
                            new_bank.union_idx, new_bank.union_val,
                            new_bank.bias)
            slot.bank = self._rebind(new_bank, arrs)
            slot.version += 1
            slot.installs += 1
            # a fresh model clears the failure streak and any quarantine
            slot.consecutive_failures = 0
            slot.quarantined = False
            ticket.version = slot.version
            if obs.metrics_enabled():
                obs.inc("serve.loop.installs")
            obs.complete("serve.install", "serve", t0_ns,
                         time.perf_counter_ns(),
                         args={"model": name, "version": slot.version})
            ticket.installed.set()

    # -- accounting ----------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            return {
                "buckets": list(self.policy.buckets),
                "route": self.route,
                "use_kernels": self.use_kernels,
                "default_budget_s": self.default_budget_s,
                "max_queue": self.max_queue,
                "requests": self._requests,
                "responses": self._responses,
                "rejects": self._rejects,
                "errors": self._errors,
                "queue_depth": self._depth,
                "compiles": self._warm_compiles,
                "scorer_cache_sizes": scorer_cache_sizes(),
                "models": {name: slot.stats()
                           for name, slot in sorted(self._slots.items())},
            }


def drive_poisson(loop: ServeLoop, X, rate_rps: float, n_requests: int,
                  model: Optional[str] = None,
                  budget_s: Optional[float] = None, seed: int = 0,
                  timeout_s: float = 60.0) -> dict:
    """Open-loop Poisson load: submit `n_requests` rows of X (cycled) at
    exponential inter-arrival gaps of mean 1/rate_rps, never waiting for
    responses (overdue arrivals are submitted immediately and the
    generator lag reported — the open-loop property that distinguishes
    offered load from achieved throughput). Returns the results plus
    latency quantiles at the MEASURED offered rate.
    """
    if rate_rps <= 0:
        raise ValueError(f"rate_rps must be > 0, got {rate_rps}")
    X = np.asarray(X, np.float32)
    arrive = np.cumsum(np.random.default_rng(seed).exponential(
        1.0 / rate_rps, size=n_requests))
    futures = []
    rejects = 0
    max_lag = 0.0
    t0 = time.perf_counter()
    for i in range(n_requests):
        target = t0 + arrive[i]
        now = time.perf_counter()
        if now < target:
            time.sleep(target - now)
        else:
            max_lag = max(max_lag, now - target)
        try:
            futures.append(loop.submit(X[i % X.shape[0]], model=model,
                                       budget_s=budget_s))
        except ServeOverload:
            rejects += 1
    t_end = time.perf_counter()
    results = [f.result(timeout=timeout_s) for f in futures]
    lat = np.asarray([r.latency_s for r in results]) if results else \
        np.zeros((0,))
    return {
        "target_rps": float(rate_rps),
        "offered_rps": n_requests / max(t_end - t0, 1e-9),
        "n_requests": n_requests,
        "responses": len(results),
        "rejects": rejects,
        "generator_lag_s": max_lag,
        "p50_s": float(np.percentile(lat, 50)) if lat.size else None,
        "p99_s": float(np.percentile(lat, 99)) if lat.size else None,
        "max_s": float(lat.max()) if lat.size else None,
        "results": results,
    }
