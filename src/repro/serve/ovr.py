"""One-vs-rest multiclass training on the vmapped batch solver.

A K-class l1 problem decomposes into K independent binary subproblems
"class k vs the rest" (Bradley et al., Parallel Coordinate Descent for
L1-Regularized Loss Minimization) — exactly the workload
`path.batch.solve_batch` already executes perfectly: K problems sharing
ONE DesignMatrix (resident once), differing only in their (B, s) label
matrix, advanced in lockstep by a single vmapped XLA program with
per-problem freeze-on-convergence.

`fit_ovr` therefore costs one compile and one design-matrix residency
regardless of K, and its output is precisely the multi-model artifact
family the serving layer consumes (DESIGN.md section 10.2).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Union

import numpy as np

from repro.core.pcdn import PCDNConfig
from repro.core.problem import L1Problem, make_problem
from repro.path.batch import BatchSolveResult, solve_batch
from repro.serve import artifact as art


def encode_labels(y) -> tuple[np.ndarray, np.ndarray]:
    """Raw labels (ints, floats, strings) -> (codes (s,) int32, classes).

    classes is the sorted unique vocabulary; codes index into it. The
    same encoding `data.libsvm.load_libsvm(..., return_classes=True)`
    produces — use that directly for libsvm files.
    """
    y = np.asarray(y)
    classes, codes = np.unique(y, return_inverse=True)
    return codes.astype(np.int32), classes


def ovr_label_matrix(codes, n_classes: Optional[int] = None,
                     dtype=np.float32) -> np.ndarray:
    """(K, s) +-1 label matrix: row k is +1 where codes == k, else -1."""
    codes = np.asarray(codes, np.int64)
    if codes.size == 0:
        raise ValueError("no labels")
    k = int(n_classes) if n_classes is not None else int(codes.max()) + 1
    if codes.min() < 0 or codes.max() >= k:
        raise ValueError(f"codes outside [0, {k})")
    return np.where(codes[None, :] == np.arange(k)[:, None],
                    1.0, -1.0).astype(dtype)


class OVRResult(NamedTuple):
    classes: np.ndarray         # (K,) label vocabulary, model order
    weights: np.ndarray         # (K, n) per-class solutions (host)
    cs: np.ndarray              # (K,) regularization value per class
    batch: BatchSolveResult     # raw per-problem solver diagnostics
    train_accuracy: float       # argmax-margin accuracy on the fit data


def fit_ovr(X, y, c: Union[float, Sequence[float]], cfg: PCDNConfig,
            loss: str = "logistic", classes: Optional[np.ndarray] = None,
            layout: str = "auto", seeds: Optional[Sequence[int]] = None,
            problem: Optional[L1Problem] = None) -> OVRResult:
    """Fit a one-vs-rest head: K binary l1 problems in one vmapped solve.

    y: integer class codes (with `classes` as vocabulary, e.g. from
    `load_libsvm(..., return_classes=True)`) or raw labels (vocabulary
    derived by `encode_labels`). c: shared scalar or one value per class.
    problem: optional prebuilt L1Problem over X (its labels are ignored;
    the design matrix is reused as-is).
    """
    if classes is None:
        codes, classes = encode_labels(y)
    else:
        codes = np.asarray(y, np.int64)
        classes = np.asarray(classes)
        order = np.argsort(classes, kind="stable")
        if not np.array_equal(order, np.arange(order.shape[0])):
            # canonicalize to the sorted vocabulary every other layer
            # assumes (libsvm codes, ModelFamily, launch.predict): remap
            # the caller's codes into sorted-class positions
            classes = classes[order]
            codes = np.argsort(order)[codes]
    K = int(classes.shape[0])
    if K < 2:
        raise ValueError(f"need >= 2 classes, got {K}")
    ys = ovr_label_matrix(codes, K)
    # np.ndim, not np.isscalar: numpy floats (spec fields, res.cs[k]) are
    # 0-d to ndim but NOT np.isscalar-true
    cs = np.full((K,), float(c), np.float64) if np.ndim(c) == 0 \
        else np.asarray(c, np.float64)
    if cs.shape != (K,):
        raise ValueError(f"need one c per class ({K}), got {cs.shape}")

    if problem is None:
        problem = make_problem(X, ys[0], c=float(cs[0]), loss=loss,
                               layout=layout)
    bres = solve_batch(problem, cfg, cs, ys=ys, seeds=seeds)
    weights = np.asarray(bres.w)
    # train accuracy straight off the final margins the carry already holds
    pred = np.argmax(np.asarray(bres.z), axis=0)
    acc = float(np.mean(pred == codes))
    return OVRResult(classes=classes, weights=weights, cs=cs, batch=bres,
                     train_accuracy=acc)


def ovr_margins(weights: np.ndarray, X) -> np.ndarray:
    """(B, K) reference margins X @ W.T (numpy; serving uses serve.predict)."""
    return np.asarray(X) @ np.asarray(weights).T


def ovr_family(res: OVRResult, loss_name: str,
               provenance: Optional[dict] = None) -> "art.ModelFamily":
    """Package an OVR fit as a servable kind="ovr" model family."""
    models = []
    for k in range(res.classes.shape[0]):
        label = res.classes[k]
        label = label.item() if hasattr(label, "item") else label
        models.append(art.artifact_from_solution(
            res.weights[k], loss_name, float(res.cs[k]), label=label,
            meta={"objective": float(res.batch.objective[k]),
                  "kkt": float(res.batch.kkt[k]),
                  "n_outer": int(res.batch.n_outer[k]),
                  "converged": bool(res.batch.converged[k])}))
    return art.ModelFamily(kind="ovr", models=tuple(models),
                           provenance=provenance or {})
