"""Bucket policy: the shape-quantization contract of serving (DESIGN.md
sections 10.4 / 14.2).

XLA compiles one program per input SHAPE, so every serving front-end —
the synchronous `serve.batcher.MicroBatcher` and the continuous-batching
`serve.loop.ServeLoop` — quantizes request batches to a small fixed set
of bucket sizes. This module owns that shared geometry so both fronts
pad identically and a bucket warmed by one is warmed for the process:

  * `BucketPolicy`  — the bucket set, `bucket_for` (smallest bucket that
    fits), and the padding/packing of a ragged chunk up to its bucket
    shape (dense zero rows, or fixed-width padded-CSC with empty rows).
  * `LatencyModel`  — per-bucket EWMA of steady-state compute latency.
    The serving loop's deadline math needs an estimate of "how long will
    this bucket take to score" to decide the latest safe flush instant;
    warmup seeds it, steady-state calls keep it current.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.design_matrix import PaddedCSCDesign, padded_csc_arrays


def default_buckets(max_batch: int) -> tuple:
    """Powers of two up to max_batch, always including max_batch itself."""
    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1, got {max_batch}")
    buckets = []
    b = 1
    while b < max_batch:
        buckets.append(b)
        b *= 2
    buckets.append(max_batch)
    return tuple(buckets)


@dataclasses.dataclass(frozen=True)
class BucketPolicy:
    """Bucket geometry + chunk packing, shared by batcher and loop.

    `layout` picks the engine-side request representation ("dense" or
    "padded_csc"); padded_csc needs the fixed column width `k_max` at
    construction (shape stability is the whole point of bucketing — a
    chunk whose column nnz overflows it raises loudly, truncation would
    silently change margins).
    """

    buckets: tuple
    layout: str = "dense"
    k_max: Optional[int] = None

    def __post_init__(self):
        if self.layout not in ("dense", "padded_csc"):
            raise ValueError(f"unknown request layout {self.layout!r}")
        if self.layout == "padded_csc" and self.k_max is None:
            raise ValueError(
                "layout='padded_csc' needs a fixed column width k_max "
                "(e.g. CSRMatrix.max_col_nnz() of the request stream) — "
                "shape stability is the whole point of bucketing")
        bs = tuple(sorted(set(int(b) for b in self.buckets)))
        if not bs or bs[0] < 1:
            raise ValueError(f"buckets must be >= 1: {self.buckets}")
        object.__setattr__(self, "buckets", bs)
        object.__setattr__(
            self, "k_max",
            None if self.k_max is None else int(self.k_max))

    @property
    def max_bucket(self) -> int:
        return self.buckets[-1]

    def bucket_for(self, r: int) -> int:
        """Smallest bucket >= r (r must not exceed the largest bucket)."""
        for b in self.buckets:
            if b >= r:
                return b
        raise ValueError(f"chunk of {r} exceeds max bucket "
                         f"{self.max_bucket}")

    # -- chunk packing -------------------------------------------------------
    def pad_dense(self, X: np.ndarray, bucket: int) -> np.ndarray:
        """(r, n) float rows -> (bucket, n), zero rows appended (their
        margins are computed and discarded by the caller)."""
        X = np.asarray(X, np.float32)
        r = X.shape[0]
        if bucket < r:
            raise ValueError(f"chunk of {r} rows does not fit bucket "
                             f"{bucket}")
        if bucket == r:
            return X
        return np.concatenate(
            [X, np.zeros((bucket - r, X.shape[1]), np.float32)])

    def pack_csc(self, csr, start: int, stop: int, bucket: int,
                 n_features: int) -> PaddedCSCDesign:
        """Rows [start, stop) of a CSRMatrix -> (bucket, n) padded-CSC.

        Padding rows simply have no nonzeros; the fixed (n, k_max) column
        width keeps the packed shape identical for every chunk of the
        same bucket. Overflowing k_max raises (see class docstring).
        """
        for a in ("data", "indices", "indptr", "shape"):
            if not hasattr(csr, a):
                raise TypeError(
                    f"padded_csc layout serves CSR request streams; got "
                    f"{type(csr).__name__} (dense rows go to "
                    f"layout='dense')")
        n = csr.shape[1]
        if n != n_features:
            raise ValueError(f"requests have {n} features, bank has "
                             f"{n_features}")
        lo, hi = csr.indptr[start], csr.indptr[stop]
        indptr = np.asarray(csr.indptr[start:stop + 1], np.int64) - lo
        indptr = np.concatenate(
            [indptr, np.full((bucket - (stop - start),), indptr[-1],
                             np.int64)])
        col_rows, col_vals, s, _ = padded_csc_arrays(
            csr.data[lo:hi], csr.indices[lo:hi], indptr, (bucket, n),
            k_max=self.k_max)
        return PaddedCSCDesign(col_rows=jnp.asarray(col_rows),
                               col_vals=jnp.asarray(col_vals),
                               _n_samples=s)


class LatencyModel:
    """Per-bucket EWMA estimate of steady-state compute latency.

    The serving loop's deadline-aware flush needs `estimate(bucket)` to
    compute the latest instant a pending chunk can still be flushed and
    meet its oldest request's deadline (DESIGN.md 14.3). Warmup seeds
    each bucket with a measured post-compile call; steady-state calls
    update the EWMA so the estimate tracks machine load. Unseen buckets
    fall back to `default_s` (conservative, so unwarmed servers flush
    early rather than late).
    """

    def __init__(self, default_s: float = 5e-3, alpha: float = 0.3):
        if not (0.0 < alpha <= 1.0):
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        self.default_s = float(default_s)
        self.alpha = float(alpha)
        self._est: Dict[int, float] = {}

    def observe(self, bucket: int, seconds: float) -> None:
        old = self._est.get(bucket)
        if old is None:
            self._est[bucket] = float(seconds)
        else:
            self._est[bucket] = (1.0 - self.alpha) * old \
                + self.alpha * float(seconds)

    def estimate(self, bucket: int) -> float:
        return self._est.get(bucket, self.default_s)

    def seeded(self, bucket: int) -> bool:
        return bucket in self._est

    def as_dict(self) -> dict:
        return {str(b): e for b, e in sorted(self._est.items())}
