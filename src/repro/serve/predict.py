"""Batched-margin prediction engine over sparse models (DESIGN.md 10.3).

Serving state is a `ModelBank`: the K models of an artifact family (an
OVR head, a path family, or one binary model) stacked into TWO sparse
layouts built once at load time —

  per-model padded (the Pallas kernel layout):
    idx (K, A_max) int32   active feature ids, sentinel == n_features
    val (K, A_max) float32 matching weights, 0 at padding

  union-compressed (the XLA scorer layout):
    union_idx (U,)   int32 sorted union of every model's active ids
    union_val (K, U) f32   each model's weights restricted to the union

A_max = max_k nnz(w_k) and U = |union|, so bank memory is K * A_max +
K * U, not K * n. Scoring touches ONLY active coordinates of the request
batch, in either request layout:

  * dense  (B, n) slab        -> ONE shared gather X[:, union_idx]
    followed by a (B, U) x (U, K) matmul — the gather (the expensive op
    on every backend) is amortized across all K models instead of paid
    per model;
  * padded-CSC request matrix -> gather the union's request columns
    once, scatter-add per model over request rows (slab_matvec's
    serving twin).

Each scorer has an XLA implementation (jitted; also the fast path on
CPU) and a Pallas kernel route (`use_kernels=True`, the per-model
gather of kernels/pcdn_margin.py); tests pin all four to the dense
matmul ground truth. `decide` turns margins into predictions: argmax
over classes for an OVR bank, sign for binary/path banks.

Dense-layout ROUTING (DESIGN.md 14.6): the union-gather scorer loses to
a plain densified matmul at low weight sparsity / small batch (the CPU
gather cost exceeds the matmul — BENCH_serve.json's scorer table shows
the measured table honestly). `margins_dense(..., route=...)` therefore
offers both: "sparse" (union-gather), "dense" (densified (K, n) matmul,
built lazily and cached on the bank), and "auto", which reads the
measured crossover point (sparsity x batch) recorded by
benchmarks/bench_serve.py under the `route_crossover` key of the
committed BENCH_serve.json and picks the winner per call.

Capacity-padded banks (DESIGN.md 14.5): `a_cap`/`u_cap` pad both
layouts to fixed widths beyond the current models' needs — the serving
loop's hot-swap installs a new model into the SAME shapes, so every
scorer program keyed on bank shapes is reused and steady state never
recompiles. idx padding uses the sentinel `n_features` (the kernels'
existing contract); union padding uses index 0 with zero weight
(always a valid gather, contributes exactly 0).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.design_matrix import PaddedCSCDesign
from repro.kernels import ops
from repro.serve.artifact import ModelFamily

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ModelBank:
    """Stacked sparse layouts of K models sharing n_features.

    `dtype` at build time sets the STORAGE dtype of val/union_val
    (f32 default; bf16 halves bank memory and scorer HBM traffic —
    DESIGN.md section 12). Every scorer upcasts to f32 before its
    contraction, so margins are always f32.
    """

    idx: Array                     # (K, A_max) int32, sentinel == n_features
    val: Array                     # (K, A_max) float, 0 at padding
    union_idx: Array               # (U,) int32 union of active ids
    union_val: Array               # (K, U) float weights on the union
    bias: Array                    # (K,) float32
    n_features: int
    kind: str = "binary"
    loss_name: str = "logistic"
    classes: Optional[np.ndarray] = None   # (K,) vocab for kind="ovr"

    @property
    def n_models(self) -> int:
        return int(self.idx.shape[0])

    @property
    def a_max(self) -> int:
        return int(self.idx.shape[1])

    @property
    def nnz(self) -> np.ndarray:
        return np.asarray(jnp.sum(self.idx < self.n_features, axis=1))

    def sparsity(self) -> float:
        """Mean fraction of zero weights across the bank's models
        (computed once and cached — route="auto" reads it per call)."""
        cached = getattr(self, "_sparsity_cache", None)
        if cached is None:
            cached = 1.0 - float(self.nnz.mean()) / max(self.n_features, 1)
            object.__setattr__(self, "_sparsity_cache", cached)
        return cached

    def dense_matrix(self) -> Array:
        """Densified (K, n) f32 weight stack for the dense-matmul route,
        built lazily from the per-model layout and cached on the bank."""
        W = getattr(self, "_dense_w_cache", None)
        if W is None:
            idx = np.asarray(self.idx)
            val = np.asarray(self.val, np.float32)
            Wn = np.zeros((self.n_models, self.n_features), np.float32)
            live = idx < self.n_features
            rows = np.repeat(np.arange(self.n_models), live.sum(axis=1))
            Wn[rows, idx[live]] = val[live]
            W = jnp.asarray(Wn)
            object.__setattr__(self, "_dense_w_cache", W)
        return W

    @classmethod
    def _build(cls, sparse_rows, bias, n: int, kind: str, loss_name: str,
               classes, dtype=np.float32, a_cap: Optional[int] = None,
               u_cap: Optional[int] = None) -> "ModelBank":
        """sparse_rows: [(indices, values)] per model -> both layouts.

        a_cap / u_cap pad the per-model and union layouts to FIXED widths
        (>= what the models need) so a later model swap at the same caps
        reuses every compiled scorer — see the module docstring.
        """
        K = len(sparse_rows)
        a_max = max(1, max(ii.shape[0] for ii, _ in sparse_rows))
        if a_cap is not None:
            if a_max > int(a_cap):
                raise ValueError(
                    f"bank needs a_max={a_max} active weights per model "
                    f"but the capacity is a_cap={a_cap}")
            a_max = int(a_cap)
        idx = np.full((K, a_max), n, np.int32)
        val = np.zeros((K, a_max), np.float32)
        for k, (ii, vv) in enumerate(sparse_rows):
            idx[k, :ii.shape[0]] = ii
            val[k, :ii.shape[0]] = vv
        union = np.unique(np.concatenate(
            [ii for ii, _ in sparse_rows] or [np.zeros(0, np.int64)]))
        if union.size == 0:
            union = np.zeros((1,), np.int64)    # all-zero bank (c_max point)
        if u_cap is not None:
            if union.shape[0] > int(u_cap):
                raise ValueError(
                    f"bank union has {union.shape[0]} active features but "
                    f"the capacity is u_cap={u_cap}")
        uval = np.zeros((K, union.shape[0]), np.float32)
        for k, (ii, vv) in enumerate(sparse_rows):
            uval[k, np.searchsorted(union, ii)] = vv
        if u_cap is not None and union.shape[0] < int(u_cap):
            # pad with index 0 / weight 0: a valid gather contributing 0
            # (the out-of-range sentinel would gather NaN under jnp.take's
            # default fill mode)
            pad = int(u_cap) - union.shape[0]
            union = np.concatenate([union, np.zeros((pad,), np.int64)])
            uval = np.concatenate([uval, np.zeros((K, pad), np.float32)],
                                  axis=1)
        b = np.zeros((K,), np.float32) if bias is None \
            else np.asarray(bias, np.float32).reshape(K)
        dtype = jnp.dtype(dtype)
        return cls(idx=jnp.asarray(idx), val=jnp.asarray(val, dtype=dtype),
                   union_idx=jnp.asarray(union.astype(np.int32)),
                   union_val=jnp.asarray(uval, dtype=dtype),
                   bias=jnp.asarray(b),
                   n_features=n, kind=kind, loss_name=loss_name,
                   classes=classes)

    @classmethod
    def from_family(cls, family: ModelFamily, dtype=np.float32,
                    a_cap: Optional[int] = None,
                    u_cap: Optional[int] = None) -> "ModelBank":
        rows = [(m.w_indices, m.w_values.astype(np.float32))
                for m in family.models]
        bias = np.asarray([m.bias for m in family.models], np.float32)
        return cls._build(rows, bias, family.n_features, family.kind,
                          family.loss_name, family.classes, dtype=dtype,
                          a_cap=a_cap, u_cap=u_cap)

    @classmethod
    def from_dense(cls, W, bias=None, kind: str = "binary",
                   loss_name: str = "logistic",
                   classes: Optional[np.ndarray] = None,
                   dtype=np.float32, a_cap: Optional[int] = None,
                   u_cap: Optional[int] = None) -> "ModelBank":
        """Stack (K, n) dense solutions (e.g. OVRResult.weights)."""
        W = np.asarray(W, np.float32)
        if W.ndim == 1:
            W = W[None, :]
        rows = [(np.flatnonzero(W[k]), W[k, np.flatnonzero(W[k])])
                for k in range(W.shape[0])]
        return cls._build(rows, bias, W.shape[1], kind, loss_name, classes,
                          dtype=dtype, a_cap=a_cap, u_cap=u_cap)


@jax.jit
def _dense_xla(X, union_idx, union_val, bias):
    """One shared active-union gather, then a small (B, U) x (U, K)
    contraction — the gather cost is paid once for all K models."""
    Xu = jnp.take(X, union_idx, axis=1)
    # bf16 bank storage upcasts here: the contraction accumulates in f32
    return Xu @ union_val.T.astype(jnp.float32) + bias[None, :]


@jax.jit
def _matmul_xla(X, W, bias):
    """The densified baseline scorer: z = X @ W.T. Beats the union
    gather at low weight sparsity / small batch (the measured crossover
    table of BENCH_serve.json; route='auto' picks per call)."""
    return X @ W.T + bias[None, :]


# -- dense-layout route selection (the measured crossover) -------------------

# Fallback when no committed BENCH_serve.json is readable: the measured
# full-run crossover of the committed artifact (CPU, K=16, n=32768) —
# union-gather wins from B>=256 at 0.99 sparsity and from B>=64 at
# 0.999; never below 0.99. min_batch_sparse=None means dense always.
DEFAULT_ROUTE_CROSSOVER = (
    {"sparsity": 0.9, "min_batch_sparse": None},
    {"sparsity": 0.99, "min_batch_sparse": 256},
    {"sparsity": 0.999, "min_batch_sparse": 64},
)

_route_lock = threading.Lock()
_route_crossover: Optional[tuple] = None


def _bench_serve_path() -> str:
    # src/repro/serve/predict.py -> repo root (guarded by os.path.exists)
    here = os.path.dirname(os.path.abspath(__file__))
    return os.path.join(here, os.pardir, os.pardir, os.pardir,
                        "BENCH_serve.json")


def route_crossover() -> tuple:
    """The dense-vs-union-gather crossover table, loaded once from the
    committed BENCH_serve.json (`route_crossover` key, full runs only)
    with DEFAULT_ROUTE_CROSSOVER as the fallback."""
    global _route_crossover
    with _route_lock:
        if _route_crossover is None:
            entries = None
            path = _bench_serve_path()
            try:
                if os.path.exists(path):
                    with open(path) as fh:
                        payload = json.load(fh)
                    if not payload.get("smoke"):
                        entries = payload.get("route_crossover")
            except (OSError, ValueError):
                entries = None          # unreadable artifact -> fallback
            if entries:
                _route_crossover = tuple(
                    {"sparsity": float(e["sparsity"]),
                     "min_batch_sparse": (None
                                          if e.get("min_batch_sparse") is None
                                          else int(e["min_batch_sparse"]))}
                    for e in entries)
            else:
                _route_crossover = DEFAULT_ROUTE_CROSSOVER
        return _route_crossover


def set_route_crossover(entries) -> None:
    """Override (or with None, reset to lazy-loaded) the crossover table
    — tests and benchmarks pin it to make routing deterministic."""
    global _route_crossover
    with _route_lock:
        _route_crossover = None if entries is None else tuple(entries)


def pick_route(sparsity: float, batch: int) -> str:
    """'sparse' (union-gather) or 'dense' (densified matmul) for a bank
    of the given weight sparsity scoring a batch of the given size, per
    the measured crossover table. Conservative outside the measured
    range: sparser-than-measured banks inherit the sparsest entry;
    batches below the measured crossover go dense."""
    best = None
    for e in sorted(route_crossover(), key=lambda e: e["sparsity"]):
        if sparsity >= e["sparsity"]:
            best = e
    if best is None or best["min_batch_sparse"] is None:
        return "dense"
    return "sparse" if batch >= best["min_batch_sparse"] else "dense"


def scorer_cache_sizes() -> dict:
    """Compiled-program counts of the jitted scorers + install program —
    the hot-swap regression tests pin these flat across traffic and
    swaps (a growing cache is a recompile)."""
    sizes = {"dense_xla": _dense_xla._cache_size(),
             "csc_xla": _csc_xla._cache_size(),
             "matmul_xla": _matmul_xla._cache_size()}
    from repro.serve import loop as _loop   # lazy: loop imports this module
    sizes["install"] = _loop._install._cache_size()
    return sizes


@functools.partial(jax.jit, static_argnames=("n_requests",))
def _csc_xla(col_rows, col_vals, union_idx, union_val, bias, n_requests):
    """Shared gather of the union's request-matrix columns; per-model
    scaled scatter-add over request rows (slab_matvec's serving twin)."""
    rows = jnp.take(col_rows, union_idx, axis=0)          # (U, k_max)
    vals = jnp.take(col_vals.astype(jnp.float32), union_idx, axis=0)

    def one(vk):                                          # (U,) weights
        z = jnp.zeros((n_requests,), jnp.float32)
        return z.at[rows].add(vals * vk[:, None].astype(jnp.float32),
                              mode="drop")

    return jax.vmap(one)(union_val).T + bias[None, :]


def margins_dense(bank: ModelBank, X, use_kernels: bool = False,
                  route: str = "sparse") -> Array:
    """(B, K) margins for a dense (B, n) request slab.

    `route` selects the XLA scorer: "sparse" (union-gather), "dense"
    (densified matmul), or "auto" (measured crossover — see pick_route).
    Ignored with use_kernels=True (the kernel path is per-model gather).
    """
    if not isinstance(X, jax.Array):
        X = jnp.asarray(np.asarray(X), jnp.float32)
    elif X.dtype != jnp.float32:
        X = X.astype(jnp.float32)
    if X.ndim != 2 or X.shape[1] != bank.n_features:
        raise ValueError(f"requests must be (B, {bank.n_features}), got "
                         f"{X.shape}")
    if use_kernels:
        return ops.serve_margins_dense(X, bank.idx, bank.val) + \
            bank.bias[None, :]
    if route == "auto":
        route = pick_route(bank.sparsity(), int(X.shape[0]))
    if route == "dense":
        return _matmul_xla(X, bank.dense_matrix(), bank.bias)
    if route != "sparse":
        raise ValueError(f"unknown dense-layout route {route!r} "
                         "(expected 'sparse', 'dense' or 'auto')")
    return _dense_xla(X, bank.union_idx, bank.union_val, bank.bias)


def margins_padded_csc(bank: ModelBank, requests,
                       use_kernels: bool = False) -> Array:
    """(B, K) margins for a padded-CSC request batch.

    `requests`: a PaddedCSCDesign or a numpy-side data.libsvm.PaddedCSC —
    the feature-major layout of the REQUEST matrix (B rows, n features).
    """
    if isinstance(requests, PaddedCSCDesign):
        rows, vals = requests.col_rows, requests.col_vals
        B, n = requests.shape
    elif all(hasattr(requests, a) for a in ("col_rows", "col_vals",
                                            "shape")):
        rows = jnp.asarray(requests.col_rows)
        vals = jnp.asarray(requests.col_vals, jnp.float32)
        B, n = requests.shape
    else:
        raise TypeError(f"not a padded-CSC request batch: "
                        f"{type(requests).__name__}")
    if n != bank.n_features:
        raise ValueError(f"requests have {n} features, bank has "
                         f"{bank.n_features}")
    if use_kernels:
        return ops.serve_margins_csc(rows, vals, bank.idx, bank.val,
                                     n_requests=int(B)) + bank.bias[None, :]
    return _csc_xla(rows, vals, bank.union_idx, bank.union_val, bank.bias,
                    n_requests=int(B))


def predict(bank: ModelBank, requests, use_kernels: bool = False) -> Array:
    """Margins for either request layout (dispatch on the request type)."""
    if hasattr(requests, "col_rows"):
        return margins_padded_csc(bank, requests, use_kernels=use_kernels)
    return margins_dense(bank, requests, use_kernels=use_kernels)


def decide(bank: ModelBank, margins) -> np.ndarray:
    """Margins -> predictions.

    ovr bank: (B,) class labels by argmax margin; binary bank: (B,) +-1
    by sign (0 counts +1, matching validation_accuracy); path bank:
    (B, K) +-1 per grid point.
    """
    m = np.asarray(margins)
    if bank.kind == "ovr":
        if bank.classes is None:
            raise ValueError("ovr bank without a class vocabulary")
        return np.asarray(bank.classes)[np.argmax(m, axis=1)]
    pred = np.sign(m)
    pred[pred == 0] = 1.0
    if bank.kind == "binary":
        return pred[:, 0]
    return pred
