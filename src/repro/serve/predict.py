"""Batched-margin prediction engine over sparse models (DESIGN.md 10.3).

Serving state is a `ModelBank`: the K models of an artifact family (an
OVR head, a path family, or one binary model) stacked into TWO sparse
layouts built once at load time —

  per-model padded (the Pallas kernel layout):
    idx (K, A_max) int32   active feature ids, sentinel == n_features
    val (K, A_max) float32 matching weights, 0 at padding

  union-compressed (the XLA scorer layout):
    union_idx (U,)   int32 sorted union of every model's active ids
    union_val (K, U) f32   each model's weights restricted to the union

A_max = max_k nnz(w_k) and U = |union|, so bank memory is K * A_max +
K * U, not K * n. Scoring touches ONLY active coordinates of the request
batch, in either request layout:

  * dense  (B, n) slab        -> ONE shared gather X[:, union_idx]
    followed by a (B, U) x (U, K) matmul — the gather (the expensive op
    on every backend) is amortized across all K models instead of paid
    per model;
  * padded-CSC request matrix -> gather the union's request columns
    once, scatter-add per model over request rows (slab_matvec's
    serving twin).

Each scorer has an XLA implementation (jitted; also the fast path on
CPU) and a Pallas kernel route (`use_kernels=True`, the per-model
gather of kernels/pcdn_margin.py); tests pin all four to the dense
matmul ground truth. `decide` turns margins into predictions: argmax
over classes for an OVR bank, sign for binary/path banks.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.design_matrix import PaddedCSCDesign
from repro.kernels import ops
from repro.serve.artifact import ModelFamily

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ModelBank:
    """Stacked sparse layouts of K models sharing n_features.

    `dtype` at build time sets the STORAGE dtype of val/union_val
    (f32 default; bf16 halves bank memory and scorer HBM traffic —
    DESIGN.md section 12). Every scorer upcasts to f32 before its
    contraction, so margins are always f32.
    """

    idx: Array                     # (K, A_max) int32, sentinel == n_features
    val: Array                     # (K, A_max) float, 0 at padding
    union_idx: Array               # (U,) int32 union of active ids
    union_val: Array               # (K, U) float weights on the union
    bias: Array                    # (K,) float32
    n_features: int
    kind: str = "binary"
    loss_name: str = "logistic"
    classes: Optional[np.ndarray] = None   # (K,) vocab for kind="ovr"

    @property
    def n_models(self) -> int:
        return int(self.idx.shape[0])

    @property
    def a_max(self) -> int:
        return int(self.idx.shape[1])

    @property
    def nnz(self) -> np.ndarray:
        return np.asarray(jnp.sum(self.idx < self.n_features, axis=1))

    def sparsity(self) -> float:
        """Mean fraction of zero weights across the bank's models."""
        return 1.0 - float(self.nnz.mean()) / max(self.n_features, 1)

    @classmethod
    def _build(cls, sparse_rows, bias, n: int, kind: str, loss_name: str,
               classes, dtype=np.float32) -> "ModelBank":
        """sparse_rows: [(indices, values)] per model -> both layouts."""
        K = len(sparse_rows)
        a_max = max(1, max(ii.shape[0] for ii, _ in sparse_rows))
        idx = np.full((K, a_max), n, np.int32)
        val = np.zeros((K, a_max), np.float32)
        for k, (ii, vv) in enumerate(sparse_rows):
            idx[k, :ii.shape[0]] = ii
            val[k, :ii.shape[0]] = vv
        union = np.unique(np.concatenate(
            [ii for ii, _ in sparse_rows] or [np.zeros(0, np.int64)]))
        if union.size == 0:
            union = np.zeros((1,), np.int64)    # all-zero bank (c_max point)
        uval = np.zeros((K, union.shape[0]), np.float32)
        for k, (ii, vv) in enumerate(sparse_rows):
            uval[k, np.searchsorted(union, ii)] = vv
        b = np.zeros((K,), np.float32) if bias is None \
            else np.asarray(bias, np.float32).reshape(K)
        dtype = jnp.dtype(dtype)
        return cls(idx=jnp.asarray(idx), val=jnp.asarray(val, dtype=dtype),
                   union_idx=jnp.asarray(union.astype(np.int32)),
                   union_val=jnp.asarray(uval, dtype=dtype),
                   bias=jnp.asarray(b),
                   n_features=n, kind=kind, loss_name=loss_name,
                   classes=classes)

    @classmethod
    def from_family(cls, family: ModelFamily,
                    dtype=np.float32) -> "ModelBank":
        rows = [(m.w_indices, m.w_values.astype(np.float32))
                for m in family.models]
        bias = np.asarray([m.bias for m in family.models], np.float32)
        return cls._build(rows, bias, family.n_features, family.kind,
                          family.loss_name, family.classes, dtype=dtype)

    @classmethod
    def from_dense(cls, W, bias=None, kind: str = "binary",
                   loss_name: str = "logistic",
                   classes: Optional[np.ndarray] = None,
                   dtype=np.float32) -> "ModelBank":
        """Stack (K, n) dense solutions (e.g. OVRResult.weights)."""
        W = np.asarray(W, np.float32)
        if W.ndim == 1:
            W = W[None, :]
        rows = [(np.flatnonzero(W[k]), W[k, np.flatnonzero(W[k])])
                for k in range(W.shape[0])]
        return cls._build(rows, bias, W.shape[1], kind, loss_name, classes,
                          dtype=dtype)


@jax.jit
def _dense_xla(X, union_idx, union_val, bias):
    """One shared active-union gather, then a small (B, U) x (U, K)
    contraction — the gather cost is paid once for all K models."""
    Xu = jnp.take(X, union_idx, axis=1)
    # bf16 bank storage upcasts here: the contraction accumulates in f32
    return Xu @ union_val.T.astype(jnp.float32) + bias[None, :]


@functools.partial(jax.jit, static_argnames=("n_requests",))
def _csc_xla(col_rows, col_vals, union_idx, union_val, bias, n_requests):
    """Shared gather of the union's request-matrix columns; per-model
    scaled scatter-add over request rows (slab_matvec's serving twin)."""
    rows = jnp.take(col_rows, union_idx, axis=0)          # (U, k_max)
    vals = jnp.take(col_vals.astype(jnp.float32), union_idx, axis=0)

    def one(vk):                                          # (U,) weights
        z = jnp.zeros((n_requests,), jnp.float32)
        return z.at[rows].add(vals * vk[:, None].astype(jnp.float32),
                              mode="drop")

    return jax.vmap(one)(union_val).T + bias[None, :]


def margins_dense(bank: ModelBank, X, use_kernels: bool = False) -> Array:
    """(B, K) margins for a dense (B, n) request slab."""
    if not isinstance(X, jax.Array):
        X = jnp.asarray(np.asarray(X), jnp.float32)
    elif X.dtype != jnp.float32:
        X = X.astype(jnp.float32)
    if X.ndim != 2 or X.shape[1] != bank.n_features:
        raise ValueError(f"requests must be (B, {bank.n_features}), got "
                         f"{X.shape}")
    if use_kernels:
        return ops.serve_margins_dense(X, bank.idx, bank.val) + \
            bank.bias[None, :]
    return _dense_xla(X, bank.union_idx, bank.union_val, bank.bias)


def margins_padded_csc(bank: ModelBank, requests,
                       use_kernels: bool = False) -> Array:
    """(B, K) margins for a padded-CSC request batch.

    `requests`: a PaddedCSCDesign or a numpy-side data.libsvm.PaddedCSC —
    the feature-major layout of the REQUEST matrix (B rows, n features).
    """
    if isinstance(requests, PaddedCSCDesign):
        rows, vals = requests.col_rows, requests.col_vals
        B, n = requests.shape
    elif all(hasattr(requests, a) for a in ("col_rows", "col_vals",
                                            "shape")):
        rows = jnp.asarray(requests.col_rows)
        vals = jnp.asarray(requests.col_vals, jnp.float32)
        B, n = requests.shape
    else:
        raise TypeError(f"not a padded-CSC request batch: "
                        f"{type(requests).__name__}")
    if n != bank.n_features:
        raise ValueError(f"requests have {n} features, bank has "
                         f"{bank.n_features}")
    if use_kernels:
        return ops.serve_margins_csc(rows, vals, bank.idx, bank.val,
                                     n_requests=int(B)) + bank.bias[None, :]
    return _csc_xla(rows, vals, bank.union_idx, bank.union_val, bank.bias,
                    n_requests=int(B))


def predict(bank: ModelBank, requests, use_kernels: bool = False) -> Array:
    """Margins for either request layout (dispatch on the request type)."""
    if hasattr(requests, "col_rows"):
        return margins_padded_csc(bank, requests, use_kernels=use_kernels)
    return margins_dense(bank, requests, use_kernels=use_kernels)


def decide(bank: ModelBank, margins) -> np.ndarray:
    """Margins -> predictions.

    ovr bank: (B,) class labels by argmax margin; binary bank: (B,) +-1
    by sign (0 counts +1, matching validation_accuracy); path bank:
    (B, K) +-1 per grid point.
    """
    m = np.asarray(margins)
    if bank.kind == "ovr":
        if bank.classes is None:
            raise ValueError("ovr bank without a class vocabulary")
        return np.asarray(bank.classes)[np.argmax(m, axis=1)]
    pred = np.sign(m)
    pred[pred == 0] = 1.0
    if bank.kind == "binary":
        return pred[:, 0]
    return pred
