"""Training/serving substrate: step factories plus re-exports of the
checkpoint/runner machinery that now lives in `repro.fault` (the
`train.checkpoint` / `train.fault_tolerance` modules are deprecation
shims)."""
from repro.train.steps import make_serve_step, make_train_step
from repro.fault.checkpoint import CheckpointManager
from repro.fault.runner import FaultTolerantRunner

__all__ = ["make_train_step", "make_serve_step", "CheckpointManager",
           "FaultTolerantRunner"]
