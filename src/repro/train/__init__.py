"""Training/serving substrate: step factories, checkpointing, fault
tolerance, elastic scaling."""
from repro.train.steps import make_serve_step, make_train_step
from repro.train.checkpoint import CheckpointManager
from repro.train.fault_tolerance import FaultTolerantRunner

__all__ = ["make_train_step", "make_serve_step", "CheckpointManager",
           "FaultTolerantRunner"]
