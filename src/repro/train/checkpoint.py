"""Deprecated location — the checkpoint machinery was promoted to
`repro.fault.checkpoint` (DESIGN.md section 16.2), where it backs the
solver/sweep checkpoint-resume path as well as the train demo.

This shim re-exports the public names and will be removed; import from
`repro.fault` instead.
"""
from __future__ import annotations

import warnings

from repro.fault.checkpoint import CheckpointManager, _SEP  # noqa: F401

warnings.warn(
    "repro.train.checkpoint is deprecated; use repro.fault.checkpoint "
    "(promoted in the fault-tolerance subsystem)",
    DeprecationWarning, stacklevel=2)

__all__ = ["CheckpointManager"]
