"""Sharded, atomic, mesh-shape-agnostic checkpointing (no orbax offline).

Layout:  <dir>/step_<N>/
            manifest.json     — tree structure, shapes, dtypes, step
            arrays.npz        — one entry per flattened leaf
            COMMITTED         — written last; a checkpoint without it is
                                incomplete and ignored on restore
Leaves are gathered to host (full arrays) so restore can re-shard onto any
mesh (elastic scaling). Writes go to a tmp dir + atomic rename; old steps
are garbage-collected keeping `keep` newest.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "§"


def _flatten_with_names(tree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in path)
        out.append((name or "leaf", leaf))
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        treedef = jax.tree_util.tree_structure(tree)
        named = _flatten_with_names(tree)
        arrays = {}
        for i, (name, leaf) in enumerate(named):
            arrays[f"{i:05d}{_SEP}{name}"] = np.asarray(
                jax.device_get(leaf))
        tmp = tempfile.mkdtemp(dir=self.directory, prefix=".tmp_ckpt_")
        try:
            np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
            manifest = {
                "step": int(step),
                "treedef": str(treedef),
                "n_leaves": len(named),
                "extra": extra or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as fh:
                json.dump(manifest, fh)
            with open(os.path.join(tmp, "COMMITTED"), "w") as fh:
                fh.write("ok")
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except Exception:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return self._step_dir(step)

    # -- restore --------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.directory, d, "COMMITTED")):
                steps.append(int(d.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[int, Any]:
        """`like` provides the tree structure (+ dtypes for casting).
        `shardings` (optional pytree of NamedSharding) re-shards on load —
        works across mesh shapes (elastic restart)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in "
                                    f"{self.directory}")
        d = self._step_dir(step)
        data = np.load(os.path.join(d, "arrays.npz"))
        keys = sorted(data.files, key=lambda s: int(s.split(_SEP)[0]))
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        assert len(keys) == len(leaves_like), \
            f"leaf count mismatch: {len(keys)} vs {len(leaves_like)}"
        shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                        if shardings is not None else [None] * len(keys))
        out = []
        for key, ref, shd in zip(keys, leaves_like, shard_leaves):
            arr = data[key]
            dtype = getattr(ref, "dtype", arr.dtype)
            a = jnp.asarray(arr, dtype=dtype)
            if shd is not None:
                a = jax.device_put(a, shd)
            out.append(a)
        return step, jax.tree_util.tree_unflatten(treedef, out)

    # -- internals --------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{int(step):08d}")

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and os.path.exists(
                os.path.join(self.directory, d, "COMMITTED")))
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
        # clean stale tmp dirs from crashed writers
        for d in os.listdir(self.directory):
            if d.startswith(".tmp_ckpt_"):
                shutil.rmtree(os.path.join(self.directory, d),
                              ignore_errors=True)
