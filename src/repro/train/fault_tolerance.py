"""Deprecated location — the fault-tolerant step runner was promoted to
`repro.fault.runner` (DESIGN.md section 16.5).

This shim re-exports the public names and will be removed; import from
`repro.fault` instead.
"""
from __future__ import annotations

import warnings

from repro.fault.runner import (ElasticMeshProvider,  # noqa: F401
                                FaultTolerantRunner, RunnerConfig,
                                StepFailure)

warnings.warn(
    "repro.train.fault_tolerance is deprecated; use repro.fault.runner "
    "(promoted in the fault-tolerance subsystem)",
    DeprecationWarning, stacklevel=2)

__all__ = ["FaultTolerantRunner", "RunnerConfig", "StepFailure",
           "ElasticMeshProvider"]
