"""train_step / serve_step factories — the functions the dry-run lowers.

`make_train_step` returns (step_fn, in_shardings, out_shardings) ready for
jax.jit; the same artifacts serve the real trainer loop and the
lower-compile-only dry-run path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import decode as dec
from repro.models import sharding as sh
from repro.models.config import ModelConfig
from repro.models.transformer import Model
from repro.optim.adamw import (AdamWConfig, AdamWState, adamw_init,
                               adamw_update)

Array = jax.Array


def _fit_axes(dim: int, axes) -> object:
    """Largest prefix of `axes` whose product divides dim (None if empty).
    long_500k has global_batch=1 — a 1-sized batch cannot shard over 16
    devices; it falls back to replicated (the model axes still shard)."""
    chosen = []
    prod = 1
    for a in axes:
        if dim % (prod * a[1]) == 0:
            chosen.append(a[0])
            prod *= a[1]
    if not chosen:
        return None
    return chosen[0] if len(chosen) == 1 else tuple(chosen)


def _batch_spec(mesh: Mesh, tree, rules: sh.ShardingRules):
    """Batch inputs: shard dim 0 over the data-like axes (when divisible)."""
    daxes = [(a, mesh.shape[a]) for a in ("pod", "data") if a in mesh.shape]

    def spec_for(x):
        nd = len(x.shape)
        d0 = _fit_axes(x.shape[0], daxes) if nd else None
        return P(*([d0] + [None] * (nd - 1)))
    return jax.tree.map(spec_for, tree)


def _named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_specs(param_specs, keep_master: bool):
    scalar = P()
    return AdamWState(
        step=scalar,
        mu=param_specs,
        nu=param_specs,
        master=param_specs if keep_master else None,
    )


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    lr_schedule: Optional[Callable] = None):
    """-> (train_step, param_specs, opt_specs). train_step(params, opt,
    batch) -> (params, opt, metrics)."""
    param_specs = model.param_specs()

    def train_step(params, opt_state: AdamWState, batch: Dict[str, Array]):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        lr = (lr_schedule(opt_state.step) if lr_schedule is not None
              else opt_cfg.lr)
        params, opt_state, metrics = adamw_update(params, grads, opt_state,
                                                  opt_cfg, lr)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step, param_specs, opt_state_specs(param_specs,
                                                    opt_cfg.keep_master)


def make_serve_step(model: Model):
    """-> serve_step(params, cache, tokens) -> (logits, cache).

    One greedy decode step for the whole request batch (the benchmark /
    dry-run unit for decode_* cells)."""
    def serve_step(params, cache, tokens):
        logits, cache = dec.decode_step(model, params, cache, tokens)
        return logits, cache
    return serve_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch):
        return model.logits(params, batch, train=False)
    return prefill_step


def cache_specs(model: Model, cache_tree):
    """Structure-aware decode-cache sharding.

    KV caches (L, B, S, Kv, Dh): batch over the data axes, then kv-heads
    over "model" when divisible; otherwise the *sequence* dim shards over
    "model" (flash-decoding-style sequence parallelism — softmax stats are
    reduced by two small psums, far cheaper than replicating a 32k cache).
    Recurrent states shard their channel dim over "model".
    """
    mesh = model.mesh
    m = mesh.shape.get("model", 1)
    daxes = [(a, mesh.shape[a]) for a in ("pod", "data") if a in mesh.shape]

    def dsp(batch):  # data axes only when they divide the batch
        return _fit_axes(batch, daxes)

    def mod(dim):
        return "model" if (m > 1 and dim % m == 0) else None

    def kv_spec(x):  # (L, B, S, Kv, Dh)
        _, B, S, Kv, Dh = x.shape
        if m > 1 and Kv % m == 0:
            return P(None, dsp(B), None, "model", None)
        return P(None, dsp(B), mod(S), None, None)

    def spec_for(path, x):
        key = path[0].key if hasattr(path[0], "key") else str(path[0])
        nd = len(x.shape)
        if nd == 0:
            return P()
        if key in ("kv", "kv0", "cross"):
            return kv_spec(x)
        if key == "h":                       # ssm (L, B, Di, N)
            return P(None, dsp(x.shape[1]), mod(x.shape[2]), None)
        if key == "conv":                    # ssm (L, B, Kc-1, Di)
            return P(None, dsp(x.shape[1]), None, mod(x.shape[3]))
        if key.startswith("lru") and key.endswith("_h"):
            return P(None, dsp(x.shape[1]), mod(x.shape[2]))
        if key.startswith("lru"):            # (L, B, Kc-1, W)
            return P(None, dsp(x.shape[1]), None, mod(x.shape[3]))
        if key.startswith("tail") and key.endswith("_h"):
            return P(dsp(x.shape[0]), mod(x.shape[1]))
        if key.startswith("tail"):           # (B, Kc-1, W)
            return P(dsp(x.shape[0]), None, mod(x.shape[2]))
        return P(*([dsp(x.shape[0])] + [None] * (nd - 1)))

    return jax.tree_util.tree_map_with_path(spec_for, cache_tree)
