"""Version-compat shims for jax APIs that moved between releases."""
from __future__ import annotations

import jax

try:  # jax >= 0.5 promotes shard_map out of experimental
    _shard_map_impl = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map_impl
    _CHECK_KW = "check_rep"


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """jax.shard_map across jax versions; `check` maps to check_vma (new)
    or check_rep (0.4.x experimental)."""
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **{_CHECK_KW: check})


def cost_analysis(compiled) -> dict:
    """`compiled.cost_analysis()` normalized to a flat dict.

    jax 0.4.x returns a one-element list of dicts (one per partition /
    executable); jax >= 0.5 returns the dict directly. Indexing the list
    with a string key is the `TypeError: list indices must be integers`
    that broke the HLO cost-model calibration tests."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)
