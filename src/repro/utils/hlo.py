"""Post-optimization HLO analysis: collective-byte accounting + roofline.

cost_analysis() has no collective numbers, so we parse the compiled
module's HLO text and sum operand sizes of every communication op
(all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute), attributing bytes to the axis groups found in
`replica_groups`. Shapes are parsed from the HLO type strings
(e.g. ``bf16[16,512,128]{...}``).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %ag = bf16[2,16,512]{2,1,0:T(8,128)} all-gather(%x), ...
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|([a-z0-9]+)\[([0-9,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def summary(self) -> str:
        parts = [f"{k}: {v / 1e9:.3f} GB x{self.count_by_kind[k]}"
                 for k, v in sorted(self.bytes_by_kind.items()) if v]
        return "; ".join(parts) or "none"


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective op in the module.

    `-done` ops are skipped so async pairs are counted once (on `-start`).
    """
    by_kind: Dict[str, int] = defaultdict(int)
    by_count: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "-done(" in stripped:
            continue  # counted at -start
        hit = None
        for kind in _COLLECTIVES:
            if f" {kind}(" in stripped or f" {kind}-start(" in stripped:
                hit = kind
                break
        if hit is None:
            continue
        # result type(s) appear between '=' and the op name
        lhs = stripped.split(f" {hit}")[0]
        eq = lhs.find("=")
        if eq < 0:
            continue
        type_str = lhs[eq + 1:]
        nbytes = 0
        for dt, dims in _SHAPE_RE.findall(type_str):
            if dt in _DTYPE_BYTES:
                nbytes += _shape_bytes(dt, dims)
        by_kind[hit] += nbytes
        by_count[hit] += 1
    return CollectiveStats(dict(by_kind), dict(by_count))


# ---------------------------------------------------------------------------
# roofline terms (TPU v5e-class constants supplied by the assignment)

PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
ICI_LATENCY = 1e-6            # per collective issue (barrier round trip)


@dataclasses.dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    n_chips: int
    model_flops: float = 0.0
    coll_count: float = 0.0   # collectives issued per step (latency term)

    @property
    def t_compute(self) -> float:
        return self.flops / (self.n_chips * PEAK_FLOPS_BF16)

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / (self.n_chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.n_chips * ICI_BW)

    @property
    def t_latency(self) -> float:
        """Serialized collective-issue latency (dominates when collectives
        are many and tiny — e.g. sequential Armijo backtracking)."""
        return self.coll_count * ICI_LATENCY

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective,
                 "latency": self.t_latency}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """roofline lower bound (max of overlappable terms)."""
        return max(self.t_compute, self.t_memory, self.t_collective,
                   self.t_latency)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def mfu_bound(self) -> float:
        """Upper bound on achievable MFU: useful flops / (chips * peak *
        roofline step time)."""
        t = self.step_time
        if t <= 0:
            return 0.0
        return self.model_flops / (self.n_chips * PEAK_FLOPS_BF16 * t)

    def as_dict(self) -> dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "coll_bytes": self.coll_bytes, "n_chips": self.n_chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "t_latency_s": self.t_latency,
            "coll_count": self.coll_count,
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
        }
