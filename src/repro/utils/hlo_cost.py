"""Trip-count-aware HLO cost model.

XLA's `compiled.cost_analysis()` counts a while-loop body ONCE regardless
of trip count (verified empirically — see tests/test_hlo_cost.py), which
undercounts scan-over-layers models by ~n_layers and misses collectives
inside the loop entirely. This module parses the post-optimization HLO
text into per-computation costs and walks the call graph multiplying by
while trip counts (parsed from the loop-condition comparison constant —
the shape jax.lax.scan always emits).

Per computation we account:
  * dot_flops    : 2 * prod(result_dims) * prod(contraction_dims)
  * bytes        : sum over top-level ops of operand + result bytes
                   (post-fusion top-level ops approximate true HBM traffic)
  * collectives  : result bytes of all-gather / all-reduce / reduce-scatter
                   / all-to-all / collective-permute (async pairs counted
                   once, at -start)
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->.*\{")
_WHILE_RE = re.compile(
    r"while\(.*\),\s*condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

# ops whose operands/results we do NOT count as memory traffic
_FREE_OPS = ("get-tuple-element", "tuple(", "parameter(", "bitcast(",
             "after-all(", "constant(", "iota(", "partition-id(",
             "replica-id(")


def _shapes_in(text: str):
    return [( dt, [int(d) for d in dims.split(",") if d])
            for dt, dims in _SHAPE_RE.findall(text)]


def _nbytes(dt: str, dims) -> int:
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES.get(dt, 4)


_META_RE = re.compile(r'op_name="([^"]*)"')


@dataclasses.dataclass
class CompCost:
    dot_flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_count: Dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    while_calls: list = dataclasses.field(default_factory=list)
    # list of (cond_name, body_name)
    fusion_calls: list = dataclasses.field(default_factory=list)
    # deferred fusion memory entries: (callee, result_bytes, operand_bytes)
    deferred_mem: list = dataclasses.field(default_factory=list)
    contains_gather: bool = False   # gather/scatter/slice ops inside
    root_is_dus: bool = False       # ROOT is a dynamic-update-slice
    max_int_constant: int = 0
    # attribution: (kind, op_name_metadata) -> flops or bytes
    dot_sources: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    coll_sources: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))


def _parse_computations(hlo: str) -> Dict[str, CompCost]:
    comps: Dict[str, CompCost] = {}
    shapes: Dict[str, tuple] = {}  # symbol -> (dtype, dims) per computation
    cur: Optional[CompCost] = None
    cur_shapes: Dict[str, tuple] = {}

    for raw in hlo.splitlines():
        line = raw.rstrip()
        hdr = _COMP_HDR_RE.match(line.strip())
        if hdr and line.strip().endswith("{"):
            cur = CompCost()
            comps[hdr.group(1)] = cur
            cur_shapes = {}
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        shs = _shapes_in(rhs.split(" ", 1)[0] if rhs.startswith(
            ("(", "f", "b", "s", "u", "p", "c")) else rhs)
        # result type = first shape(s) before the op name
        # take everything before the first '(' that follows the type
        result_shapes = []
        # result part is rhs up to the op token; simplest: shapes before op word
        op_split = re.match(r"^(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+([a-z\-]+)",
                            rhs)
        if op_split:
            result_shapes = _shapes_in(op_split.group(1))
            op = op_split.group(2)
        else:
            op = rhs.split("(")[0].strip()
            result_shapes = _shapes_in(rhs.split(op)[0]) if op else []
        if result_shapes:
            # store the first (or tuple sum) for the symbol table
            cur_shapes[name] = result_shapes
        rbytes = sum(_nbytes(dt, dims) for dt, dims in result_shapes)

        for c in _CONST_RE.findall(rhs):
            cur.max_int_constant = max(cur.max_int_constant, int(c))

        wm = _WHILE_RE.search(rhs)
        if wm:
            cur.while_calls.append((wm.group(1), wm.group(2)))
            continue  # while op itself moves no data

        if "gather(" in rhs or "scatter(" in rhs or \
                "dynamic-slice(" in rhs or "dynamic-update-slice(" in rhs:
            cur.contains_gather = True
        if line.strip().startswith("ROOT") and "dynamic-update-slice(" in rhs:
            cur.root_is_dus = True

        if " fusion(" in f" {rhs}":
            cm = _CALLS_RE.search(rhs)
            if cm:
                # credit dots nested inside the fusion at this call site
                # (CPU XLA keeps matvecs as dots inside loop fusions)
                cur.fusion_calls.append(cm.group(1))
                # defer the memory accounting until the callee's content
                # is known (gather/DUS-bearing fusions must not count
                # their giant table/buffer operands as traffic)
                arg_str = rhs.split("(", 1)[1]
                obl = []
                for oname in _OPERAND_RE.findall(arg_str.split(")", 1)[0]):
                    osh = cur_shapes.get(oname)
                    if osh:
                        obl.append(sum(_nbytes(dt, dims)
                                       for dt, dims in osh))
                cur.deferred_mem.append((cm.group(1), rbytes, tuple(obl)))
                continue

        if any(f in rhs for f in _FREE_OPS) and not rhs.startswith("fusion"):
            # cheap bookkeeping ops — but note constants still recorded above
            if op in ("get-tuple-element", "tuple", "parameter", "bitcast",
                      "constant", "iota", "after-all", "partition-id",
                      "replica-id"):
                continue

        is_async_done = "-done(" in rhs
        coll = next((k for k in _COLL_KINDS if f" {k}(" in f" {rhs}" or
                     f" {k}-start(" in f" {rhs}"), None)
        if coll and not is_async_done:
            cur.coll_bytes[coll] += rbytes
            cur.coll_count[coll] += 1
            meta = _META_RE.search(rhs)
            src = meta.group(1) if meta else name
            cur.coll_sources[f"{coll} | {src}"] += rbytes

        # dot flops
        if re.search(r"\bdot\(", rhs):
            lhs_c = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
            ops = _OPERAND_RE.findall(rhs.split("(", 1)[1])
            lhs_name = ops[0] if ops else None
            lhs_shape = cur_shapes.get(lhs_name)
            k = 1
            if lhs_c and lhs_shape:
                dims = lhs_shape[0][1]
                for ci in lhs_c.group(1).split(","):
                    if ci:
                        k *= dims[int(ci)]
            result_elems = 0
            for dt, dims in result_shapes:
                n = 1
                for d in dims:
                    n *= d
                result_elems += n
            cur.dot_flops += 2.0 * result_elems * k
            meta = _META_RE.search(rhs)
            src = meta.group(1) if meta else name
            cur.dot_sources[src] += 2.0 * result_elems * k
        if "convolution(" in rhs:
            # rough: 2 * result_elems * (kernel_elems per output)
            result_elems = sum(
                int(__import__("numpy").prod(dims) if dims else 1)
                for _, dims in result_shapes)
            cur.dot_flops += 2.0 * result_elems  # lower bound

        # memory traffic: operands + result. In-place slice updates touch
        # only the slice: counting the full aliased buffer overstates a
        # KV-cache decode step by ~1000x (measured) — on TPU a
        # dynamic-update-slice writes `update` bytes, not the whole cache.
        if not is_async_done:
            if "dynamic-update-slice(" in rhs:
                arg_str = rhs.split("(", 1)[1]
                ops = _OPERAND_RE.findall(arg_str.split(")", 1)[0])
                upd = cur_shapes.get(ops[1]) if len(ops) > 1 else None
                if upd:
                    cur.bytes += 2 * sum(_nbytes(dt, dims)
                                         for dt, dims in upd)
                continue
            if "dynamic-slice(" in rhs:
                cur.bytes += 2 * rbytes  # read slice + write result
                continue
            if re.search(r"\bgather\(", rhs):
                cur.bytes += 2 * rbytes  # touched rows only, not the table
                continue
            if re.search(r"\bscatter\(", rhs):
                # read+write the scattered region (~updates operand size)
                arg_str = rhs.split("(", 1)[1]
                ops = _OPERAND_RE.findall(arg_str.split(")", 1)[0])
                upd = cur_shapes.get(ops[-1]) if ops else None
                if upd:
                    cur.bytes += 2 * sum(_nbytes(dt, dims)
                                         for dt, dims in upd)
                continue
            obytes = 0
            arg_str = rhs.split("(", 1)[1] if "(" in rhs else ""
            for oname in _OPERAND_RE.findall(arg_str.split(")", 1)[0]):
                osh = cur_shapes.get(oname)
                if osh:
                    obytes += sum(_nbytes(dt, dims) for dt, dims in osh)
            cur.bytes += rbytes + obytes
    return comps


@dataclasses.dataclass
class ModuleCost:
    flops: float
    bytes: float
    coll_bytes: Dict[str, float]
    coll_count: Dict[str, float]
    trip_counts: Dict[str, int]
    dot_sources: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_sources: Dict[str, float] = dataclasses.field(default_factory=dict)

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())

    def top_dots(self, k: int = 12):
        return sorted(self.dot_sources.items(), key=lambda kv: -kv[1])[:k]

    def top_colls(self, k: int = 12):
        return sorted(self.coll_sources.items(), key=lambda kv: -kv[1])[:k]


def _finalize_bytes(comps: Dict[str, CompCost]) -> None:
    """Resolve deferred fusion memory entries with callee knowledge."""
    for c in comps.values():
        for callee, rbytes, obl in c.deferred_mem:
            fc = comps.get(callee)
            if fc is not None and fc.root_is_dus:
                # in-place update: count only the small (update) operands
                c.bytes += 2 * sum(b for b in obl if b < 0.5 * rbytes)
            elif fc is not None and fc.contains_gather:
                # gather-style: touched rows ~= result; exclude the table
                c.bytes += 2 * rbytes + sum(b for b in obl
                                            if b <= 4 * rbytes)
            else:
                c.bytes += rbytes + sum(obl)
        c.deferred_mem = []


def analyze(hlo: str, entry: Optional[str] = None) -> ModuleCost:
    comps = _parse_computations(hlo)
    _finalize_bytes(comps)
    # entry = computation named in "ENTRY %name" line
    if entry is None:
        m = re.search(r"ENTRY\s+%([\w.\-]+)", hlo)
        entry = m.group(1) if m else max(
            comps, key=lambda k: comps[k].dot_flops)

    # fusion sub-computations are already represented by their call sites'
    # top-level fusion op; exclude them from the walk by only following
    # while calls from each computation.
    flops = 0.0
    bytes_ = 0.0
    coll_b: Dict[str, float] = defaultdict(float)
    coll_c: Dict[str, float] = defaultdict(float)
    trips: Dict[str, int] = {}
    dot_src: Dict[str, float] = defaultdict(float)
    coll_src: Dict[str, float] = defaultdict(float)

    def walk(name: str, mult: float, depth=0):
        nonlocal flops, bytes_
        c = comps.get(name)
        if c is None or depth > 32:
            return
        flops += mult * c.dot_flops
        bytes_ += mult * c.bytes
        for k, v in c.coll_bytes.items():
            coll_b[k] += mult * v
            coll_c[k] += mult * c.coll_count[k]
        for k, v in c.dot_sources.items():
            dot_src[k] += mult * v
        for k, v in c.coll_sources.items():
            coll_src[k] += mult * v
        for fname in c.fusion_calls:
            fc = comps.get(fname)
            if fc is not None and fc.dot_flops:
                flops += mult * fc.dot_flops
                for k, v in fc.dot_sources.items():
                    dot_src[k] += mult * v
        for cond, body in c.while_calls:
            trip = max(comps.get(cond, CompCost()).max_int_constant, 1)
            trips[body] = trip
            walk(body, mult * trip, depth + 1)
            walk(cond, mult * (trip + 1), depth + 1)

    walk(entry, 1.0)
    return ModuleCost(flops=flops, bytes=bytes_, coll_bytes=dict(coll_b),
                      coll_count=dict(coll_c), trip_counts=trips,
                      dot_sources=dict(dot_src), coll_sources=dict(coll_src))
