"""Parameter counting: total and active (MoE) — used for MODEL_FLOPS."""
from __future__ import annotations

import numpy as np

from repro.models.config import ModelConfig


def _attn_params(cfg: ModelConfig) -> int:
    d, H, Kv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, \
        cfg.resolved_head_dim
    n = d * H * Dh + 2 * d * Kv * Dh + H * Dh * d
    if cfg.qkv_bias:
        n += H * Dh + 2 * Kv * Dh
    return n


def _mlp_params(cfg: ModelConfig, d_ff: int) -> int:
    d = cfg.d_model
    if cfg.mlp_type in ("swiglu", "geglu"):
        return 3 * d * d_ff
    return 2 * d * d_ff


def _ssm_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    s = cfg.ssm
    Di = s.expand * d
    R = s.dt_rank or -(-d // 16)
    N = s.d_state
    return (d * 2 * Di + s.d_conv * Di + Di + Di * (R + 2 * N)
            + R * Di + Di + Di * N + Di + Di * d)


def _rec_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    W = cfg.hybrid.lru_width or d
    Kc = cfg.hybrid.conv_width
    lru = 2 * d * W + Kc * W + W + 2 * W * W + 3 * W + W * d
    return lru + _mlp_params(cfg, cfg.d_ff)


def param_count(cfg: ModelConfig, active_only: bool = False) -> int:
    """Total (or MoE-active) parameter count, embeddings included."""
    embed = cfg.vocab_size * cfg.d_model
    if not cfg.tie_embeddings:
        embed *= 2
    per_layer_norms = 2 * cfg.d_model

    if cfg.family in ("dense", "vlm"):
        layer = _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff) + \
            per_layer_norms
        total = cfg.n_layers * layer
    elif cfg.family == "moe":
        m = cfg.moe
        n_moe = cfg.n_layers - (1 if m.first_layer_dense else 0)
        router = cfg.d_model * m.n_experts
        experts_total = m.n_experts * _mlp_params(cfg, m.d_ff_expert) / \
            (3 if cfg.mlp_type in ("swiglu", "geglu") else 2) * \
            (3 if cfg.mlp_type in ("swiglu", "geglu") else 2)
        experts_total = m.n_experts * _mlp_params(cfg, m.d_ff_expert)
        experts_active = m.top_k * _mlp_params(cfg, m.d_ff_expert)
        shared = (_mlp_params(cfg, m.d_ff_shared) if m.n_shared else 0)
        moe_layer = _attn_params(cfg) + router + shared + per_layer_norms
        total = n_moe * (moe_layer +
                         (experts_active if active_only else experts_total))
        if m.first_layer_dense:
            total += _attn_params(cfg) + _mlp_params(cfg, m.d_ff_dense) + \
                per_layer_norms
    elif cfg.family == "ssm":
        total = cfg.n_layers * (_ssm_params(cfg) + cfg.d_model)
    elif cfg.family == "hybrid":
        nt = cfg.n_layers // 3
        rem = cfg.n_layers - 3 * nt
        attn_layer = _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff) + \
            per_layer_norms
        rec_layer = _rec_params(cfg) + per_layer_norms
        total = nt * (2 * rec_layer + attn_layer) + rem * rec_layer
    elif cfg.family == "encdec":
        enc_layer = _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff) + \
            per_layer_norms
        dec_layer = 2 * _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff) + \
            3 * cfg.d_model
        total = cfg.encdec.n_encoder_layers * enc_layer + \
            cfg.n_layers * dec_layer
    else:
        raise ValueError(cfg.family)
    return int(total + embed + cfg.d_model)


def active_param_count(cfg: ModelConfig) -> int:
    return param_count(cfg, active_only=True)
