"""Pytest config. NOTE: no XLA device-count flag here — smoke tests and
benches must see 1 device (the 512-device override lives ONLY in
launch/dryrun.py and subprocess-based sharding tests).

Hypothesis fallback: three modules use property-based tests. When the
`hypothesis` package is absent (it is not baked into every image — see
requirements-dev.txt) we install a minimal stub BEFORE collection, so the
modules import cleanly and every @given test reports SKIPPED instead of
the whole module erroring out of collection.
"""
import os
import sys
import types

import pytest

# Hermeticity: a user-level autotune cache could route the ops.* wrappers
# to the XLA reference impl, turning every kernel-vs-oracle test vacuous.
# Tests always run the default (Pallas) configs; autotune-specific tests
# set REPRO_AUTOTUNE explicitly per-case via monkeypatch.
os.environ.setdefault("REPRO_AUTOTUNE", "off")

try:  # real hypothesis wins whenever it is installed
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - depends on environment
    class _AnyStrategy:
        """Stands in for any strategy object/combinator; tests never run."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

        def __repr__(self):
            return "<hypothesis stub strategy>"

    _ANY = _AnyStrategy()

    def _given(*_args, **_kwargs):
        def deco(fn):
            # zero-arg wrapper WITHOUT functools.wraps: copying __wrapped__
            # would make pytest introspect the original signature and hunt
            # for fixtures named after the hypothesis-provided arguments.
            def wrapper():
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def _settings(*_args, **_kwargs):
        if _args and callable(_args[0]) and not _kwargs:
            return _args[0]  # bare @settings
        return lambda fn: fn

    def _assume(condition):
        return bool(condition)

    _hyp = types.ModuleType("hypothesis")
    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _ANY  # PEP 562: st.<anything>
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.assume = _assume
    _hyp.strategies = _st
    _hyp.HealthCheck = _ANY
    _hyp.__stub__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device subprocess tests (deselect with "
        "-m 'not slow')")
