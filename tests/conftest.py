"""Pytest config. NOTE: no XLA device-count flag here — smoke tests and
benches must see 1 device (the 512-device override lives ONLY in
launch/dryrun.py and subprocess-based sharding tests)."""
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: multi-device subprocess tests (deselect with "
        "-m 'not slow')")
