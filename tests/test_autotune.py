"""Autotuner contract tests (kernels/autotune + the ops.py dispatch):
cache round-trip, corrupt/stale-entry fallback, resolve precedence,
REPRO_KERNELS_INTERPRET resolution, and the committed BENCH_kernels.json
headline guard."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture
def tuned_env(tmp_path, monkeypatch):
    """Autotuning ON against a private empty cache file."""
    cache = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE", "on")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(cache))
    autotune.invalidate_cache()
    yield cache
    autotune.invalidate_cache()


# -- bucketing / keys ---------------------------------------------------------


def test_next_pow2():
    assert [autotune.next_pow2(v) for v in (1, 2, 3, 5, 8, 1000)] == \
        [1, 2, 4, 8, 8, 1024]


def test_shape_bucket_is_order_insensitive():
    assert autotune.shape_bucket(s=1000, p=37) == \
        autotune.shape_bucket(p=33, s=513)


def test_cache_key_distinguishes_dtype_and_backend():
    b = autotune.shape_bucket(s=256)
    k1 = autotune.cache_key("pcdn_direction", b, jnp.float32, "cpu")
    k2 = autotune.cache_key("pcdn_direction", b, jnp.bfloat16, "cpu")
    k3 = autotune.cache_key("pcdn_direction", b, jnp.float32, "tpu")
    assert len({k1, k2, k3}) == 3


# -- cache round-trip and fallback -------------------------------------------


def test_cache_round_trip(tuned_env):
    bucket = autotune.shape_bucket(s=512, p=128)
    cfg = {"impl": "xla", "block_s": 256, "block_p": 64}
    assert autotune.record("pcdn_direction", bucket, jnp.float32, cfg,
                           us=10.0, default_us=20.0, backend="cpu")
    assert tuned_env.exists()
    got = autotune.lookup("pcdn_direction", bucket, jnp.float32,
                          backend="cpu")
    assert got == cfg
    # a different bucket misses
    assert autotune.lookup("pcdn_direction",
                           autotune.shape_bucket(s=4096, p=128),
                           jnp.float32, backend="cpu") is None


def test_corrupt_cache_falls_back_to_defaults(tuned_env):
    tuned_env.write_text("{ not json !!!")
    autotune.invalidate_cache()
    bucket = autotune.shape_bucket(s=512, p=128)
    assert autotune.lookup("pcdn_direction", bucket, jnp.float32) is None
    assert autotune.resolve("pcdn_direction", bucket, jnp.float32) == \
        autotune.DEFAULTS["pcdn_direction"]


def test_wrong_version_cache_ignored(tuned_env):
    tuned_env.write_text(json.dumps({"version": 999, "entries": {
        "anything": {"config": {"impl": "xla"}}}}))
    autotune.invalidate_cache()
    assert autotune.lookup("pcdn_direction",
                           autotune.shape_bucket(s=512, p=128),
                           jnp.float32) is None


def test_stale_entry_falls_back_to_defaults(tuned_env):
    """Configs written by an older search space (unknown keys, values no
    longer candidates) must not crash — they resolve to the defaults."""
    bucket = autotune.shape_bucket(s=512, p=128)
    key = autotune.cache_key("pcdn_direction", bucket, jnp.float32, "cpu")
    stale_key_cfg = {"impl": "xla", "block_retired_axis": 4}
    stale_val_cfg = {"impl": "xla", "block_s": 999999}
    payload = {"version": autotune.CACHE_VERSION,
               "entries": {key: {"config": stale_key_cfg}}}
    tuned_env.write_text(json.dumps(payload))
    autotune.invalidate_cache()
    assert autotune.lookup("pcdn_direction", bucket, jnp.float32,
                           backend="cpu") is None
    payload["entries"][key]["config"] = stale_val_cfg
    tuned_env.write_text(json.dumps(payload))
    autotune.invalidate_cache()
    assert autotune.lookup("pcdn_direction", bucket, jnp.float32,
                           backend="cpu") is None
    assert autotune.resolve("pcdn_direction", bucket, jnp.float32) == \
        autotune.DEFAULTS["pcdn_direction"]


def test_autotune_off_ignores_cache(tuned_env, monkeypatch):
    bucket = autotune.shape_bucket(s=512, p=128)
    autotune.record("pcdn_direction", bucket, jnp.float32,
                    {"impl": "xla", "block_s": 256, "block_p": 64},
                    backend="cpu")
    monkeypatch.setenv("REPRO_AUTOTUNE", "off")
    assert autotune.lookup("pcdn_direction", bucket, jnp.float32,
                           backend="cpu") is None


def test_record_unwritable_path_returns_false(monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE",
                       "/proc/definitely/not/writable/cache.json")
    autotune.invalidate_cache()
    ok = autotune.record("pcdn_direction",
                         autotune.shape_bucket(s=512, p=128), jnp.float32,
                         {"impl": "xla", "block_s": 256, "block_p": 64})
    autotune.invalidate_cache()
    assert ok is False


# -- resolve precedence and dispatch ------------------------------------------


def test_resolve_precedence(tuned_env):
    """defaults <- cached winner <- non-None per-call overrides."""
    bucket = autotune.shape_bucket(s=512, p=128)
    base = autotune.resolve("pcdn_direction", bucket, jnp.float32)
    assert base == autotune.DEFAULTS["pcdn_direction"]
    autotune.record("pcdn_direction", bucket, jnp.float32,
                    {"impl": "xla", "block_s": 256, "block_p": 64})
    cached = autotune.resolve("pcdn_direction", bucket, jnp.float32)
    assert cached["impl"] == "xla" and cached["block_s"] == 256
    over = autotune.resolve("pcdn_direction", bucket, jnp.float32,
                            {"impl": "pallas", "block_s": None,
                             "block_p": 32})
    assert over["impl"] == "pallas"       # explicit override wins
    assert over["block_s"] == 256         # None override falls through
    assert over["block_p"] == 32


def test_cached_winner_changes_ops_dispatch(tuned_env, monkeypatch):
    """A persisted impl=xla winner reroutes the public wrapper."""
    s, P = 512, 128
    XB = jnp.asarray(np.random.default_rng(0).standard_normal((s, P)),
                     jnp.float32)
    u = jnp.ones((s,))
    v = jnp.ones((s,))
    w = jnp.zeros((P,))
    hits = []
    real = ops._direction_xla
    monkeypatch.setattr(ops, "_direction_xla",
                        lambda *a, **k: (hits.append(1), real(*a, **k))[1])
    ops.pcdn_direction(XB, u, v, w)
    assert not hits                        # default routes to pallas
    autotune.record("pcdn_direction", autotune.shape_bucket(s=s, p=P),
                    jnp.float32,
                    {"impl": "xla", "block_s": 512, "block_p": 128})
    ops.pcdn_direction(XB, u, v, w)
    assert hits                            # cached winner routes to xla


# -- tune() strategies (deterministic fake timer) -----------------------------


def _fake_cost(cfg):
    """Deterministic synthetic cost surface with its optimum off-default:
    xla beats pallas, bigger block_s is better."""
    us = 100.0
    if cfg["impl"] == "xla":
        us -= 50.0
    us -= (cfg.get("block_s") or 0) / 100.0
    us -= (cfg.get("block_p") or 0) / 1000.0
    return us


@pytest.mark.parametrize("strategy", ["exhaustive", "hillclimb"])
def test_tune_finds_winner_and_persists(tuned_env, monkeypatch, strategy):
    monkeypatch.setattr(autotune, "time_call",
                        lambda fn, repeats=5, warmup=1: fn())

    def runner(cfg):
        return lambda: _fake_cost(cfg)

    bucket = autotune.shape_bucket(s=1024, p=128)
    res = autotune.tune("pcdn_direction", runner, bucket, jnp.float32,
                        strategy=strategy, backend="faketest")
    # the surface is separable, so both strategies find the global
    # optimum: xla, largest block_s, largest block_p
    assert res.config == {"impl": "xla", "block_s": 1024, "block_p": 256}
    assert res.us <= res.default_us        # never worse than default
    assert res.speedup >= 1.0
    assert res.trajectory[0]["config"] == \
        autotune.DEFAULTS["pcdn_direction"]
    # persisted winner is immediately visible to lookup
    assert autotune.lookup("pcdn_direction", bucket, jnp.float32,
                           backend="faketest") == res.config


def test_tune_skips_infeasible_candidates(tuned_env, monkeypatch):
    monkeypatch.setattr(autotune, "time_call",
                        lambda fn, repeats=5, warmup=1: fn())

    def runner(cfg):
        if cfg["impl"] == "xla":
            raise RuntimeError("infeasible on this backend")
        return lambda: _fake_cost(cfg)

    res = autotune.tune("pcdn_direction", runner,
                        autotune.shape_bucket(s=1024, p=128), jnp.float32,
                        persist=False)
    assert res.config["impl"] == "pallas"


# -- REPRO_KERNELS_INTERPRET resolution ---------------------------------------


@pytest.fixture
def interpret_reset(monkeypatch):
    saved = ops.INTERPRET
    yield monkeypatch
    ops.INTERPRET = saved


def test_interpret_auto_mode(interpret_reset):
    """auto == compiled on TPU, interpreter everywhere else."""
    interpret_reset.setenv("REPRO_KERNELS_INTERPRET", "auto")
    ops.INTERPRET = None
    assert ops.interpret_mode() is (jax.default_backend() != "tpu")


def test_interpret_env_unset_behaves_as_auto(interpret_reset):
    interpret_reset.delenv("REPRO_KERNELS_INTERPRET", raising=False)
    ops.INTERPRET = None
    assert ops.interpret_mode() is (jax.default_backend() != "tpu")


@pytest.mark.parametrize("env,expect", [("1", True), ("true", True),
                                        ("0", False), ("false", False),
                                        ("off", False)])
def test_interpret_env_forced(interpret_reset, env, expect):
    interpret_reset.setenv("REPRO_KERNELS_INTERPRET", env)
    ops.INTERPRET = None
    assert ops.interpret_mode() is expect


def test_interpret_legacy_assignment_short_circuits(interpret_reset):
    """`ops.INTERPRET = x` (the pre-env API) overrides the env var."""
    interpret_reset.setenv("REPRO_KERNELS_INTERPRET", "1")
    ops.INTERPRET = False
    assert ops.interpret_mode() is False
    ops.INTERPRET = True
    assert ops.interpret_mode() is True


def test_backend_tag_reflects_interpret(interpret_reset):
    ops.INTERPRET = True
    assert autotune.backend_tag().endswith("-interp")
    ops.INTERPRET = False
    assert not autotune.backend_tag().endswith("-interp")


# -- committed headline artifact guard ----------------------------------------


def _load_headline():
    path = os.path.join(REPO_ROOT, "BENCH_kernels.json")
    if not os.path.exists(path):
        pytest.skip("BENCH_kernels.json not committed yet")
    with open(path) as fh:
        return json.load(fh)


def test_bench_kernels_headline_tuned_never_worse():
    """The committed artifact must report tuned <= default for EVERY
    kernel x shape x dtype cell (the autotuner always measures the
    default, so a regression here means the artifact is stale or the
    tuner broke)."""
    bench = _load_headline()
    assert bench["cells"], "empty benchmark artifact"
    for c in bench["cells"]:
        assert c["tuned"]["us"] <= c["default"]["us"] * 1.001, \
            f"{c['kernel']} {c['shape']} {c['dtype']}: tuned " \
            f"{c['tuned']['us']}us > default {c['default']['us']}us"


def test_bench_kernels_headline_speedup_floor():
    """At least one cell shows the >= 1.3x tuned-over-default headline."""
    bench = _load_headline()
    best = max(c["speedup"] for c in bench["cells"])
    assert best >= 1.3, f"best speedup {best:.2f} < 1.3"


def test_bench_kernels_bf16_study_within_envelope():
    """The committed bf16-vs-fp32 matched-iteration study must sit inside
    the envelope the --dtype bf16 CLI gate promises (<= 1e-3)."""
    bench = _load_headline()
    study = bench.get("bf16_study")
    if study is None:
        pytest.skip("artifact carries no bf16 study")
    assert study["max_objective_rel_diff"] <= study["envelope_rel_diff"]
    assert study["pass"] is True


def test_bench_kernels_roofline_terms_present():
    bench = _load_headline()
    for c in bench["cells"]:
        r = c["roofline"]
        assert r["bound"] in ("compute", "memory")
        assert r["flops"] > 0 and r["bytes"] > 0
        assert r["t_compute_us"] >= 0 and r["t_memory_us"] >= 0
