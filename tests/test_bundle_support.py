"""Support-restricted bundle step equivalence suite (DESIGN.md §11).

The support-scoped line search / margin maintenance must be a pure
re-scoping of the full pass: phi(z_i + alpha * 0) - phi(z_i) == 0
wherever the bundle touches no nonzero of row i, so the accepted alpha,
the per-bundle n_steps, and the whole objective trajectory must match
the full-scope solver across losses, layouts, shrink on/off, and the
sharded 1x1-mesh backend. Plus the row-support primitive itself, the
fused `pcdn_bundle` kernel vs its unfused pipeline, and the
BENCH_bundle.json headline guard.
"""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import PCDNConfig, make_problem, solve
from repro.core import bundles as B
from repro.core.design_matrix import padded_row_support
from repro.core.linesearch import (ArmijoParams, armijo_batched,
                                   armijo_chunked, candidate_alphas,
                                   objective_delta)
from repro.core.losses import get_loss
from repro.core.pcdn import make_bundle_step, resolve_ls_scope
from repro.data import make_classification

RNG = np.random.default_rng(11)


def _problem_pair(s=96, n=70, sparsity=0.95, loss="logistic", l2=0.0,
                  seed=3):
    X, y, _ = make_classification(s, n, sparsity=sparsity, corr=0.3,
                                  seed=seed)
    pd = make_problem(X, y, c=1.0, loss=loss, elastic_net_l2=l2)
    ps = make_problem(X, y, c=1.0, loss=loss, elastic_net_l2=l2,
                      layout="padded_csc")
    return pd, ps


# -- the row-support primitive ------------------------------------------------

def test_padded_row_support_unique_sorted_sentinel():
    s = 50
    rows = jnp.asarray(RNG.integers(0, s + 1, size=(8, 6)), jnp.int32)
    sup = padded_row_support(rows, s)
    sup_np = np.asarray(sup.support)
    assert sup_np.shape == (48,)
    assert np.all(np.diff(sup_np) >= 0)                    # sorted
    real = sup_np[sup_np < s]
    assert len(real) == len(set(real.tolist()))            # unique
    assert set(real.tolist()) == set(
        r for r in np.asarray(rows).ravel().tolist() if r < s)
    # pos maps every entry back to its own row id
    assert np.array_equal(sup_np[np.asarray(sup.pos)], np.asarray(rows))


def test_slab_matvec_support_matches_dense_delta():
    _, ps = _problem_pair(seed=5)
    design = ps.design
    idx = jnp.asarray(RNG.permutation(70)[:16], jnp.int32)
    slab = design.gather_slab(idx)
    sup = design.slab_row_support(slab)
    d = jnp.asarray(RNG.standard_normal(16), jnp.float32)
    dense = design.slab_matvec(slab, d)
    delta_R = design.slab_matvec_support(slab, sup.pos, d)
    scattered = design.scatter_support(jnp.zeros_like(dense), sup.support,
                                       delta_R)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(scattered))


# -- scope resolution ---------------------------------------------------------

def test_resolve_scope_rules():
    from repro.core.pcdn import AUTO_SUPPORT_MARGIN

    pd, ps = _problem_pair(s=2048, n=128, sparsity=0.995, seed=41)
    k = ps.design.k_max
    p_small = max(1, ps.n_samples // (AUTO_SUPPORT_MARGIN * k))  # margin ok
    p_big = ps.n_samples // (AUTO_SUPPORT_MARGIN * k) + 1        # margin not
    assert resolve_ls_scope(PCDNConfig(P=8), pd) == "full"       # dense auto
    assert resolve_ls_scope(PCDNConfig(P=p_small), ps) == "support"
    assert resolve_ls_scope(PCDNConfig(P=p_big), ps) == "full"
    assert resolve_ls_scope(PCDNConfig(P=p_big, ls_scope="support"),
                            ps) == "support"                     # forced
    assert resolve_ls_scope(PCDNConfig(P=p_small, ls_scope="full"),
                            ps) == "full"
    with pytest.raises(ValueError):
        resolve_ls_scope(PCDNConfig(P=8, ls_scope="support"), pd)


# -- per-step equivalence: identical accepted alpha and n_steps ---------------

@pytest.mark.parametrize("loss", ["logistic", "squared_hinge", "squared"])
@pytest.mark.parametrize("l2", [0.0, 0.3])
def test_bundle_step_support_matches_full(loss, l2):
    _, ps = _problem_pair(loss=loss, l2=l2, seed=17)
    n, s = ps.n_features, ps.n_samples
    w = jnp.asarray(RNG.standard_normal(n).astype(np.float32))
    w = w * (RNG.random(n) < 0.4)
    z = ps.margins(w)
    step_full = make_bundle_step(ps, PCDNConfig(P=16, ls_scope="full"))
    step_sup = make_bundle_step(ps, PCDNConfig(P=16, ls_scope="support"))
    step_ker = make_bundle_step(ps, PCDNConfig(P=16, ls_scope="support",
                                               use_kernels=True))
    idxs = B.partition(jax.random.PRNGKey(0), n, 16)
    cf = cs = ck = (w, z)
    for t in range(idxs.shape[0]):
        cf, (qf, af) = step_full(cf, idxs[t])
        cs, (qs, a_s) = step_sup(cs, idxs[t])
        ck, (qk, ak) = step_ker(ck, idxs[t])
        assert float(af) == float(a_s), (t, float(af), float(a_s))
        assert int(qf) == int(qs)
        np.testing.assert_allclose(float(af), float(ak), rtol=0, atol=0)
        assert int(qf) == int(qk)
    np.testing.assert_allclose(np.asarray(cf[0]), np.asarray(cs[0]),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(cf[1]), np.asarray(cs[1]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(cs[0]), np.asarray(ck[0]),
                               rtol=1e-5, atol=1e-6)


# -- trajectory equivalence: losses x layouts x shrink ------------------------

@pytest.mark.parametrize("loss", ["logistic", "squared_hinge", "squared"])
@pytest.mark.parametrize("shrink", [False, True])
def test_trajectories_support_vs_full(loss, shrink):
    """Support-scoped sparse == full-scope sparse == full-scope dense."""
    pd, ps = _problem_pair(loss=loss, seed=23)
    kw = dict(P=24, max_outer=12, seed=4, shrink=shrink)
    rd = solve(pd, PCDNConfig(ls_scope="full", **kw))
    rf = solve(ps, PCDNConfig(ls_scope="full", **kw))
    rs = solve(ps, PCDNConfig(ls_scope="support", **kw))
    np.testing.assert_allclose(rs.history.objective, rf.history.objective,
                               rtol=1e-6)
    np.testing.assert_allclose(rs.history.objective, rd.history.objective,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(rs.w), np.asarray(rf.w),
                               atol=1e-5)
    # n_steps equality is pinned per-step from identical carries in
    # test_bundle_step_support_matches_full; across whole trajectories
    # the CONVERGED iteration evaluates the Armijo check at its exact
    # boundary (d ~ 0 => f_delta ~ 0 <= sigma*alpha*Delta ~ 0), where
    # summation-order ulps can legitimately flip a candidate.


def test_chunked_equals_batched_linesearch():
    """armijo_chunked accepts the same alpha/n_steps as armijo_batched."""
    loss = get_loss("logistic")
    params = ArmijoParams()
    for seed in range(6):
        rng = np.random.default_rng(seed)
        s, P = 200, 12
        z = jnp.asarray(rng.standard_normal(s), jnp.float32)
        # large deltas force deep backtracking on some seeds
        delta = jnp.asarray(rng.standard_normal(s) * (10.0 ** seed),
                            jnp.float32)
        y = jnp.asarray(np.sign(rng.standard_normal(s)), jnp.float32)
        w_B = jnp.asarray(rng.standard_normal(P), jnp.float32)
        d_B = jnp.asarray(rng.standard_normal(P), jnp.float32)
        Delta = jnp.asarray(-abs(rng.standard_normal()), jnp.float32)
        rb = armijo_batched(loss, 1.0, z, delta, y, w_B, d_B, Delta, params)
        rc = armijo_chunked(loss, 1.0, z, delta, y, w_B, d_B, Delta, params)
        if bool(rb.accepted):
            assert float(rb.alpha) == float(rc.alpha)
            assert int(rb.n_steps) == int(rc.n_steps)
        assert bool(rb.accepted) == bool(rc.accepted)


# -- sharded 1x1-mesh backend -------------------------------------------------

def _csr_of(X):
    from repro.data.libsvm import CSRMatrix
    rows, cols = np.nonzero(X)
    vals = X[rows, cols].astype(np.float32)
    indptr = np.concatenate(
        [[0], np.cumsum(np.bincount(rows, minlength=X.shape[0]))]
    ).astype(np.int64)
    return CSRMatrix(vals, cols.astype(np.int32), indptr, X.shape)


def test_sharded_1x1_support_matches_full():
    from jax.sharding import Mesh
    from repro.engine import ShardedBackend, ShardedPCDNConfig
    from repro.engine import loop as engine_loop

    X, y, _ = make_classification(120, 80, sparsity=0.96, corr=0.3, seed=9)
    csr = _csr_of(X)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    res = {}
    for scope in ("full", "support"):
        cfg = ShardedPCDNConfig(P_local=16, c=1.0, seed=5, ls_scope=scope)
        be = ShardedBackend(csr, y, mesh, cfg, layout="padded_csc")
        res[scope] = engine_loop.solve(be, 1.0, max_outer=10, tol_kkt=1e-6)
    np.testing.assert_allclose(res["support"].history.objective,
                               res["full"].history.objective, rtol=1e-6)
    np.testing.assert_array_equal(res["support"].history.ls_steps,
                                  res["full"].history.ls_steps)


def test_sharded_support_requires_batched_ls():
    from jax.sharding import Mesh
    from repro.engine import ShardedBackend, ShardedPCDNConfig

    X, y, _ = make_classification(60, 40, sparsity=0.9, corr=0.3, seed=2)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    cfg = ShardedPCDNConfig(P_local=8, c=1.0, ls_scope="support",
                            ls_kind="backtracking")
    with pytest.raises(ValueError, match="ls_scope='support'"):
        ShardedBackend(_csr_of(X), y, mesh, cfg, layout="padded_csc")


# -- fused kernel vs the unfused pipeline -------------------------------------

@pytest.mark.parametrize("kind", ["logistic", "squared_hinge", "squared"])
@pytest.mark.parametrize("l2", [0.0, 0.2])
def test_pcdn_bundle_kernel_matches_ref(kind, l2):
    from repro.kernels import ops, ref

    _, ps = _problem_pair(s=130, n=90, sparsity=0.93, seed=31)
    design = ps.design
    idx = jnp.asarray(
        np.concatenate([RNG.permutation(90)[:13], [90, 90, 90]]),
        jnp.int32)                                  # ragged: 3 sentinels
    slab = design.gather_slab(idx)
    sup = design.slab_row_support(slab)
    z = jnp.asarray(RNG.standard_normal(130), jnp.float32)
    y = jnp.asarray(np.sign(RNG.standard_normal(130)), jnp.float32)
    z_R = jnp.take(z, sup.support, mode="fill", fill_value=0)
    y_R = jnp.take(y, sup.support, mode="fill", fill_value=1)
    w_B, _ = B.gather_vec(
        jnp.asarray(RNG.standard_normal(90), jnp.float32), idx)
    alphas = candidate_alphas(ArmijoParams(), jnp.float32)
    args = (slab.vals, sup.pos, z_R, y_R, w_B, alphas, 1.3)
    kw = dict(kind=kind, l2=l2, sigma=0.01, gamma=0.0)
    uw1, uz1, a1, q1 = ops.pcdn_bundle(*args, **kw)
    uw2, uz2, a2, q2 = ref.pcdn_bundle_ref(*args, **kw)
    assert float(a1) == float(a2)
    assert int(q1) == int(q2)
    np.testing.assert_allclose(uw1, uw2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(uz1, uz2, rtol=1e-5, atol=1e-6)


# -- hypothesis properties ----------------------------------------------------

@given(st.integers(0, 2 ** 31 - 1),
       st.sampled_from(["logistic", "squared_hinge", "squared"]))
@settings(max_examples=25, deadline=None)
def test_objective_delta_zero_alpha_is_zero(seed, kind):
    """F(w + 0*d) - F(w) must be EXACTLY zero, not merely small — the
    support restriction's correctness rests on this bitwise identity."""
    rng = np.random.default_rng(seed)
    s, P = 40, 6
    loss = get_loss(kind)
    z = jnp.asarray(rng.standard_normal(s) * 5, jnp.float32)
    delta = jnp.asarray(rng.standard_normal(s) * 100, jnp.float32)
    y = jnp.asarray(np.sign(rng.standard_normal(s)), jnp.float32)
    w_B = jnp.asarray(rng.standard_normal(P), jnp.float32)
    d_B = jnp.asarray(rng.standard_normal(P), jnp.float32)
    out = objective_delta(loss, 2.0, z, delta, y, w_B, d_B,
                          jnp.float32(0.0), l2=0.5)
    assert float(out) == 0.0


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_fused_bundle_matches_unfused_property(seed):
    """Random slabs: the fused kernel's update == the jnp support path."""
    from repro.kernels import ops, ref

    rng = np.random.default_rng(seed)
    s, P, k = 60, 9, 5
    rows = jnp.asarray(rng.integers(0, s + 1, size=(P, k)), jnp.int32)
    vals = jnp.asarray(rng.standard_normal((P, k)).astype(np.float32) *
                       (np.asarray(rows) < s))
    sup = padded_row_support(rows, s)
    z = jnp.asarray(rng.standard_normal(s), jnp.float32)
    y = jnp.asarray(np.sign(rng.standard_normal(s)), jnp.float32)
    z_R = jnp.take(z, sup.support, mode="fill", fill_value=0)
    y_R = jnp.take(y, sup.support, mode="fill", fill_value=1)
    w_B = jnp.asarray(rng.standard_normal(P), jnp.float32)
    alphas = candidate_alphas(ArmijoParams(), jnp.float32)
    args = (vals, sup.pos, z_R, y_R, w_B, alphas, 1.0)
    uw1, uz1, a1, q1 = ops.pcdn_bundle(*args)
    uw2, uz2, a2, q2 = ref.pcdn_bundle_ref(*args)
    assert float(a1) == float(a2) and int(q1) == int(q2)
    np.testing.assert_allclose(uw1, uw2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(uz1, uz2, rtol=1e-5, atol=1e-6)


# -- the committed benchmark headline -----------------------------------------

def test_bench_bundle_reports_support_headline():
    """The committed BENCH_bundle.json must report the acceptance number:
    support-scoped line search >= 2x over full-scope at sparsity 0.999
    (full-run figures; CI smoke runs only overwrite the file AFTER the
    test stage)."""
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "BENCH_bundle.json")
    if not os.path.exists(path):
        pytest.skip("no BENCH_bundle.json checked out")
    payload = json.load(open(path))
    if payload.get("smoke"):
        pytest.skip("local --smoke run overwrote the committed full-run "
                    "figures; the acceptance number is pinned on full runs")
    assert payload["linesearch_speedup_at_0999"] >= 2.0
    assert payload["bundle_step_speedup_at_0999"] >= 2.0
    assert payload["objective_traj_max_rel_diff"] <= 1e-6
