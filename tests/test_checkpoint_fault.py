"""Checkpoint manager + fault-tolerant runner tests (now living in
`repro.fault`; the deprecated `repro.train.*` shim paths are pinned at
the bottom)."""
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.fault.checkpoint import CheckpointManager
from repro.fault.runner import (FaultTolerantRunner, RunnerConfig,
                                StepFailure)


def tree_eq(a, b):
    return all(bool(jnp.all(x == y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


@pytest.fixture()
def state():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "opt": {"mu": jnp.ones((3, 4)) * 0.5,
                    "step": jnp.asarray(7, jnp.int32)}}


def test_roundtrip(tmp_path, state):
    cm = CheckpointManager(str(tmp_path))
    cm.save(3, state)
    step, restored = cm.restore(state)
    assert step == 3
    assert tree_eq(state, restored)


def test_incomplete_checkpoint_ignored(tmp_path, state):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, state)
    # simulate a crashed writer: step dir without COMMITTED
    bad = os.path.join(str(tmp_path), "step_00000009")
    os.makedirs(bad)
    assert cm.latest_step() == 1


def test_gc_keeps_newest(tmp_path, state):
    cm = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        cm.save(s, state)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(str(tmp_path))
                   if d.startswith("step_"))
    assert steps == [3, 4]


def test_restore_casts_dtype(tmp_path, state):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, state)
    like = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float16)
                        if x.dtype == jnp.float32 else x, state)
    _, restored = cm.restore(like)
    assert restored["w"].dtype == jnp.float16


# -- fault-tolerant runner ----------------------------------------------------

def make_step():
    def step(state, idx):
        w = state["w"] + idx + 1
        return {"w": w}, {"loss": float(jnp.sum(w))}
    return step


def expected_after(n):
    w = 0.0
    for i in range(n):
        w += i + 1
    return w


def test_runner_no_faults(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    r = FaultTolerantRunner(make_step(), {"w": jnp.zeros(())}, cm,
                            RunnerConfig(ckpt_every=3))
    r.run(7)
    assert float(r.state["w"]) == expected_after(7)


def test_runner_crash_recovery_deterministic(tmp_path):
    """A crash mid-run restores the checkpoint and converges to the exact
    fault-free state (steps are pure functions of (state, idx))."""
    cm = CheckpointManager(str(tmp_path))
    crashed = {"done": False}

    def inject(step, attempt):
        if step == 5 and attempt == 0 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("injected device loss")

    r = FaultTolerantRunner(make_step(), {"w": jnp.zeros(())}, cm,
                            RunnerConfig(ckpt_every=2),
                            inject_fault=inject)
    r.run(8)
    assert float(r.state["w"]) == expected_after(8)
    kinds = [e["kind"] for e in r.events]
    assert "crash" in kinds and "restore" in kinds


def test_runner_resume_from_disk(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    r1 = FaultTolerantRunner(make_step(), {"w": jnp.zeros(())}, cm,
                             RunnerConfig(ckpt_every=2))
    r1.run(4)  # final save at step 4
    # brand-new runner (process restart) resumes from step 4
    r2 = FaultTolerantRunner(make_step(), {"w": jnp.zeros(())}, cm,
                             RunnerConfig(ckpt_every=2))
    assert r2.start_step == 4
    r2.run(4)
    assert float(r2.state["w"]) == expected_after(8)


def test_straggler_reissue(tmp_path):
    """A step exceeding the deadline is re-issued and succeeds."""
    import time as _t
    cm = CheckpointManager(str(tmp_path))
    slow = {"hit": False}

    def step(state, idx):
        if idx == 6 and not slow["hit"]:
            slow["hit"] = True
            _t.sleep(0.6)
        return {"w": state["w"] + idx + 1}, {}

    r = FaultTolerantRunner(
        step, {"w": jnp.zeros(())}, cm,
        RunnerConfig(ckpt_every=100, straggler_factor=3.0,
                     min_deadline_s=0.3, warmup_steps=2))
    r.run(8)
    assert float(r.state["w"]) == expected_after(8)
    assert any(e["kind"] == "straggler" for e in r.events)


def test_runner_gives_up_after_retries(tmp_path):
    cm = CheckpointManager(str(tmp_path))

    def bad_step(state, idx):
        raise RuntimeError("always broken")

    r = FaultTolerantRunner(bad_step, {"w": jnp.zeros(())}, cm,
                            RunnerConfig(max_retries_per_step=2))
    with pytest.raises(StepFailure):
        r.run(1)


# -- deprecated shim paths ----------------------------------------------------

def test_train_shims_warn_and_reexport():
    """The old `repro.train.checkpoint` / `.fault_tolerance` module paths
    still import (with a DeprecationWarning) and expose the same objects
    `repro.fault` does."""
    import importlib
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        tc = importlib.import_module("repro.train.checkpoint")
        tf = importlib.import_module("repro.train.fault_tolerance")
        importlib.reload(tc)
        importlib.reload(tf)
    assert any(issubclass(w.category, DeprecationWarning) for w in rec)
    assert tc.CheckpointManager is CheckpointManager
    assert tf.FaultTolerantRunner is FaultTolerantRunner
    # the package-level names point at the promoted implementations too
    import repro.train as train
    assert train.CheckpointManager is CheckpointManager
    assert train.FaultTolerantRunner is FaultTolerantRunner
