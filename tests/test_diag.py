"""Solver-health diagnostics (DESIGN.md section 15): per-feature KKT
attribution vs direct recomputation on both design layouts, the
structural extra-output dispatch, backtrack forensics, the certified-P
estimator vs numpy.linalg.eigvalsh, the health-report CLI, the metrics
JSONL validator exit codes, and the perf-regression sentinel."""
import dataclasses
import importlib.util
import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro import obs
from repro.core import PCDNConfig, make_problem, solve
from repro.data import make_classification
from repro.diag import forensics, kkt, safep
from repro.diag import report as diag_report
from repro.engine import (LocalBackend, ShardedBackend, ShardedPCDNConfig,
                          loop as engine_loop)
from repro.launch import common as launch_common
from repro.launch.mesh import make_host_mesh

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _obs_off():
    """Diagnostics must not depend on (or leak into) the telemetry
    planes — same process-state hygiene as test_obs."""
    obs.disable()
    obs.registry.reset()
    yield
    obs.disable()
    obs.registry.reset()


@pytest.fixture(scope="module")
def data():
    return make_classification(220, 96, sparsity=0.8, corr=0.3, seed=5)


# ---------------------------------------------------------------------------
# KKT attribution: the record_kkt_vec harvest (DESIGN.md section 15.1)


@pytest.mark.parametrize("layout", ["dense", "padded_csc"])
def test_kkt_vec_matches_direct_recomputation(data, layout):
    """The final recorded violation row must equal a direct dense
    recomputation of the minimum-norm subgradient at the final iterate —
    on BOTH design layouts."""
    X, y, _ = data
    prob = make_problem(X, y, c=1.0, layout=layout)
    cfg = PCDNConfig(P=32, max_outer=8, tol_kkt=0.0, seed=0,
                     record_kkt_vec=True)
    res = solve(prob, cfg)
    h = res.history
    assert h.kkt_vec is not None
    assert h.kkt_vec.shape == (res.n_outer, prob.n_features)
    w = jnp.asarray(res.w)
    g = prob.full_grad(prob.design.matvec(w), w)
    direct = np.asarray(prob.kkt_violation_from_grad(w, g), np.float64)
    np.testing.assert_allclose(h.kkt_vec[-1].astype(np.float64), direct,
                               atol=1e-5)
    # the scalar stop criterion is the max of the recorded vector, at
    # every iteration, not just the last
    np.testing.assert_allclose(h.kkt_vec.max(axis=1), h.kkt, rtol=1e-5,
                               atol=1e-7)


def test_record_kkt_vec_off_is_bit_identical_and_registry_silent(data):
    """The acceptance guarantee: the harvest is pure passthrough — same
    iterates to the bit with it off, and no registry activity either
    way."""
    X, y, _ = data
    prob = make_problem(X, y, c=1.0)
    cfg = PCDNConfig(P=32, max_outer=10, tol_kkt=1e-8, seed=0)
    r_off = solve(prob, cfg)
    r_on = solve(prob, dataclasses.replace(cfg, record_kkt_vec=True))
    assert r_on.n_outer == r_off.n_outer
    np.testing.assert_array_equal(np.asarray(r_off.w), np.asarray(r_on.w))
    assert r_off.history.kkt_vec is None
    assert r_on.history.kkt_vec is not None
    assert obs.registry.get_registry().empty


def test_structural_dispatch_arity_combinations(data):
    """Extra outer outputs dispatch by structure: a 2-tuple is the
    (q, alpha) aux, a bare array the violation vector — in any
    combination after the 9-tuple contract."""
    X, y, _ = data
    prob = make_problem(X, y, c=1.0)
    n = prob.n_features
    b = n // 32 + (n % 32 > 0)
    cfg = PCDNConfig(P=32, max_outer=3, seed=0)

    def outer_of(c):
        bk = LocalBackend(prob, c)
        st = bk.init_state()
        return bk.outer(st.w, st.z, st.key, st.active, jnp.asarray(True),
                        jnp.asarray(1.0, st.w.dtype))

    out = outer_of(dataclasses.replace(cfg, record_kkt_vec=True))
    assert len(out) == 10 and out[9].shape == (n,)

    out = outer_of(dataclasses.replace(cfg, record_aux=True,
                                       record_kkt_vec=True))
    assert len(out) == 11
    q, alpha = out[9]
    assert q.shape == (b,) and alpha.shape == (b,)
    assert out[10].shape == (n,)

    # both planes land in history from one solve
    res = solve(prob, dataclasses.replace(cfg, max_outer=5, tol_kkt=0.0,
                                          record_aux=True,
                                          record_kkt_vec=True))
    h = res.history
    assert h.bundle_q is not None and h.kkt_vec is not None
    assert h.bundle_q.shape[0] == h.kkt_vec.shape[0] == res.n_outer


def test_sharded_1x1_kkt_vec_matches_local(data):
    X, y, _ = data
    mesh = make_host_mesh(1, 1)
    cfg = ShardedPCDNConfig(P_local=32, c=1.0, seed=0,
                            record_kkt_vec=True)
    backend = ShardedBackend(X, y, mesh, cfg)
    res = engine_loop.solve(backend, 1.0, max_outer=5, tol_kkt=0.0)
    h = res.history
    assert h.kkt_vec is not None
    assert h.kkt_vec.shape[0] == res.n_outer
    # per-shard violation of padded features is exactly zero and the max
    # reproduces the scalar stop series
    np.testing.assert_allclose(h.kkt_vec.max(axis=1), h.kkt, rtol=1e-5,
                               atol=1e-7)


def test_engine_callback_five_args(data):
    X, y, _ = data
    prob = make_problem(X, y, c=1.0)
    seen = []
    solve(prob, PCDNConfig(P=32, max_outer=4, tol_kkt=0.0, seed=0),
          callback=lambda k, w, f, kkt_f, mean_q: seen.append(
              (k, float(f), float(kkt_f), float(mean_q))))
    assert len(seen) == 4
    assert [s[0] for s in seen] == [0, 1, 2, 3]
    assert all(np.isfinite(s[1]) for s in seen)


def test_progress_callback_gate():
    class Args:
        progress = False
    assert launch_common.make_progress_callback(Args()) is None
    Args.progress = True
    cb = launch_common.make_progress_callback(Args())
    assert cb is not None
    cb(3, None, 1.25, 1e-3, 0.5)  # 5-arg engine signature


# ---------------------------------------------------------------------------
# kkt analysis units


def _toy_series():
    # 3 iterations x 4 features, hand-chosen
    return np.array([[1.0, 0.5, 0.0, 2.0],
                     [0.5, 0.0, 0.1, 1.0],
                     [0.2, 0.0, 0.0, 0.6]])


def test_top_offenders_ranked_by_final():
    off = kkt.top_offenders(_toy_series(), k=2, tol=0.0)
    assert [o["feature"] for o in off] == [3, 0]
    assert off[0]["viol_final"] == 0.6
    assert off[0]["viol_max"] == 2.0
    assert off[0]["iters_violating"] == 3
    assert off[1]["iters_violating"] == 3


def test_violation_histogram_shape_contract():
    h = kkt.violation_histogram(_toy_series())
    assert h["count"] == 4
    assert h["zeros"] == 2          # features 1 and 2 end at exactly 0
    assert len(h["counts"]) == len(h["bounds"]) + 1
    assert sum(h["counts"]) == h["count"] - h["zeros"]
    assert h["max"] == 0.6


def test_active_churn_counts_crossings():
    ch = kkt.active_churn(_toy_series(), tol=0.3)
    assert ch["n_violating"] == [3, 2, 1]
    assert ch["entered"] == [0, 0, 0]
    assert ch["left"] == [0, 1, 1]
    assert ch["total_churn"] == 2


def test_attribution_block_is_json_ready():
    block = kkt.attribution(_toy_series(), tol=1e-3, top_k=3)
    json.dumps(block)  # must not raise
    assert block["n_iters"] == 3 and block["n_features"] == 4
    assert len(block["offenders"]) == 3


# ---------------------------------------------------------------------------
# backtrack forensics units


def test_backtrack_heatmap_masks_sentinels():
    q = np.array([[0, 2, -1, -1],
                  [1, 4, 0, -1]])
    h = forensics.backtrack_heatmap(q)
    assert h["bundles_ran"] == 5
    assert sum(h["depth_counts"]) == 5
    assert h["depth_counts"][4] == 1
    assert h["per_iter_max"] == [2.0, 4.0]
    # iteration 1: one of three live bundles at depth >= 3
    assert h["per_iter_deep_frac"][1] == pytest.approx(1.0 / 3.0)


def test_worst_bundles_and_alpha_trajectory():
    q = np.array([[0, 5], [3, -1]])
    worst = forensics.worst_bundles(q, k=2)
    assert worst[0] == {"iter": 0, "bundle": 1, "q": 5}
    assert worst[1] == {"iter": 1, "bundle": 0, "q": 3}
    a = forensics.alpha_trajectory(np.array([[1.0, 0.25],
                                             [0.5, np.nan]]))
    assert a["per_iter_min"] == [0.25, 0.5]


def test_divergence_postmortem_keys_and_growth():
    obj = [10.0, 8.0, 9.0, 30.0]
    pm = forensics.divergence_postmortem(
        obj, kkt=[1.0, 0.5, 2.0, 9.0], ls_steps=[1.0, 2.0, 5.0, 4.0],
        bundle_q=np.array([[0, 1], [1, 2], [5, 4], [3, 3]]),
        bundle_alpha=np.array([[1.0, 0.5], [0.5, 0.25],
                               [0.03125, 0.0625], [0.125, 0.125]]))
    assert pm["trip_iter"] == 3 and pm["onset_iter"] == 1
    assert pm["objective_growth"] == pytest.approx(22.0)
    assert pm["deepest_mean_q"] == 5.0
    assert pm["alpha_floor"] == pytest.approx(0.03125)
    assert pm["worst_bundles"][0]["q"] == 5
    json.dumps(pm)


def test_divergence_guard_attaches_postmortem():
    """A guard trip must come back with the post-mortem attached —
    driven through a synthetic outer whose objective blows up, so the
    trip is deterministic."""
    n, b = 8, 2
    objectives = iter([3.0, 2.0, 5.0, 50.0])

    def outer(w, z, key, active, recheck, c):
        f = next(objectives)
        q = jnp.full((b,), 4, jnp.int32)
        alpha = jnp.full((b,), 0.0625)
        viol = jnp.full((n,), 0.5)
        return (w, z, key, jnp.asarray(f), jnp.asarray(9.0),
                jnp.asarray(n), jnp.asarray(4.0), active,
                jnp.asarray(n), (q, alpha), viol)

    state = engine_loop.EngineState(
        w=jnp.zeros(n), z=jnp.zeros(4),
        key=jnp.zeros(2, jnp.uint32), active=jnp.ones(n, bool))
    _, res = engine_loop.run_outer_loop(
        outer, state, 1.0, max_outer=10, tol_kkt=1e-12,
        divergence_guard=lambda f: f > 10.0)
    assert res.diverged and not res.converged
    pm = res.postmortem
    assert pm is not None
    assert pm["trip_iter"] == 3 and pm["onset_iter"] == 1
    assert pm["objective_growth"] == pytest.approx(48.0)
    assert "heatmap" in pm and "alpha" in pm   # aux rode along
    assert res.history.kkt_vec is not None     # and the viol plane too
    json.dumps(pm)


# ---------------------------------------------------------------------------
# certified safe parallelism (DESIGN.md section 15.3)


@pytest.mark.parametrize("s,n,sparsity", [(60, 40, 0.0), (80, 50, 0.9)])
def test_power_iteration_matches_eigvalsh(s, n, sparsity):
    X, y, _ = make_classification(s, n, sparsity=sparsity, seed=7)
    for layout in ("dense", "padded_csc"):
        prob = make_problem(X, y, c=1.0, layout=layout)
        got = safep.power_iteration_rho(prob.design, n_iter=3000)
        Xd = np.asarray(X, np.float64) if layout == "dense" else \
            np.asarray(prob.design.to_dense(), np.float64)
        norms = np.linalg.norm(Xd, axis=0)
        norms[norms == 0] = 1.0
        Xn = Xd / norms
        rho_direct = float(np.linalg.eigvalsh(Xn.T @ Xn).max())
        assert got["converged"]
        assert got["rho"] == pytest.approx(rho_direct, rel=1e-4)


def test_omega_row_support_both_layouts():
    X, y, _ = make_classification(50, 30, sparsity=0.9, seed=3)
    direct = int(np.max(np.sum(np.asarray(X) != 0, axis=1)))
    for layout in ("dense", "padded_csc"):
        prob = make_problem(X, y, c=1.0, layout=layout)
        assert safep.omega_row_support(prob.design) == direct


def test_eso_and_spectral_edge_cases():
    # no coupling -> every coordinate independent -> tau = n
    assert safep.eso_safe_p(omega=1, n_features=64) == 64
    assert safep.eso_safe_p(omega=0, n_features=64) == 64
    assert safep.eso_safe_p(omega=5, n_features=1) == 1
    # dense coupling at beta_max=2: tau = 1 + (n-1)/(omega-1) = 2
    assert safep.eso_safe_p(omega=64, n_features=64) == 2
    assert safep.spectral_safe_p(rho=1.0, n_features=64) == 64
    assert safep.spectral_safe_p(rho=64.0, n_features=64) == 1
    assert safep.spectral_safe_p(rho=0.0, n_features=64) == 64


def test_certify_record_shape():
    X, y, _ = make_classification(40, 24, sparsity=0.5, seed=1)
    prob = make_problem(X, y, c=1.0)
    cert = safep.certify(prob.design, observed_p=8)
    assert cert["P_cert"] == max(cert["P_spectral"], cert["P_eso"])
    assert 1 <= cert["P_cert"] <= cert["n_features"]
    assert cert["observed_P"] == 8
    json.dumps(cert)


# ---------------------------------------------------------------------------
# report CLI (DESIGN.md section 15.4)


def _fake_report(tmp_path, with_postmortem=False):
    hist = {"outer_iter": [0, 1, 2],
            "objective": [3.0, 2.0, 1.5],
            "kkt": [1.0, 0.5, 0.1],
            "nnz": [20, 15, 12],
            "ls_steps": [0.0, 1.0, 0.5],
            "wall_time": [0.1, 0.2, 0.3],
            "n_active": [24, 24, 24],
            "bundle_q": [[0, 0], [1, 2], [0, 1]],
            "bundle_alpha": [[1.0, 1.0], [0.5, 0.25], [1.0, 0.5]],
            "kkt_vec": np.abs(
                np.random.default_rng(0).standard_normal((3, 24))
            ).tolist()}
    rep = {"provenance": {"solver": "pcdn", "P": 8, "tol_kkt": 1e-3},
           "loss": "logistic", "n_features": 24, "objective": 1.5,
           "converged": True, "nnz": 12, "seconds": 0.3,
           "history": hist}
    if with_postmortem:
        rep["postmortem"] = forensics.divergence_postmortem(
            hist["objective"], hist["kkt"], hist["ls_steps"],
            bundle_q=hist["bundle_q"], bundle_alpha=hist["bundle_alpha"])
    p = tmp_path / "report.json"
    p.write_text(json.dumps(rep))
    return p


def test_report_cli_renders_sections(tmp_path):
    rp = _fake_report(tmp_path, with_postmortem=True)
    out = tmp_path / "health.md"
    rc = diag_report.main(["--report", str(rp), "-o", str(out)])
    assert rc == 0
    md = out.read_text()
    for section in ("# Solver health report", "## Run summary",
                    "## Convergence", "## Top KKT offenders",
                    "## Backtrack forensics", "## Divergence post-mortem"):
        assert section in md, f"missing {section}"


def test_report_cli_requires_an_input():
    with pytest.raises(SystemExit) as exc:
        diag_report.main([])
    assert exc.value.code == 2


def test_build_payload_from_metrics_and_trace_only():
    records = [{"ts": "t", "metrics": {
        "counters": {"solver.outer_iters": 5},
        "gauges": {"solver.kkt": 0.1},
        "histograms": {}}}]
    trace = {"traceEvents": [
        {"name": "solve", "ph": "X", "ts": 0, "dur": 1000,
         "pid": 1, "tid": 1}]}
    payload = diag_report.build_payload(metrics_records=records,
                                        trace=trace)
    md = diag_report.render_markdown(payload)
    assert "## Metrics summary" in md and "## Trace summary" in md


# ---------------------------------------------------------------------------
# metrics JSONL validator (the CI gate)


def _good_record():
    return {"ts": "2026-01-01T00:00:00", "run": "r",
            "metrics": {"counters": {"a": 1}, "gauges": {"g": 0.5},
                        "histograms": {"h": {
                            "count": 2, "sum": 3.0, "min": 1.0,
                            "max": 2.0, "mean": 1.5, "p50": 1.0,
                            "p99": 2.0, "bounds": [1.5],
                            "counts": [1, 1]}}}}


def test_validate_metrics_record_rejects_bad_shapes():
    from repro.obs import validate as v
    v.validate_metrics_record(_good_record())
    for mutate in (
        lambda r: r.pop("ts"),
        lambda r: r["metrics"]["counters"].update(a="x"),
        lambda r: r["metrics"].update(extra={}),
        lambda r: r["metrics"]["histograms"]["h"].update(counts=[1]),
        lambda r: r["metrics"]["histograms"]["h"].update(count=5),
        lambda r: r["metrics"]["histograms"]["h"].update(bounds=[2, 1],
                                                        counts=[0, 1, 1],
                                                        count=2),
    ):
        r = json.loads(json.dumps(_good_record()))
        mutate(r)
        with pytest.raises(ValueError):
            v.validate_metrics_record(r)


def test_validate_metrics_file_line_numbers(tmp_path):
    from repro.obs import validate as v
    p = tmp_path / "run.jsonl"
    p.write_text(json.dumps(_good_record()) + "\nnot json\n")
    with pytest.raises(ValueError, match="line 2"):
        v.validate_metrics_file(str(p))
    (tmp_path / "empty.jsonl").write_text("")
    with pytest.raises(ValueError, match="empty"):
        v.validate_metrics_file(str(tmp_path / "empty.jsonl"))


def test_validate_cli_exit_codes_metrics(tmp_path):
    good = tmp_path / "good.jsonl"
    good.write_text(json.dumps(_good_record()) + "\n")
    bad = tmp_path / "bad.jsonl"
    bad.write_text(json.dumps({"no_ts": 1}) + "\n")
    env = dict(os.environ, PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-m", "repro.obs.validate",
                        str(good)], capture_output=True, text=True,
                       cwd=REPO_ROOT, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK (1 records)" in r.stdout
    r = subprocess.run([sys.executable, "-m", "repro.obs.validate",
                        str(good), str(bad)], capture_output=True,
                       text=True, cwd=REPO_ROOT, env=env)
    assert r.returncode == 1
    assert "INVALID" in r.stderr
    r = subprocess.run([sys.executable, "-m", "repro.obs.validate"],
                       capture_output=True, text=True, cwd=REPO_ROOT,
                       env=env)
    assert r.returncode == 2


# ---------------------------------------------------------------------------
# perf-regression sentinel


def _load_sentinel():
    path = os.path.join(REPO_ROOT, "benchmarks", "sentinel.py")
    spec = importlib.util.spec_from_file_location("bench_sentinel", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_sentinel_missing_vs_strict(tmp_path):
    sent = _load_sentinel()
    out_dir = str(tmp_path / "results")
    status, results, _ = sent.run(str(tmp_path), strict=False,
                                  out_dir=out_dir)
    assert status == 0
    assert all(r["status"] == "MISSING" for r in results)
    status, _, _ = sent.run(str(tmp_path), strict=True, out_dir=out_dir)
    assert status == 1


def test_sentinel_pass_fail_and_trajectory(tmp_path):
    sent = _load_sentinel()
    root = tmp_path
    (root / "BENCH_diag.json").write_text(json.dumps({
        "backend": "cpu",
        "attribution": {"overhead_pct": 1.0},
        "safep": {"agreement": True}}))
    out_dir = str(root / "results")
    status, results, traj = sent.run(str(root), strict=False,
                                     out_dir=out_dir)
    diag_rows = [r for r in results if r["artifact"] == "BENCH_diag.json"]
    assert all(r["status"] == "OK" for r in diag_rows)
    assert status == 0
    tpath = os.path.join(out_dir, "BENCH_trajectory.json")
    assert os.path.exists(tpath)
    with open(tpath) as fh:
        saved = json.load(fh)
    assert saved["artifacts"]["BENCH_diag.json"]["headlines"][
        "attribution.overhead_pct"] == 1.0
    assert saved["status"] == "pass"

    # regression: overhead over budget must fail the gate
    (root / "BENCH_diag.json").write_text(json.dumps({
        "attribution": {"overhead_pct": 12.0},
        "safep": {"agreement": True}}))
    status, results, _ = sent.run(str(root), strict=False, out_dir=out_dir)
    assert status == 1
    bad = [r for r in results
           if r["key"] == "attribution.overhead_pct"][0]
    assert bad["status"] == "FAIL"
    # malformed artifact is UNREADABLE, not a crash
    (root / "BENCH_diag.json").write_text("{ nope")
    status, results, _ = sent.run(str(root), strict=False, out_dir=out_dir)
    assert status == 1
    assert any(r["status"] == "UNREADABLE" for r in results)


def test_sentinel_passes_on_committed_artifacts():
    """The committed repo-root artifacts must satisfy their own gate."""
    sent = _load_sentinel()
    status, results, _ = sent.run(REPO_ROOT, strict=True,
                                  out_dir=os.path.join(
                                      sent.RESULTS_DIR))
    assert status == 0, [r for r in results if r["status"] != "OK"]
