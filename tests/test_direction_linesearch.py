"""Unit + property tests: Eq. 5 closed form, Armijo variants (Eq. 6/11)."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.direction import newton_direction, delta_decrement
from repro.core.linesearch import (ArmijoParams, armijo_backtracking,
                                   armijo_batched, candidate_alphas,
                                   objective_delta)
from repro.core.losses import get_loss
from repro.core.problem import make_problem
from repro.data import make_classification


# -- Eq. 5 is the argmin of the 1-D subproblem (Eq. 4) ------------------------

@settings(max_examples=50, deadline=None)
@given(st.floats(-5, 5), st.floats(0.01, 10), st.floats(-3, 3))
def test_newton_direction_is_argmin(g, h, w):
    d = float(newton_direction(jnp.float32(g), jnp.float32(h),
                               jnp.float32(w))[()])

    def obj(dd):
        return g * dd + 0.5 * h * dd * dd + abs(w + dd)

    # compare against a fine grid around the candidate
    grid = np.linspace(d - 2.0, d + 2.0, 4001)
    vals = [obj(x) for x in grid]
    assert obj(d) <= min(vals) + 1e-4


@settings(max_examples=50, deadline=None)
@given(st.floats(-5, 5), st.floats(0.01, 10), st.floats(-3, 3))
def test_newton_direction_subgradient_optimality(g, h, w):
    """0 in subdifferential of the subproblem at d*."""
    d = float(newton_direction(jnp.float32(g), jnp.float32(h),
                               jnp.float32(w))[()])
    slope = g + h * d
    wd = w + d
    if abs(wd) > 1e-6:
        assert abs(slope + np.sign(wd)) < 1e-3
    else:
        assert abs(slope) <= 1 + 1e-3


# -- line-search variants select the same alpha --------------------------------

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.sampled_from(["logistic", "squared_hinge"]))
def test_backtracking_equals_batched(seed, loss_name):
    X, y, _ = make_classification(80, 30, sparsity=0.4, seed=seed % 50)
    prob = make_problem(X, y, c=1.0, loss=loss_name)
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal(30) * 0.3, jnp.float32)
    z = prob.margins(w)
    idx = jnp.arange(10)
    XB = prob.X[:, :10]
    w_B = w[:10]
    g, h = prob.bundle_grad_hess(z, XB, w_B)
    d = newton_direction(g, h, w_B)
    Delta = delta_decrement(g, h, w_B, d, 0.0)
    delta_z = XB @ d
    ap = ArmijoParams()
    loss = get_loss(loss_name)
    r1 = armijo_backtracking(loss, 1.0, z, delta_z, prob.y, w_B, d, Delta,
                             ap)
    r2 = armijo_batched(loss, 1.0, z, delta_z, prob.y, w_B, d, Delta, ap)
    assert bool(r1.accepted) == bool(r2.accepted)
    if bool(r1.accepted):
        assert abs(float(r1.alpha) - float(r2.alpha)) < 1e-7
        assert int(r1.n_steps) == int(r2.n_steps)


def test_accepted_alpha_satisfies_armijo():
    X, y, _ = make_classification(100, 40, sparsity=0.3, seed=9)
    prob = make_problem(X, y, c=2.0)
    w = jnp.zeros(40, jnp.float32)
    z = prob.margins(w)
    XB = prob.X
    g, h = prob.bundle_grad_hess(z, XB, w)
    d = newton_direction(g, h, w)
    Delta = delta_decrement(g, h, w, d, 0.0)
    ap = ArmijoParams()
    res = armijo_batched(prob.loss, 2.0, z, XB @ d, prob.y, w, d, Delta, ap)
    assert bool(res.accepted)
    fd = objective_delta(prob.loss, 2.0, z, XB @ d, prob.y, w, d, res.alpha)
    assert float(fd) <= ap.sigma * float(res.alpha) * float(Delta) + 1e-5


def test_candidate_alphas_geometry():
    ap = ArmijoParams(beta=0.5, max_steps=10)
    a = np.asarray(candidate_alphas(ap))
    assert a[0] == 1.0
    assert np.allclose(a[1:] / a[:-1], 0.5)
