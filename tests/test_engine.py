"""Unified engine (DESIGN.md section 9): backend contract + local-vs-
sharded equivalence.

Multi-device coverage runs in a subprocess with 8 forced host devices
(same isolation rule as test_sharded_pcdn.py); the single-process tests
exercise the engine through a 1x1-mesh ShardedBackend, which needs no
device-count flag.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.core import PCDNConfig, make_problem, solve
from repro.data import make_classification
from repro.engine import (LocalBackend, ShardedBackend, ShardedPCDNConfig,
                          loop as engine_loop)


@pytest.fixture(scope="module")
def data():
    return make_classification(300, 128, sparsity=0.8, corr=0.3, seed=2)


def test_engine_solve_matches_pcdn_solve(data):
    """pcdn.solve is a thin engine caller — same result through either."""
    X, y, _ = data
    prob = make_problem(X, y, c=1.0)
    cfg = PCDNConfig(P=32, max_outer=80, tol_kkt=1e-4)
    direct = solve(prob, cfg)
    via_engine = engine_loop.solve(
        LocalBackend(prob, cfg), prob.c, max_outer=cfg.max_outer,
        tol_kkt=cfg.tol_kkt)
    assert direct.converged and via_engine.converged
    assert via_engine.objective == pytest.approx(direct.objective)
    np.testing.assert_array_equal(np.asarray(direct.w),
                                  np.asarray(via_engine.w))


def test_local_backend_contract(data):
    X, y, _ = data
    prob = make_problem(X, y, c=1.0)
    b = LocalBackend(prob, PCDNConfig(P=32))
    assert b.n_features == 128 and b.n_samples == 300
    st = b.init_state()
    assert st.w.shape == (128,) and st.z.shape == (300,)
    assert bool(st.active.all())
    w0 = np.zeros(128, np.float32)
    w0[3] = 1.5
    st2 = b.init_state(w0)
    np.testing.assert_allclose(np.asarray(st2.z),
                               np.asarray(prob.margins(st2.w)), rtol=1e-6)
    assert b.c_max() == pytest.approx(prob.c_max())


def test_sharded_backend_1x1_mesh_matches_local(data):
    """The backend contract holds on a trivial mesh without any forced
    device count — same engine loop, same answer as the local backend."""
    X, y, _ = data
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # tol 1e-3, like test_sharded_pcdn: at 1e-4 the sharded line search's
    # different f32 reduction order gives a long pre-existing KKT plateau
    cfg = ShardedPCDNConfig(P_local=32, c=1.0, tol_kkt=1e-3)
    backend = ShardedBackend(X, y, mesh, cfg)
    res = engine_loop.solve(backend, 1.0, max_outer=120, tol_kkt=1e-3)
    ref = solve(make_problem(X, y, c=1.0),
                PCDNConfig(P=32, max_outer=120, tol_kkt=1e-3))
    assert res.converged and ref.converged
    assert res.objective == pytest.approx(ref.objective, rel=1e-4)
    assert backend.c_max() == pytest.approx(
        make_problem(X, y, c=1.0).c_max(), rel=1e-5)
    assert backend.host_weights(res.w).shape == (128,)


def test_shrink_stop_consistency_guard(data):
    """A stop tolerance tighter than the backend's compiled un-shrink
    threshold would stall silently; the engine refuses it loudly."""
    X, y, _ = data
    prob = make_problem(X, y, c=1.0)
    backend = LocalBackend(prob, PCDNConfig(P=32, shrink=True,
                                            tol_kkt=1e-3))
    with pytest.raises(ValueError, match="un-shrink"):
        engine_loop.solve(backend, 1.0, max_outer=10, tol_kkt=1e-4)
    # equal or looser stop tolerances are fine
    engine_loop.check_shrink_stop_consistency(backend, 1e-3)
    engine_loop.check_shrink_stop_consistency(backend, 1e-2)


def test_lockstep_loop_freezes_on_convergence():
    """run_lockstep_loop freezes a converged problem's carry exactly."""
    import jax.numpy as jnp

    w = jnp.asarray([[1.0, 1.0], [8.0, 8.0]])
    (w_out,), f, kkt, nnz, n_outer, done = engine_loop.run_lockstep_loop(
        lambda w: (w * 0.5, jnp.abs(w[:, 0] * 0.5),
                   jnp.abs(w[:, 0] * 0.5), jnp.sum(w != 0, axis=1)),
        (w,), (), max_outer=10, tol_kkt=1.0, dtype=jnp.float32)
    # problem 0 converges after 1 iteration (0.5 <= 1), problem 1 needs 3
    assert int(n_outer[0]) == 1 and int(n_outer[1]) == 3
    assert bool(done.all())
    # problem 0's carry frozen at its first post-convergence value
    np.testing.assert_allclose(np.asarray(w_out[0]), [0.5, 0.5])
    np.testing.assert_allclose(np.asarray(w_out[1]), [1.0, 1.0])


SCRIPT = r"""
import dataclasses
import numpy as np
import jax
from repro.core import PCDNConfig, make_problem, solve
from repro.data import make_classification
from repro.engine import (LocalBackend, ShardedBackend, ShardedPCDNConfig,
                          loop as engine_loop)
from repro.path import PathConfig, run_path

X, y, _ = make_classification(512, 256, sparsity=0.7, corr=0.4, seed=3)
mesh = jax.make_mesh((2, 4), ("data", "model"))
tol = 1e-4

# 1) full solve trajectory WITH SHRINKING: local vs sharded to fp32
local = solve(make_problem(X, y, c=1.0),
              PCDNConfig(P=64, max_outer=150, tol_kkt=tol, shrink=True))
scfg = ShardedPCDNConfig(P_local=16, c=1.0, shrink=True, tol_kkt=tol)
sh = engine_loop.solve(ShardedBackend(X, y, mesh, scfg), 1.0,
                       max_outer=150, tol_kkt=tol)
assert local.converged and sh.converged
rel = abs(sh.objective - local.objective) / abs(local.objective)
assert rel < 1e-4, (sh.objective, local.objective)
assert float(sh.history.kkt[-1]) <= tol      # full-set stop on the mesh
assert int(sh.history.n_active.min()) < 256  # shrinking engaged

# ... and on the padded-CSC sharded layout
shs = engine_loop.solve(
    ShardedBackend(X, y, mesh, scfg, layout="padded_csc"), 1.0,
    max_outer=150, tol_kkt=tol)
assert shs.converged
assert abs(shs.objective - local.objective) / abs(local.objective) < 1e-4

# 2) warm-started 2-point path sweep: per-point agreement to fp32
pcfg = PathConfig(solver=PCDNConfig(P=64, max_outer=200, tol_kkt=tol,
                                    shrink=True), n_points=2, span=8.0)
r_local = run_path(make_problem(X, y, c=1.0), pcfg)
r_shard = run_path(None, pcfg,
                   backend=ShardedBackend(X, y, mesh, scfg))
assert all(p.converged for p in r_local.points)
assert all(p.converged for p in r_shard.points)
for pl, ps in zip(r_local.points, r_shard.points):
    assert abs(ps.c - pl.c) / pl.c < 1e-4            # same analytic grid
    assert abs(ps.objective - pl.objective) / abs(pl.objective) < 1e-4, \
        (ps.objective, pl.objective)
    assert ps.kkt <= tol

# 3) Pallas-kernel routing through the sharded bundle step: same answer
kcfg = dataclasses.replace(scfg, shrink=False, use_kernels=True)
ncfg = dataclasses.replace(scfg, shrink=False, use_kernels=False)
rk = engine_loop.solve(ShardedBackend(X, y, mesh, kcfg), 1.0,
                       max_outer=60, tol_kkt=1e-3)
rn = engine_loop.solve(ShardedBackend(X, y, mesh, ncfg), 1.0,
                       max_outer=60, tol_kkt=1e-3)
assert rk.converged and rn.converged
assert abs(rk.objective - rn.objective) / abs(rn.objective) < 1e-5

# 4) the path CLI's sharded mode end-to-end (acceptance criterion)
from repro.launch import path as launch_path
payload = launch_path.main([
    "--backend", "sharded", "--data-parallel", "2", "--model-parallel",
    "4", "--dataset", "a9a", "--scale", "0.05", "--points", "3",
    "--span", "10", "--P", "16", "--max-outer", "60", "--shrink"])
assert payload["backend"] == "sharded" and len(payload["points"]) == 3
print("ENGINE_OK")
"""


@pytest.mark.slow
def test_engine_local_vs_sharded_multi_device():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = env.get("PYTHONPATH", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "ENGINE_OK" in out.stdout, out.stdout + out.stderr
